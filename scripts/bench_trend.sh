#!/usr/bin/env bash
# Bench goodput trend gate: compare this run's BENCH_*.json artifacts
# against the committed previous run and fail on a goodput regression
# of more than 10%.
#
# The benches already upload BENCH_<name>.json as CI artifacts (the
# machine-readable perf trajectory); this script closes the loop by
# diffing every "goodput_rps" field in a fresh artifact against the
# matching committed file under scripts/bench_baseline/.  A fresh file
# with no committed counterpart seeds the trajectory (copied into the
# baseline dir and reported -- commit it); a file whose shape changed
# (different number of goodput fields) is re-seeded rather than
# compared, since the bench itself was redesigned.
#
# Usage: scripts/bench_trend.sh [--update]
#   Fresh artifacts are read from $BENCH_OUT (default: bench-out/).
#   --update re-seeds every baseline from the fresh run.

set -euo pipefail
cd "$(dirname "$0")/.."

fresh_dir=${BENCH_OUT:-bench-out}
base_dir=scripts/bench_baseline
threshold=0.90 # fresh goodput must stay >= 90% of the committed run

extract() {
    grep -oE '"goodput_rps":[0-9.eE+-]+' "$1" | grep -oE '[0-9.eE+-]+$' || true
}

if [[ "${1:-}" == "--update" ]]; then
    mkdir -p "$base_dir"
    cp "$fresh_dir"/BENCH_*.json "$base_dir"/
    echo "bench baseline reseeded from $fresh_dir/"
    exit 0
fi

shopt -s nullglob
fresh=("$fresh_dir"/BENCH_*.json)
if (( ${#fresh[@]} == 0 )); then
    echo "no $fresh_dir/BENCH_*.json found -- run the benches first" >&2
    exit 1
fi

mkdir -p "$base_dir"
status=0
for f in "${fresh[@]}"; do
    name=$(basename "$f")
    base="$base_dir/$name"
    if [[ ! -f "$base" ]]; then
        cp "$f" "$base"
        echo "SEED $name: no committed baseline -- seeded (commit $base)"
        continue
    fi
    mapfile -t new < <(extract "$f")
    mapfile -t old < <(extract "$base")
    if (( ${#new[@]} == 0 )); then
        echo "SKIP $name: no goodput_rps fields"
        continue
    fi
    if (( ${#new[@]} != ${#old[@]} )); then
        cp "$f" "$base"
        echo "RESEED $name: bench shape changed" \
             "(${#old[@]} -> ${#new[@]} goodput fields; commit $base)"
        continue
    fi
    ok=1
    for i in "${!new[@]}"; do
        verdict=$(awk -v n="${new[$i]}" -v o="${old[$i]}" -v t="$threshold" \
            'BEGIN { print (o > 0 && n < t * o) ? "FAIL" : "OK" }')
        if [[ "$verdict" == "FAIL" ]]; then
            echo "FAIL $name: goodput_rps[$i] ${new[$i]} fell below" \
                 "${threshold} x committed ${old[$i]}" >&2
            ok=0
            status=1
        fi
    done
    if (( ok == 1 )); then
        echo "OK $name: ${#new[@]} goodput field(s) within 10% of baseline"
    fi
done

if (( status != 0 )); then
    cat >&2 <<'EOF'

Goodput regressed more than 10% against the committed bench trajectory.
If the regression is a deliberate trade (new feature cost, redesigned
bench), re-seed with scripts/bench_trend.sh --update and commit the new
scripts/bench_baseline/ files in the same change, explaining why.
EOF
fi
exit "$status"
