#!/usr/bin/env bash
# Hot-path lock lint: fail CI when a coordinator/, obs/ or server/
# file grows new Mutex/RwLock acquisitions.
#
# The serving request path (rust/src/coordinator/) must stay lock-free
# per request: metrics go through pre-resolved Arc handles with striped
# atomic counters, spans through the tracer's ring (DESIGN.md §12).
# rust/src/obs/ is covered too: its locks are legitimate but must stay
# OFF the request path (the tracer's per-slot micro-locks, the sink's
# buffer, the drift monitor's per-tier window -- all touched only by
# sampled/background work), so growth there is equally suspicious.
# rust/src/server/ joined with the event-driven frontend (DESIGN.md
# §15): the reactor's readiness loop owns all connection state on one
# thread, hands work to util::threadpool over channels, and must never
# grow a registry lock -- the baseline for every server/ file is zero.
# rust/src/util/bufpool.rs is the one covered util/ file: the reply
# buffer pool's bounded free-list is Mutex-guarded by design (get/put/
# stats -- three acquisitions, each a push/pop under an uncontended
# lock, amortised across a whole reply's worth of rendering), and that
# count is frozen; the pool must never grow per-byte or per-field
# locking.
# The acquisitions that legitimately remain -- the batcher's gate, the
# pool's replica-slot RwLock, the bufpool free-list, and the obs-side
# ones above -- are frozen in scripts/hotpath_lock_baseline.txt; adding
# an acquisition anywhere in these trees fails this check until the
# baseline is consciously re-justified (update the file IN THE SAME
# COMMIT and explain why the new lock cannot live off the hot path).
#
# Usage: scripts/check_hotpath_locks.sh [--update]

set -euo pipefail
cd "$(dirname "$0")/.."

baseline=scripts/hotpath_lock_baseline.txt
pattern='\.lock\(\)|\.read\(\)|\.write\(\)'

current() {
    # stable per-file counts of lock/read/write acquisitions
    for f in rust/src/coordinator/*.rs rust/src/obs/*.rs rust/src/server/*.rs \
             rust/src/util/bufpool.rs; do
        printf '%s %s\n' "$f" "$(grep -c -E "$pattern" "$f" || true)"
    done | sort
}

if [[ "${1:-}" == "--update" ]]; then
    current > "$baseline"
    echo "baseline rewritten: $baseline"
    exit 0
fi

if [[ ! -f "$baseline" ]]; then
    echo "missing $baseline -- run: scripts/check_hotpath_locks.sh --update" >&2
    exit 1
fi

status=0
while read -r file count; do
    allowed=$(awk -v f="$file" '$1 == f { print $2 }' "$baseline")
    allowed=${allowed:-0}
    if (( count > allowed )); then
        echo "FAIL $file: $count lock acquisitions > baseline $allowed" >&2
        status=1
    fi
done < <(current)

if (( status != 0 )); then
    cat >&2 <<'EOF'

New Mutex/RwLock acquisitions in the coordinator request path or the
observability layer.  Move the work off the hot path (pre-resolved
metric handles, the obs ring, the JSONL sink's background flusher, the
shadow worker thread), or -- if the lock is genuinely unavoidable --
update scripts/hotpath_lock_baseline.txt in this commit and justify it
in the commit message.
EOF
    exit "$status"
fi
echo "hot-path lock lint: OK (coordinator/ + obs/ + server/ + bufpool lock counts within baseline)"
