#!/usr/bin/env bash
# Hot-path allocation lint: fail CI when a reply-path file grows new
# String allocations.
#
# PR 10 made the reply path allocation-free in steady state: workers
# render verdicts straight into pooled Vec<u8> reply buffers
# (util::bufpool) via the `*_into` renderers, the reactor's line framer
# reuses pooled line buffers, and the pipeline stages features into a
# per-batcher scratch Vec (DESIGN.md §16).  The cheapest way to undo
# all of that is one innocent `format!` or `.to_string()` on the
# per-request path, so this lint freezes the per-file count of String
# allocation spellings in the four hot files:
#
#   rust/src/server/conn.rs       -- framing, write queue, writev
#   rust/src/server/reactor.rs    -- event loops, worker dispatch
#   rust/src/server/proto.rs      -- render_*_into byte renderers
#   rust/src/coordinator/pipeline.rs -- per-batch feature staging
#
# The occurrences that legitimately remain are all COLD: gauge/thread
# names formatted once at shard spawn, the invalid-UTF-8 lossy fallback
# (error replies only), error-to_string in error arms, and proto's
# String wrappers + cold admin renders (stats/metrics/events/...).
# They are frozen in scripts/hotpath_alloc_baseline.txt; growth fails
# this check until the baseline is consciously re-justified (update the
# file IN THE SAME COMMIT and explain why the new allocation cannot
# render into the pooled buffer instead).
#
# This is a textual proxy, not an allocator hook: it cannot see Vec
# growth or Box/channel traffic (the worker hand-off still allocates a
# job box and channel nodes -- see DESIGN.md §16 for the honest
# residual list).  It exists to catch the common regression, not to
# prove zero-alloc.
#
# Usage: scripts/check_hotpath_allocs.sh [--update]

set -euo pipefail
cd "$(dirname "$0")/.."

baseline=scripts/hotpath_alloc_baseline.txt
pattern='format!|to_string|String::'

current() {
    # stable per-file counts of String-allocation spellings
    for f in rust/src/server/conn.rs rust/src/server/reactor.rs \
             rust/src/server/proto.rs rust/src/coordinator/pipeline.rs; do
        printf '%s %s\n' "$f" "$(grep -c -E "$pattern" "$f" || true)"
    done | sort
}

if [[ "${1:-}" == "--update" ]]; then
    current > "$baseline"
    echo "baseline rewritten: $baseline"
    exit 0
fi

if [[ ! -f "$baseline" ]]; then
    echo "missing $baseline -- run: scripts/check_hotpath_allocs.sh --update" >&2
    exit 1
fi

status=0
while read -r file count; do
    allowed=$(awk -v f="$file" '$1 == f { print $2 }' "$baseline")
    allowed=${allowed:-0}
    if (( count > allowed )); then
        echo "FAIL $file: $count String allocations > baseline $allowed" >&2
        status=1
    fi
done < <(current)

if (( status != 0 )); then
    cat >&2 <<'EOF'

New format!/to_string/String:: spellings on the reply hot path.  Render
into the caller's pooled Vec<u8> instead (render_*_into, write_num_bytes,
write_str_bytes), or -- if the allocation is genuinely cold (startup,
error arm, admin command) -- update scripts/hotpath_alloc_baseline.txt
in this commit and justify it in the commit message.
EOF
    exit "$status"
fi
echo "hot-path alloc lint: OK (reply-path String allocation counts within baseline)"
