//! End-to-end serving driver (the DESIGN.md "e2e" experiment): boot the
//! full stack -- PJRT engine, calibrated cascade, dynamic batcher, TCP
//! server -- fire a Poisson request stream at it from concurrent client
//! connections, and report latency/throughput + exit-tier routing.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_e2e
//! ```
//!
//! Results are recorded in EXPERIMENTS.md.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use abc_serve::calib;
use abc_serve::coordinator::batcher::BatcherConfig;
use abc_serve::coordinator::cascade::Cascade;
use abc_serve::coordinator::replica::{PoolConfig, ReplicaPool};
use abc_serve::data::workload::Arrival;
use abc_serve::metrics::Metrics;
use abc_serve::runtime::engine::Engine;
use abc_serve::server::{serve, Client};
use abc_serve::types::RuleKind;
use abc_serve::util::rng::Rng;
use abc_serve::util::stats::Samples;
use abc_serve::zoo::manifest::Manifest;
use abc_serve::zoo::registry::SuiteRuntime;

const SUITE: &str = "synth-cifar10";
const PORT: u16 = 7979;
const N_REQUESTS: usize = 2000;
const N_CLIENTS: usize = 8;
const RATE_RPS: f64 = 800.0;
const REPLICAS: usize = 2;
const MAX_QUEUE: usize = 256;

fn main() -> anyhow::Result<()> {
    // ---- boot the serving stack -------------------------------------
    let manifest = Manifest::load("artifacts")?;
    let engine = Arc::new(Engine::cpu()?);
    let rt = Arc::new(SuiteRuntime::load(engine, &manifest, SUITE, false)?);
    let val = rt.dataset(&manifest, "val")?;
    let cal = calib::calibrate(&rt.tiers, RuleKind::MeanScore, &val, 100, 0.05)?;
    let cascade = Arc::new(Cascade::new(rt.tiers.clone(), cal.policy.clone()));
    let metrics = Metrics::new();
    let pool = Arc::new(ReplicaPool::spawn(
        cascade,
        PoolConfig {
            replicas: REPLICAS,
            max_queue: MAX_QUEUE,
            batcher: BatcherConfig { max_batch: 64, max_wait: Duration::from_millis(2) },
            ..PoolConfig::default()
        },
        Arc::clone(&metrics),
    ));
    let server = std::thread::spawn(move || serve(pool, PORT));
    std::thread::sleep(Duration::from_millis(200)); // listener up

    // ---- drive a Poisson workload from N_CLIENTS connections --------
    let test = Arc::new(rt.dataset(&manifest, "test")?);
    let mut rng = Rng::new(42);
    let arrivals = Arrival::Poisson { rate: RATE_RPS }.generate(N_REQUESTS, &mut rng);
    let t_start = Instant::now();
    let next_req = Arc::new(AtomicUsize::new(0));
    let hits = Arc::new(AtomicUsize::new(0));
    let exit1 = Arc::new(AtomicUsize::new(0));
    let lat_chunks: Vec<_> = (0..N_CLIENTS)
        .map(|c| {
            let test = Arc::clone(&test);
            let next = Arc::clone(&next_req);
            let hits = Arc::clone(&hits);
            let exit1 = Arc::clone(&exit1);
            let arrivals = arrivals.clone();
            std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
                let mut client = Client::connect(PORT)?;
                let mut lats = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::SeqCst);
                    if i >= N_REQUESTS {
                        return Ok(lats);
                    }
                    // open-loop pacing: wait for this request's arrival time
                    let due = Duration::from_secs_f64(arrivals[i]);
                    if let Some(wait) = due.checked_sub(t_start.elapsed()) {
                        std::thread::sleep(wait);
                    }
                    let row = i % test.n;
                    let t0 = Instant::now();
                    let (pred, exit_tier) =
                        client.infer(i as u64, test.row(row))?;
                    lats.push(t0.elapsed().as_secs_f64());
                    if pred == test.y[row] {
                        hits.fetch_add(1, Ordering::SeqCst);
                    }
                    if exit_tier == 1 {
                        exit1.fetch_add(1, Ordering::SeqCst);
                    }
                    let _ = c;
                }
            })
        })
        .collect();
    let mut lats = Samples::new();
    for h in lat_chunks {
        lats.extend(&h.join().unwrap()?);
    }
    let wall = t_start.elapsed().as_secs_f64();

    // ---- report ------------------------------------------------------
    println!(
        "\n=== serve_e2e: {SUITE}, {N_REQUESTS} reqs, {N_CLIENTS} clients, \
         Poisson {RATE_RPS} rps, {REPLICAS} replicas (max-queue {MAX_QUEUE}) ==="
    );
    println!("throughput     : {:.0} req/s (wall {:.2}s)", N_REQUESTS as f64 / wall, wall);
    println!("accuracy       : {:.3}", hits.load(Ordering::SeqCst) as f64 / N_REQUESTS as f64);
    println!("tier-1 exits   : {:.1}%", 100.0 * exit1.load(Ordering::SeqCst) as f64 / N_REQUESTS as f64);
    println!("latency p50    : {:.2} ms", lats.p50() * 1e3);
    println!("latency p90    : {:.2} ms", lats.p90() * 1e3);
    println!("latency p99    : {:.2} ms", lats.p99() * 1e3);
    println!("latency mean   : {:.2} ms", lats.mean() * 1e3);
    println!("\nserver metrics:");
    for (name, value) in metrics.snapshot() {
        println!("  {name}: {value}");
    }

    // ---- shut down ----------------------------------------------------
    Client::connect(PORT)?.shutdown()?;
    server.join().unwrap()?;
    Ok(())
}
