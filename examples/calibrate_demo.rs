//! Threshold-calibration walkthrough (paper Appendix B): how the safe
//! deferral threshold theta is estimated from ~100 samples, how stable it
//! is as the sample count grows, and what selection rates different error
//! tolerances buy (Appendix C).
//!
//! ```bash
//! make artifacts && cargo run --release --example calibrate_demo
//! ```

use std::sync::Arc;

use abc_serve::calib::collect_points;
use abc_serve::calib::threshold::{estimate_theta, evaluate_theta};
use abc_serve::runtime::engine::Engine;
use abc_serve::types::RuleKind;
use abc_serve::zoo::manifest::Manifest;
use abc_serve::zoo::registry::SuiteRuntime;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load("artifacts")?;
    let engine = Arc::new(Engine::cpu()?);
    let rt = SuiteRuntime::load(engine, &manifest, "synth-imagenet", false)?;
    let val = rt.dataset(&manifest, "val")?;

    let tier = &rt.tiers[1]; // the 48-wide tier
    println!(
        "tier 2 of synth-imagenet (ensemble val acc {:.3})\n",
        rt.suite.tiers[1].val_acc_ensemble
    );

    let points = collect_points(tier, RuleKind::MeanScore, &val, val.n)?;
    let holdout = &points[points.len() / 2..];

    println!("-- theta stability vs calibration samples (Fig. 6) --");
    println!("{:>6} {:>9} {:>11} {:>16}", "n", "theta", "selection", "holdout failure");
    for n in [100, 200, 500, 1000, 2000] {
        let est = estimate_theta(&points[..n], 0.05);
        let (fail, _) = evaluate_theta(holdout, est.theta);
        println!(
            "{:>6} {:>9.4} {:>10.1}% {:>15.2}%",
            n,
            est.theta,
            est.selection_rate * 100.0,
            fail * 100.0
        );
    }

    println!("\n-- selection rate vs error tolerance (Fig. 7) --");
    println!("{:>8} {:>9} {:>11}", "epsilon", "theta", "selection");
    for eps in [0.01, 0.03, 0.05, 0.10] {
        let est = estimate_theta(&points[..100], eps);
        println!(
            "{:>8.2} {:>9.4} {:>10.1}%",
            eps,
            est.theta,
            est.selection_rate * 100.0
        );
    }
    println!(
        "\nThe estimate from 100 samples is already within noise of the\n\
         2000-sample estimate -- the paper's App. B claim."
    );
    Ok(())
}
