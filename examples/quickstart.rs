//! Quickstart: load a suite's cascade, calibrate it from ~100 validation
//! samples (paper App. B), and classify a handful of test rows.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use abc_serve::calib;
use abc_serve::coordinator::cascade::Cascade;
use abc_serve::runtime::engine::Engine;
use abc_serve::types::RuleKind;
use abc_serve::zoo::manifest::Manifest;
use abc_serve::zoo::registry::SuiteRuntime;

fn main() -> anyhow::Result<()> {
    // 1. Load the artifacts manifest and spin up the PJRT CPU engine.
    let manifest = Manifest::load("artifacts")?;
    let engine = Arc::new(Engine::cpu()?);
    println!("PJRT platform: {}", engine.platform());

    // 2. Load one suite's tier ladder (compiled executables + weights).
    let rt = SuiteRuntime::load(engine, &manifest, "synth-cifar10", false)?;
    println!(
        "loaded {} tiers: {:?}",
        rt.n_tiers(),
        rt.suite.tiers.iter().map(|t| t.hidden.clone()).collect::<Vec<_>>()
    );

    // 3. Calibrate the agreement thresholds on 100 validation samples.
    let val = rt.dataset(&manifest, "val")?;
    let cal = calib::calibrate(&rt.tiers, RuleKind::MeanScore, &val, 100, 0.05)?;
    for (i, est) in cal.estimates.iter().enumerate() {
        println!(
            "tier {}: theta={:.4} (selects {:.0}% of calibration data)",
            i + 1,
            est.theta,
            est.selection_rate * 100.0
        );
    }

    // 4. Build the cascade and classify test samples.
    let cascade = Cascade::new(rt.tiers.clone(), cal.policy.clone());
    let test = rt.dataset(&manifest, "test")?;
    let n = 512;
    let results = cascade.classify_batch(&test.x[..n * test.dim], n)?;
    let hits = results
        .iter()
        .zip(&test.y)
        .filter(|(r, &y)| r.prediction == y)
        .count();
    let mut exits = vec![0usize; rt.n_tiers()];
    for r in &results {
        exits[r.exit_level - 1] += 1;
    }
    println!(
        "\nclassified {n} samples: accuracy {:.1}%, exits per tier {:?}",
        100.0 * hits as f64 / n as f64,
        exits
    );
    println!(
        "=> {:.0}% of requests never reached the expensive tiers",
        100.0 * exits[0] as f64 / n as f64
    );
    Ok(())
}
