//! Edge-to-cloud scenario demo (paper §5.2.1): place tier 1 "on-device",
//! the top tier "in the cloud", and watch what deferral does to
//! communication cost across the paper's delay classes.
//!
//! ```bash
//! make artifacts && cargo run --release --example edge_cloud_demo
//! ```

use std::sync::Arc;

use abc_serve::calib;
use abc_serve::coordinator::cascade::Cascade;
use abc_serve::cost::comm::{CommModel, Placement, DELAY_CLASSES};
use abc_serve::runtime::engine::Engine;
use abc_serve::types::RuleKind;
use abc_serve::zoo::manifest::Manifest;
use abc_serve::zoo::registry::SuiteRuntime;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load("artifacts")?;
    let engine = Arc::new(Engine::cpu()?);
    let rt = SuiteRuntime::load(engine, &manifest, "synth-sst2", false)?;
    let val = rt.dataset(&manifest, "val")?;
    let test = rt.dataset(&manifest, "test")?;

    // two-level placement: tiny ensemble on the edge, big one in the cloud
    let tiers = vec![rt.tiers[0].clone(), rt.tiers.last().unwrap().clone()];
    let cal = calib::calibrate(&tiers, RuleKind::MeanScore, &val, 100, 0.05)?;
    let cascade = Cascade::new(tiers, cal.policy.clone());
    let (_, report) = cascade.evaluate(&test.x, &test.y, test.n)?;

    println!("suite: synth-sst2 (SST-2 stand-in)");
    println!(
        "edge tier handles {:.1}% of requests at accuracy {:.3}\n",
        report.exit_fractions[0] * 100.0,
        report.accuracy
    );
    println!(
        "{:<8} {:>14} {:>14} {:>10}",
        "delay", "ABC comm (ms)", "cloud-only (ms)", "reduction"
    );
    for (delay_s, label) in DELAY_CLASSES {
        let comm = CommModel::new(delay_s, vec![Placement::Edge, Placement::Cloud]);
        let abc_ms = comm.mean_comm_time(&report.exit_fractions) * 1e3;
        let cloud_ms = comm.cloud_only_time() * 1e3;
        println!(
            "{:<8} {:>14.4} {:>14.4} {:>9.1}x",
            label,
            abc_ms,
            cloud_ms,
            cloud_ms / abc_ms.max(1e-12)
        );
    }
    println!(
        "\n(paper Fig. 4a reports up to 14x on SST-2 -- the reduction factor\n\
         here is 1/(1 - edge-exit-fraction), the same mechanism)"
    );
    Ok(())
}
