//! Black-box API cascade demo (paper §5.2.3): ABC's voting rule over the
//! simulated together.ai fleet vs FrugalGPT / AutoMix / MoT, on the
//! GSM8K stand-in.
//!
//! ```bash
//! cargo run --release --example api_cascade_demo
//! ```

use abc_serve::baselines::api_policies::{
    run_abc_voting, run_automix, run_frugal_gpt, run_mot, run_single_model,
    AutoMixKind,
};
use abc_serve::sim::api_llm::{best_of_tier, build_agents, default_tasks, generate_samples};
use abc_serve::util::rng::Rng;

fn main() {
    let task = default_tasks().remove(0); // synth-gsm8k
    let samples = generate_samples(&task);
    let agents = build_agents(&task);
    let tiers = [1usize, 2, 3];

    println!("task: {} ({} samples, answer space {})\n", task.name, samples.len(), task.answer_space);
    println!("{:<28} {:>9} {:>12} {:>14}", "policy", "accuracy", "$/query", "vs ABC cost");

    let abc = run_abc_voting(&task, &samples, &agents, &tiers, 0.34, &mut Rng::new(1));
    let abc_unan = run_abc_voting(&task, &samples, &agents, &tiers, 0.67, &mut Rng::new(7));
    let runs = vec![
        abc_unan,
        run_single_model(&task, &samples, best_of_tier(&agents, 3), &mut Rng::new(2)),
        run_frugal_gpt(&task, &samples, &agents, &tiers, 0.6, &mut Rng::new(3)),
        run_automix(&task, &samples, &agents, &tiers, AutoMixKind::Threshold, &mut Rng::new(4)),
        run_automix(&task, &samples, &agents, &tiers, AutoMixKind::Pomdp, &mut Rng::new(5)),
        run_mot(&task, &samples, &agents, &tiers, 5, 0.8, &mut Rng::new(6)),
    ];
    println!(
        "{:<28} {:>9.3} {:>12.6} {:>14}",
        abc.policy, abc.accuracy, abc.usd_per_query, "1.0x"
    );
    for r in &runs {
        println!(
            "{:<28} {:>9.3} {:>12.6} {:>13.1}x",
            r.policy,
            r.accuracy,
            r.usd_per_query,
            r.usd_per_query / abc.usd_per_query
        );
    }
    println!(
        "\nABC routes {:.0}% of queries to the 8B tier and pays the 405B\n\
         price only for the contested tail (exit fractions: {:?}).",
        abc.exit_fractions[0] * 100.0,
        abc.exit_fractions
            .iter()
            .map(|f| (f * 100.0).round() / 100.0)
            .collect::<Vec<_>>()
    );
}
