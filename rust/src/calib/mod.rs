//! Threshold calibration (paper Appendix B): estimate per-tier agreement
//! thresholds from a small validation sample.

pub mod threshold;

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::deferral::{DeferralPolicy, TierRule};
use crate::data::format::Dataset;
use crate::runtime::executable::TierExecutable;
use crate::types::RuleKind;
use threshold::{estimate_theta, CalPoint, ThetaEstimate};

/// Calibration output: the policy plus per-tier estimates for reporting.
#[derive(Debug)]
pub struct Calibration {
    pub policy: DeferralPolicy,
    pub estimates: Vec<ThetaEstimate>,
}

/// Collect (score, correct) calibration points for one tier executable
/// over the first `n` samples of `cal`.
pub fn collect_points(
    tier: &TierExecutable,
    rule: RuleKind,
    cal: &Dataset,
    n: usize,
) -> Result<Vec<CalPoint>> {
    let n = n.min(cal.n);
    let outs = tier.run(&cal.x[..n * cal.dim], n)?;
    Ok(outs
        .iter()
        .zip(&cal.y[..n])
        .map(|(o, &y)| CalPoint {
            score: rule.score_of(o),
            correct: o.majority == y,
        })
        .collect())
}

/// Calibrate every non-final tier of a ladder on `n_cal` samples
/// (the paper uses ~100).
///
/// `epsilon` is the TOTAL cascade error budget (the xi of Eq. 2): each
/// accepting tier can contribute P(select AND wrong) <= eps_tier, and
/// these events are disjoint across tiers, so we split the budget
/// uniformly: eps_tier = epsilon / (n_tiers - 1).  (Prop 4.1 is stated
/// for two levels where the two coincide.)
pub fn calibrate(
    tiers: &[Arc<TierExecutable>],
    rule: RuleKind,
    cal: &Dataset,
    n_cal: usize,
    epsilon: f64,
) -> Result<Calibration> {
    let mut rules = Vec::new();
    let mut estimates = Vec::new();
    let eps_tier = epsilon / tiers.len().saturating_sub(1).max(1) as f64;
    for tier in &tiers[..tiers.len().saturating_sub(1)] {
        let points = collect_points(tier, rule, cal, n_cal)?;
        let est = estimate_theta(&points, eps_tier);
        rules.push(TierRule { rule, theta: est.theta });
        estimates.push(est);
    }
    Ok(Calibration {
        policy: DeferralPolicy::new(rules, tiers.len()),
        estimates,
    })
}

/// CONDITIONAL calibration (ablation; see experiments::ablation):
/// tier i's threshold is estimated on the calibration samples the
/// already-calibrated tiers 1..i-1 DEFER -- the distribution the tier
/// actually sees in deployment, instead of the marginal distribution the
/// paper's App. B recipe uses.  Costs nothing extra at serving time; the
/// trade-off is fewer effective calibration samples per deeper tier.
pub fn calibrate_conditional(
    tiers: &[Arc<TierExecutable>],
    rule: RuleKind,
    cal: &Dataset,
    n_cal: usize,
    epsilon: f64,
) -> Result<Calibration> {
    let n = n_cal.min(cal.n);
    let mut rules = Vec::new();
    let mut estimates = Vec::new();
    let eps_tier = epsilon / tiers.len().saturating_sub(1).max(1) as f64;
    // indices of calibration samples still "in flight"
    let mut active: Vec<usize> = (0..n).collect();
    for tier in &tiers[..tiers.len().saturating_sub(1)] {
        if active.is_empty() {
            // nothing reaches this tier in calibration: defer everything
            rules.push(TierRule { rule, theta: f32::INFINITY });
            estimates.push(threshold::ThetaEstimate {
                theta: f32::INFINITY,
                failure_rate: 0.0,
                selection_rate: 0.0,
                n: 0,
            });
            continue;
        }
        let mut sub = Vec::with_capacity(active.len() * cal.dim);
        for &i in &active {
            sub.extend_from_slice(cal.row(i));
        }
        let outs = tier.run(&sub, active.len())?;
        let points: Vec<CalPoint> = outs
            .iter()
            .zip(active.iter())
            .map(|(o, &i)| CalPoint {
                score: rule.score_of(o),
                correct: o.majority == cal.y[i],
            })
            .collect();
        let est = estimate_theta(&points, eps_tier);
        rules.push(TierRule { rule, theta: est.theta });
        estimates.push(est);
        // keep only the deferred samples for the next tier
        active = active
            .iter()
            .zip(&points)
            .filter(|(_, p)| p.score <= est.theta)
            .map(|(&i, _)| i)
            .collect();
    }
    Ok(Calibration {
        policy: DeferralPolicy::new(rules, tiers.len()),
        estimates,
    })
}
