//! Agreement-threshold estimation (paper Appendix B).
//!
//! Safe deferral (Def. 4.1) wants a theta such that
//!
//! ```text
//! P( s(x) >= theta  AND  H(x) != y )  <=  epsilon
//! ```
//!
//! Given (score, correct) pairs from a small calibration set (the paper
//! uses ~100 samples), we use the plug-in estimator p_hat(theta) and pick
//! the SMALLEST theta whose failure rate is within epsilon -- smallest,
//! because selection rate P(s > theta) is monotonically non-increasing in
//! theta, so the smallest feasible theta maximises selection (Eq. 2's
//! objective).
//!
//! Deferral then uses `score <= theta` (strict acceptance above theta),
//! matching `TierRule::decide`.

/// One calibration observation.
#[derive(Debug, Clone, Copy)]
pub struct CalPoint {
    pub score: f32,
    pub correct: bool,
}

/// Result of a threshold estimation.
#[derive(Debug, Clone, Copy)]
pub struct ThetaEstimate {
    pub theta: f32,
    /// Empirical failure rate P(select AND wrong) at `theta`.
    pub failure_rate: f64,
    /// Empirical selection rate P(score > theta) at `theta`.
    pub selection_rate: f64,
    /// Number of calibration samples used.
    pub n: usize,
}

/// Estimate the smallest feasible theta for tolerance `epsilon`.
///
/// Candidate thresholds are the distinct observed scores (plus a sentinel
/// above the max, which always satisfies the constraint by deferring
/// everything -- the paper's always-feasible r(x)=1).
///
/// An EMPTY calibration set degrades to that sentinel (theta = +inf,
/// nothing selected): with no evidence, the only safe policy is to
/// defer everything.  This is what `calibrate_conditional` needs for
/// tiers that no calibration sample reaches, and what the gear planner
/// gets for a candidate `k` with no data.
pub fn estimate_theta(points: &[CalPoint], epsilon: f64) -> ThetaEstimate {
    if points.is_empty() {
        return ThetaEstimate {
            theta: f32::INFINITY,
            failure_rate: 0.0,
            selection_rate: 0.0,
            n: 0,
        };
    }
    let n = points.len();
    // Sort descending by score; sweep thresholds from high to low,
    // keeping running counts of selected-and-wrong.
    let mut sorted: Vec<CalPoint> = points.to_vec();
    sorted.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());

    // theta candidates: just below each distinct score value.  Using the
    // score value itself works because acceptance is strict (> theta):
    // theta = s_i accepts exactly the points with score > s_i.
    let mut wrong_sel = 0usize;
    let mut n_sel = 0usize;
    let mut i = 0;
    // Sentinel: theta = +inf-equivalent (defer all): always feasible.
    let max_score = sorted[0].score;
    let mut feasible = (max_score, 0usize, 0usize);
    while i < n {
        // advance over a group of equal scores; they become SELECTED when
        // theta drops below their score value.
        let s = sorted[i].score;
        while i < n && sorted[i].score == s {
            n_sel += 1;
            if !sorted[i].correct {
                wrong_sel += 1;
            }
            i += 1;
        }
        // theta = next lower distinct score (or -inf at the end) accepts
        // all points processed so far. Use theta just below s: the next
        // candidate theta value is the next distinct score; evaluate
        // feasibility of "theta = that value".
        let theta = if i < n { sorted[i].score } else { f32::NEG_INFINITY };
        let fail = wrong_sel as f64 / n as f64;
        if fail <= epsilon {
            feasible = (theta, wrong_sel, n_sel);
        } else {
            break; // failure rate only grows as theta decreases
        }
    }
    let (theta, wrong, sel) = feasible;
    ThetaEstimate {
        theta,
        failure_rate: wrong as f64 / n as f64,
        selection_rate: sel as f64 / n as f64,
        n,
    }
}

/// Evaluate the failure/selection rates of a FIXED theta on a holdout set
/// (used by Fig. 6/7 to verify stability).
pub fn evaluate_theta(points: &[CalPoint], theta: f32) -> (f64, f64) {
    let n = points.len().max(1);
    let mut wrong_sel = 0usize;
    let mut n_sel = 0usize;
    for p in points {
        if p.score > theta {
            n_sel += 1;
            if !p.correct {
                wrong_sel += 1;
            }
        }
    }
    (wrong_sel as f64 / n as f64, n_sel as f64 / n as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn pts(data: &[(f32, bool)]) -> Vec<CalPoint> {
        data.iter().map(|&(s, c)| CalPoint { score: s, correct: c }).collect()
    }

    #[test]
    fn perfect_scores_select_everything() {
        // all correct: theta can drop below the minimum score
        let p = pts(&[(0.9, true), (0.5, true), (0.3, true)]);
        let est = estimate_theta(&p, 0.01);
        assert_eq!(est.theta, f32::NEG_INFINITY);
        assert_eq!(est.selection_rate, 1.0);
        assert_eq!(est.failure_rate, 0.0);
    }

    #[test]
    fn wrong_high_score_blocks() {
        // the top-scoring point is wrong: any theta below it fails eps=0
        let p = pts(&[(0.95, false), (0.9, true), (0.5, true)]);
        let est = estimate_theta(&p, 1e-9);
        // only feasible theta keeps everything deferred
        assert_eq!(est.selection_rate, 0.0);
        assert!((est.theta - 0.95).abs() < 1e-6);
    }

    #[test]
    fn epsilon_allows_some_errors() {
        // 10 points, 1 wrong in the middle; eps=0.1 tolerates it
        let mut data = vec![(0.95, true), (0.9, true), (0.85, false)];
        for i in 0..7 {
            data.push((0.8 - i as f32 * 0.05, true));
        }
        let p = pts(&data);
        let strict = estimate_theta(&p, 1e-9);
        let lax = estimate_theta(&p, 0.1);
        assert!(lax.selection_rate > strict.selection_rate);
        assert_eq!(lax.selection_rate, 1.0);
        assert!(lax.failure_rate <= 0.1);
    }

    #[test]
    fn selection_monotone_in_epsilon() {
        let mut rng = Rng::new(11);
        let points: Vec<CalPoint> = (0..500)
            .map(|_| {
                let score = rng.f32();
                // higher score => more likely correct
                let correct = rng.bool(0.3 + 0.68 * score as f64);
                CalPoint { score, correct }
            })
            .collect();
        let mut last = -1.0;
        for eps in [0.0, 0.01, 0.03, 0.05, 0.1, 0.3] {
            let est = estimate_theta(&points, eps);
            assert!(
                est.selection_rate >= last,
                "selection rate not monotone at eps {eps}"
            );
            assert!(est.failure_rate <= eps + 1e-12);
            last = est.selection_rate;
        }
    }

    #[test]
    fn estimate_respects_constraint_on_holdout_in_distribution() {
        // calibrate on 100 points (paper's budget), evaluate on 10x more
        let gen = |rng: &mut Rng, n: usize| -> Vec<CalPoint> {
            (0..n)
                .map(|_| {
                    let score = rng.f32();
                    let correct = rng.bool(0.2 + 0.79 * score as f64);
                    CalPoint { score, correct }
                })
                .collect()
        };
        let mut rng = Rng::new(12);
        let cal = gen(&mut rng, 100);
        let hold = gen(&mut rng, 1000);
        let est = estimate_theta(&cal, 0.05);
        let (fail, sel) = evaluate_theta(&hold, est.theta);
        // generalisation slack: 5% tolerance + binomial noise
        assert!(fail <= 0.05 + 0.05, "holdout failure {fail}");
        assert!(sel > 0.0);
    }

    #[test]
    fn empty_calibration_set_defers_everything() {
        let est = estimate_theta(&[], 0.05);
        assert_eq!(est.theta, f32::INFINITY);
        assert_eq!(est.selection_rate, 0.0);
        assert_eq!(est.failure_rate, 0.0);
        assert_eq!(est.n, 0);
        // the sentinel composes with evaluate_theta: nothing selected
        let holdout = pts(&[(0.9, true), (0.5, false)]);
        assert_eq!(evaluate_theta(&holdout, est.theta), (0.0, 0.0));
    }

    #[test]
    fn all_agree_points_share_one_fate() {
        // full agreement everywhere (score 1.0): a single threshold
        // candidate -- select all (if clean) or nothing (if any wrong)
        let clean = pts(&[(1.0, true), (1.0, true), (1.0, true)]);
        let est = estimate_theta(&clean, 0.0);
        assert_eq!(est.theta, f32::NEG_INFINITY);
        assert_eq!(est.selection_rate, 1.0);
        assert_eq!(est.failure_rate, 0.0);

        let tainted = pts(&[(1.0, true), (1.0, true), (1.0, false)]);
        let strict = estimate_theta(&tainted, 0.0);
        assert_eq!(strict.selection_rate, 0.0, "one failure must block eps=0");
        assert_eq!(strict.theta, 1.0);
        // a budget of 1/3 admits the whole agreement group
        let lax = estimate_theta(&tainted, 1.0 / 3.0 + 1e-9);
        assert_eq!(lax.selection_rate, 1.0);
        assert!((lax.failure_rate - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn epsilon_zero_is_exact_not_lenient() {
        // eps = 0.0 exactly (not 1e-9): only a perfect prefix selects
        let p = pts(&[(0.9, true), (0.8, true), (0.7, false), (0.6, true)]);
        let est = estimate_theta(&p, 0.0);
        assert_eq!(est.failure_rate, 0.0);
        // the wrong point at 0.7 caps selection at the two above it
        assert!((est.selection_rate - 0.5).abs() < 1e-12);
        assert!((est.theta - 0.7).abs() < 1e-6, "theta {}", est.theta);
    }

    #[test]
    fn ties_at_the_sentinel_threshold() {
        // every point shares the max score AND the sentinel equals that
        // score: the group is admitted or refused atomically
        let p = pts(&[(0.5, true), (0.5, true), (0.5, false)]);
        let strict = estimate_theta(&p, 0.1);
        // group failure rate 1/3 > 0.1: sentinel (defer all) wins
        assert_eq!(strict.selection_rate, 0.0);
        assert_eq!(strict.theta, 0.5);
        assert_eq!(evaluate_theta(&p, strict.theta), (0.0, 0.0));
        let lax = estimate_theta(&p, 0.5);
        assert_eq!(lax.selection_rate, 1.0);
        assert_eq!(lax.theta, f32::NEG_INFINITY);
    }

    #[test]
    fn evaluate_theta_counts() {
        let p = pts(&[(0.9, false), (0.8, true), (0.2, true)]);
        let (fail, sel) = evaluate_theta(&p, 0.5);
        assert!((sel - 2.0 / 3.0).abs() < 1e-9);
        assert!((fail - 1.0 / 3.0).abs() < 1e-9);
        let (fail_hi, sel_hi) = evaluate_theta(&p, 1.0);
        assert_eq!((fail_hi, sel_hi), (0.0, 0.0));
    }

    #[test]
    fn ties_handled_as_groups() {
        // equal scores must move together: theta between them is impossible
        let p = pts(&[(0.5, true), (0.5, false), (0.4, true)]);
        let est = estimate_theta(&p, 0.2);
        // selecting the 0.5 group brings 1 wrong of 3 = 0.33 > 0.2 -> no selection
        assert_eq!(est.selection_rate, 0.0);
        let est2 = estimate_theta(&p, 0.34);
        assert!(est2.selection_rate >= 2.0 / 3.0);
    }
}
