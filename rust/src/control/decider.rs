//! The decider stack: pure per-tick decisions for the unified control
//! loop.
//!
//! One tick folds one [`Observation`] per unit into that unit's
//! [`ControlState`] and runs three deciders over the result:
//!
//! 1. **[`GearDecider`]** -- walks a ladder per configured unit.  A
//!    monolithic geared pool walks its offline [`GearPlan`]
//!    (`ControlState::step_fleet`, fleet-aware so renting precedes
//!    accuracy trades).  A tiered fleet walks per-tier **theta rungs**
//!    ([`GearLadder::Thetas`]): the decider observing tier N's pool
//!    actuates tier N-1's deferral threshold -- tier N's arrivals ARE
//!    tier N-1's deferrals, so lowering the upstream theta is the knob
//!    that thins exactly the stream drowning tier N.
//! 2. **The scale decider** (per elastic unit, policy in
//!    [`ScaleConfig`]) -- sizes the unit's fleet for the active gear's
//!    per-replica capacity at
//!    `max(EWMA, forecast)` (the [`crate::control::Forecaster`] hook:
//!    rising trends provision a warm-up early), with the queue-pressure
//!    kicker and the warming-counts-against-reprovisioning rule.
//! 3. **[`BudgetArbiter`]** -- reconciles the proposals under the
//!    fleet-wide `--max-dollars-hour` burn cap: drains always pass
//!    (they only return money), scale-ups are granted cheapest-unit
//!    first (under the paper's §5.2.2 placement that is
//!    cheapest-tier-first, starving the expensive top pool last), and
//!    gear downshifts are evaluated at the *attainable* fleet -- max
//!    replicas clamped to what the budget still affords -- so the
//!    stack prefers renting before trading accuracy, and trades
//!    accuracy exactly when it can no longer afford to rent.
//!
//! Dwell coupling: a plan-gear shift and its matching resize land in
//! the same tick (one atomic capacity decision).  A theta shift
//! instead consumes the OBSERVING tier's dwell -- gear and scale share
//! one clock per unit -- which is the fleet-level hysteresis guard:
//! the two levers that relieve an overloaded tier (rent it more
//! machines; lower the adjacent tier's theta) cannot both slam in at
//! once and then both reverse, and the tier whose arrival stream the
//! shift just thinned cannot resize against pre-shift numbers.  The
//! ACTUATED tier is deliberately not blocked: its own arrivals are
//! unchanged by its theta (the stage still runs on every row; theta
//! only splits exit from defer), so its scale decisions stay live.
//!
//! Everything here is a pure function of (config, states, observations,
//! counts, prices, forecasts, dt) -- unit-tested below without threads.

use crate::control::scale::ScaleConfig;
use crate::control::state::{
    ControlState, ControllerConfig, Observation, Shift, Trigger,
};
use crate::cost::rental::Gpu;
use crate::obs::drift::{AlarmState, DriftStatus};
use crate::planner::gear::{GearConfig, GearPlan};

/// One rung of a per-tier theta ladder: the runtime operating point a
/// tiered fleet's gear decider actuates.  Rung 0 is the most accurate
/// (usually `theta: None` -- the stage's own calibrated policy); deeper
/// rungs lower the threshold so the tier exits more requests locally
/// instead of deferring them to the more expensive tier below.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierRung {
    /// Threshold override; `None` restores the calibrated policy.
    pub theta: Option<f32>,
    /// Batch cap while this rung is active.
    pub max_batch: usize,
}

/// What ladder a [`GearDecider`] walks.
#[derive(Debug, Clone)]
pub enum GearLadder {
    /// A full offline plan (monolithic geared pools): rungs quote real
    /// capacities, so downshifts jump to the sustaining gear.
    Plan(GearPlan),
    /// Per-tier theta rungs (tiered fleets), most accurate first:
    /// rungs do not change the observed unit's own capacity, so the
    /// walk is one hysteretic rung per dwell.
    Thetas(Vec<TierRung>),
}

/// One ladder-walking decider; see the module docs.
#[derive(Debug, Clone)]
pub struct GearDecider {
    /// Unit whose observation drives the walk.
    pub obs_unit: usize,
    /// Unit actuated on a shift.  Equal to `obs_unit` for plan ladders;
    /// the upstream tier (`obs_unit - 1`) for theta ladders.
    pub act_unit: usize,
    pub ladder: GearLadder,
}

impl GearDecider {
    pub fn ladder_len(&self) -> usize {
        match &self.ladder {
            GearLadder::Plan(p) => p.len(),
            GearLadder::Thetas(t) => t.len(),
        }
    }

    /// The runtime config actuated at rung `r`.
    pub fn config_at(&self, r: usize) -> GearConfig {
        match &self.ladder {
            GearLadder::Plan(p) => p.gears[r].config(),
            GearLadder::Thetas(t) => GearConfig {
                gear_id: r,
                thetas: t[r].theta.into_iter().collect(),
                work_factor: 1.0,
                max_batch: t[r].max_batch,
            },
        }
    }

    /// Fold the observation into the obs unit's state and propose a
    /// shift.  `fleet` is the attainable replica basis (None: judge
    /// plan rungs by their planned allocations); `per_replica_rps` is
    /// the obs unit's per-replica capacity (theta ladders only).
    fn decide(
        &self,
        state: &mut ControlState,
        ctrl: &ControllerConfig,
        obs: Observation,
        dt_s: f64,
        fleet: Option<usize>,
        per_replica_rps: f64,
    ) -> Option<(Shift, Trigger)> {
        match &self.ladder {
            GearLadder::Plan(p) => state.step_fleet(p, ctrl, obs, dt_s, fleet),
            GearLadder::Thetas(t) => {
                let capacity = per_replica_rps * fleet.unwrap_or(1).max(1) as f64;
                state.step_watermark(ctrl, obs, dt_s, capacity, t.len())
            }
        }
    }
}

/// Per-unit knobs for the scale decider.
#[derive(Debug, Clone, Copy)]
pub struct UnitControl {
    /// Offered load one replica of this unit sustains (rows/s of ITS
    /// stage).  `None`: derive from the unit's active plan gear
    /// (monolithic geared pools).
    pub per_replica_rps: Option<f64>,
    /// Elastic sizing policy; `None` pins the unit's fleet.
    pub scale: Option<ScaleConfig>,
}

/// The fleet-wide burn-rate cap; see the module docs.
#[derive(Debug, Clone, Copy)]
pub struct BudgetArbiter {
    /// $/hour ceiling; 0 disables the cap.  Warming, Live and Draining
    /// slots all bill (a rented machine bills until returned).
    pub max_dollars_per_hour: f64,
}

impl BudgetArbiter {
    pub fn uncapped(&self) -> bool {
        self.max_dollars_per_hour <= 0.0
    }

    /// Current burn: every provisioned slot at its unit's price.
    pub fn bill(counts: &[(usize, usize, usize)], gpus: &[Gpu]) -> f64 {
        counts
            .iter()
            .zip(gpus)
            .map(|(&(w, l, d), g)| (w + l + d) as f64 * g.dollars_per_hour())
            .sum()
    }

    /// Extra replicas of `gpu` the headroom above `bill` affords.
    pub fn affordable(&self, bill: f64, gpu: Gpu) -> usize {
        if self.uncapped() {
            return usize::MAX;
        }
        let headroom = (self.max_dollars_per_hour - bill).max(0.0);
        (headroom / gpu.dollars_per_hour()).floor() as usize
    }
}

/// The recalibration decider: pure predicate over a tier's live
/// [`DriftStatus`] deciding whether the control loop should re-ground
/// that tier's serving theta from the drift observatory's windowed
/// estimate.  Fires only on a *latched* Breach (the
/// [`crate::obs::drift::DriftAlarm`] hysteresis already filtered
/// flaps -- the alarm's streak requirement IS this decider's dwell)
/// and only when the live estimate is finite: the defer-all sentinel
/// (`+inf`, empty window) and the select-all sentinel (`-inf`,
/// all-agree window) are degradation markers, not operating points a
/// tier should serve at.
#[derive(Debug, Clone, Copy)]
pub struct DriftDecider;

impl DriftDecider {
    /// Should the loop re-ground this tier's theta now?
    pub fn should_reground(status: &DriftStatus) -> bool {
        status.alarm == AlarmState::Breach && status.theta_live.is_finite()
    }
}

/// The decider stack's full configuration: what one
/// [`crate::control::ControlLoop`] ticks.
#[derive(Debug, Clone)]
pub struct ControlConfig {
    /// Watermarks, dwell, sampling period, EWMA smoothing.
    pub ctrl: ControllerConfig,
    /// One entry per target unit.
    pub units: Vec<UnitControl>,
    /// Ladder deciders (at most one per observed unit).
    pub gears: Vec<GearDecider>,
    /// Fleet-wide burn budget in $/hour; 0 disables the cap.
    pub max_dollars_per_hour: f64,
    /// Act on drift-observatory breaches: when a tier's
    /// [`crate::obs::drift::DriftAlarm`] latches Breach, re-ground its
    /// serving theta from the live windowed estimate
    /// ([`DriftDecider`]; `serve --recalibrate`).  Off by default --
    /// the observatory then only reports.
    pub recalibrate: bool,
    /// Multiplier applied to `max_dollars_per_hour` while the SLO
    /// observatory's *premium* burn-rate alarm is latched Breach
    /// (`serve --slo-boost`): the budget arbiter temporarily affords
    /// more machines exactly when the protected class is burning its
    /// error budget, and the cap snaps back once the alarm clears.
    /// `1.0` (the default) disables the coupling; only meaningful with
    /// a finite budget (an uncapped arbiter has nothing to relax).
    pub slo_boost: f64,
}

impl ControlConfig {
    /// Gear-only control of a monolithic geared pool (what
    /// `planner::Controller` used to spawn).
    pub fn gear_plan(plan: GearPlan, ctrl: ControllerConfig) -> ControlConfig {
        ControlConfig {
            ctrl,
            units: vec![UnitControl { per_replica_rps: None, scale: None }],
            gears: vec![GearDecider {
                obs_unit: 0,
                act_unit: 0,
                ladder: GearLadder::Plan(plan),
            }],
            max_dollars_per_hour: 0.0,
            recalibrate: false,
            slo_boost: 1.0,
        }
    }

    /// Coupled gear + elastic-fleet control of a monolithic geared pool
    /// (what `autoscale::Autoscaler` used to spawn), optionally under a
    /// burn budget.
    pub fn autoscaled(
        plan: GearPlan,
        ctrl: ControllerConfig,
        scale: ScaleConfig,
        max_dollars_per_hour: f64,
    ) -> ControlConfig {
        ControlConfig {
            ctrl,
            units: vec![UnitControl { per_replica_rps: None, scale: Some(scale) }],
            gears: vec![GearDecider {
                obs_unit: 0,
                act_unit: 0,
                ladder: GearLadder::Plan(plan),
            }],
            max_dollars_per_hour,
            recalibrate: false,
            slo_boost: 1.0,
        }
    }

    /// Per-tier control of a tiered fleet: each tier sized against its
    /// own deferral-driven arrivals (what `autoscale::TieredAutoscaler`
    /// used to spawn), plus per-tier gear shifting for every tier with
    /// theta rungs -- tier `i`'s rungs are walked by the decider
    /// observing tier `i + 1`'s pool (the stream those rungs thin).
    /// The last tier's rungs are ignored: it has no downstream observer
    /// and its theta is meaningless (the final stage always exits).
    pub fn tiered(
        tiers: Vec<TierControl>,
        ctrl: ControllerConfig,
        max_dollars_per_hour: f64,
    ) -> ControlConfig {
        let n = tiers.len();
        let mut units = Vec::with_capacity(n);
        let mut gears = Vec::new();
        for (i, t) in tiers.into_iter().enumerate() {
            units.push(UnitControl {
                per_replica_rps: Some(t.per_replica_rps),
                scale: t.scale,
            });
            if !t.rungs.is_empty() && i + 1 < n {
                gears.push(GearDecider {
                    obs_unit: i + 1,
                    act_unit: i,
                    ladder: GearLadder::Thetas(t.rungs),
                });
            }
        }
        ControlConfig {
            ctrl,
            units,
            gears,
            max_dollars_per_hour,
            recalibrate: false,
            slo_boost: 1.0,
        }
    }

    /// Panic early on nonsense configs (the loop thread cannot surface
    /// errors later).
    pub fn validate(&self, n_units: usize) {
        assert_eq!(
            self.units.len(),
            n_units,
            "config has {} units, target has {n_units}",
            self.units.len()
        );
        assert!(
            self.ctrl.up_util < self.ctrl.down_util,
            "hysteresis requires up_util < down_util"
        );
        assert!(self.ctrl.ewma_alpha > 0.0 && self.ctrl.ewma_alpha <= 1.0);
        assert!(self.max_dollars_per_hour >= 0.0);
        assert!(self.slo_boost >= 1.0, "slo_boost must not shrink the budget");
        for u in &self.units {
            if let Some(s) = &u.scale {
                s.validate();
            }
        }
        let mut seen = vec![false; n_units];
        for g in &self.gears {
            assert!(g.obs_unit < n_units && g.act_unit < n_units);
            assert!(
                !std::mem::replace(&mut seen[g.obs_unit], true),
                "unit {} has two gear deciders",
                g.obs_unit
            );
            assert!(g.ladder_len() >= 1, "empty gear ladder");
            if matches!(g.ladder, GearLadder::Thetas(_)) {
                assert!(
                    self.units[g.obs_unit].per_replica_rps.unwrap_or(0.0) > 0.0,
                    "theta ladder on unit {} needs its per_replica_rps",
                    g.obs_unit
                );
            }
        }
        for (i, u) in self.units.iter().enumerate() {
            if u.scale.is_some() && u.per_replica_rps.is_none() {
                assert!(
                    self.plan_for(i).is_some(),
                    "elastic unit {i} needs per_replica_rps or a plan ladder"
                );
            }
        }
    }

    /// The plan ladder actuating `unit`, if any (the scale decider's
    /// per-replica capacity source for monolithic geared pools).
    fn plan_for(&self, unit: usize) -> Option<&GearPlan> {
        self.gears.iter().find_map(|g| match &g.ladder {
            GearLadder::Plan(p) if g.act_unit == unit => Some(p),
            _ => None,
        })
    }

    /// The decider observing `unit`, if any.
    pub fn decider_for_obs(&self, unit: usize) -> Option<&GearDecider> {
        self.gears.iter().find(|g| g.obs_unit == unit)
    }
}

/// One tier's control knobs (input to [`ControlConfig::tiered`]).
#[derive(Debug, Clone)]
pub struct TierControl {
    /// Rows/s one replica of this tier sustains (its own stage).
    pub per_replica_rps: f64,
    /// Elastic sizing; `None` pins the tier's fleet.
    pub scale: Option<ScaleConfig>,
    /// Theta ladder for THIS tier's deferral threshold, rung 0 most
    /// accurate; empty = no gear shifting at this tier.
    pub rungs: Vec<TierRung>,
}

/// One applied-or-proposed gear shift.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShiftAction {
    pub obs_unit: usize,
    pub act_unit: usize,
    pub from: usize,
    pub to: usize,
    pub shift: Shift,
    pub trigger: Trigger,
}

/// One applied-or-proposed fleet resize.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScaleAction {
    pub unit: usize,
    /// Provisioned fleet (warming + live) the decision was made at.
    pub fleet: usize,
    /// Live count at decision time (the drain basis).
    pub live: usize,
    pub target: usize,
    pub trigger: Trigger,
    /// "scale", or "budget" when the arbiter clamped the policy's ask.
    pub decider: &'static str,
}

/// Everything one tick decided.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Tick {
    pub shifts: Vec<ShiftAction>,
    pub scales: Vec<ScaleAction>,
}

/// Run the full decider stack over one tick's observations.  Mutates
/// `states` (EWMA, dwell, rung) exactly as the loop thread would; the
/// thread half only samples, applies and records.  `forecasts[i]` is
/// unit `i`'s predicted arrival rate (0 = none).
pub fn decide_tick(
    cfg: &ControlConfig,
    states: &mut [ControlState],
    obs: &[Observation],
    counts: &[(usize, usize, usize)],
    gpus: &[Gpu],
    forecasts: &[f64],
    dt_s: f64,
) -> Tick {
    let n = cfg.units.len();
    assert_eq!(states.len(), n);
    assert_eq!(obs.len(), n);
    assert_eq!(counts.len(), n);
    assert_eq!(gpus.len(), n);
    assert_eq!(forecasts.len(), n);
    let budget = BudgetArbiter { max_dollars_per_hour: cfg.max_dollars_per_hour };
    let mut bill = if budget.uncapped() {
        0.0
    } else {
        BudgetArbiter::bill(counts, gpus)
    };
    let mut tick = Tick::default();
    let mut folded = vec![false; n];
    // plan shifts keep the shifted unit's resize in the same tick (one
    // atomic capacity decision)
    let mut plan_shifted = vec![false; n];

    // -- gear phase ------------------------------------------------------
    for g in &cfg.gears {
        let u = g.obs_unit;
        let (w, l, _) = counts[u];
        let fleet = w + l;
        // the attainable fleet: what the unit could actually grow to
        // under its bounds AND the budget -- renting is tried first,
        // accuracy trades start where affording more machines stops
        let basis = match &cfg.units[u].scale {
            Some(s) => {
                let extra = s
                    .max_replicas
                    .saturating_sub(fleet)
                    .min(budget.affordable(bill, gpus[u]));
                Some(fleet + extra)
            }
            None => match &g.ladder {
                // fixed fleets judge plan rungs by their planned quotes
                GearLadder::Plan(_) => None,
                // theta rungs are judged at the fixed fleet itself
                GearLadder::Thetas(_) => Some(fleet),
            },
        };
        let per_rps = cfg.units[u].per_replica_rps.unwrap_or(0.0);
        let from = states[u].current();
        let shift =
            g.decide(&mut states[u], &cfg.ctrl, obs[u], dt_s, basis, per_rps);
        folded[u] = true;
        if let Some((shift, trigger)) = shift {
            if matches!(g.ladder, GearLadder::Plan(_)) {
                plan_shifted[u] = true;
            }
            tick.shifts.push(ShiftAction {
                obs_unit: u,
                act_unit: g.act_unit,
                from,
                to: states[u].current(),
                shift,
                trigger,
            });
        }
    }
    // (the fleet-level hysteresis guard needs no extra bookkeeping
    // here: a theta shift reset its OBSERVING unit's state inside
    // step_watermark, and gear + scale share that clock, so the tier
    // whose arrivals the shift just changed skips the scale phase
    // below until the dwell expires)

    // every unit folds its observation exactly once per tick -- also
    // the ones with no decider at all, so their EWMA telemetry (and a
    // later-enabled decider's starting state) tracks real traffic
    for i in 0..n {
        if !folded[i] {
            states[i].observe(&cfg.ctrl, obs[i], dt_s);
            folded[i] = true;
        }
    }

    // -- scale phase, cheapest unit first --------------------------------
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        gpus[a]
            .dollars_per_hour()
            .partial_cmp(&gpus[b].dollars_per_hour())
            .expect("prices are never NaN")
            .then(a.cmp(&b))
    });
    for i in order {
        let Some(scale) = &cfg.units[i].scale else {
            continue;
        };
        // a plan shift already consumed the dwell; it still gets its
        // matching resize this tick (shifting to a cheaper gear without
        // releasing the machines it no longer needs would waste exactly
        // the rent the shift saved)
        if !plan_shifted[i] && !states[i].dwell_ok(&cfg.ctrl) {
            continue;
        }
        let (warming, live, _) = counts[i];
        let fleet = live + warming;
        // the pressure kicker rents one extra machine for queue debt
        // the rate EWMA cannot see -- but only when nothing is already
        // warming: capacity in flight will relieve the same debt, and
        // kicking every dwell until it goes Live would re-rent it
        let pressured =
            obs[i].outstanding_frac > cfg.ctrl.queue_pressure && warming == 0;
        let per_rps = cfg.units[i].per_replica_rps.unwrap_or_else(|| {
            let plan = cfg.plan_for(i).expect("validated: plan or rps");
            plan.gears[states[i].current()].per_replica_rps()
        });
        // the Forecaster hook: a rising trend provisions a warm-up
        // early; falling trends forecast 0 so drains stay reactive
        let rps = states[i].ewma_rps().max(forecasts[i]);
        let asked = scale.target(rps, per_rps, fleet, pressured);
        if asked > fleet {
            let granted =
                fleet + (asked - fleet).min(budget.affordable(bill, gpus[i]));
            if granted > fleet {
                bill += (granted - fleet) as f64 * gpus[i].dollars_per_hour();
                tick.scales.push(ScaleAction {
                    unit: i,
                    fleet,
                    live,
                    target: granted,
                    trigger: if pressured { Trigger::Pressure } else { Trigger::Rate },
                    decider: if granted < asked { "budget" } else { "scale" },
                });
                states[i].note_action();
            }
        } else if asked < live {
            // drains are always allowed (they only return money), but
            // the bill is not discounted yet: a draining slot bills
            // until it retires, and the next tick sees the real counts
            tick.scales.push(ScaleAction {
                unit: i,
                fleet,
                live,
                target: asked,
                trigger: Trigger::Rate,
                decider: "scale",
            });
            states[i].note_action();
        }
    }
    tick
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::gear::Gear;
    use std::time::Duration;

    fn gear(acc: f64, work: f64, rps: f64) -> Gear {
        Gear {
            id: 0,
            k: 3,
            epsilon: 0.03,
            theta: 0.6,
            mid: vec![],
            max_batch: 8,
            replicas: 2,
            tier_fleet: vec![],
            dollar_per_req: 0.0,
            accuracy: acc,
            relative_cost: work,
            sustainable_rps: rps,
        }
    }

    /// per-replica: top 500 rps, fast 2000 rps (quoted at 2 replicas).
    fn plan2() -> GearPlan {
        GearPlan::new(vec![gear(0.95, 1.0, 1000.0), gear(0.85, 0.25, 4000.0)])
            .unwrap()
    }

    fn ctrl() -> ControllerConfig {
        ControllerConfig {
            dwell: Duration::from_millis(100),
            ewma_alpha: 1.0,
            ..ControllerConfig::default()
        }
    }

    fn scale(min: usize, max: usize) -> ScaleConfig {
        ScaleConfig {
            min_replicas: min,
            max_replicas: max,
            ..ScaleConfig::default()
        }
    }

    fn obs(rps: f64) -> Observation {
        Observation { arrival_rps: rps, outstanding_frac: 0.0, p99_s: f64::NAN }
    }

    fn mono_cfg() -> ControlConfig {
        ControlConfig::autoscaled(plan2(), ctrl(), scale(1, 4), 0.0)
    }

    fn states(cfg: &ControlConfig) -> Vec<ControlState> {
        cfg.units
            .iter()
            .map(|_| ControlState::new(0, &cfg.ctrl))
            .collect()
    }

    /// One-unit tick with no forecast and an H100 price tag.
    fn tick1(
        cfg: &ControlConfig,
        st: &mut [ControlState],
        o: Observation,
        warming: usize,
        live: usize,
    ) -> Tick {
        decide_tick(
            cfg,
            st,
            &[o],
            &[(warming, live, 0)],
            &[Gpu::H100],
            &[0.0],
            0.2,
        )
    }

    #[test]
    fn rising_load_rents_replicas_before_trading_accuracy() {
        let cfg = mono_cfg();
        let mut st = states(&cfg);
        // 1500 rps: the max fleet of the top gear sustains 4*500=2000,
        // so no shift -- but the 1-replica fleet must grow to 4
        let t = tick1(&cfg, &mut st, obs(1500.0), 0, 1);
        assert!(t.shifts.is_empty());
        assert_eq!(t.scales.len(), 1);
        let s = t.scales[0];
        assert_eq!((s.target, s.trigger, s.decider), (4, Trigger::Rate, "scale"));
        assert_eq!(st[0].current(), 0, "accuracy held while machines are cheap");
    }

    #[test]
    fn drowning_load_shifts_and_resizes_in_one_tick() {
        let cfg = mono_cfg();
        let mut st = states(&cfg);
        // 3000 rps drowns even 4x top (1700 effective): downshift to
        // the fast gear AND size its fleet in the same decision -- the
        // fast gear (2000 rps/replica) releases down to 3 machines (the
        // conservative scale_down_util sizing; 2 would run at 75%)
        let t = tick1(&cfg, &mut st, obs(3000.0), 0, 4);
        assert_eq!(t.shifts.len(), 1);
        assert_eq!(
            (t.shifts[0].shift, t.shifts[0].trigger),
            (Shift::Down, Trigger::Rate)
        );
        assert_eq!(st[0].current(), 1);
        assert_eq!(t.scales.len(), 1);
        assert_eq!(t.scales[0].target, 3);
    }

    #[test]
    fn calm_load_upshifts_then_drains_the_surplus() {
        let cfg = mono_cfg();
        let mut st = vec![ControlState::new(1, &cfg.ctrl)];
        // 300 rps on the fast gear: upshift (top's max fleet runs at
        // 0.15) and size the top-gear fleet for 300 rps (1 replica)
        let t = tick1(&cfg, &mut st, obs(300.0), 0, 4);
        assert_eq!(t.shifts.len(), 1);
        assert_eq!(t.shifts[0].shift, Shift::Up);
        assert_eq!(t.scales.len(), 1);
        assert_eq!(t.scales[0].target, 1);
    }

    #[test]
    fn dwell_blocks_lone_scale_actions_but_not_the_shift_resize_pair() {
        let cfg = mono_cfg();
        let mut st = states(&cfg);
        st[0].note_action();
        let t = decide_tick(
            &cfg,
            &mut st,
            &[obs(1500.0)],
            &[(0, 1, 0)],
            &[Gpu::H100],
            &[0.0],
            0.02,
        );
        assert!(t.shifts.is_empty());
        assert!(t.scales.is_empty(), "dwell must gate scale actions too");
        // once the dwell expires the pending resize applies
        let t = tick1(&cfg, &mut st, obs(1500.0), 0, 1);
        assert_eq!(t.scales.len(), 1);
        assert_eq!(t.scales[0].target, 4);
        // and the next decision's dwell is consumed by that scale action
        let t = decide_tick(
            &cfg,
            &mut st,
            &[obs(3000.0)],
            &[(0, 4, 0)],
            &[Gpu::H100],
            &[0.0],
            0.02,
        );
        assert_eq!(t, Tick::default());
    }

    #[test]
    fn queue_pressure_scales_up_even_at_calm_ewma() {
        let cfg = mono_cfg();
        let mut st = vec![ControlState::new(1, &cfg.ctrl)];
        // rate looks idle but queues are jammed: the gear machine steps
        // down if it can (it cannot: already fastest), the fleet grows
        let jammed =
            Observation { arrival_rps: 5.0, outstanding_frac: 0.9, p99_s: f64::NAN };
        let t = tick1(&cfg, &mut st, jammed, 0, 2);
        assert!(t.shifts.is_empty(), "already in the fastest gear");
        assert_eq!(t.scales.len(), 1);
        assert_eq!(t.scales[0].target, 3);
        assert_eq!(t.scales[0].trigger, Trigger::Pressure);
    }

    #[test]
    fn warming_replicas_count_against_reprovisioning() {
        let cfg = mono_cfg();
        let mut st = states(&cfg);
        // first decision provisions 3 more machines (slow warm-up: they
        // stay Warming)
        let t = tick1(&cfg, &mut st, obs(1500.0), 0, 1);
        assert_eq!(t.scales[0].target, 4);
        // while they warm, the same load must NOT re-provision: the
        // in-flight capacity already covers the target
        let t = tick1(&cfg, &mut st, obs(1500.0), 3, 1);
        assert!(t.scales.is_empty(), "re-provisioned capacity in flight");
        // even a jammed queue doesn't kick the fleet past the in-flight
        // capacity: the warm-ups will relieve the same debt
        let jammed = Observation {
            arrival_rps: 1500.0,
            outstanding_frac: 0.9,
            p99_s: f64::NAN,
        };
        let t = tick1(&cfg, &mut st, jammed, 3, 1);
        assert!(t.scales.is_empty(), "pressure re-rented warming capacity");
        // once they go live nothing changes either
        let t = tick1(&cfg, &mut st, obs(1500.0), 0, 4);
        assert!(t.scales.is_empty());
    }

    #[test]
    fn steady_state_decides_nothing() {
        let cfg = mono_cfg();
        let mut st = states(&cfg);
        // 600 rps on 2 live top-gear replicas: util 0.6, inside every band
        for _ in 0..10 {
            let t = tick1(&cfg, &mut st, obs(600.0), 0, 2);
            assert_eq!(t, Tick::default());
        }
    }

    #[test]
    fn forecast_provisions_ahead_of_the_ewma() {
        let cfg = mono_cfg();
        let mut st = states(&cfg);
        // EWMA says 300 rps (1 replica is plenty) but the trend
        // forecasts 1500: provision for the forecast now
        let t = decide_tick(
            &cfg,
            &mut st,
            &[obs(300.0)],
            &[(0, 1, 0)],
            &[Gpu::H100],
            &[1500.0],
            0.2,
        );
        assert_eq!(t.scales.len(), 1, "{t:?}");
        assert_eq!(t.scales[0].target, 4);
        // a forecast never drains below what the EWMA needs: with the
        // fleet already at 4 and both signals calm, release follows the
        // EWMA (forecast 0 = no prediction)
        let t = decide_tick(
            &cfg,
            &mut st,
            &[obs(300.0)],
            &[(0, 4, 0)],
            &[Gpu::H100],
            &[0.0],
            0.2,
        );
        assert_eq!(t.scales[0].target, 1);
    }

    // ----- tiered fleets ------------------------------------------------

    /// cheap fast front tier, midsize interior, slow top; no gear
    /// shifting (the TieredAutoscaler-equivalent shape).
    fn fleet_cfg(max_dollars: f64) -> ControlConfig {
        let tier = |rps: f64| TierControl {
            per_replica_rps: rps,
            scale: Some(scale(1, 4)),
            rungs: vec![],
        };
        ControlConfig::tiered(
            vec![tier(2000.0), tier(1000.0), tier(400.0)],
            ctrl(),
            max_dollars,
        )
    }

    fn gpus3() -> Vec<Gpu> {
        vec![Gpu::V100, Gpu::A6000, Gpu::H100]
    }

    #[test]
    fn tiers_size_independently_against_their_own_arrivals() {
        let cfg = fleet_cfg(0.0);
        cfg.validate(3);
        let mut st = states(&cfg);
        // tier arrivals thin out down the cascade: 3000 offered, 40%
        // defer to tier 2, a third of that reaches the top
        let o = [obs(3000.0), obs(1200.0), obs(400.0)];
        let c = [(0, 1, 0), (0, 1, 0), (0, 1, 0)];
        let t = decide_tick(&cfg, &mut st, &o, &c, &gpus3(), &[0.0; 3], 0.2);
        // 3000/(2000*0.85) -> 2; 1200/(1000*0.85) -> 2; 400/(400*0.85) -> 2
        assert_eq!(t.scales.len(), 3);
        assert!(t.scales.iter().all(|s| s.target == 2), "{t:?}");
        // a calm interior tier is left alone while the top grows
        let mut st = states(&cfg);
        let o = [obs(1000.0), obs(100.0), obs(700.0)];
        let t = decide_tick(&cfg, &mut st, &o, &c, &gpus3(), &[0.0; 3], 0.2);
        assert_eq!(t.scales.len(), 1);
        assert_eq!((t.scales[0].unit, t.scales[0].target), (2, 3));
    }

    #[test]
    fn dwell_gates_each_tier_separately() {
        let cfg = fleet_cfg(0.0);
        let mut st = states(&cfg);
        let c = [(0, 1, 0), (0, 1, 0), (0, 1, 0)];
        // first decision consumes tier 0's dwell only
        let o = [obs(3000.0), obs(0.0), obs(0.0)];
        let t = decide_tick(&cfg, &mut st, &o, &c, &gpus3(), &[0.0; 3], 0.2);
        assert_eq!(t.scales.len(), 1);
        // immediately after, tier 0 is blocked but tier 2 can still act
        let o = [obs(3000.0), obs(0.0), obs(700.0)];
        let c2 = [(0, 2, 0), (0, 1, 0), (0, 1, 0)];
        let t = decide_tick(&cfg, &mut st, &o, &c2, &gpus3(), &[0.0; 3], 0.01);
        assert_eq!(t.scales.len(), 1);
        assert_eq!((t.scales[0].unit, t.scales[0].target), (2, 3));
    }

    #[test]
    fn queue_pressure_kicks_a_tier_without_rate_evidence() {
        let cfg = fleet_cfg(0.0);
        let mut st = states(&cfg);
        let jammed =
            Observation { arrival_rps: 5.0, outstanding_frac: 0.9, p99_s: f64::NAN };
        let o = [obs(5.0), jammed, obs(5.0)];
        let c = [(0, 1, 0), (0, 1, 0), (0, 1, 0)];
        let t = decide_tick(&cfg, &mut st, &o, &c, &gpus3(), &[0.0; 3], 0.2);
        assert_eq!(t.scales.len(), 1);
        let s = t.scales[0];
        assert_eq!((s.unit, s.target, s.trigger), (1, 2, Trigger::Pressure));
        // warming capacity suppresses the kicker
        let mut st = states(&cfg);
        let c = [(0, 1, 0), (1, 1, 0), (0, 1, 0)];
        let t = decide_tick(&cfg, &mut st, &o, &c, &gpus3(), &[0.0; 3], 0.2);
        assert!(t.scales.is_empty(), "{t:?}");
    }

    #[test]
    fn dollar_budget_clamps_cheapest_first() {
        // current bill: 1xV100 + 1xA6000 + 1xH100 = 3.79 $/h.  Budget
        // leaves 1.60 of headroom: tier 0 can afford 3 more V100s
        // (1.50), then nothing is left for the H100 the top tier wants.
        let cfg = fleet_cfg(5.39);
        let mut st = states(&cfg);
        let o = [obs(6000.0), obs(0.0), obs(3000.0)];
        let c = [(0, 1, 0), (0, 1, 0), (0, 1, 0)];
        let t = decide_tick(&cfg, &mut st, &o, &c, &gpus3(), &[0.0; 3], 0.2);
        assert_eq!(t.scales.len(), 1, "expensive tier starved: {t:?}");
        assert_eq!((t.scales[0].unit, t.scales[0].target), (0, 4));
        // drains are always allowed: they only return money
        let mut st = states(&cfg);
        let o = [obs(0.0), obs(0.0), obs(0.0)];
        let c = [(0, 4, 0), (0, 1, 0), (0, 2, 0)];
        let t = decide_tick(&cfg, &mut st, &o, &c, &gpus3(), &[0.0; 3], 0.2);
        assert_eq!(t.scales.len(), 2);
        assert!(t.scales.iter().all(|s| s.target == 1));
        // draining slots still count against the budget: with 3 slots
        // draining elsewhere the headroom is gone entirely
        let cfg2 = fleet_cfg(4.0);
        let mut st = states(&cfg2);
        let o = [obs(6000.0), obs(0.0), obs(0.0)];
        let c = [(0, 1, 0), (0, 1, 3), (0, 1, 0)]; // 3 A6000s draining
        let t = decide_tick(&cfg2, &mut st, &o, &c, &gpus3(), &[0.0; 3], 0.2);
        assert!(t.scales.is_empty(), "budget must count draining slots: {t:?}");
    }

    #[test]
    fn partial_budget_grants_attribute_the_arbiter() {
        // headroom affords exactly one more V100 though the policy asks
        // for three: the grant is clamped and attributed to "budget"
        let cfg = fleet_cfg(4.29);
        let mut st = states(&cfg);
        let o = [obs(6000.0), obs(0.0), obs(0.0)];
        let c = [(0, 1, 0), (0, 1, 0), (0, 1, 0)];
        let t = decide_tick(&cfg, &mut st, &o, &c, &gpus3(), &[0.0; 3], 0.2);
        assert_eq!(t.scales.len(), 1);
        let s = t.scales[0];
        assert_eq!((s.unit, s.target, s.decider), (0, 2, "budget"));
    }

    #[test]
    fn unbounded_budget_never_clamps() {
        let cfg = fleet_cfg(0.0);
        let mut st = states(&cfg);
        let o = [obs(1e9), obs(1e9), obs(1e9)];
        let c = [(0, 1, 0), (0, 1, 0), (0, 1, 0)];
        let t = decide_tick(&cfg, &mut st, &o, &c, &gpus3(), &[0.0; 3], 0.2);
        assert_eq!(t.scales.len(), 3);
        assert!(t.scales.iter().all(|s| s.target == 4), "max bound applies");
    }

    // ----- per-tier gear shifting ---------------------------------------

    /// 3 tiers, fixed single-replica fleets, theta ladders on tiers 0
    /// and 1 (walked by the deciders observing tiers 1 and 2).
    fn geared_fleet_cfg() -> ControlConfig {
        let rungs = vec![
            TierRung { theta: None, max_batch: 8 },
            TierRung { theta: Some(0.6), max_batch: 8 },
            TierRung { theta: Some(0.3), max_batch: 8 },
        ];
        let tier = |rps: f64, rungs: Vec<TierRung>| TierControl {
            per_replica_rps: rps,
            scale: None,
            rungs,
        };
        ControlConfig::tiered(
            vec![
                tier(2000.0, rungs.clone()),
                tier(1000.0, rungs.clone()),
                tier(400.0, rungs), // last tier: rungs ignored
            ],
            ctrl(),
            0.0,
        )
    }

    #[test]
    fn downstream_overload_lowers_the_upstream_theta() {
        let cfg = geared_fleet_cfg();
        cfg.validate(3);
        assert_eq!(cfg.gears.len(), 2, "last tier gets no decider");
        let mut st = states(&cfg);
        // the top tier (capacity 400) drowns at 800 rps of deferrals:
        // the decider observing unit 2 lowers unit 1's theta one rung
        let o = [obs(100.0), obs(300.0), obs(800.0)];
        let c = [(0, 1, 0), (0, 1, 0), (0, 1, 0)];
        let t = decide_tick(&cfg, &mut st, &o, &c, &gpus3(), &[0.0; 3], 0.2);
        assert_eq!(t.shifts.len(), 1, "{t:?}");
        let s = t.shifts[0];
        assert_eq!((s.obs_unit, s.act_unit), (2, 1));
        assert_eq!((s.from, s.to, s.shift), (0, 1, Shift::Down));
        // the actuated rung's config carries the theta override
        let dec = cfg.decider_for_obs(2).unwrap();
        assert_eq!(dec.config_at(1).thetas, vec![0.6]);
        assert!(dec.config_at(0).thetas.is_empty(), "rung 0 is calibrated");
        // once the deferral stream calms, the theta is restored
        let o = [obs(100.0), obs(300.0), obs(100.0)];
        let t = decide_tick(&cfg, &mut st, &o, &c, &gpus3(), &[0.0; 3], 0.2);
        assert_eq!(t.shifts.len(), 1);
        assert_eq!((t.shifts[0].to, t.shifts[0].shift), (0, Shift::Up));
    }

    #[test]
    fn theta_shift_guards_the_observing_tiers_resize() {
        // tier 2 elastic under a budget with zero headroom (bill at
        // (1,1,2) slots = 0.5 + 0.8 + 2*2.49 = 6.28): renting is
        // exhausted, so relief comes from tier 1's theta ladder
        let rungs = vec![
            TierRung { theta: None, max_batch: 8 },
            TierRung { theta: Some(0.5), max_batch: 8 },
        ];
        let cfg = ControlConfig::tiered(
            vec![
                TierControl {
                    per_replica_rps: 2000.0,
                    scale: None,
                    rungs: rungs.clone(),
                },
                TierControl { per_replica_rps: 1000.0, scale: None, rungs },
                TierControl {
                    per_replica_rps: 400.0,
                    scale: Some(scale(1, 4)),
                    rungs: vec![],
                },
            ],
            ctrl(),
            6.28,
        );
        cfg.validate(3);
        let mut st = states(&cfg);
        // tier 2 drowning: the theta shift lands, and the SAME tick can
        // not also resize tier 2 -- the shift consumed its dwell (gear
        // and scale share one clock: the fleet-level hysteresis guard)
        let o = [obs(100.0), obs(300.0), obs(1600.0)];
        let c = [(0, 1, 0), (0, 1, 0), (0, 2, 0)];
        let t = decide_tick(&cfg, &mut st, &o, &c, &gpus3(), &[0.0; 3], 0.2);
        assert_eq!(t.shifts.len(), 1);
        assert_eq!((t.shifts[0].obs_unit, t.shifts[0].act_unit), (2, 1));
        assert!(t.scales.is_empty(), "resize raced the shift: {t:?}");
        // within the dwell, even post-shift (thinned) arrivals that
        // would justify draining tier 2 are held: no reacting to a
        // stream the shift just changed until a full dwell of evidence
        let o2 = [obs(100.0), obs(300.0), obs(100.0)];
        let t = decide_tick(&cfg, &mut st, &o2, &c, &gpus3(), &[0.0; 3], 0.02);
        assert_eq!(t, Tick::default(), "acted inside the guard dwell");
        // once the dwell expires the stack moves again (here: the calm
        // stream restores the theta first -- accuracy before release)
        let t = decide_tick(&cfg, &mut st, &o2, &c, &gpus3(), &[0.0; 3], 0.2);
        assert_eq!(t.shifts.len(), 1);
        assert_eq!(t.shifts[0].shift, Shift::Up);
    }

    #[test]
    fn undecided_units_still_fold_their_observations() {
        // tier 0 fixed, no rungs, no scale: nothing decides on it, but
        // its EWMA must still track traffic (per-tier gauges, and any
        // decider enabled later must not start from a frozen state)
        let cfg = ControlConfig::tiered(
            vec![
                TierControl {
                    per_replica_rps: 2000.0,
                    scale: None,
                    rungs: vec![],
                },
                TierControl {
                    per_replica_rps: 1000.0,
                    scale: Some(scale(1, 4)),
                    rungs: vec![],
                },
            ],
            ctrl(),
            0.0,
        );
        cfg.validate(2);
        let mut st = states(&cfg);
        let o = [obs(1234.0), obs(10.0)];
        let c = [(0, 1, 0), (0, 1, 0)];
        let gpus = vec![Gpu::V100, Gpu::H100];
        decide_tick(&cfg, &mut st, &o, &c, &gpus, &[0.0; 2], 0.2);
        assert_eq!(st[0].ewma_rps(), 1234.0, "undecided unit's EWMA froze");
    }

    #[test]
    fn drift_decider_regrounds_only_on_finite_latched_breach() {
        let status = |alarm, theta_live| DriftStatus {
            tier: 0,
            alarm,
            samples: 100,
            window: 100,
            agreement: 0.6,
            failure_rate: 0.2,
            epsilon: 0.05,
            theta_live,
            theta_cal: Some(0.6),
        };
        assert!(DriftDecider::should_reground(&status(AlarmState::Breach, 0.4)));
        // pre-latch states never actuate
        assert!(!DriftDecider::should_reground(&status(AlarmState::Ok, 0.4)));
        assert!(!DriftDecider::should_reground(&status(AlarmState::Warn, 0.4)));
        // degradation sentinels are not operating points
        assert!(!DriftDecider::should_reground(&status(
            AlarmState::Breach,
            f32::INFINITY
        )));
        assert!(!DriftDecider::should_reground(&status(
            AlarmState::Breach,
            f32::NEG_INFINITY
        )));
    }

    #[test]
    fn unaffordable_rent_falls_back_to_accuracy_trades() {
        // top tier elastic 1..4 but the budget affords nothing beyond
        // the current bill: renting is denied, so the decider observing
        // the drowned top tier trades accuracy upstream instead
        let rungs = vec![
            TierRung { theta: None, max_batch: 8 },
            TierRung { theta: Some(0.5), max_batch: 8 },
        ];
        let mut cfg = ControlConfig::tiered(
            vec![
                TierControl {
                    per_replica_rps: 2000.0,
                    scale: None,
                    rungs: rungs.clone(),
                },
                TierControl {
                    per_replica_rps: 1000.0,
                    scale: None,
                    rungs: rungs.clone(),
                },
                TierControl {
                    per_replica_rps: 400.0,
                    scale: Some(scale(1, 4)),
                    rungs: vec![],
                },
            ],
            ctrl(),
            3.79, // exactly the current 1+1+1 bill: zero headroom
        );
        cfg.validate(3);
        let mut st = states(&cfg);
        let o = [obs(100.0), obs(300.0), obs(800.0)];
        let c = [(0, 1, 0), (0, 1, 0), (0, 1, 0)];
        let t = decide_tick(&cfg, &mut st, &o, &c, &gpus3(), &[0.0; 3], 0.2);
        assert!(t.scales.is_empty(), "budget affords nothing: {t:?}");
        assert_eq!(t.shifts.len(), 1, "accuracy trade must step in: {t:?}");
        assert_eq!(t.shifts[0].act_unit, 1);
        // with budget headroom instead, renting wins and no shift fires
        cfg.max_dollars_per_hour = 0.0;
        let mut st = states(&cfg);
        let t = decide_tick(&cfg, &mut st, &o, &c, &gpus3(), &[0.0; 3], 0.2);
        assert!(t.shifts.is_empty(), "rented instead: {t:?}");
        assert_eq!(t.scales.len(), 1);
        assert_eq!(t.scales[0].unit, 2);
    }
}
