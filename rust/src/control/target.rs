//! What the control plane observes and actuates: [`ControlTarget`], the
//! per-registry view a serving backend exposes to the single
//! [`crate::control::ControlLoop`] thread.
//!
//! A target is a set of **units**, each an independently observable and
//! actuable capacity pool with its own metrics registry:
//!
//! * a monolithic [`ReplicaPool`] is one unit (its shared registry);
//! * a [`TieredFleet`] is one unit per cascade level (each tier pool's
//!   private registry, so unit N's arrivals are tier N-1's deferrals).
//!
//! Observation is registry-shaped (counter deltas, outstanding
//! fractions, slot counts) and actuation is uniform: set a gear
//! ([`ControlTarget::set_gear`] -- swap the shared `GearHandle` on a
//! geared pool, retune one tier's theta/batch on a fleet), rent
//! ([`ControlTarget::scale_up`]) or release ([`ControlTarget::drain`])
//! replicas, and advance replica lifecycles.  The deciders never see
//! the concrete backend, so gear + scale policy is written once and
//! serves both layouts.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::replica::ReplicaPool;
use crate::coordinator::router::TieredFleet;
use crate::cost::rental::Gpu;
use crate::metrics::Metrics;
use crate::obs::drift::DriftStatus;
use crate::obs::slo::SloStatus;
use crate::planner::gear::GearConfig;

/// One serving backend as seen by the control loop; see module docs.
pub trait ControlTarget: Send + Sync {
    /// Independently controlled units (1 for a pool, tiers for a fleet).
    fn n_units(&self) -> usize;
    /// Unit `i`'s own metrics registry (the sampler's counter source).
    fn unit_metrics(&self, unit: usize) -> Arc<Metrics>;
    /// Unit `i`'s (warming, live, draining) slot counts.
    fn unit_counts(&self, unit: usize) -> (usize, usize, usize);
    /// Outstanding requests across all of unit `i`'s slots.
    fn unit_outstanding(&self, unit: usize) -> usize;
    /// Unit `i`'s provisioned admission capacity (slots x queue depth).
    fn unit_queue_capacity(&self, unit: usize) -> usize;
    /// GPU class unit `i` rents (the budget arbiter's price basis).
    fn unit_gpu(&self, unit: usize) -> Gpu;
    /// Replica-seconds unit `i` has accrued (rental telemetry).
    fn unit_replica_seconds(&self, unit: usize) -> f64;
    /// Ladder rung unit `i`'s actuator starts at (a geared pool reports
    /// its handle's active gear; everything else starts at 0).
    fn initial_gear(&self, unit: usize) -> usize {
        let _ = unit;
        0
    }
    /// Advance every replica lifecycle (promote warmed, retire drained).
    fn advance(&self, now: Instant);
    /// Actuate a gear on unit `i`: thresholds + batch cap, affecting
    /// only batches formed later (never in-flight requests).
    fn set_gear(&self, unit: usize, cfg: &GearConfig);
    /// Provision `n` replicas on unit `i` (Warming for `warmup`).
    fn scale_up(&self, unit: usize, n: usize, warmup: Duration);
    /// Begin gracefully draining `n` of unit `i`'s Live replicas.
    fn drain(&self, unit: usize, n: usize);
    /// Unit `i`'s live drift status from the drift observatory, when
    /// the target shadow-samples (`None`: no observatory, or `i` is the
    /// final tier, which never early-exits and is not monitored).
    fn drift_status(&self, unit: usize) -> Option<DriftStatus> {
        let _ = unit;
        None
    }
    /// Re-ground unit `i`'s serving theta from the observatory's live
    /// windowed estimate, returning the theta now being served.  `None`
    /// when the observatory refuses (no latched breach, thin window, or
    /// a non-finite estimate) or the target has no observatory.
    fn reground_theta(&self, unit: usize) -> Option<f32> {
        let _ = unit;
        None
    }
    /// The per-class SLO statuses from the target's SLO observatory,
    /// refreshed to now (`None`: no observatory attached).  The loop's
    /// budget-boost coupling (`ControlConfig::slo_boost`) keys on the
    /// premium class's burn alarm.
    fn slo_statuses(&self) -> Option<Vec<SloStatus>> {
        None
    }
    /// The target-level registry the loop records events and publishes
    /// control gauges into (== the unit registry for a pool, the fleet
    /// registry for a tiered fleet).
    fn control_metrics(&self) -> &Arc<Metrics>;
    /// Refresh derived telemetry (gauges) after a tick.
    fn publish(&self) {}
}

impl ControlTarget for ReplicaPool {
    fn n_units(&self) -> usize {
        1
    }

    fn unit_metrics(&self, _unit: usize) -> Arc<Metrics> {
        Arc::clone(self.metrics())
    }

    fn unit_counts(&self, _unit: usize) -> (usize, usize, usize) {
        self.counts()
    }

    fn unit_outstanding(&self, _unit: usize) -> usize {
        self.total_outstanding()
    }

    fn unit_queue_capacity(&self, _unit: usize) -> usize {
        // ALL slots count -- outstanding includes work still queued on
        // Draining (and Warming) replicas, so a live-only denominator
        // would read >1.0 right after a drain and flap the pressure
        // trigger.
        self.n_slots() * self.max_queue()
    }

    fn unit_gpu(&self, _unit: usize) -> Gpu {
        self.gpu()
    }

    fn unit_replica_seconds(&self, _unit: usize) -> f64 {
        self.replica_seconds()
    }

    fn initial_gear(&self, _unit: usize) -> usize {
        self.gear().map(|h| h.gear_id()).unwrap_or(0)
    }

    fn advance(&self, now: Instant) {
        ReplicaPool::advance(self, now);
    }

    fn set_gear(&self, _unit: usize, cfg: &GearConfig) {
        if let Some(handle) = self.gear() {
            handle.store(cfg.clone());
        }
        self.set_max_batch(cfg.max_batch);
    }

    fn scale_up(&self, _unit: usize, n: usize, warmup: Duration) {
        ReplicaPool::scale_up(self, n, warmup);
    }

    fn drain(&self, _unit: usize, n: usize) {
        ReplicaPool::drain(self, n);
    }

    fn slo_statuses(&self) -> Option<Vec<SloStatus>> {
        self.slo().map(|s| {
            s.refresh();
            s.statuses()
        })
    }

    fn control_metrics(&self) -> &Arc<Metrics> {
        self.metrics()
    }
}

impl ControlTarget for TieredFleet {
    fn n_units(&self) -> usize {
        self.n_tiers()
    }

    fn unit_metrics(&self, unit: usize) -> Arc<Metrics> {
        Arc::clone(self.tier(unit).pool().metrics())
    }

    fn unit_counts(&self, unit: usize) -> (usize, usize, usize) {
        self.tier(unit).pool().counts()
    }

    fn unit_outstanding(&self, unit: usize) -> usize {
        self.tier(unit).pool().total_outstanding()
    }

    fn unit_queue_capacity(&self, unit: usize) -> usize {
        let pool = self.tier(unit).pool();
        pool.n_slots() * pool.max_queue()
    }

    fn unit_gpu(&self, unit: usize) -> Gpu {
        self.tier(unit).gpu()
    }

    fn unit_replica_seconds(&self, unit: usize) -> f64 {
        self.tier(unit).pool().replica_seconds()
    }

    fn advance(&self, now: Instant) {
        TieredFleet::advance(self, now);
    }

    fn set_gear(&self, unit: usize, cfg: &GearConfig) {
        // a tier's gear is (theta override, batch cap); an empty theta
        // list restores the stage's own calibrated policy
        self.set_tier_gear(unit, cfg.thetas.first().copied(), cfg.max_batch);
    }

    fn scale_up(&self, unit: usize, n: usize, warmup: Duration) {
        self.tier(unit).pool().scale_up(n, warmup);
    }

    fn drain(&self, unit: usize, n: usize) {
        self.tier(unit).pool().drain(n);
    }

    fn drift_status(&self, unit: usize) -> Option<DriftStatus> {
        self.drift().and_then(|m| m.status(unit))
    }

    fn reground_theta(&self, unit: usize) -> Option<f32> {
        // the monitor guards the actuation (latched breach, full-enough
        // window, finite estimate); only a granted reground touches the
        // serving adapter
        let theta = self.drift()?.reground(unit)?;
        self.set_tier_theta(unit, Some(theta));
        Some(theta)
    }

    fn slo_statuses(&self) -> Option<Vec<SloStatus>> {
        self.slo().map(|s| {
            s.refresh();
            s.statuses()
        })
    }

    fn control_metrics(&self) -> &Arc<Metrics> {
        self.metrics()
    }

    fn publish(&self) {
        self.refresh_gauges();
    }
}
