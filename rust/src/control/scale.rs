//! The scale policy: arrival EWMA + active gear -> target replica
//! count.  Pure and clock-free, like `ControlState`, so the sizing math
//! is unit-testable without threads.
//!
//! Sizing is M/D/1-flavoured provisioning rather than queueing-exact:
//! hold the fleet where the EWMA runs at or below `scale_up_util` of
//! capacity, and release machines only down to a size that would still
//! run below the stricter `scale_down_util` -- the gap between the two
//! watermarks is the hysteresis band that keeps on-off traffic from
//! flapping the fleet at the sample rate (the shared dwell clock in
//! the control loop bounds it further).  Queue pressure adds a kicker:
//! when outstanding work crosses the controller's `queue_pressure`
//! watermark the target is bumped at least one above the current fleet
//! even if the rate EWMA looks calm (a stuck queue is capacity debt
//! the arrival rate cannot see).

/// Fleet bounds + watermarks for one unit's scale decider.
#[derive(Debug, Clone, Copy)]
pub struct ScaleConfig {
    /// Never drain below this many replicas (>= 1).
    pub min_replicas: usize,
    /// Never provision above this many replicas.
    pub max_replicas: usize,
    /// Scale up when the fleet would otherwise run above this
    /// utilisation; new fleets are sized to run at it.
    pub scale_up_util: f64,
    /// Scale down only to a fleet that would still run below this
    /// (must be < `scale_up_util` for hysteresis).
    pub scale_down_util: f64,
    /// Simulated provisioning delay for new replicas (Warming ->
    /// Live); the rental clock runs during it.
    pub warmup: std::time::Duration,
}

impl Default for ScaleConfig {
    fn default() -> Self {
        ScaleConfig {
            min_replicas: 1,
            max_replicas: 4,
            scale_up_util: crate::types::UTIL_HIGH_WATERMARK,
            scale_down_util: crate::types::UTIL_LOW_WATERMARK,
            warmup: std::time::Duration::ZERO,
        }
    }
}

impl ScaleConfig {
    /// Panic early on nonsense configs (mirrors `ControlLoop::spawn`).
    pub fn validate(&self) {
        assert!(self.min_replicas >= 1, "min_replicas must be >= 1");
        assert!(
            self.min_replicas <= self.max_replicas,
            "min_replicas {} > max_replicas {}",
            self.min_replicas,
            self.max_replicas
        );
        assert!(
            self.scale_down_util < self.scale_up_util,
            "hysteresis requires scale_down_util < scale_up_util"
        );
        assert!(self.scale_up_util > 0.0 && self.scale_up_util <= 1.0);
        assert!(self.scale_down_util > 0.0);
    }

    /// Replicas needed to serve `rps` at `util` utilisation of
    /// `per_replica_rps`-capacity machines.
    fn needed(&self, rps: f64, per_replica_rps: f64, util: f64) -> usize {
        if rps <= 0.0 {
            return 0;
        }
        (rps / (per_replica_rps.max(1e-9) * util)).ceil() as usize
    }

    /// The target fleet size for the observed load.  `per_replica_rps`
    /// is the ACTIVE gear's per-replica capacity (a gear shift changes
    /// it, which is why the control loop re-evaluates the target in the
    /// same tick as the shift).  `pressured` is the controller's
    /// queue-pressure signal.  Pure; the caller clamps nothing -- the
    /// result is already within `[min_replicas, max_replicas]`.
    pub fn target(
        &self,
        ewma_rps: f64,
        per_replica_rps: f64,
        current: usize,
        pressured: bool,
    ) -> usize {
        let up = self.needed(ewma_rps, per_replica_rps, self.scale_up_util);
        let down = self.needed(ewma_rps, per_replica_rps, self.scale_down_util);
        // `down >= up` always (stricter watermark needs more machines):
        // the [up, down] band is where the current fleet is left alone
        let mut t = if up > current {
            up
        } else if down < current {
            down
        } else {
            current
        };
        if pressured {
            t = t.max(current + 1);
        }
        t.clamp(self.min_replicas, self.max_replicas)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(min: usize, max: usize) -> ScaleConfig {
        ScaleConfig { min_replicas: min, max_replicas: max, ..ScaleConfig::default() }
    }

    #[test]
    fn validate_rejects_nonsense() {
        cfg(1, 4).validate();
        let r = std::panic::catch_unwind(|| {
            ScaleConfig { min_replicas: 0, ..cfg(1, 4) }.validate()
        });
        assert!(r.is_err());
        let r = std::panic::catch_unwind(|| cfg(5, 4).validate());
        assert!(r.is_err());
        let r = std::panic::catch_unwind(|| {
            ScaleConfig { scale_down_util: 0.9, ..cfg(1, 4) }.validate()
        });
        assert!(r.is_err());
    }

    #[test]
    fn sizes_fleet_to_the_up_watermark() {
        let c = cfg(1, 8);
        // 500 rps machines at 85%: 425 effective each
        assert_eq!(c.target(100.0, 500.0, 1, false), 1);
        assert_eq!(c.target(426.0, 500.0, 1, false), 2, "past one machine");
        assert_eq!(c.target(1700.0, 500.0, 1, false), 4);
        // jumps straight to the needed size, no one-at-a-time crawl
        assert_eq!(c.target(3000.0, 500.0, 1, false), 8, "clamped at max");
        assert_eq!(c.target(1e9, 500.0, 1, false), 8);
    }

    #[test]
    fn holds_inside_the_hysteresis_band() {
        let c = cfg(1, 8);
        // 4 machines at 500 rps: up-sized for >1700, down-sized for
        // loads where 3 machines stay under 60% (900)
        for rps in [1000.0, 1200.0, 1500.0, 1700.0] {
            assert_eq!(c.target(rps, 500.0, 4, false), 4, "flapped at {rps}");
        }
        // calm enough that a smaller fleet runs under 60%: release
        assert_eq!(c.target(850.0, 500.0, 4, false), 3);
        assert_eq!(c.target(500.0, 500.0, 4, false), 2);
        assert_eq!(c.target(0.0, 500.0, 4, false), 1, "idle drains to min");
    }

    #[test]
    fn scale_down_is_conservative_not_greedy() {
        let c = cfg(1, 8);
        // 4 -> 3 only if 3 machines would run below scale_down_util:
        // 1000 rps on 3x500 = 67% > 60% -> hold the 4th
        assert_eq!(c.target(1000.0, 500.0, 4, false), 4);
        // at 890 rps, 3 machines run at 59% -> release one
        assert_eq!(c.target(890.0, 500.0, 4, false), 3);
    }

    #[test]
    fn pressure_kicks_the_fleet_up_even_when_rate_looks_calm() {
        let c = cfg(1, 8);
        assert_eq!(c.target(10.0, 500.0, 2, true), 3, "queue debt adds one");
        // but never past the fleet cap
        assert_eq!(c.target(10.0, 500.0, 8, true), 8);
    }

    #[test]
    fn respects_min_and_max() {
        let c = cfg(2, 5);
        assert_eq!(c.target(0.0, 500.0, 3, false), 2);
        assert_eq!(c.target(1e9, 500.0, 3, false), 5);
        // degenerate per-replica capacity never divides by zero
        assert_eq!(c.target(100.0, 0.0, 3, false), 5);
    }

    #[test]
    fn gear_shift_changes_the_target_through_per_replica_capacity() {
        let c = cfg(1, 8);
        // same 1600 rps load: the top gear (400 rps/replica) needs 5
        // machines, the fast gear (1600 rps/replica) needs 2
        assert_eq!(c.target(1600.0, 400.0, 5, false), 5);
        assert_eq!(c.target(1600.0, 1600.0, 5, false), 2);
    }
}
