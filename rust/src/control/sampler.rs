//! Per-unit metrics sampler: counter/bucket deltas in, one
//! [`Observation`] + elapsed seconds out per call.
//!
//! One [`Sampler`] watches one metrics registry -- a monolithic pool's
//! shared registry, or one tier pool's private registry in a tiered
//! fleet (whose submitted + shed deltas are exactly the upstream tier's
//! deferral stream).  Every metric handle is resolved once so the
//! sample path never pays a registry lock, and the latency quantile is
//! WINDOWED (bucket-snapshot deltas) so a past overload can never latch
//! the SLO.

use std::sync::Arc;
use std::time::Instant;

use crate::control::state::Observation;
use crate::metrics::Metrics;

/// Delta sampler over one registry; see the module docs.
pub struct Sampler {
    submitted: Arc<crate::metrics::Counter>,
    shed: Arc<crate::metrics::Counter>,
    latency: Arc<crate::metrics::Histogram>,
    last_arrivals: u64,
    last_buckets: Vec<u64>,
    last_tick: Instant,
}

impl Sampler {
    pub fn new(metrics: &Metrics) -> Sampler {
        let submitted = metrics.counter("requests_submitted");
        let shed = metrics.counter("requests_shed");
        let latency = metrics.histogram("request_latency_s");
        Sampler {
            last_arrivals: submitted.get() + shed.get(),
            last_buckets: latency.bucket_snapshot(),
            last_tick: Instant::now(),
            submitted,
            shed,
            latency,
        }
    }

    /// Take one sample: offered arrival rate since the last call, the
    /// unit's outstanding work as a fraction of `queue_capacity` (its
    /// provisioned admission slots -- pass ALL slots' worth, Draining
    /// and Warming included, or the fraction reads >1.0 right after a
    /// drain and flaps the pressure trigger), and the WINDOWED p99
    /// (this interval's samples only -- the all-time quantile would
    /// latch one past overload into a permanent SLO breach).
    pub fn sample(
        &mut self,
        outstanding: usize,
        queue_capacity: usize,
    ) -> (Observation, f64) {
        let now = Instant::now();
        let dt_s = now.duration_since(self.last_tick).as_secs_f64().max(1e-9);
        self.last_tick = now;
        let arrivals = self.submitted.get() + self.shed.get();
        let buckets = self.latency.bucket_snapshot();
        let p99_s = crate::metrics::Histogram::quantile_between(
            &self.last_buckets,
            &buckets,
            0.99,
        );
        self.last_buckets = buckets;
        let obs = Observation {
            arrival_rps: arrivals.saturating_sub(self.last_arrivals) as f64 / dt_s,
            outstanding_frac: outstanding as f64 / queue_capacity.max(1) as f64,
            p99_s,
        };
        self.last_arrivals = arrivals;
        (obs, dt_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampler_reads_deltas_not_totals() {
        let m = Metrics::new();
        m.counter("requests_submitted").add(100);
        let mut s = Sampler::new(&m);
        // arrivals before construction are the baseline, not a delta
        std::thread::sleep(std::time::Duration::from_millis(5));
        let (obs, dt) = s.sample(0, 10);
        assert_eq!(obs.arrival_rps, 0.0);
        assert!(dt > 0.0);
        // submitted + shed both count as offered load
        m.counter("requests_submitted").add(30);
        m.counter("requests_shed").add(10);
        std::thread::sleep(std::time::Duration::from_millis(5));
        let (obs, dt) = s.sample(5, 10);
        assert!((obs.arrival_rps - 40.0 / dt).abs() < 1e-6);
        assert!((obs.outstanding_frac - 0.5).abs() < 1e-12);
        // empty latency window reads NaN, never a stale value
        assert!(obs.p99_s.is_nan());
        // zero capacity never divides by zero
        let (obs, _) = s.sample(3, 0);
        assert!((obs.outstanding_frac - 3.0).abs() < 1e-12);
    }

    #[test]
    fn sampler_p99_is_windowed() {
        let m = Metrics::new();
        let h = m.histogram("request_latency_s");
        for _ in 0..100 {
            h.record(1.0); // a past overload
        }
        let mut s = Sampler::new(&m);
        for _ in 0..100 {
            h.record(0.001); // the current calm window
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
        let (obs, _) = s.sample(0, 1);
        assert!(
            obs.p99_s < 0.01,
            "windowed p99 latched the past overload: {}",
            obs.p99_s
        );
    }
}
