//! Arrival-trend forecasting for predictive scale-up.
//!
//! A purely reactive autoscaler rents a machine only after the EWMA has
//! already crossed the watermark; with a non-zero warm-up the new
//! capacity arrives one warm-up late, and the queue eats the
//! difference.  The [`Forecaster`] closes that gap (the ROADMAP's
//! "predictive scale-up" item): it keeps a short window of the arrival
//! EWMA, fits a least-squares line over it, and extrapolates
//! `horizon_s` ahead -- the scale decider sizes the fleet to
//! `max(ewma, forecast)`, so a *rising* trend provisions before the
//! watermark breach instead of after it.
//!
//! Only the warm-up side is predictive: a falling trend forecasts 0
//! (ignored), so drains stay reactive -- releasing a machine early on a
//! guess risks goodput, holding it a little longer only risks rent the
//! hysteresis band already tolerates.

use std::collections::VecDeque;

/// EWMA samples retained for the trend fit (at the default 20ms sample
/// period this spans ~640ms -- a few dwells, short enough to track
/// on-off edges).
pub const FORECAST_WINDOW: usize = 32;

/// Linear-trend extrapolator over the arrival EWMA; see module docs.
#[derive(Debug, Clone)]
pub struct Forecaster {
    window: VecDeque<(f64, f64)>,
    cap: usize,
    horizon_s: f64,
}

impl Forecaster {
    /// `horizon_s` is how far ahead to extrapolate -- the control loop
    /// passes warm-up + dwell, the time a scale-up decision takes to
    /// become serving capacity.
    pub fn new(cap: usize, horizon_s: f64) -> Forecaster {
        assert!(cap >= 3, "a trend needs at least 3 samples");
        Forecaster { window: VecDeque::with_capacity(cap), cap, horizon_s }
    }

    /// Record one (time, EWMA) sample; evicts beyond the window.
    pub fn push(&mut self, t_s: f64, ewma_rps: f64) {
        if self.window.len() >= self.cap {
            self.window.pop_front();
        }
        self.window.push_back((t_s, ewma_rps));
    }

    /// Predicted arrival rate `horizon_s` past the newest sample, from
    /// the least-squares line over the window.  Returns 0.0 -- "no
    /// prediction" -- when the window holds fewer than 3 samples, has
    /// no time spread, or the trend is flat/falling (predictive
    /// scale-up only; drains stay reactive).
    pub fn forecast(&self) -> f64 {
        let n = self.window.len();
        if n < 3 {
            return 0.0;
        }
        let nf = n as f64;
        let mean_t = self.window.iter().map(|(t, _)| t).sum::<f64>() / nf;
        let mean_y = self.window.iter().map(|(_, y)| y).sum::<f64>() / nf;
        let mut cov = 0.0;
        let mut var = 0.0;
        for (t, y) in &self.window {
            cov += (t - mean_t) * (y - mean_y);
            var += (t - mean_t) * (t - mean_t);
        }
        if var <= 1e-12 {
            return 0.0;
        }
        let slope = cov / var;
        if slope <= 0.0 {
            return 0.0;
        }
        let t_last = self.window.back().expect("non-empty").0;
        (mean_y + slope * (t_last + self.horizon_s - mean_t)).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rising_trend_extrapolates_ahead() {
        // 1000 rps/s ramp sampled every 20ms; horizon 100ms
        let mut f = Forecaster::new(16, 0.1);
        for i in 0..10 {
            let t = i as f64 * 0.02;
            f.push(t, 1000.0 * t);
        }
        let got = f.forecast();
        // last sample is 180 rps at t=0.18; the line predicts ~280 at
        // t=0.28
        assert!((got - 280.0).abs() < 1.0, "forecast {got}");
    }

    #[test]
    fn flat_and_falling_trends_predict_nothing() {
        let mut f = Forecaster::new(16, 0.1);
        for i in 0..10 {
            f.push(i as f64 * 0.02, 500.0);
        }
        assert_eq!(f.forecast(), 0.0, "flat trend must not predict");
        let mut f = Forecaster::new(16, 0.1);
        for i in 0..10 {
            f.push(i as f64 * 0.02, 1000.0 - 50.0 * i as f64);
        }
        assert_eq!(f.forecast(), 0.0, "falling trend must not predict");
    }

    #[test]
    fn needs_three_samples_and_time_spread() {
        let mut f = Forecaster::new(8, 0.1);
        assert_eq!(f.forecast(), 0.0);
        f.push(0.0, 100.0);
        f.push(0.02, 200.0);
        assert_eq!(f.forecast(), 0.0, "two samples are not a trend");
        // zero time spread is degenerate, not a division by zero
        let mut f = Forecaster::new(8, 0.1);
        for _ in 0..5 {
            f.push(1.0, 100.0);
        }
        assert_eq!(f.forecast(), 0.0);
    }

    #[test]
    fn window_evicts_old_regimes() {
        let mut f = Forecaster::new(4, 0.1);
        // an old falling regime...
        for i in 0..10 {
            f.push(i as f64 * 0.02, 1000.0 - 90.0 * i as f64);
        }
        // ...followed by a sharp rise: only the window's 4 samples count
        for i in 10..14 {
            f.push(i as f64 * 0.02, 100.0 + 500.0 * (i - 9) as f64);
        }
        assert!(f.forecast() > 0.0, "rise hidden by evicted history");
    }
}
