//! The control loop: ONE thread per serve process that observes,
//! decides and actuates for every unit of a [`ControlTarget`].
//!
//! Each tick the loop advances replica lifecycles, samples every unit's
//! registry ([`crate::control::Sampler`]), consults the per-unit
//! [`crate::control::Forecaster`]s, runs the pure decider stack
//! ([`decide_tick`]), and applies the result: gear actuation through
//! [`ControlTarget::set_gear`] and fleet resizes through
//! [`ControlTarget::scale_up`] / [`ControlTarget::drain`].  Gear swaps
//! only affect batches formed later and drains are graceful, so no
//! decision ever drops or duplicates an in-flight request.
//!
//! Telemetry (target's control registry): `gear_shift_up` /
//! `gear_shift_down` / `scale_up_total` / `scale_down_total` counters;
//! `gear_current`, `arrival_ewma_rps`, `latency_p99_s`,
//! `replicas_live` / `replicas_warming` / `replicas_draining`,
//! `replica_seconds` gauges for single-unit targets (`tier_{i}_`-
//! prefixed `ewma_rps` / `gear` gauges for fleets, whose remaining
//! per-tier gauges come from the fleet's own `publish`); and one
//! [`crate::metrics::EventLog`] entry per action, recording the decider
//! ("gear" | "scale" | "budget" | "drift"), the trigger, and the tier
//! index.  With `ControlConfig::recalibrate` armed the loop also runs
//! the [`DriftDecider`] each tick: a tier whose drift alarm latched
//! Breach gets its serving theta re-grounded from the observatory's
//! live estimate (`drift_reground_total` counter, `decider="drift"`
//! events).
//!
//! With `ControlConfig::slo_boost > 1.0` and a finite budget the loop
//! also runs the SLO coupling each tick: while the target's premium
//! burn-rate alarm ([`crate::obs::slo::SloObservatory`]) is latched
//! Breach, the tick decides under `max_dollars_per_hour * slo_boost`
//! -- the arbiter affords extra machines exactly while the protected
//! class burns its error budget -- and snaps back when it clears
//! (`slo_boost_active` gauge, `decider="slo"` transition events tagged
//! `class="premium"`).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::control::decider::{
    decide_tick, ControlConfig, DriftDecider, GearLadder,
};
use crate::control::forecast::{Forecaster, FORECAST_WINDOW};
use crate::control::sampler::Sampler;
use crate::control::state::{ControlState, Shift};
use crate::control::target::ControlTarget;
use crate::metrics::{EventKind, EventRecord};
use crate::obs::drift::AlarmState;
use crate::types::Class;

/// Handle to the running control thread; stops and joins on drop.
pub struct ControlLoop {
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

impl ControlLoop {
    /// Spawn the loop over a target.  Geared pools must have been
    /// spawned with the shared `GearHandle` for plan actuation to take
    /// effect; the handle's active gear id picks the starting rung.
    pub fn spawn(target: Arc<dyn ControlTarget>, cfg: ControlConfig) -> ControlLoop {
        cfg.validate(target.n_units());
        for g in &cfg.gears {
            assert!(
                target.initial_gear(g.act_unit) < g.ladder_len(),
                "unit {} starts past its ladder",
                g.act_unit
            );
        }
        let stop = Arc::new(AtomicBool::new(false));
        let stopf = Arc::clone(&stop);
        let join = std::thread::Builder::new()
            .name("abc-control".into())
            .spawn(move || run(target.as_ref(), &cfg, &stopf))
            .expect("spawn control loop");
        ControlLoop { stop, join: Some(join) }
    }

    /// Ask the thread to exit and wait for it.  Idempotent.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ControlLoop {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Pre-resolved per-unit gauges (the tick path must not pay a
/// format!/registry lock).
struct UnitGauges {
    ewma: Arc<crate::metrics::Gauge>,
    p99: Arc<crate::metrics::Gauge>,
    lifecycle: Option<LifecycleGauges>,
}

struct LifecycleGauges {
    live: Arc<crate::metrics::Gauge>,
    warming: Arc<crate::metrics::Gauge>,
    draining: Arc<crate::metrics::Gauge>,
    seconds: Arc<crate::metrics::Gauge>,
}

fn run(target: &dyn ControlTarget, cfg: &ControlConfig, stop: &AtomicBool) {
    let n = target.n_units();
    let control = Arc::clone(target.control_metrics());
    let shifts_up = control.counter("gear_shift_up");
    let shifts_down = control.counter("gear_shift_down");
    let scale_ups = control.counter("scale_up_total");
    let scale_downs = control.counter("scale_down_total");
    // registered only when the recalibration loop is armed, so a
    // report-only observatory leaves no dangling zero counter here
    let regrounds = cfg
        .recalibrate
        .then(|| control.counter("drift_reground_total"));
    // the SLO budget coupling: armed only with a boost AND a cap (an
    // uncapped arbiter has nothing to relax).  The boosted config is
    // cloned once here, not per tick.
    let slo_armed = cfg.slo_boost > 1.0 && cfg.max_dollars_per_hour > 0.0;
    let slo_boost_gauge = slo_armed.then(|| control.gauge("slo_boost_active"));
    let boosted_cfg = slo_armed.then(|| {
        let mut c = cfg.clone();
        c.max_dollars_per_hour *= c.slo_boost;
        c
    });
    let mut was_burning = false;
    // single-unit targets keep the legacy gauge names; fleets get
    // tier-prefixed EWMA gauges (their lifecycle gauges come from the
    // fleet's own publish)
    let gauges: Vec<UnitGauges> = (0..n)
        .map(|i| {
            if n == 1 {
                UnitGauges {
                    ewma: control.gauge("arrival_ewma_rps"),
                    p99: control.gauge("latency_p99_s"),
                    lifecycle: Some(LifecycleGauges {
                        live: control.gauge("replicas_live"),
                        warming: control.gauge("replicas_warming"),
                        draining: control.gauge("replicas_draining"),
                        seconds: control.gauge("replica_seconds"),
                    }),
                }
            } else {
                UnitGauges {
                    ewma: control.gauge(&format!("tier_{i}_ewma_rps")),
                    p99: control.gauge(&format!("tier_{i}_p99_s")),
                    lifecycle: None,
                }
            }
        })
        .collect();
    // one gear gauge per ACTUATED unit
    let gear_gauges: Vec<Arc<crate::metrics::Gauge>> = cfg
        .gears
        .iter()
        .map(|g| {
            if n == 1 {
                control.gauge("gear_current")
            } else {
                control.gauge(&format!("tier_{}_gear", g.act_unit))
            }
        })
        .collect();

    let gpus: Vec<_> = (0..n).map(|i| target.unit_gpu(i)).collect();
    let mut samplers: Vec<Sampler> = (0..n)
        .map(|i| Sampler::new(&target.unit_metrics(i)))
        .collect();
    let mut states: Vec<ControlState> = (0..n)
        .map(|i| {
            let start = match cfg.decider_for_obs(i) {
                Some(g) if matches!(g.ladder, GearLadder::Plan(_)) => {
                    target.initial_gear(g.act_unit)
                }
                _ => 0,
            };
            ControlState::new(start, &cfg.ctrl)
        })
        .collect();
    for (g, gauge) in cfg.gears.iter().zip(&gear_gauges) {
        gauge.set(states[g.obs_unit].current() as f64);
    }
    let mut forecasters: Vec<Forecaster> = (0..n)
        .map(|i| {
            let warmup = cfg.units[i]
                .scale
                .map(|s| s.warmup.as_secs_f64())
                .unwrap_or(0.0);
            Forecaster::new(FORECAST_WINDOW, warmup + cfg.ctrl.dwell.as_secs_f64())
        })
        .collect();
    let t0 = Instant::now();

    let mut obs = Vec::with_capacity(n);
    let mut counts = Vec::with_capacity(n);
    let mut forecasts = Vec::with_capacity(n);
    while !stop.load(Ordering::SeqCst) {
        std::thread::sleep(cfg.ctrl.sample_every);
        // lifecycle first: promote warmed replicas / retire drained
        // ones, so this tick's counts and capacities are current
        target.advance(Instant::now());
        obs.clear();
        counts.clear();
        forecasts.clear();
        let mut dt_s = 0.0f64;
        for i in 0..n {
            let (o, dt) = samplers[i].sample(
                target.unit_outstanding(i),
                target.unit_queue_capacity(i),
            );
            obs.push(o);
            counts.push(target.unit_counts(i));
            forecasts.push(if cfg.units[i].scale.is_some() {
                forecasters[i].forecast()
            } else {
                0.0
            });
            dt_s = dt_s.max(dt);
        }
        // -- SLO budget boost (opt-in) -----------------------------------
        // decide this tick under the boosted cap while the premium
        // class's burn alarm is latched Breach; the alarm's hysteresis
        // is the coupling's flap guard, so no extra dwell here
        let burning = boosted_cfg.is_some()
            && target.slo_statuses().is_some_and(|ss| {
                ss.iter().any(|s| {
                    s.class == Class::Premium && s.alarm == AlarmState::Breach
                })
            });
        if let Some(g) = &slo_boost_gauge {
            g.set(if burning { 1.0 } else { 0.0 });
        }
        if burning != was_burning {
            was_burning = burning;
            let live: usize = (0..n).map(|i| target.unit_counts(i).1).sum();
            control.events().record(EventRecord {
                kind: EventKind::Scale,
                decider: "slo",
                trigger: if burning { "breach" } else { "recovered" },
                tier: 0,
                old_gear: 0,
                new_gear: 0,
                old_replicas: live,
                new_replicas: live,
                class: Some(Class::Premium.name()),
            });
        }
        let eff_cfg = match &boosted_cfg {
            Some(b) if burning => b,
            _ => cfg,
        };
        let tick =
            decide_tick(eff_cfg, &mut states, &obs, &counts, &gpus, &forecasts, dt_s);
        let now_s = t0.elapsed().as_secs_f64();
        for i in 0..n {
            forecasters[i].push(now_s, states[i].ewma_rps());
            gauges[i].ewma.set(states[i].ewma_rps());
            if obs[i].p99_s.is_finite() {
                gauges[i].p99.set(obs[i].p99_s);
            }
        }
        for s in &tick.shifts {
            let decider = cfg
                .decider_for_obs(s.obs_unit)
                .expect("shift came from a decider");
            target.set_gear(s.act_unit, &decider.config_at(s.to));
            match s.shift {
                Shift::Up => shifts_up.inc(),
                Shift::Down => shifts_down.inc(),
            }
            let live = target.unit_counts(s.act_unit).1;
            control.events().record(EventRecord {
                kind: EventKind::Shift,
                decider: "gear",
                trigger: s.trigger.name(),
                tier: s.act_unit,
                old_gear: s.from,
                new_gear: s.to,
                old_replicas: live,
                new_replicas: live,
                class: None,
            });
        }
        for (gi, g) in cfg.gears.iter().enumerate() {
            gear_gauges[gi].set(states[g.obs_unit].current() as f64);
        }
        for a in &tick.scales {
            if a.target > a.fleet {
                let warmup = cfg.units[a.unit]
                    .scale
                    .map(|s| s.warmup)
                    .unwrap_or_default();
                target.scale_up(a.unit, a.target - a.fleet, warmup);
                scale_ups.inc();
            } else {
                target.drain(a.unit, a.live - a.target);
                scale_downs.inc();
            }
            let rung = states[a.unit].current();
            control.events().record(EventRecord {
                kind: EventKind::Scale,
                decider: a.decider,
                trigger: a.trigger.name(),
                tier: a.unit,
                old_gear: rung,
                new_gear: rung,
                old_replicas: a.fleet,
                new_replicas: a.target,
                class: None,
            });
        }
        // -- drift recalibration (opt-in) --------------------------------
        // re-ground a tier's serving theta from the observatory's live
        // estimate when its alarm has latched Breach.  Deliberately
        // outside the BudgetArbiter and the per-unit dwell clocks: a
        // reground changes accuracy, not capacity or spend, and the
        // alarm's own hysteresis streak already is its dwell.
        if let Some(regrounds) = &regrounds {
            for i in 0..n {
                let breached = target
                    .drift_status(i)
                    .is_some_and(|s| DriftDecider::should_reground(&s));
                if !breached {
                    continue;
                }
                if target.reground_theta(i).is_some() {
                    regrounds.inc();
                    let rung = states[i].current();
                    let live = target.unit_counts(i).1;
                    control.events().record(EventRecord {
                        kind: EventKind::Shift,
                        decider: "drift",
                        trigger: "breach",
                        tier: i,
                        old_gear: rung,
                        new_gear: rung,
                        old_replicas: live,
                        new_replicas: live,
                        class: None,
                    });
                }
            }
        }
        // lifecycle + rental telemetry every tick
        for (i, g) in gauges.iter().enumerate() {
            if let Some(l) = &g.lifecycle {
                let (warming, live, draining) = target.unit_counts(i);
                l.live.set(live as f64);
                l.warming.set(warming as f64);
                l.draining.set(draining as f64);
                l.seconds.set(target.unit_replica_seconds(i));
            }
        }
        target.publish();
    }
}
