//! The control plane's pure decision state: one EWMA + dwell clock +
//! ladder position per controlled unit.
//!
//! [`ControlState`] is a pure function of (configuration, observation,
//! elapsed time) -- no clocks, no metrics, no locks -- so every decider
//! in the stack (`control::decider`) is unit-testable without threads.
//! Two ladder-walking styles share it:
//!
//! * [`ControlState::step_fleet`] walks a [`GearPlan`] whose rungs quote
//!   real capacities: rate-driven downshifts jump straight to the most
//!   accurate gear that sustains the EWMA (one dwell per rung would
//!   crawl through a deep burst), upshifts project the next gear up
//!   against the stricter watermark;
//! * [`ControlState::step_watermark`] walks a ladder whose rungs do NOT
//!   change the observed unit's own capacity (per-tier theta rungs: a
//!   lower theta thins the *downstream* arrival stream, not this
//!   pool's).  It steps one rung per dwell on the same watermark
//!   triggers -- there is no capacity model to jump by.
//!
//! Both styles fold the observation through [`ControlState::observe`]
//! exactly once per tick and share the dwell clock with scale decisions
//! ([`ControlState::dwell_ok`] / [`ControlState::note_action`]), so a
//! gear shift and a fleet resize can never thrash against each other.

use std::time::Duration;

use crate::planner::gear::GearPlan;

/// Watermarks + pacing for the control plane.
#[derive(Debug, Clone, Copy)]
pub struct ControllerConfig {
    /// Metrics sampling period.
    pub sample_every: Duration,
    /// Minimum time between actions per unit (hysteresis dwell).
    pub dwell: Duration,
    /// Downshift when `ewma_rps / capacity` exceeds this.
    pub down_util: f64,
    /// Upshift only when the next gear up would still sit below this
    /// (must be < `down_util` for hysteresis).
    pub up_util: f64,
    /// Downshift when outstanding work exceeds this fraction of the
    /// unit's total admission capacity; upshifts require calm queues
    /// (below half of it).
    pub queue_pressure: f64,
    /// Optional p99 SLO in seconds; breaching it forces a downshift
    /// (0 disables).
    pub p99_slo_s: f64,
    /// Per-sample EWMA smoothing factor in (0, 1].
    pub ewma_alpha: f64,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig {
            sample_every: Duration::from_millis(20),
            dwell: Duration::from_millis(250),
            down_util: crate::types::UTIL_HIGH_WATERMARK,
            up_util: crate::types::UTIL_LOW_WATERMARK,
            queue_pressure: 0.50,
            p99_slo_s: 0.0,
            ewma_alpha: 0.30,
        }
    }
}

/// One metrics sample the state machine consumes.
#[derive(Debug, Clone, Copy)]
pub struct Observation {
    /// Instantaneous arrival rate over the last sample period, req/s
    /// (admitted + shed: offered load, not goodput).
    pub arrival_rps: f64,
    /// Outstanding work / unit admission capacity, in [0, 1].
    pub outstanding_frac: f64,
    /// Request latency p99 over the last sample window only, seconds
    /// (NaN when the window holds no samples -- never triggers the SLO).
    pub p99_s: f64,
}

/// Direction of a gear shift.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Shift {
    /// Toward accuracy (lower ladder index).
    Up,
    /// Toward throughput (higher ladder index).
    Down,
}

/// What forced a controller decision (event-log attribution).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Trigger {
    /// Arrival-rate EWMA crossed a utilisation watermark.
    Rate,
    /// Outstanding work crossed the queue-pressure watermark.
    Pressure,
    /// The windowed p99 breached the SLO.
    Slo,
}

impl Trigger {
    pub fn name(&self) -> &'static str {
        match self {
            Trigger::Rate => "rate",
            Trigger::Pressure => "pressure",
            Trigger::Slo => "slo",
        }
    }
}

/// One unit's pure decision state (EWMA, dwell clock, ladder rung).
#[derive(Debug, Clone)]
pub struct ControlState {
    current: usize,
    ewma_rps: f64,
    since_shift_s: f64,
}

impl ControlState {
    /// Start at ladder rung `current` (usually the top, index 0).  The
    /// dwell clock starts satisfied so a controller dropped into an
    /// already-overloaded system reacts on the first sample.
    pub fn new(current: usize, cfg: &ControllerConfig) -> ControlState {
        ControlState {
            current,
            ewma_rps: 0.0,
            since_shift_s: cfg.dwell.as_secs_f64(),
        }
    }

    pub fn current(&self) -> usize {
        self.current
    }

    pub fn ewma_rps(&self) -> f64 {
        self.ewma_rps
    }

    /// Fold one observation over `dt_s` seconds into the EWMA and
    /// advance the dwell clock, WITHOUT deciding anything.  The decider
    /// stack calls this exactly once per unit per tick: through
    /// [`ControlState::step_fleet`] / [`ControlState::step_watermark`]
    /// when the unit has a gear decider, directly when it only scales.
    pub fn observe(&mut self, cfg: &ControllerConfig, obs: Observation, dt_s: f64) {
        self.ewma_rps =
            cfg.ewma_alpha * obs.arrival_rps + (1.0 - cfg.ewma_alpha) * self.ewma_rps;
        self.since_shift_s += dt_s.max(0.0);
    }

    /// Whether the shared dwell clock permits another action.  The
    /// scale decider consults this before a resize so gear shifts and
    /// scale decisions share one hysteresis clock.
    pub fn dwell_ok(&self, cfg: &ControllerConfig) -> bool {
        self.since_shift_s >= cfg.dwell.as_secs_f64()
    }

    /// Reset the shared dwell clock (a scale action counts like a
    /// shift: both are capacity decisions and must not thrash).
    pub fn note_action(&mut self) {
        self.since_shift_s = 0.0;
    }

    /// Fold in one observation over `dt_s` seconds; returns the shift to
    /// apply, if any.  Pure: no clocks, no metrics, no locks.
    pub fn step(
        &mut self,
        plan: &GearPlan,
        cfg: &ControllerConfig,
        obs: Observation,
        dt_s: f64,
    ) -> Option<Shift> {
        self.step_fleet(plan, cfg, obs, dt_s, None).map(|(s, _)| s)
    }

    /// [`ControlState::step`] with fleet-aware capacity and trigger
    /// attribution.  With `fleet = Some(n)` every gear's capacity is
    /// evaluated at `n` replicas (`per_replica_rps * n`) instead of its
    /// planned allocation -- the control plane passes the *attainable*
    /// fleet (max replicas, clamped to what the dollar budget affords)
    /// so rate-driven downshifts fire only when even the fleet it could
    /// actually rent cannot sustain the load (renting machines is tried
    /// before trading accuracy; see `control::decider`).
    pub fn step_fleet(
        &mut self,
        plan: &GearPlan,
        cfg: &ControllerConfig,
        obs: Observation,
        dt_s: f64,
        fleet: Option<usize>,
    ) -> Option<(Shift, Trigger)> {
        self.observe(cfg, obs, dt_s);
        if self.since_shift_s < cfg.dwell.as_secs_f64() {
            return None;
        }
        let capacity = |idx: usize| {
            let g = &plan.gears[idx];
            match fleet {
                Some(n) => g.per_replica_rps() * n as f64,
                None => g.sustainable_rps,
            }
        };
        let util = self.ewma_rps / capacity(self.current).max(1e-9);
        let slo_breached = cfg.p99_slo_s > 0.0 && obs.p99_s > cfg.p99_slo_s;
        if (util > cfg.down_util
            || obs.outstanding_frac > cfg.queue_pressure
            || slo_breached)
            && self.current + 1 < plan.len()
        {
            // rate-driven overload jumps straight to the most accurate
            // gear that sustains the EWMA at the downshift watermark
            // (one dwell per rung would crawl through a deep burst);
            // pressure/SLO-driven shifts without rate evidence step one.
            // The rung is chosen at the SAME capacity basis as the
            // trigger (fleet-scaled when `fleet` is set): judging the
            // jump by the plan's smaller per-allocation quotes would
            // overshoot to the bottom of the ladder when one rung down
            // at the full fleet already sustains the load.
            let target = (0..plan.len())
                .find(|&i| self.ewma_rps <= capacity(i) * cfg.down_util)
                .unwrap_or(plan.len() - 1);
            self.current = target.clamp(self.current + 1, plan.len() - 1);
            self.since_shift_s = 0.0;
            let trigger = if util > cfg.down_util {
                Trigger::Rate
            } else if slo_breached {
                Trigger::Slo
            } else {
                Trigger::Pressure
            };
            return Some((Shift::Down, trigger));
        }
        if self.current > 0 {
            let projected = self.ewma_rps / capacity(self.current - 1).max(1e-9);
            if projected < cfg.up_util
                && obs.outstanding_frac < cfg.queue_pressure / 2.0
                && !slo_breached
            {
                self.current -= 1;
                self.since_shift_s = 0.0;
                return Some((Shift::Up, Trigger::Rate));
            }
        }
        None
    }

    /// Walk a ladder whose rungs leave the observed unit's own capacity
    /// unchanged (per-tier theta rungs): one rung per dwell, same
    /// watermark triggers as [`ControlState::step_fleet`], judged
    /// against the fixed `capacity_rps`.  There is no per-rung capacity
    /// model to jump by, so deep overloads descend one dwell at a time;
    /// the dwell clock bounds the rung-oscillation a theta shift's own
    /// arrival-thinning can otherwise cause.
    pub fn step_watermark(
        &mut self,
        cfg: &ControllerConfig,
        obs: Observation,
        dt_s: f64,
        capacity_rps: f64,
        ladder_len: usize,
    ) -> Option<(Shift, Trigger)> {
        self.observe(cfg, obs, dt_s);
        if self.since_shift_s < cfg.dwell.as_secs_f64() {
            return None;
        }
        let util = self.ewma_rps / capacity_rps.max(1e-9);
        let slo_breached = cfg.p99_slo_s > 0.0 && obs.p99_s > cfg.p99_slo_s;
        if (util > cfg.down_util
            || obs.outstanding_frac > cfg.queue_pressure
            || slo_breached)
            && self.current + 1 < ladder_len
        {
            self.current += 1;
            self.since_shift_s = 0.0;
            let trigger = if util > cfg.down_util {
                Trigger::Rate
            } else if slo_breached {
                Trigger::Slo
            } else {
                Trigger::Pressure
            };
            return Some((Shift::Down, trigger));
        }
        if self.current > 0
            && util < cfg.up_util
            && obs.outstanding_frac < cfg.queue_pressure / 2.0
            && !slo_breached
        {
            self.current -= 1;
            self.since_shift_s = 0.0;
            return Some((Shift::Up, Trigger::Rate));
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::gear::Gear;

    fn plan3() -> GearPlan {
        let gear = |acc: f64, rps: f64| Gear {
            id: 0,
            k: 3,
            epsilon: 0.03,
            theta: 0.6,
            mid: vec![],
            max_batch: 8,
            replicas: 1,
            tier_fleet: vec![],
            dollar_per_req: 0.0,
            accuracy: acc,
            relative_cost: 1.0,
            sustainable_rps: rps,
        };
        GearPlan::new(vec![
            gear(0.95, 1000.0),
            gear(0.90, 2000.0),
            gear(0.80, 4000.0),
        ])
        .unwrap()
    }

    fn cfg() -> ControllerConfig {
        ControllerConfig {
            dwell: Duration::from_millis(100),
            ewma_alpha: 1.0, // no smoothing: tests reason about exact rates
            ..ControllerConfig::default()
        }
    }

    fn obs(rps: f64) -> Observation {
        Observation { arrival_rps: rps, outstanding_frac: 0.0, p99_s: f64::NAN }
    }

    #[test]
    fn overload_shifts_down_until_sustainable() {
        let plan = plan3();
        let cfg = cfg();
        let mut s = ControlState::new(0, &cfg);
        // 1500 rps >> gear 0's 850 effective (0.85 * 1000): down
        assert_eq!(s.step(&plan, &cfg, obs(1500.0), 0.02), Some(Shift::Down));
        assert_eq!(s.current(), 1);
        // dwell blocks an immediate second shift
        assert_eq!(s.step(&plan, &cfg, obs(1500.0), 0.02), None);
        // after the dwell expires: 1500 < 0.85 * 2000 so no downshift, and
        // gear 0 would run at 1.5 > up_util so no upshift -- stable
        assert_eq!(s.step(&plan, &cfg, obs(1500.0), 0.2), None);
        assert_eq!(s.current(), 1);
    }

    #[test]
    fn deep_overload_jumps_straight_to_the_fastest_gear_and_stops() {
        let plan = plan3();
        let cfg = cfg();
        let mut s = ControlState::new(0, &cfg);
        // 9000 rps exceeds every gear: one decision reaches the bottom
        // of the ladder instead of crawling one dwell per rung
        assert_eq!(s.step(&plan, &cfg, obs(9000.0), 0.2), Some(Shift::Down));
        assert_eq!(s.current(), plan.len() - 1, "bottom of the ladder");
        // and never indexes past the end
        assert_eq!(s.step(&plan, &cfg, obs(90_000.0), 0.2), None);
        // a moderate overload from the top lands on the matching middle
        // gear, not the bottom: 1500 <= 0.85 * 2000
        let mut s = ControlState::new(0, &cfg);
        assert_eq!(s.step(&plan, &cfg, obs(1500.0), 0.2), Some(Shift::Down));
        assert_eq!(s.current(), 1);
    }

    #[test]
    fn calm_load_shifts_back_up_with_hysteresis() {
        let plan = plan3();
        let cfg = cfg();
        let mut s = ControlState::new(2, &cfg);
        // 1500 rps: gear 1 (2000 rps) would run at 0.75 > up_util 0.6 ->
        // stay despite being < down_util on the current gear
        assert_eq!(s.step(&plan, &cfg, obs(1500.0), 0.2), None);
        assert_eq!(s.current(), 2);
        // 500 rps: gear 1 would run at 0.25 < 0.6 -> up
        assert_eq!(s.step(&plan, &cfg, obs(500.0), 0.2), Some(Shift::Up));
        assert_eq!(s.current(), 1);
        // and further up once the dwell passes
        assert_eq!(s.step(&plan, &cfg, obs(500.0), 0.2), Some(Shift::Up));
        assert_eq!(s.current(), 0);
        // at the top there is no further up
        assert_eq!(s.step(&plan, &cfg, obs(1.0), 0.2), None);
    }

    #[test]
    fn queue_pressure_forces_downshift_even_at_low_ewma() {
        let plan = plan3();
        let cfg = cfg();
        let mut s = ControlState::new(0, &cfg);
        let pressured =
            Observation { arrival_rps: 10.0, outstanding_frac: 0.9, p99_s: f64::NAN };
        assert_eq!(s.step(&plan, &cfg, pressured, 0.2), Some(Shift::Down));
        // busy queues also veto upshifts
        let mut s = ControlState::new(1, &cfg);
        let busyish =
            Observation { arrival_rps: 10.0, outstanding_frac: 0.4, p99_s: f64::NAN };
        assert_eq!(s.step(&plan, &cfg, busyish, 0.2), None);
        assert_eq!(s.current(), 1);
    }

    #[test]
    fn p99_slo_breach_forces_downshift() {
        let plan = plan3();
        let cfg = ControllerConfig { p99_slo_s: 0.050, ..cfg() };
        let mut s = ControlState::new(0, &cfg);
        let slow =
            Observation { arrival_rps: 10.0, outstanding_frac: 0.0, p99_s: 0.200 };
        assert_eq!(s.step(&plan, &cfg, slow, 0.2), Some(Shift::Down));
        // NaN p99 (no samples yet) never triggers
        let mut s = ControlState::new(0, &cfg);
        assert_eq!(s.step(&plan, &cfg, obs(10.0), 0.2), None);
    }

    #[test]
    fn dwell_bounds_shift_rate_under_oscillating_load() {
        let plan = plan3();
        let cfg = cfg();
        let mut s = ControlState::new(0, &cfg);
        let mut shifts = 0;
        // 10 Hz flip-flop between idle and overload for 2 simulated
        // seconds; 100ms dwell caps shifts at ~1 per dwell
        for i in 0..40 {
            let rps = if i % 2 == 0 { 5000.0 } else { 0.0 };
            if s.step(&plan, &cfg, obs(rps), 0.05).is_some() {
                shifts += 1;
            }
        }
        assert!(shifts <= 20, "dwell failed to bound thrash: {shifts} shifts");
        assert!(shifts >= 1, "controller never reacted");
    }

    #[test]
    fn ewma_smooths_a_single_spike_away() {
        let plan = plan3();
        let cfg = ControllerConfig { ewma_alpha: 0.2, ..cfg() };
        let mut s = ControlState::new(0, &cfg);
        // steady calm traffic...
        for _ in 0..5 {
            assert_eq!(s.step(&plan, &cfg, obs(100.0), 0.2), None);
        }
        // ...one wild sample: EWMA only reaches 0.2*5000 + 0.8*~100 ~ 1080,
        // barely over gear 0; with alpha=0.2 a single spike may shift once
        // at most, and calm samples pull it back up
        s.step(&plan, &cfg, obs(5000.0), 0.2);
        for _ in 0..20 {
            s.step(&plan, &cfg, obs(100.0), 0.2);
        }
        assert_eq!(s.current(), 0, "spike left the controller downshifted");
    }

    #[test]
    fn fleet_capacity_suppresses_downshift_until_the_max_fleet_drowns() {
        // plan quotes 1-replica capacities; a 4-replica max fleet
        // quadruples what each gear can carry
        let plan = plan3();
        let cfg = cfg();
        let mut s = ControlState::new(0, &cfg);
        // 1500 rps would downshift at planned capacity (1000), but the
        // max fleet sustains 4000: rent replicas instead of shifting
        assert_eq!(s.step_fleet(&plan, &cfg, obs(1500.0), 0.2, Some(4)), None);
        assert_eq!(s.current(), 0);
        // 5000 rps drowns even 4x gear 0 (3400 effective): shift, with
        // rate attribution
        let got = s.step_fleet(&plan, &cfg, obs(5000.0), 0.2, Some(4));
        assert_eq!(got, Some((Shift::Down, Trigger::Rate)));
        // upshift projection is fleet-aware too: back at 1500 rps the
        // 4-replica gear 0 runs at 0.375 < up_util -> up
        let got = s.step_fleet(&plan, &cfg, obs(1500.0), 0.2, Some(4));
        assert_eq!(got, Some((Shift::Up, Trigger::Rate)));
    }

    #[test]
    fn triggers_attribute_the_cause() {
        let plan = plan3();
        let base = cfg();
        let cfg = ControllerConfig { p99_slo_s: 0.050, ..base };
        // pure pressure (rate calm, p99 fine)
        let mut s = ControlState::new(0, &cfg);
        let pressured =
            Observation { arrival_rps: 10.0, outstanding_frac: 0.9, p99_s: f64::NAN };
        assert_eq!(
            s.step_fleet(&plan, &cfg, pressured, 0.2, None),
            Some((Shift::Down, Trigger::Pressure))
        );
        // pure SLO breach
        let mut s = ControlState::new(0, &cfg);
        let slow =
            Observation { arrival_rps: 10.0, outstanding_frac: 0.0, p99_s: 0.2 };
        assert_eq!(
            s.step_fleet(&plan, &cfg, slow, 0.2, None),
            Some((Shift::Down, Trigger::Slo))
        );
        // rate wins attribution when it is the cause
        let mut s = ControlState::new(0, &cfg);
        assert_eq!(
            s.step_fleet(&plan, &cfg, obs(5000.0), 0.2, None),
            Some((Shift::Down, Trigger::Rate))
        );
    }

    #[test]
    fn shared_dwell_clock_blocks_and_resets() {
        let plan = plan3();
        let cfg = cfg();
        let mut s = ControlState::new(0, &cfg);
        assert!(s.dwell_ok(&cfg), "dwell starts satisfied");
        // a scale action consumes the dwell...
        s.note_action();
        assert!(!s.dwell_ok(&cfg));
        // ...and blocks gear shifts until it expires
        assert_eq!(s.step(&plan, &cfg, obs(5000.0), 0.02), None);
        assert_eq!(s.step(&plan, &cfg, obs(5000.0), 0.2), Some(Shift::Down));
    }

    #[test]
    fn watermark_ladder_steps_one_rung_per_dwell() {
        let cfg = cfg();
        let mut s = ControlState::new(0, &cfg);
        // capacity 1000 rps, ladder of 3 rungs: 2000 rps overloads
        let got = s.step_watermark(&cfg, obs(2000.0), 0.2, 1000.0, 3);
        assert_eq!(got, Some((Shift::Down, Trigger::Rate)));
        assert_eq!(s.current(), 1);
        // dwell blocks the next rung...
        assert_eq!(s.step_watermark(&cfg, obs(2000.0), 0.02, 1000.0, 3), None);
        // ...then it descends again, and stops at the bottom
        let got = s.step_watermark(&cfg, obs(2000.0), 0.2, 1000.0, 3);
        assert_eq!(got, Some((Shift::Down, Trigger::Rate)));
        assert_eq!(s.current(), 2);
        assert_eq!(s.step_watermark(&cfg, obs(2000.0), 0.2, 1000.0, 3), None);
        // calm load climbs back one rung per dwell
        let got = s.step_watermark(&cfg, obs(100.0), 0.2, 1000.0, 3);
        assert_eq!(got, Some((Shift::Up, Trigger::Rate)));
        let got = s.step_watermark(&cfg, obs(100.0), 0.2, 1000.0, 3);
        assert_eq!(got, Some((Shift::Up, Trigger::Rate)));
        assert_eq!(s.current(), 0);
        assert_eq!(s.step_watermark(&cfg, obs(100.0), 0.2, 1000.0, 3), None);
    }

    #[test]
    fn watermark_ladder_hysteresis_band_holds() {
        let cfg = cfg();
        let mut s = ControlState::new(1, &cfg);
        // 700 rps on 1000 capacity: util 0.7 sits between up (0.6) and
        // down (0.85) watermarks -- no shift either way
        for _ in 0..5 {
            assert_eq!(s.step_watermark(&cfg, obs(700.0), 0.2, 1000.0, 3), None);
        }
        assert_eq!(s.current(), 1);
        // pressure forces a downshift even at calm rate, busy queues
        // veto the upshift
        let jam =
            Observation { arrival_rps: 10.0, outstanding_frac: 0.9, p99_s: f64::NAN };
        assert_eq!(
            s.step_watermark(&cfg, jam, 0.2, 1000.0, 3),
            Some((Shift::Down, Trigger::Pressure))
        );
        let busyish =
            Observation { arrival_rps: 10.0, outstanding_frac: 0.4, p99_s: f64::NAN };
        assert_eq!(s.step_watermark(&cfg, busyish, 0.2, 1000.0, 3), None);
        assert_eq!(s.current(), 2);
    }
}
