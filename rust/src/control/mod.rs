//! The unified control plane: one decider loop for gears + scaling.
//!
//! PR 2-4 grew three divergent control threads -- the gear controller
//! (`planner::controller`), the gear-coupled monolithic autoscaler
//! (`autoscale::autoscaler`) and the per-tier fleet autoscaler
//! (`autoscale::tiered`) -- each with its own copy of the sampler,
//! EWMA, dwell and watermark logic.  CascadeServe's result (and the
//! paper's 3x rental-cost claim) depends on adapting cascade
//! configuration and fleet size *jointly*, in a single coordinated
//! loop; this module is that loop:
//!
//! * [`target`] -- [`ControlTarget`]: the per-registry observation +
//!   actuation surface a backend exposes.  A monolithic `ReplicaPool`
//!   is one unit; a `TieredFleet` is one unit per cascade level (tier
//!   N's arrivals = tier N-1's deferrals);
//! * [`sampler`] -- [`Sampler`]: counter/bucket deltas -> one
//!   [`Observation`] per unit per tick (windowed p99, so past
//!   overloads never latch the SLO);
//! * [`state`] -- [`ControlState`]: the pure EWMA + dwell + ladder-rung
//!   state machine both ladder styles walk;
//! * [`decider`] -- the pure per-tick stack: [`GearDecider`] (plan
//!   ladders for monolithic pools, per-tier theta rungs for fleets),
//!   the scale decider ([`ScaleConfig`] sizing with the queue-pressure
//!   kicker), and the [`BudgetArbiter`] reconciling both under
//!   `--max-dollars-hour` (rent before trading accuracy; grant
//!   cheapest-tier-first; trade accuracy exactly where renting stops
//!   being affordable);
//! * [`forecast`] -- [`Forecaster`]: linear trend over the EWMA window
//!   consulted by the scale decider for predictive warm-up;
//! * [`plane`] -- [`ControlLoop`]: the ONE thread per serve process
//!   that samples, ticks the stack, and actuates (and, with
//!   `--recalibrate` armed, runs the [`DriftDecider`]: a tier whose
//!   drift alarm latched Breach gets its serving theta re-grounded
//!   from the drift observatory's live windowed estimate).
//!
//! **Per-tier gear shifting** (new with this module): each tier of a
//! tiered fleet carries a ladder of theta rungs actuated through
//! `TieredFleet::set_tier_gear`.  The rungs of tier N are walked by the
//! decider observing tier N+1's pool -- lowering tier N's theta exits
//! more requests locally, thinning exactly the deferral stream that
//! drowns the (more expensive) tier below.  The fleet-level hysteresis
//! guard: gear and scale share one dwell clock per unit, and a theta
//! shift consumes the OBSERVING tier's dwell -- the tier whose arrival
//! stream the shift just thinned can neither re-shift nor resize on
//! pre-shift numbers, so adjacent tiers cannot oscillate against each
//! other (the actuated tier is deliberately not blocked: its own
//! arrivals are unchanged by its theta).
//!
//! Entry points: `repro serve --plan` (gear-only), `repro serve
//! --autoscale` (gears + elasticity; synthesizes a one-gear plan from
//! `--top-rps` when no plan is given), `repro serve --tiered
//! --autoscale` (per-tier scaling + gear shifting + budget), and the
//! integration suites `rust/tests/planner_integration.rs`,
//! `rust/tests/autoscale_integration.rs`,
//! `rust/tests/tiered_integration.rs`.

pub mod decider;
pub mod forecast;
pub mod plane;
pub mod sampler;
pub mod scale;
pub mod state;
pub mod target;

pub use decider::{
    decide_tick, BudgetArbiter, ControlConfig, DriftDecider, GearDecider,
    GearLadder, ScaleAction, ShiftAction, Tick, TierControl, TierRung,
    UnitControl,
};
pub use forecast::Forecaster;
pub use plane::ControlLoop;
pub use sampler::Sampler;
pub use scale::ScaleConfig;
pub use state::{ControlState, ControllerConfig, Observation, Shift, Trigger};
pub use target::ControlTarget;
