//! Line-delimited-JSON TCP serving front end over a [`ReplicaPool`].
//!
//! Protocol (one JSON object per line, both directions):
//!
//! ```text
//! -> {"id": 1, "features": [0.1, -0.2, ...]}
//! <- {"id": 1, "prediction": 3, "exit_tier": 1, "latency_s": 0.0021,
//!     "scores": [0.67]}
//! -> {"cmd": "metrics"}
//! <- {"metrics": {"requests_submitted": "42", ...}}
//! -> {"cmd": "stats"}
//! <- {"stats": {"counters": {...}, "gauges": {...},
//!     "histograms": {"request_latency_s": {"n":..,"p99":..}, ...}}}
//! -> {"cmd": "events"}
//! <- {"events": [{"seq":0,"ts_s":...,"kind":"shift","decider":"gear",
//!     "trigger":"rate","tier":0,"old_gear":0,"new_gear":1,
//!     "old_replicas":2,"new_replicas":2},
//!     ...], "dropped": 0}          (control-plane decisions)
//! -> {"cmd": "prom"}
//! <- {"prom": "# TYPE requests_submitted counter\n..."}
//!                                  (Prometheus text exposition as one
//!                                   JSON string field)
//! -> {"cmd": "traces"}
//! <- {"traces": [{"request_id": 42, "spans":
//!     [{"kind":"enqueue","tier":0,"ts_s":...,"dur_s":0},
//!      {"kind":"queue_wait","tier":0,...}, ...]}, ...],
//!     "spans": 97, "dropped": 0, "sample_every": 100}
//!                                  (retained sampled trace spans,
//!                                   grouped per request; empty with
//!                                   the same shape when tracing is
//!                                   off -- see `serve --trace-sample`)
//! -> {"cmd": "drift"}
//! <- {"drift": {"tiers": [{"tier":0,"alarm":"ok","samples":412,
//!     "window":412,"agreement_live":0.97,"failure_rate":0.03,
//!     "epsilon":0.05,"theta_live":0.31,"theta_cal":0.35}, ...],
//!     "sample_every": 100, "regrounds": 0}}
//!                                  (the drift observatory: per-tier
//!                                   live agreement vs the calibrated
//!                                   theta; empty with the same shape
//!                                   when shadow sampling is off -- see
//!                                   `serve --tiered --shadow-sample`)
//! -> {"cmd": "slo"}
//! <- {"slo": {"classes": [{"class":"premium","target_s":0.05,
//!     "submitted":40,"completed":38,"shed":2,"deferred":11,
//!     "in_slo":37,"attainment":0.925,"p99_s":0.021,
//!     "goodput_rps":12.5,"fast_burn":1.5,"slow_burn":0.9,
//!     "alarm":"ok"}, ...], "goal": 0.95}}
//!                                  (the SLO observatory: per-class
//!                                   ledgers, windowed p99/goodput and
//!                                   burn-rate alarms; empty with the
//!                                   same shape when no observatory is
//!                                   attached -- see `serve --slo-goal`)
//! -> {"cmd": "shutdown"}           (stops accepting; drains in-flight)
//! ```
//!
//! Infer lines MAY carry an SLO class tag
//! (`{"id":1,"features":[...],"class":"premium"}`); untagged lines
//! default to `standard`, keeping the pre-class wire shape
//! byte-compatible.
//!
//! When the pool serves under a gear plan (`serve --plan`), verdict
//! replies additionally carry `"gear": <ladder index>` -- the gear
//! active at reply time -- and the `stats` gauges include
//! `gear_current` / `arrival_ewma_rps` from the controller.
//!
//! When serving a tiered fleet (`serve --tiered`; see
//! `coordinator::router`), the `stats` gauges include per-tier queue
//! depth (`tier_{i}_outstanding`), live replicas (`tier_{i}_live`),
//! exit fractions (`tier_{i}_exit_frac`) and the fleet rental bill
//! (`fleet_dollars`, `fleet_dollars_per_hour`), refreshed at snapshot
//! time; `events` carries the control plane's per-tier shift and scale
//! decisions (decider + tier index on every entry).
//!
//! When every replica's bounded queue is full, admission control sheds
//! the request instead of queueing it; the reply is the typed
//! `Overloaded` verdict:
//!
//! ```text
//! <- {"error": "overloaded", "overloaded": true,
//!     "outstanding": 128, "limit": 128}
//! ```
//!
//! `outstanding` is the pool-wide in-flight count at shed time and
//! `limit` is `replicas * max_queue`.  Load-aware clients key on
//! `"overloaded": true` and back off; naive clients still see an
//! `"error"` field.  Other request failures keep the plain
//! `{"error": msg}` shape.
//!
//! Two frontends serve this wire protocol, selected by
//! `serve --frontend reactor|threads` (no hyper/tokio offline):
//!
//! * **reactor** (default): N event-loop shards (`serve --shards`,
//!   default `min(4, cores/2)`) multiplex every connection over
//!   nonblocking sockets -- raw `epoll` on Linux, portable `poll(2)`
//!   elsewhere -- while one shared worker pool sized to cores runs
//!   parse/infer/render (see the `reactor` module and DESIGN.md
//!   §15-§16).  Shard 0 accepts and hands each socket to the
//!   least-loaded shard.  Per-connection state machines frame lines out
//!   of a read scratch and sequence replies back into dispatch order,
//!   so pipelined clients see FIFO answers; replies travel in pooled
//!   buffers and drain via `writev(2)`.  Backpressure: when a write
//!   queue tops its cap, in-flight lines top the limit, or admission
//!   control sheds, the reactor stops polling that socket for
//!   readability and overload propagates to the client's TCP window
//!   instead of unbounded server memory.
//! * **threads**: the original thread-per-connection blocking path,
//!   kept behind the flag for differential testing.  Handlers read
//!   with a short socket timeout and re-check the shared stop flag
//!   between reads.
//!
//! Both frontends answer through the same `dispatch_line_into`, and hot
//! infer lines decode through the lazy `JsonScan` fast path (no JSON
//! tree) with fallback to the full parser, so wire replies are
//! byte-identical across frontends and parse paths -- pinned by
//! differential tests.
//!
//! Shutdown (`{"cmd":"shutdown"}`): both frontends stop accepting,
//! answer every complete line already received -- including lines
//! still sitting in kernel socket buffers at shutdown time -- flush
//! those replies, and join within ~[`READ_POLL`] plus in-flight
//! inference time.  (The seed blocked forever in `read_line` here.)

pub mod proto;

#[cfg(unix)]
pub mod conn;
#[cfg(unix)]
pub mod reactor;

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::replica::{PoolError, ReplicaPool};
use crate::coordinator::router::TieredFleet;
use crate::metrics::Metrics;
use crate::obs::{DriftMonitor, SloObservatory, Tracer};
use crate::types::{Class, Request, Verdict};
use proto::{
    render_drift, render_error_into, render_events, render_metrics,
    render_overloaded_into, render_prom_reply, render_slo, render_stats,
    render_traces, render_verdict_into, scan_request_line,
};

/// How long a blocking handler (or the reactor's poller) waits before
/// re-checking for new work / the stop flag.
pub(crate) const READ_POLL: Duration = Duration::from_millis(50);

/// Which serving frontend `serve_with` runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Frontend {
    /// Event-driven: one poller thread + a worker pool sized to cores.
    #[default]
    Reactor,
    /// Thread-per-connection blocking I/O (the pre-reactor frontend).
    Threads,
}

impl Frontend {
    pub fn parse(s: &str) -> Option<Frontend> {
        match s {
            "reactor" => Some(Frontend::Reactor),
            "threads" => Some(Frontend::Threads),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Frontend::Reactor => "reactor",
            Frontend::Threads => "threads",
        }
    }
}

/// What the TCP front end serves over: a monolithic [`ReplicaPool`]
/// (every replica runs the whole cascade) or a [`TieredFleet`] (one
/// pool per cascade level, routed deferral).  The protocol, admission
/// control rendering and stats plumbing are identical either way -- the
/// monolithic pool is just the degenerate one-pool case.
pub trait InferBackend: Send + Sync {
    /// Classify one request, blocking for the verdict.
    fn infer(&self, request: Request) -> Result<Verdict, PoolError>;
    /// The registry `stats` / `metrics` / `events` render from.
    fn metrics(&self) -> &Arc<Metrics>;
    /// Active gear ladder index when serving under a plan (monolithic
    /// geared pools only).
    fn gear_id(&self) -> Option<usize> {
        None
    }
    /// Refresh derived telemetry (gauges) before a snapshot command.
    fn publish(&self) {}
    /// The attached request tracer, when tracing is enabled
    /// (`serve --trace-sample`); `{"cmd":"traces"}` renders from it.
    fn tracer(&self) -> Option<&Arc<Tracer>> {
        None
    }
    /// The attached drift observatory, when shadow sampling is enabled
    /// (`serve --tiered --shadow-sample`); `{"cmd":"drift"}` renders
    /// from it.
    fn drift(&self) -> Option<&Arc<DriftMonitor>> {
        None
    }
    /// The attached SLO observatory, when per-class telemetry is
    /// enabled; `{"cmd":"slo"}` renders from it.
    fn slo(&self) -> Option<&Arc<SloObservatory>> {
        None
    }
}

impl InferBackend for ReplicaPool {
    fn infer(&self, request: Request) -> Result<Verdict, PoolError> {
        ReplicaPool::infer(self, request)
    }

    fn metrics(&self) -> &Arc<Metrics> {
        ReplicaPool::metrics(self)
    }

    fn gear_id(&self) -> Option<usize> {
        self.gear().map(|h| h.gear_id())
    }

    fn publish(&self) {
        if let Some(slo) = ReplicaPool::slo(self) {
            slo.refresh();
        }
    }

    fn tracer(&self) -> Option<&Arc<Tracer>> {
        ReplicaPool::tracer(self)
    }

    fn slo(&self) -> Option<&Arc<SloObservatory>> {
        ReplicaPool::slo(self)
    }
}

impl InferBackend for TieredFleet {
    fn infer(&self, request: Request) -> Result<Verdict, PoolError> {
        TieredFleet::infer(self, request)
    }

    fn metrics(&self) -> &Arc<Metrics> {
        TieredFleet::metrics(self)
    }

    fn publish(&self) {
        self.refresh_gauges();
    }

    fn tracer(&self) -> Option<&Arc<Tracer>> {
        TieredFleet::tracer(self)
    }

    fn drift(&self) -> Option<&Arc<DriftMonitor>> {
        TieredFleet::drift(self)
    }

    fn slo(&self) -> Option<&Arc<SloObservatory>> {
        TieredFleet::slo(self)
    }
}

/// Serve forever (until a client sends `{"cmd": "shutdown"}`) on the
/// default frontend.
pub fn serve(pool: Arc<dyn InferBackend>, port: u16) -> Result<()> {
    serve_with(pool, port, Frontend::default())
}

/// Serve on an explicitly chosen frontend.
pub fn serve_with(
    pool: Arc<dyn InferBackend>,
    port: u16,
    frontend: Frontend,
) -> Result<()> {
    serve_sharded(pool, port, frontend, 0)
}

/// Serve with an explicit reactor shard count (`--shards`).  `shards`
/// 0 auto-sizes to the machine; the threaded frontend ignores it (one
/// thread per connection has no event loop to shard).
pub fn serve_sharded(
    pool: Arc<dyn InferBackend>,
    port: u16,
    frontend: Frontend,
    shards: usize,
) -> Result<()> {
    match frontend {
        Frontend::Reactor => serve_reactor_frontend(pool, port, shards),
        Frontend::Threads => serve_threads(pool, port),
    }
}

#[cfg(unix)]
fn serve_reactor_frontend(
    pool: Arc<dyn InferBackend>,
    port: u16,
    shards: usize,
) -> Result<()> {
    let cfg = reactor::ReactorConfig {
        shards,
        ..Default::default()
    };
    reactor::serve_reactor_with(pool, port, cfg)
}

/// Non-unix builds have no poller; the reactor selection degrades to
/// the portable threaded frontend rather than failing to serve.
#[cfg(not(unix))]
fn serve_reactor_frontend(
    pool: Arc<dyn InferBackend>,
    port: u16,
    _shards: usize,
) -> Result<()> {
    serve_threads(pool, port)
}

/// Side effects of one decoded-and-answered line the frontend must act
/// on (stop serving, apply shed backpressure).  The reply bytes land in
/// the caller's buffer via [`dispatch_line_into`].
pub(crate) struct DispatchFlags {
    /// The line was `{"cmd":"shutdown"}`: stop accepting and drain.
    pub shutdown: bool,
    /// Admission control shed this request (reactor: pause reads until
    /// the connection drains).
    pub shed: bool,
}

/// Decode one trimmed, non-empty line, run it against the backend, and
/// render the reply (no trailing newline) into `out` -- a reusable
/// buffer, so the hot infer path allocates nothing.  Hot infer lines
/// decode through the lazy `JsonScan` path and render through the
/// byte-level `_into` writers; control commands and malformed input
/// take the tree parser / `String` renders (cold).  Both frontends
/// answer through this single function, which is what makes their wire
/// replies byte-identical.
pub(crate) fn dispatch_line_into(
    pool: &dyn InferBackend,
    line: &str,
    out: &mut Vec<u8>,
) -> DispatchFlags {
    let mut shutdown = false;
    let mut shed = false;
    match scan_request_line(line) {
        Ok(proto::Incoming::Infer(request)) => match pool.infer(request) {
            // report the gear active at *reply* time: cheap, and a
            // shift mid-request is visible either way
            Ok(verdict) => render_verdict_into(out, &verdict, pool.gear_id()),
            Err(PoolError::Overloaded { outstanding, limit }) => {
                shed = true;
                render_overloaded_into(out, outstanding, limit);
            }
            Err(e) => render_error_into(out, &e.to_string()),
        },
        Ok(proto::Incoming::Metrics) => {
            pool.publish();
            out.extend_from_slice(render_metrics(pool.metrics()).as_bytes());
        }
        Ok(proto::Incoming::Stats) => {
            pool.publish();
            out.extend_from_slice(render_stats(pool.metrics()).as_bytes());
        }
        Ok(proto::Incoming::Events) => {
            out.extend_from_slice(render_events(pool.metrics()).as_bytes());
        }
        Ok(proto::Incoming::Prom) => {
            pool.publish();
            out.extend_from_slice(render_prom_reply(pool.metrics()).as_bytes());
        }
        Ok(proto::Incoming::Traces) => {
            out.extend_from_slice(render_traces(pool.tracer()).as_bytes());
        }
        Ok(proto::Incoming::Drift) => {
            out.extend_from_slice(render_drift(pool.drift()).as_bytes());
        }
        Ok(proto::Incoming::Slo) => {
            // publish first so the windowed p99/burn gauges in the
            // reply are no staler than one refresh interval
            pool.publish();
            out.extend_from_slice(render_slo(pool.slo()).as_bytes());
        }
        Ok(proto::Incoming::Shutdown) => {
            shutdown = true;
            out.extend_from_slice(br#"{"ok":true,"shutdown":true}"#);
        }
        Err(e) => render_error_into(out, &e),
    }
    DispatchFlags { shutdown, shed }
}

/// The thread-per-connection frontend: blocking sockets, one handler
/// thread per client.
pub fn serve_threads(pool: Arc<dyn InferBackend>, port: u16) -> Result<()> {
    let listener = TcpListener::bind(("127.0.0.1", port))?;
    let stop = Arc::new(AtomicBool::new(false));
    listener.set_nonblocking(true)?;
    let mut handlers = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _addr)) => {
                stream.set_nonblocking(false)?;
                // line-RPC: Nagle + delayed-ACK adds ~40-90ms per turn
                stream.set_nodelay(true)?;
                let pool = Arc::clone(&pool);
                let stop = Arc::clone(&stop);
                handlers.push(std::thread::spawn(move || {
                    let _ = handle_conn(stream, pool, stop);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e.into()),
        }
    }
    for h in handlers {
        let _ = h.join();
    }
    Ok(())
}

/// What one poll of the connection produced.
enum Read1 {
    Line(String),
    Eof,
    /// Timed out with no complete line while the server is stopping.
    Stopping,
}

/// Pull one `\n`-terminated line out of `pending`/the socket, polling the
/// stop flag between short read timeouts.  Partial lines survive timeouts
/// because bytes accumulate in `pending` (a `BufReader::read_line` would
/// discard the partial tail on every timeout).
///
/// On stop, one final short-timeout drain pulls in whatever the kernel
/// has already accepted (`drained` keeps it to one pass), so complete
/// lines received before the shutdown are still answered -- the same
/// guarantee the reactor's drain phase gives.
fn read_line_interruptible(
    stream: &mut TcpStream,
    pending: &mut Vec<u8>,
    stop: &AtomicBool,
    drained: &mut bool,
) -> std::io::Result<Read1> {
    let mut buf = [0u8; 4096];
    loop {
        if let Some(pos) = pending.iter().position(|&b| b == b'\n') {
            let raw: Vec<u8> = pending.drain(..=pos).collect();
            return Ok(Read1::Line(String::from_utf8_lossy(&raw).into_owned()));
        }
        if stop.load(Ordering::SeqCst) {
            if *drained {
                return Ok(Read1::Stopping);
            }
            *drained = true;
            let _ = stream.set_read_timeout(Some(Duration::from_millis(5)));
            loop {
                match stream.read(&mut buf) {
                    Ok(0) => break,
                    Ok(n) => pending.extend_from_slice(&buf[..n]),
                    Err(_) => break, // timeout/would-block/broken: done
                }
            }
            continue; // top of loop re-scans pending for complete lines
        }
        match stream.read(&mut buf) {
            Ok(0) => return Ok(Read1::Eof),
            Ok(n) => pending.extend_from_slice(&buf[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue; // loop re-checks the stop flag
            }
            Err(e) => return Err(e),
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    pool: Arc<dyn InferBackend>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    stream.set_read_timeout(Some(READ_POLL))?;
    let mut reader = stream.try_clone()?;
    let mut writer = stream;
    let mut pending: Vec<u8> = Vec::new();
    let mut drained = false;
    // one reply buffer for the connection's lifetime: the hot infer
    // loop renders into it and never allocates per request
    let mut reply: Vec<u8> = Vec::new();
    loop {
        let line = match read_line_interruptible(
            &mut reader,
            &mut pending,
            &stop,
            &mut drained,
        )? {
            Read1::Line(l) => l,
            Read1::Eof => return Ok(()), // client closed
            Read1::Stopping => return Ok(()),
        };
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        reply.clear();
        let flags = dispatch_line_into(pool.as_ref(), trimmed, &mut reply);
        reply.push(b'\n');
        writer.write_all(&reply)?;
        if flags.shutdown {
            stop.store(true, Ordering::SeqCst);
            return Ok(());
        }
    }
}

/// Parsed reply to one infer line: answered, or shed by admission
/// control.  (Failures surface as `Err` from [`Client::infer_reply`].)
pub enum InferReply {
    Verdict(crate::types::Verdict),
    Overloaded { outstanding: usize, limit: usize },
}

/// Minimal blocking client for tests/examples/loadgen.  This is the
/// single client-side implementation of the wire protocol; extend it
/// rather than hand-building lines elsewhere.
pub struct Client {
    reader: std::io::BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(port: u16) -> Result<Client> {
        let stream = TcpStream::connect(("127.0.0.1", port))?;
        stream.set_nodelay(true)?;
        Ok(Client {
            reader: std::io::BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    pub fn roundtrip(&mut self, line: &str) -> Result<String> {
        use std::io::BufRead;
        writeln!(self.writer, "{line}")?;
        let mut reply = String::new();
        self.reader.read_line(&mut reply)?;
        Ok(reply.trim().to_string())
    }

    /// Send one inference request and parse the reply, surfacing
    /// admission-control sheds as [`InferReply::Overloaded`] rather
    /// than as errors.  Untagged: the server books it as `standard`.
    pub fn infer_reply(&mut self, id: u64, features: &[f32]) -> Result<InferReply> {
        self.infer_reply_class(id, features, None)
    }

    /// [`Client::infer_reply`] with an explicit SLO class tag; `None`
    /// sends the untagged (pre-class) line shape.
    pub fn infer_reply_class(
        &mut self,
        id: u64,
        features: &[f32],
        class: Option<Class>,
    ) -> Result<InferReply> {
        let feats = features
            .iter()
            .map(|f| format!("{f}"))
            .collect::<Vec<_>>()
            .join(",");
        let tag = match class {
            Some(c) => format!(r#","class":"{}""#, c.name()),
            None => String::new(),
        };
        let reply = self
            .roundtrip(&format!(r#"{{"id":{id},"features":[{feats}]{tag}}}"#))?;
        let v = crate::util::json::Json::parse(&reply)
            .map_err(|e| anyhow::anyhow!("bad reply {reply:?}: {e}"))?;
        if v.get("overloaded").as_bool() == Some(true) {
            return Ok(InferReply::Overloaded {
                outstanding: v.get("outstanding").as_usize().unwrap_or(0),
                limit: v.get("limit").as_usize().unwrap_or(0),
            });
        }
        if let Some(err) = v.get("error").as_str() {
            anyhow::bail!("server error: {err}");
        }
        Ok(InferReply::Verdict(crate::types::Verdict {
            request_id: v.get("id").as_u64().unwrap_or(id),
            prediction: v.req_f64("prediction")? as u32,
            exit_tier: v.req_f64("exit_tier")? as usize,
            tier_scores: v
                .get("scores")
                .as_arr()
                .map(|a| {
                    a.iter()
                        .filter_map(|s| s.as_f64())
                        .map(|s| s as f32)
                        .collect()
                })
                .unwrap_or_default(),
            latency_s: v.get("latency_s").as_f64().unwrap_or(0.0),
        }))
    }

    /// Classify one feature vector; returns (prediction, exit_tier).
    /// Overload sheds are reported as errors here; use
    /// [`Client::infer_reply`] to distinguish them.
    pub fn infer(&mut self, id: u64, features: &[f32]) -> Result<(u32, usize)> {
        match self.infer_reply(id, features)? {
            InferReply::Verdict(v) => Ok((v.prediction, v.exit_tier)),
            InferReply::Overloaded { outstanding, limit } => anyhow::bail!(
                "server error: overloaded ({outstanding}/{limit} outstanding)"
            ),
        }
    }

    /// Fetch the controller event log (`{"cmd":"events"}`): gear
    /// shifts + scale actions, oldest first.
    pub fn events(&mut self) -> Result<crate::util::json::Json> {
        let reply = self.roundtrip(r#"{"cmd":"events"}"#)?;
        let v = crate::util::json::Json::parse(&reply)
            .map_err(|e| anyhow::anyhow!("bad events reply {reply:?}: {e}"))?;
        anyhow::ensure!(
            v.get("events").as_arr().is_some(),
            "events reply missing 'events' array: {reply}"
        );
        Ok(v)
    }

    /// Fetch the structured stats snapshot (`{"cmd":"stats"}`).
    pub fn stats(&mut self) -> Result<crate::util::json::Json> {
        let reply = self.roundtrip(r#"{"cmd":"stats"}"#)?;
        let v = crate::util::json::Json::parse(&reply)
            .map_err(|e| anyhow::anyhow!("bad stats reply {reply:?}: {e}"))?;
        anyhow::ensure!(
            v.get("stats").as_obj().is_some(),
            "stats reply missing 'stats' object: {reply}"
        );
        Ok(v)
    }

    /// Fetch the Prometheus text exposition (`{"cmd":"prom"}`): the
    /// decoded multi-line scrape body.
    pub fn prom(&mut self) -> Result<String> {
        let reply = self.roundtrip(r#"{"cmd":"prom"}"#)?;
        let v = crate::util::json::Json::parse(&reply)
            .map_err(|e| anyhow::anyhow!("bad prom reply {reply:?}: {e}"))?;
        v.get("prom")
            .as_str()
            .map(|s| s.to_string())
            .ok_or_else(|| anyhow::anyhow!("prom reply missing 'prom' text: {reply}"))
    }

    /// Fetch the retained trace spans (`{"cmd":"traces"}`), grouped per
    /// request.
    pub fn traces(&mut self) -> Result<crate::util::json::Json> {
        let reply = self.roundtrip(r#"{"cmd":"traces"}"#)?;
        let v = crate::util::json::Json::parse(&reply)
            .map_err(|e| anyhow::anyhow!("bad traces reply {reply:?}: {e}"))?;
        anyhow::ensure!(
            v.get("traces").as_arr().is_some(),
            "traces reply missing 'traces' array: {reply}"
        );
        Ok(v)
    }

    /// Fetch the drift observatory snapshot (`{"cmd":"drift"}`):
    /// per-tier alarm / live agreement / theta statuses.
    pub fn drift(&mut self) -> Result<crate::util::json::Json> {
        let reply = self.roundtrip(r#"{"cmd":"drift"}"#)?;
        let v = crate::util::json::Json::parse(&reply)
            .map_err(|e| anyhow::anyhow!("bad drift reply {reply:?}: {e}"))?;
        anyhow::ensure!(
            v.get("drift").as_obj().is_some(),
            "drift reply missing 'drift' object: {reply}"
        );
        Ok(v)
    }

    /// Fetch the SLO observatory snapshot (`{"cmd":"slo"}`): per-class
    /// ledgers, windowed p99/goodput and burn-rate alarm states.
    pub fn slo(&mut self) -> Result<crate::util::json::Json> {
        let reply = self.roundtrip(r#"{"cmd":"slo"}"#)?;
        let v = crate::util::json::Json::parse(&reply)
            .map_err(|e| anyhow::anyhow!("bad slo reply {reply:?}: {e}"))?;
        anyhow::ensure!(
            v.get("slo").as_obj().is_some(),
            "slo reply missing 'slo' object: {reply}"
        );
        Ok(v)
    }

    pub fn shutdown(&mut self) -> Result<()> {
        let _ = self.roundtrip(r#"{"cmd":"shutdown"}"#)?;
        Ok(())
    }
}
