//! Line-delimited-JSON TCP serving front end.
//!
//! Protocol (one JSON object per line, both directions):
//!
//! ```text
//! -> {"id": 1, "features": [0.1, -0.2, ...]}
//! <- {"id": 1, "prediction": 3, "exit_tier": 1, "latency_s": 0.0021,
//!     "scores": [0.67]}
//! -> {"cmd": "metrics"}
//! <- {"metrics": {"requests_submitted": "42", ...}}
//! -> {"cmd": "shutdown"}           (stops accepting; drains in-flight)
//! ```
//!
//! Built on std TCP + threads (no hyper/tokio offline); each connection
//! gets a handler thread, requests flow through the shared Pipeline's
//! dynamic batcher, so concurrent clients batch together.

pub mod proto;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::pipeline::Pipeline;
use proto::{parse_request_line, render_error, render_metrics, render_verdict};

/// Serve forever (until a client sends `{"cmd": "shutdown"}`).
pub fn serve(pipeline: Arc<Pipeline>, port: u16) -> Result<()> {
    let listener = TcpListener::bind(("127.0.0.1", port))?;
    let stop = Arc::new(AtomicBool::new(false));
    listener.set_nonblocking(true)?;
    let mut handlers = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _addr)) => {
                stream.set_nonblocking(false)?;
                // line-RPC: Nagle + delayed-ACK adds ~40-90ms per turn
                stream.set_nodelay(true)?;
                let pipeline = Arc::clone(&pipeline);
                let stop = Arc::clone(&stop);
                handlers.push(std::thread::spawn(move || {
                    let _ = handle_conn(stream, pipeline, stop);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(e) => return Err(e.into()),
        }
    }
    for h in handlers {
        let _ = h.join();
    }
    Ok(())
}

fn handle_conn(
    stream: TcpStream,
    pipeline: Arc<Pipeline>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        match parse_request_line(trimmed) {
            Ok(proto::Incoming::Infer(request)) => {
                let reply = match pipeline.infer(request) {
                    Ok(verdict) => render_verdict(&verdict),
                    Err(e) => render_error(&format!("{e:#}")),
                };
                writeln!(writer, "{reply}")?;
            }
            Ok(proto::Incoming::Metrics) => {
                writeln!(writer, "{}", render_metrics(pipeline.metrics()))?;
            }
            Ok(proto::Incoming::Shutdown) => {
                stop.store(true, Ordering::SeqCst);
                writeln!(writer, "{}", r#"{"ok":true,"shutdown":true}"#)?;
                return Ok(());
            }
            Err(e) => {
                writeln!(writer, "{}", render_error(&e))?;
            }
        }
    }
}

/// Minimal blocking client for tests/examples.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(port: u16) -> Result<Client> {
        let stream = TcpStream::connect(("127.0.0.1", port))?;
        stream.set_nodelay(true)?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    pub fn roundtrip(&mut self, line: &str) -> Result<String> {
        writeln!(self.writer, "{line}")?;
        let mut reply = String::new();
        self.reader.read_line(&mut reply)?;
        Ok(reply.trim().to_string())
    }

    /// Classify one feature vector; returns (prediction, exit_tier).
    pub fn infer(&mut self, id: u64, features: &[f32]) -> Result<(u32, usize)> {
        let feats = features
            .iter()
            .map(|f| format!("{f}"))
            .collect::<Vec<_>>()
            .join(",");
        let reply =
            self.roundtrip(&format!(r#"{{"id":{id},"features":[{feats}]}}"#))?;
        let v = crate::util::json::Json::parse(&reply)
            .map_err(|e| anyhow::anyhow!("bad reply {reply:?}: {e}"))?;
        if let Some(err) = v.get("error").as_str() {
            anyhow::bail!("server error: {err}");
        }
        Ok((
            v.req_f64("prediction")? as u32,
            v.req_f64("exit_tier")? as usize,
        ))
    }

    pub fn shutdown(&mut self) -> Result<()> {
        let _ = self.roundtrip(r#"{"cmd":"shutdown"}"#)?;
        Ok(())
    }
}
