//! Wire protocol: line-JSON encode/decode for the serving front end.

use std::sync::Arc;

use crate::metrics::Metrics;
use crate::obs::{DriftMonitor, SloObservatory, Tracer};
use crate::types::{Class, Request, Verdict};
use crate::util::json::{
    write_num_bytes, write_str_bytes, Json, JsonObj, JsonScan,
};

/// A parsed inbound line.
#[derive(Debug)]
pub enum Incoming {
    Infer(Request),
    Metrics,
    Stats,
    Events,
    Prom,
    Traces,
    Drift,
    Slo,
    Shutdown,
}

/// Parse one request line.
pub fn parse_request_line(line: &str) -> Result<Incoming, String> {
    let v = Json::parse(line).map_err(|e| format!("bad json: {e}"))?;
    if let Some(cmd) = v.get("cmd").as_str() {
        return match cmd {
            "metrics" => Ok(Incoming::Metrics),
            "stats" => Ok(Incoming::Stats),
            "events" => Ok(Incoming::Events),
            "prom" => Ok(Incoming::Prom),
            "traces" => Ok(Incoming::Traces),
            "drift" => Ok(Incoming::Drift),
            "slo" => Ok(Incoming::Slo),
            "shutdown" => Ok(Incoming::Shutdown),
            other => Err(format!("unknown cmd {other:?}")),
        };
    }
    let id = v
        .get("id")
        .as_u64()
        .ok_or_else(|| "missing numeric 'id'".to_string())?;
    let features: Vec<f32> = v
        .get("features")
        .as_arr()
        .ok_or_else(|| "missing 'features' array".to_string())?
        .iter()
        .map(|x| x.as_f64().map(|f| f as f32))
        .collect::<Option<Vec<f32>>>()
        .ok_or_else(|| "non-numeric feature".to_string())?;
    if features.is_empty() {
        return Err("empty features".to_string());
    }
    // optional SLO class tag; absent keeps the pre-class wire shape
    // (and the Standard default) byte-compatible
    let class = match v.get("class") {
        Json::Null => Class::Standard,
        j => {
            let s = j.as_str().ok_or_else(|| "non-string 'class'".to_string())?;
            Class::parse(s).ok_or_else(|| format!("unknown class {s:?}"))?
        }
    };
    Ok(Incoming::Infer(Request { id, features, arrival_s: 0.0, class }))
}

/// Lazy fast path for the hot wire: extract `id`/`features`/`class`
/// with [`JsonScan`] -- no tree allocation -- and fall back to
/// [`parse_request_line`] whenever the scanner is not *sure* (control
/// commands, malformed input, escaped keys, anything that needs an
/// error message).  Because the scanner only ever accepts documents the
/// tree parser accepts with the same meaning, both entry points return
/// identical results on every line; the differential property test
/// below pins that.
pub fn scan_request_line(line: &str) -> Result<Incoming, String> {
    match scan_infer(line) {
        Some(inc) => Ok(inc),
        None => parse_request_line(line),
    }
}

/// The happy path: a well-formed infer object with no `cmd` key, a
/// numeric `id`, a non-empty flat numeric `features` array, and an
/// absent / null / plain-string `class`.  Anything else is `None`.
fn scan_infer(line: &str) -> Option<Incoming> {
    let scan = JsonScan::new(line);
    if scan.has_field("cmd")? {
        return None; // control commands and cmd-typed errors: tree path
    }
    // has_field proved the whole document scans, so a None from the
    // field accessors below means "absent or needs the parser's error"
    let id = scan.field_u64("id")?;
    let mut nums: Vec<f64> = Vec::new();
    if scan.field_nums("features", &mut nums)? == 0 {
        return None; // the "empty features" error text is the parser's
    }
    // same rounding hop as the tree path: f64 token -> f32 feature
    let features: Vec<f32> = nums.iter().map(|&f| f as f32).collect();
    let class = match scan.field("class") {
        None => Class::Standard, // absent, like the tree's Json::Null
        Some("null") => Class::Standard,
        Some(_) => {
            let s = scan.field_str("class")?; // non-string/escaped: fall back
            Class::parse(s)? // unknown class: the parser renders the error
        }
    };
    Some(Incoming::Infer(Request { id, features, arrival_s: 0.0, class }))
}

/// Render a verdict reply line into a reusable buffer -- the
/// zero-allocation hot path (DESIGN.md §16).  Emits bytes identical to
/// the `JsonObj` tree rendering this replaced: compact, insertion order
/// `id, prediction, exit_tier, latency_s, scores[, gear]`.  `gear` is
/// the active gear's ladder index when the server runs under a gear
/// plan; ungeared deployments omit the field, keeping the PR-1 wire
/// shape byte-compatible.
pub fn render_verdict_into(out: &mut Vec<u8>, v: &Verdict, gear: Option<usize>) {
    out.extend_from_slice(b"{\"id\":");
    write_num_bytes(out, v.request_id as f64);
    out.extend_from_slice(b",\"prediction\":");
    write_num_bytes(out, v.prediction as f64);
    out.extend_from_slice(b",\"exit_tier\":");
    write_num_bytes(out, v.exit_tier as f64);
    out.extend_from_slice(b",\"latency_s\":");
    write_num_bytes(out, v.latency_s);
    out.extend_from_slice(b",\"scores\":[");
    for (i, &s) in v.tier_scores.iter().enumerate() {
        if i > 0 {
            out.push(b',');
        }
        write_num_bytes(out, s as f64);
    }
    out.push(b']');
    if let Some(g) = gear {
        out.extend_from_slice(b",\"gear\":");
        write_num_bytes(out, g as f64);
    }
    out.push(b'}');
}

/// Render a verdict reply line.  Cold-path wrapper over
/// [`render_verdict_into`] for callers that want an owned `String`.
pub fn render_verdict(v: &Verdict, gear: Option<usize>) -> String {
    let mut out = Vec::new();
    render_verdict_into(&mut out, v, gear);
    String::from_utf8(out).expect("render_verdict_into emits UTF-8")
}

/// Render an error reply line into a reusable buffer.  Byte-identical
/// to the `JsonObj` rendering: `{"error":"<escaped msg>"}`.
pub fn render_error_into(out: &mut Vec<u8>, msg: &str) {
    out.extend_from_slice(b"{\"error\":");
    write_str_bytes(out, msg);
    out.push(b'}');
}

/// Render an error reply line.  Cold-path wrapper over
/// [`render_error_into`].
pub fn render_error(msg: &str) -> String {
    let mut out = Vec::new();
    render_error_into(&mut out, msg);
    String::from_utf8(out).expect("render_error_into emits UTF-8")
}

/// Render the load-shedding reply into a reusable buffer: the request
/// was refused by admission control, not failed.  Keeps an `"error"`
/// field so clients that only check for errors still treat it as a
/// non-answer, while load-aware clients key on `"overloaded": true`
/// and back off / retry.
pub fn render_overloaded_into(out: &mut Vec<u8>, outstanding: usize, limit: usize) {
    out.extend_from_slice(b"{\"error\":\"overloaded\",\"overloaded\":true,\"outstanding\":");
    write_num_bytes(out, outstanding as f64);
    out.extend_from_slice(b",\"limit\":");
    write_num_bytes(out, limit as f64);
    out.push(b'}');
}

/// Render the load-shedding reply.  Cold-path wrapper over
/// [`render_overloaded_into`].
pub fn render_overloaded(outstanding: usize, limit: usize) -> String {
    let mut out = Vec::new();
    render_overloaded_into(&mut out, outstanding, limit);
    String::from_utf8(out).expect("render_overloaded_into emits UTF-8")
}

/// Render the metrics snapshot.
pub fn render_metrics(metrics: &Metrics) -> String {
    let mut inner = JsonObj::new();
    for (name, value) in metrics.snapshot() {
        inner.insert(name, Json::str(value));
    }
    let mut obj = JsonObj::new();
    obj.insert("metrics", Json::Obj(inner));
    Json::Obj(obj).to_string()
}

/// Render the structured stats snapshot (`{"cmd":"stats"}` reply):
/// counters/gauges as numbers, histograms as quantile objects --
/// machine-readable where `metrics` is display-oriented.
pub fn render_stats(metrics: &Metrics) -> String {
    let mut obj = JsonObj::new();
    obj.insert("stats", metrics.snapshot_json());
    Json::Obj(obj).to_string()
}

/// Render the controller event log (`{"cmd":"events"}` reply): the
/// retained gear-shift/scale-action events, oldest first, plus how
/// many older events the bounded ring evicted.
pub fn render_events(metrics: &Metrics) -> String {
    let mut obj = JsonObj::new();
    obj.insert("events", metrics.events().to_json());
    obj.insert("dropped", Json::num(metrics.events().dropped() as f64));
    Json::Obj(obj).to_string()
}

/// Render the Prometheus text exposition (`{"cmd":"prom"}` reply):
/// the multi-line scrape body rides as one JSON string field, so the
/// line-oriented protocol stays line-oriented.
pub fn render_prom_reply(metrics: &Metrics) -> String {
    let mut obj = JsonObj::new();
    obj.insert("prom", Json::str(metrics.render_prom()));
    Json::Obj(obj).to_string()
}

/// Render the retained trace spans (`{"cmd":"traces"}` reply), grouped
/// per request, plus ring accounting and the active sampling rate.  A
/// deployment without tracing answers the same shape, empty.
pub fn render_traces(tracer: Option<&Arc<Tracer>>) -> String {
    let mut obj = JsonObj::new();
    match tracer {
        Some(t) => {
            obj.insert("traces", t.snapshot_traces());
            obj.insert("spans", Json::num(t.recorded() as f64));
            obj.insert("dropped", Json::num(t.dropped() as f64));
            obj.insert("sample_every", Json::num(t.sample_every() as f64));
        }
        None => {
            obj.insert("traces", Json::Arr(Vec::new()));
            obj.insert("spans", Json::num(0.0));
            obj.insert("dropped", Json::num(0.0));
            obj.insert("sample_every", Json::num(0.0));
        }
    }
    Json::Obj(obj).to_string()
}

/// Render the drift observatory snapshot (`{"cmd":"drift"}` reply):
/// per-tier alarm / agreement / live-vs-calibrated theta statuses.  A
/// deployment without shadow sampling answers the same shape, empty
/// (`tiers: []`, `sample_every: 0`).  Non-finite thetas (the monitor's
/// defer-all / select-all sentinels) render as JSON null.
pub fn render_drift(monitor: Option<&Arc<DriftMonitor>>) -> String {
    let mut obj = JsonObj::new();
    match monitor {
        Some(m) => {
            obj.insert("drift", m.to_json());
        }
        None => {
            let mut empty = JsonObj::new();
            empty.insert("tiers", Json::Arr(Vec::new()));
            empty.insert("sample_every", Json::num(0.0));
            empty.insert("regrounds", Json::num(0.0));
            obj.insert("drift", Json::Obj(empty));
        }
    }
    Json::Obj(obj).to_string()
}

/// Render the SLO observatory snapshot (`{"cmd":"slo"}` reply): the
/// per-class ledgers, windowed p99/goodput, burn rates and alarm
/// states.  A deployment without an observatory answers the same
/// shape, empty (`classes: []`, `goal: 0`).  NaN quantiles (an empty
/// class window) render as JSON null.
pub fn render_slo(slo: Option<&Arc<SloObservatory>>) -> String {
    let mut obj = JsonObj::new();
    match slo {
        Some(s) => {
            obj.insert("slo", s.to_json());
        }
        None => {
            let mut empty = JsonObj::new();
            empty.insert("classes", Json::Arr(Vec::new()));
            empty.insert("goal", Json::num(0.0));
            obj.insert("slo", Json::Obj(empty));
        }
    }
    Json::Obj(obj).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_infer_line() {
        let inc = parse_request_line(r#"{"id": 7, "features": [1.5, -2.0]}"#).unwrap();
        match inc {
            Incoming::Infer(r) => {
                assert_eq!(r.id, 7);
                assert_eq!(r.features, vec![1.5, -2.0]);
                assert_eq!(r.class, Class::Standard, "untagged defaults");
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn parse_infer_line_with_class_tag() {
        let inc =
            parse_request_line(r#"{"id": 7, "features": [1.0], "class": "batch"}"#)
                .unwrap();
        match inc {
            Incoming::Infer(r) => assert_eq!(r.class, Class::Batch),
            _ => panic!("wrong variant"),
        }
        // unknown class strings are an error, not a silent default
        assert!(
            parse_request_line(r#"{"id": 7, "features": [1.0], "class": "gold"}"#)
                .is_err()
        );
        assert!(
            parse_request_line(r#"{"id": 7, "features": [1.0], "class": 3}"#)
                .is_err()
        );
    }

    #[test]
    fn parse_commands() {
        assert!(matches!(
            parse_request_line(r#"{"cmd": "metrics"}"#).unwrap(),
            Incoming::Metrics
        ));
        assert!(matches!(
            parse_request_line(r#"{"cmd": "stats"}"#).unwrap(),
            Incoming::Stats
        ));
        assert!(matches!(
            parse_request_line(r#"{"cmd": "events"}"#).unwrap(),
            Incoming::Events
        ));
        assert!(matches!(
            parse_request_line(r#"{"cmd": "prom"}"#).unwrap(),
            Incoming::Prom
        ));
        assert!(matches!(
            parse_request_line(r#"{"cmd": "traces"}"#).unwrap(),
            Incoming::Traces
        ));
        assert!(matches!(
            parse_request_line(r#"{"cmd": "drift"}"#).unwrap(),
            Incoming::Drift
        ));
        assert!(matches!(
            parse_request_line(r#"{"cmd": "slo"}"#).unwrap(),
            Incoming::Slo
        ));
        assert!(matches!(
            parse_request_line(r#"{"cmd": "shutdown"}"#).unwrap(),
            Incoming::Shutdown
        ));
    }

    #[test]
    fn events_line_shape() {
        use crate::metrics::{EventKind, EventRecord};
        let m = Metrics::new();
        m.events().record(EventRecord {
            kind: EventKind::Shift,
            decider: "gear",
            trigger: "rate",
            tier: 0,
            old_gear: 0,
            new_gear: 1,
            old_replicas: 2,
            new_replicas: 2,
            class: None,
        });
        m.events().record(EventRecord {
            kind: EventKind::Scale,
            decider: "budget",
            trigger: "pressure",
            tier: 1,
            old_gear: 1,
            new_gear: 1,
            old_replicas: 2,
            new_replicas: 4,
            class: None,
        });
        let line = render_events(&m);
        let parsed = Json::parse(&line).unwrap();
        let events = parsed.get("events").as_arr().unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].get("kind").as_str(), Some("shift"));
        assert_eq!(events[0].get("decider").as_str(), Some("gear"));
        assert_eq!(events[1].get("kind").as_str(), Some("scale"));
        assert_eq!(events[1].get("decider").as_str(), Some("budget"));
        assert_eq!(events[1].get("tier").as_u64(), Some(1));
        assert_eq!(events[1].get("trigger").as_str(), Some("pressure"));
        assert_eq!(events[1].get("new_replicas").as_u64(), Some(4));
        assert_eq!(parsed.get("dropped").as_u64(), Some(0));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_request_line("not json").is_err());
        assert!(parse_request_line(r#"{"cmd": "nope"}"#).is_err());
        assert!(parse_request_line(r#"{"id": 1}"#).is_err());
        assert!(parse_request_line(r#"{"id": 1, "features": []}"#).is_err());
        assert!(parse_request_line(r#"{"id": 1, "features": ["x"]}"#).is_err());
        assert!(parse_request_line(r#"{"features": [1.0]}"#).is_err());
    }

    #[test]
    fn verdict_roundtrips_through_json() {
        let v = Verdict {
            request_id: 3,
            prediction: 9,
            exit_tier: 2,
            tier_scores: vec![0.33, 1.0],
            latency_s: 0.004,
        };
        let line = render_verdict(&v, None);
        let parsed = Json::parse(&line).unwrap();
        assert_eq!(parsed.get("id").as_u64(), Some(3));
        assert_eq!(parsed.get("prediction").as_u64(), Some(9));
        assert_eq!(parsed.get("exit_tier").as_u64(), Some(2));
        assert_eq!(parsed.get("scores").as_arr().unwrap().len(), 2);
        // ungeared replies omit the gear field entirely
        assert!(parsed.get("gear").as_u64().is_none());
        // geared replies carry the active ladder index
        let geared = Json::parse(&render_verdict(&v, Some(2))).unwrap();
        assert_eq!(geared.get("gear").as_u64(), Some(2));
    }

    #[test]
    fn stats_line_is_structured() {
        let m = Metrics::new();
        m.counter("requests_submitted").add(5);
        m.gauge("gear_current").set(1.0);
        m.histogram("request_latency_s").record(0.002);
        let line = render_stats(&m);
        let parsed = Json::parse(&line).unwrap();
        let stats = parsed.get("stats");
        assert_eq!(
            stats.get("counters").get("requests_submitted").as_u64(),
            Some(5)
        );
        assert_eq!(stats.get("gauges").get("gear_current").as_f64(), Some(1.0));
        assert_eq!(
            stats
                .get("histograms")
                .get("request_latency_s")
                .get("n")
                .as_u64(),
            Some(1)
        );
    }

    #[test]
    fn prom_line_carries_the_exposition_text() {
        let m = Metrics::new();
        m.counter("requests_submitted").add(2);
        m.histogram("request_latency_s").record(0.01);
        let line = render_prom_reply(&m);
        let parsed = Json::parse(&line).unwrap();
        let text = parsed.get("prom").as_str().unwrap();
        // the multi-line scrape body survives the JSON string hop
        assert!(text.contains("# TYPE requests_submitted counter"));
        assert!(text.contains("requests_submitted 2\n"));
        assert!(text.contains("request_latency_s{quantile=\"0.99\"}"));
    }

    #[test]
    fn traces_line_shape_with_and_without_tracer() {
        use crate::obs::{SpanKind, Tracer};
        // no tracer: same shape, empty
        let parsed = Json::parse(&render_traces(None)).unwrap();
        assert_eq!(parsed.get("traces").as_arr().unwrap().len(), 0);
        assert_eq!(parsed.get("spans").as_u64(), Some(0));
        assert_eq!(parsed.get("sample_every").as_u64(), Some(0));
        // with a tracer: spans grouped per request
        let t = Tracer::new(1);
        t.record(5, SpanKind::Enqueue, 0, 0.0);
        t.record(5, SpanKind::Complete, 1, 0.003);
        let parsed = Json::parse(&render_traces(Some(&t))).unwrap();
        assert_eq!(parsed.get("spans").as_u64(), Some(2));
        assert_eq!(parsed.get("dropped").as_u64(), Some(0));
        assert_eq!(parsed.get("sample_every").as_u64(), Some(1));
        let traces = parsed.get("traces").as_arr().unwrap();
        assert_eq!(traces.len(), 1);
        assert_eq!(traces[0].get("request_id").as_u64(), Some(5));
        let spans = traces[0].get("spans").as_arr().unwrap();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].get("kind").as_str(), Some("enqueue"));
        assert_eq!(spans[1].get("kind").as_str(), Some("complete"));
        assert_eq!(spans[1].get("tier").as_u64(), Some(1));
    }

    #[test]
    fn drift_line_shape_with_and_without_monitor() {
        use crate::calib::threshold::CalPoint;
        use crate::obs::{DriftConfig, DriftMonitor};
        // no monitor: same shape, empty
        let parsed = Json::parse(&render_drift(None)).unwrap();
        let drift = parsed.get("drift");
        assert_eq!(drift.get("tiers").as_arr().unwrap().len(), 0);
        assert_eq!(drift.get("sample_every").as_u64(), Some(0));
        // with a monitor: per-tier statuses, and the empty-window
        // theta_live sentinel (+inf) rides as null without breaking
        // the line's JSON
        let cfg = DriftConfig {
            sample_every: 10,
            min_samples: 1,
            hysteresis: 1,
            ..DriftConfig::default()
        };
        // 0.5 is exact in binary, so the f32 -> f64 -> JSON hop
        // preserves it bit-for-bit
        let m = DriftMonitor::new(cfg, &[Some(0.5), None, None], &Metrics::new());
        m.record(0, CalPoint { score: 0.9, correct: true });
        let parsed = Json::parse(&render_drift(Some(&m))).unwrap();
        let drift = parsed.get("drift");
        assert_eq!(drift.get("sample_every").as_u64(), Some(10));
        let tiers = drift.get("tiers").as_arr().unwrap();
        assert_eq!(tiers.len(), 2, "final tier unmonitored");
        assert_eq!(tiers[0].get("tier").as_u64(), Some(0));
        assert_eq!(tiers[0].get("alarm").as_str(), Some("ok"));
        assert_eq!(tiers[0].get("samples").as_u64(), Some(1));
        assert_eq!(tiers[0].get("agreement_live").as_f64(), Some(1.0));
        assert_eq!(tiers[0].get("theta_cal").as_f64(), Some(0.5));
        // tier 1 has no observations: its all-agree/empty sentinel
        // theta is null, theta_cal was spawned as None -> null too
        assert!(tiers[1].get("theta_live").as_f64().is_none());
        assert!(tiers[1].get("theta_cal").as_f64().is_none());
        assert_eq!(tiers[1].get("failure_rate").as_f64(), Some(0.0));
    }

    #[test]
    fn slo_line_shape_with_and_without_observatory() {
        use crate::obs::slo::SloConfig;
        // no observatory: same shape, empty
        let parsed = Json::parse(&render_slo(None)).unwrap();
        let slo = parsed.get("slo");
        assert_eq!(slo.get("classes").as_arr().unwrap().len(), 0);
        assert_eq!(slo.get("goal").as_f64(), Some(0.0));
        // with one: all classes in index order, ledgers attached
        let m = Metrics::new();
        let obs = SloObservatory::new(SloConfig::default(), &m);
        obs.record_submitted(Class::Premium);
        obs.record_completed(Class::Premium, 0.01);
        obs.tick(1.0);
        let parsed = Json::parse(&render_slo(Some(&obs))).unwrap();
        let slo = parsed.get("slo");
        let classes = slo.get("classes").as_arr().unwrap();
        assert_eq!(classes.len(), Class::COUNT);
        assert_eq!(classes[0].get("class").as_str(), Some("premium"));
        assert_eq!(classes[0].get("submitted").as_u64(), Some(1));
        assert_eq!(classes[0].get("alarm").as_str(), Some("ok"));
        // an idle class rides the same line with null quantiles
        assert_eq!(classes[2].get("class").as_str(), Some("batch"));
        assert!(classes[2].get("p99_s").as_f64().is_none());
        assert_eq!(slo.get("goal").as_f64(), Some(0.95));
    }

    #[test]
    fn into_renders_match_the_json_tree() {
        // reference renders built through the JsonObj tree -- the shape
        // every client has seen since PR 1 -- pinned byte-for-byte
        // against the zero-allocation writers that replaced them
        fn tree_verdict(v: &Verdict, gear: Option<usize>) -> String {
            let mut obj = JsonObj::new();
            obj.insert("id", Json::num(v.request_id as f64));
            obj.insert("prediction", Json::num(v.prediction as f64));
            obj.insert("exit_tier", Json::num(v.exit_tier as f64));
            obj.insert("latency_s", Json::num(v.latency_s));
            obj.insert(
                "scores",
                Json::Arr(
                    v.tier_scores.iter().map(|&s| Json::num(s as f64)).collect(),
                ),
            );
            if let Some(g) = gear {
                obj.insert("gear", Json::num(g as f64));
            }
            Json::Obj(obj).to_string()
        }
        let verdicts = [
            Verdict {
                request_id: 0,
                prediction: 0,
                exit_tier: 0,
                tier_scores: vec![],
                latency_s: 0.0,
            },
            Verdict {
                request_id: u64::MAX >> 10,
                prediction: 9,
                exit_tier: 2,
                tier_scores: vec![0.33, 1.0, 0.1 + 0.2],
                latency_s: 0.004,
            },
            Verdict {
                request_id: 3,
                prediction: 1,
                exit_tier: 1,
                tier_scores: vec![f32::NAN, 0.5],
                latency_s: f64::INFINITY,
            },
        ];
        for v in &verdicts {
            for gear in [None, Some(0), Some(7)] {
                assert_eq!(
                    render_verdict(v, gear).into_bytes(),
                    {
                        let mut out = Vec::new();
                        render_verdict_into(&mut out, v, gear);
                        out
                    },
                    "wrapper and _into must agree"
                );
                assert_eq!(render_verdict(v, gear), tree_verdict(v, gear));
            }
        }
        for msg in ["boom", "quo\"te \\ back", "ctl \u{1} tab\t", "😀"] {
            let mut obj = JsonObj::new();
            obj.insert("error", Json::str(msg));
            assert_eq!(render_error(msg), Json::Obj(obj).to_string());
        }
        for (o, l) in [(0, 0), (128, 128), (999_999, 12)] {
            let mut obj = JsonObj::new();
            obj.insert("error", Json::str("overloaded"));
            obj.insert("overloaded", Json::Bool(true));
            obj.insert("outstanding", Json::num(o as f64));
            obj.insert("limit", Json::num(l as f64));
            assert_eq!(render_overloaded(o, l), Json::Obj(obj).to_string());
        }
    }

    #[test]
    fn error_line_shape() {
        let line = render_error("boom \"x\"");
        let parsed = Json::parse(&line).unwrap();
        assert_eq!(parsed.get("error").as_str(), Some("boom \"x\""));
    }

    #[test]
    fn overloaded_line_shape() {
        let line = render_overloaded(128, 128);
        let parsed = Json::parse(&line).unwrap();
        assert_eq!(parsed.get("overloaded").as_bool(), Some(true));
        assert_eq!(parsed.get("error").as_str(), Some("overloaded"));
        assert_eq!(parsed.get("outstanding").as_u64(), Some(128));
        assert_eq!(parsed.get("limit").as_u64(), Some(128));
    }

    #[test]
    fn scan_request_line_matches_parse_on_the_basics() {
        // the hot path: no tree, same Request
        let line = r#"{"id": 7, "features": [1.5, -2.0], "class": "batch"}"#;
        match scan_request_line(line).unwrap() {
            Incoming::Infer(r) => {
                assert_eq!(r.id, 7);
                assert_eq!(r.features, vec![1.5, -2.0]);
                assert_eq!(r.class, Class::Batch);
            }
            _ => panic!("wrong variant"),
        }
        // explicit null class matches the tree parser's absent default
        match scan_request_line(r#"{"id":1,"features":[1.0],"class":null}"#).unwrap() {
            Incoming::Infer(r) => assert_eq!(r.class, Class::Standard),
            _ => panic!("wrong variant"),
        }
        // commands fall back to the tree path, same variants
        assert!(matches!(
            scan_request_line(r#"{"cmd": "shutdown"}"#).unwrap(),
            Incoming::Shutdown
        ));
        // errors are byte-identical because they all come from the parser
        for bad in [
            "not json",
            r#"{"cmd": "nope"}"#,
            r#"{"id": 1}"#,
            r#"{"id": 1.5, "features": [1.0]}"#,
            r#"{"id": "7", "features": [1.0]}"#,
            r#"{"id": 1, "features": []}"#,
            r#"{"id": 1, "features": ["x"]}"#,
            r#"{"id": 1, "features": {"not":"arr"}}"#,
            r#"{"id": 1, "features": [1.0], "class": "gold"}"#,
            r#"{"id": 1, "features": [1.0], "class": 3}"#,
            r#"{"id": 1, "features": [1.0]"#,
        ] {
            assert_eq!(
                scan_request_line(bad).unwrap_err(),
                parse_request_line(bad).unwrap_err(),
                "error text must come from one place: {bad}"
            );
        }
    }

    #[test]
    fn scan_equals_parse_on_generated_lines() {
        use crate::prop_assert;
        use crate::util::minicheck::{check, Shrink};
        use crate::util::rng::Rng;

        #[derive(Clone, Debug)]
        struct Line(String);
        impl Shrink for Line {}

        fn num_token(rng: &mut Rng) -> String {
            match rng.below(6) {
                0 => format!("{}", rng.below(1000)),
                1 => format!("-{}", rng.below(1000)),
                2 => format!("{}.{}", rng.below(100), rng.below(1000)),
                3 => format!("{}e{}", rng.below(50), rng.below(4)),
                4 => format!("{}.0", rng.below(100)),
                _ => format!("{:.3}", rng.range_f64(-10.0, 10.0)),
            }
        }

        // values the scanner must skip without understanding
        fn extra_value(rng: &mut Rng) -> String {
            match rng.below(7) {
                0 => r#""plain""#.to_string(),
                1 => r#""esc \" \\ \n A""#.to_string(),
                2 => r#""pair 😀 ok""#.to_string(),
                3 => r#"{"deep":{"er":[1,{"x":null}]}}"#.to_string(),
                4 => "[[],[1,2],[{}]]".to_string(),
                5 => "true".to_string(),
                _ => "null".to_string(),
            }
        }

        fn gen_line(rng: &mut Rng) -> Line {
            let mut parts: Vec<String> = Vec::new();
            match rng.below(8) {
                0 => {}                                     // missing id
                1 => parts.push(r#""id":"7""#.to_string()), // stringified
                2 => parts.push(r#""id":1.5"#.to_string()), // fractional
                3 => parts.push(format!(r#""id":{}.0"#, rng.below(100))),
                4 => parts.push(format!(r#""id":{}e2"#, rng.below(90))),
                _ => parts.push(format!(r#""id":{}"#, rng.below(1_000_000))),
            }
            match rng.below(8) {
                0 => {} // missing features
                1 => parts.push(r#""features":[]"#.to_string()),
                2 => parts.push(r#""features":[1,"x"]"#.to_string()),
                3 => parts.push(r#""features":{"not":"arr"}"#.to_string()),
                _ => {
                    let n = 1 + rng.below(6);
                    let elems: Vec<String> =
                        (0..n).map(|_| num_token(rng)).collect();
                    parts.push(format!(r#""features":[{}]"#, elems.join(",")));
                }
            }
            match rng.below(10) {
                0 => parts.push(r#""class":"premium""#.to_string()),
                1 => parts.push(r#""class":"standard""#.to_string()),
                2 => parts.push(r#""class":"batch""#.to_string()),
                3 => parts.push(r#""class":"gold""#.to_string()), // unknown
                4 => parts.push(r#""class":3"#.to_string()),      // non-string
                5 => parts.push(r#""class":null"#.to_string()),
                // escaped class: the scanner defers, the parser unescapes
                6 => parts.push("\"class\":\"bat\\u0063h\"".to_string()),
                _ => {} // absent
            }
            if rng.bool(0.15) {
                let cmd = ["metrics", "stats", "shutdown", "nope"];
                parts.push(format!(r#""cmd":"{}""#, cmd[rng.below(cmd.len())]));
            }
            if rng.bool(0.3) {
                parts.push(format!(
                    r#""extra{}":{}"#,
                    rng.below(3),
                    extra_value(rng)
                ));
            }
            if rng.bool(0.1) {
                // duplicate key: last occurrence wins on both paths
                parts.push(format!(r#""id":{}"#, rng.below(50)));
            }
            rng.shuffle(&mut parts);
            let sep = if rng.bool(0.5) { "," } else { " , " };
            let mut line = format!("{{{}}}", parts.join(sep));
            if rng.bool(0.3) {
                line = format!("  {line} ");
            }
            match rng.below(12) {
                0 => {
                    // truncate at a char boundary: both paths must reject
                    let cut = rng.below(line.len() + 1);
                    line = line.chars().take(cut).collect();
                }
                1 => line.push_str(" trailing"),
                _ => {}
            }
            Line(line)
        }

        check(0x5EED_0009, 4000, gen_line, |l| {
            let a = parse_request_line(&l.0);
            let b = scan_request_line(&l.0);
            match (&a, &b) {
                (Ok(x), Ok(y)) => prop_assert!(
                    format!("{x:?}") == format!("{y:?}"),
                    "value divergence on {:?}: parse={x:?} scan={y:?}",
                    l.0
                ),
                (Err(x), Err(y)) => prop_assert!(
                    x == y,
                    "error divergence on {:?}: parse={x:?} scan={y:?}",
                    l.0
                ),
                _ => prop_assert!(
                    false,
                    "ok/err divergence on {:?}: parse={a:?} scan={b:?}",
                    l.0
                ),
            }
            Ok(())
        });
    }
}
