//! Per-connection state machine for the event-driven frontend
//! (DESIGN.md §15-§16): read scratch -> line framing -> dispatch
//! (tracked by a FIFO reply sequencer) -> pooled write queue drained
//! with vectored writes, with pause/resume decisions the reactor turns
//! into poller interest changes.
//!
//! Everything except the socket reads/writes is plain data owned by one
//! reactor shard (no locks, no shared state), so framing, sequencing
//! and the backpressure rule unit-test here without a poller.
//!
//! The reply path is allocation-free in steady state: replies arrive as
//! [`PooledBuf`]s rendered by workers, queue here without copying, and
//! recycle into the [`BufPool`](crate::util::bufpool::BufPool) the
//! moment the socket accepts their bytes.  One `writev(2)` drains as
//! many queued replies as the kernel will take (up to [`MAX_IOV`] per
//! call); `scripts/check_hotpath_allocs.sh` freezes this file's
//! allocation count.

use std::collections::{BTreeMap, VecDeque};
use std::io::{self, Read};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::bufpool::PooledBuf;

/// Largest tolerated unterminated line.  A client that streams this much
/// without a newline is broken or hostile; the reactor hangs up instead
/// of buffering without bound.
pub const MAX_LINE: usize = 1 << 20;

/// Size of the per-shard read scratch: one `read(2)` per readiness
/// event lands here before framing (DESIGN.md §16).
pub const READ_SCRATCH: usize = 64 << 10;

/// Most reply buffers one `writev(2)` will gather.  64 newline-framed
/// JSON replies comfortably exceed a TCP send buffer's appetite per
/// call, so a larger batch would not reduce syscalls further.
pub const MAX_IOV: usize = 64;

/// Write syscalls issued on the reactor reply path (both `writev` and
/// the portable fallback), for the bench's writes-per-reply column.
static WRITE_SYSCALLS: AtomicU64 = AtomicU64::new(0);

/// Reply buffers fully drained to a socket on the reactor reply path.
static REPLIES_WRITTEN: AtomicU64 = AtomicU64::new(0);

/// Cumulative `(write_syscalls, replies_written)` across all reactor
/// connections since process start.  Benches diff two snapshots to get
/// a writes-per-reply ratio for one load interval.
pub fn wire_stats() -> (u64, u64) {
    (
        WRITE_SYSCALLS.load(Ordering::Relaxed),
        REPLIES_WRITTEN.load(Ordering::Relaxed),
    )
}

/// Backpressure thresholds (DESIGN.md §15).  A connection's reads pause
/// when its un-drained output exceeds `write_buf_cap`, when more than
/// `max_inflight` of its lines are dispatched but unanswered, or after
/// admission control sheds one of its requests; reads resume at half the
/// watermark (hysteresis) so the interest registration doesn't flap.
#[derive(Debug, Clone, Copy)]
pub struct Backpressure {
    pub write_buf_cap: usize,
    pub max_inflight: u64,
}

impl Default for Backpressure {
    fn default() -> Self {
        Backpressure { write_buf_cap: 256 << 10, max_inflight: 128 }
    }
}

/// Accumulates raw socket bytes and yields complete `\n`-terminated
/// lines.  Partial tails survive between reads; `scan_from` remembers
/// how far the newline scan got so repeated pushes of a long partial
/// line stay O(new bytes), not O(buffer).
///
/// Framing is two-step -- [`next_line_end`](LineFramer::next_line_end)
/// finds a line, [`take_line`](LineFramer::take_line) moves its bytes
/// into a caller-supplied buffer -- so the reactor checks out a pooled
/// buffer only once a complete line is known to exist.
#[derive(Default)]
pub struct LineFramer {
    buf: Vec<u8>,
    scan_from: usize,
}

impl LineFramer {
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet returned as lines.
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Exclusive end offset of the next complete line (terminator
    /// included), if one is buffered.  A `Some` must be consumed with
    /// [`take_line`](LineFramer::take_line) before scanning again.
    pub fn next_line_end(&mut self) -> Option<usize> {
        match self.buf[self.scan_from..].iter().position(|&b| b == b'\n') {
            Some(off) => Some(self.scan_from + off + 1),
            None => {
                self.scan_from = self.buf.len();
                None
            }
        }
    }

    /// Move the line ending at `end` (from
    /// [`next_line_end`](LineFramer::next_line_end)) into `out`.
    pub fn take_line(&mut self, end: usize, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.buf[..end]);
        self.buf.drain(..end);
        self.scan_from = 0;
    }
}

/// Restores per-connection FIFO reply order over out-of-order worker
/// completions: lines get ascending sequence numbers at dispatch; a
/// completed reply is released only once every earlier one has been.
///
/// Generic over the reply payload so the reactor sequences
/// [`PooledBuf`]s without re-boxing; the in-order fast path releases a
/// completion that arrives in sequence without touching the stash, so
/// single-in-flight traffic never allocates a tree node.
pub struct ReplySequencer<T> {
    next_seq: u64,
    next_write: u64,
    stash: BTreeMap<u64, T>,
}

impl<T> Default for ReplySequencer<T> {
    fn default() -> Self {
        ReplySequencer { next_seq: 0, next_write: 0, stash: BTreeMap::new() }
    }
}

impl<T> ReplySequencer<T> {
    /// Claim the sequence number for a newly dispatched line.
    pub fn alloc(&mut self) -> u64 {
        let s = self.next_seq;
        self.next_seq += 1;
        s
    }

    /// Record one completion; push every reply now releasable (in
    /// sequence order) onto `out`.
    pub fn complete(&mut self, seq: u64, reply: T, out: &mut Vec<T>) {
        if seq == self.next_write {
            out.push(reply);
            self.next_write += 1;
        } else {
            self.stash.insert(seq, reply);
        }
        while let Some(r) = self.stash.remove(&self.next_write) {
            out.push(r);
            self.next_write += 1;
        }
    }

    /// Dispatched lines whose replies have not yet been released.
    pub fn outstanding(&self) -> u64 {
        self.next_seq - self.next_write
    }

    /// Replies parked waiting for an earlier sequence number.
    pub fn stashed(&self) -> usize {
        self.stash.len()
    }
}

/// One client connection owned by a reactor shard.
pub struct Conn {
    pub stream: TcpStream,
    framer: LineFramer,
    seq: ReplySequencer<PooledBuf>,
    /// Released replies awaiting the socket, front first.  Each buffer
    /// is one newline-terminated reply; popping one recycles it.
    wqueue: VecDeque<PooledBuf>,
    /// Bytes of `wqueue.front()` already written.
    wpos: usize,
    /// Total unwritten bytes across `wqueue` (invariant: sum of queued
    /// lengths minus `wpos`).
    out_bytes: usize,
    /// Scratch reused by `complete` for sequencer releases.
    ready: Vec<PooledBuf>,
    /// (read, write) interest currently registered with the poller.
    pub registered: (bool, bool),
    /// Reads deliberately stopped by the backpressure rule.
    pub paused: bool,
    /// No more reads (client EOF or server drain); close once idle.
    pub closing: bool,
    /// I/O error observed; close immediately, dropping pending output.
    pub broken: bool,
    shed_pause: bool,
}

impl Conn {
    pub fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            framer: LineFramer::default(),
            seq: ReplySequencer::default(),
            wqueue: VecDeque::new(),
            wpos: 0,
            out_bytes: 0,
            ready: Vec::new(),
            registered: (true, false),
            paused: false,
            closing: false,
            broken: false,
            shed_pause: false,
        }
    }

    /// One `read(2)` into the shard's shared scratch per readiness
    /// event (level-triggered polling re-arms the event while more
    /// bytes wait in the kernel, so a single read per wakeup keeps
    /// per-event latency flat without losing data).  EOF marks the
    /// connection closing.
    pub fn on_readable(&mut self, scratch: &mut [u8]) -> io::Result<()> {
        loop {
            match self.stream.read(scratch) {
                Ok(0) => {
                    self.closing = true;
                    break;
                }
                Ok(n) => {
                    self.framer.push(&scratch[..n]);
                    if self.framer.buffered() > MAX_LINE {
                        return Err(io::Error::new(
                            io::ErrorKind::InvalidData,
                            "line exceeds MAX_LINE",
                        ));
                    }
                    break;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }

    /// Read until `WouldBlock` or EOF -- the shutdown-drain final read,
    /// which must pull in every complete line the kernel has already
    /// accepted (after this the connection stops reading, so
    /// level-triggered re-notification can no longer finish the job).
    pub fn read_all(&mut self, scratch: &mut [u8]) -> io::Result<()> {
        while !self.closing {
            let before = self.framer.buffered();
            self.on_readable(scratch)?;
            if self.framer.buffered() == before {
                break; // WouldBlock: the kernel is empty
            }
        }
        Ok(())
    }

    /// See [`LineFramer::next_line_end`].
    pub fn next_line_end(&mut self) -> Option<usize> {
        self.framer.next_line_end()
    }

    /// See [`LineFramer::take_line`].
    pub fn take_line(&mut self, end: usize, out: &mut Vec<u8>) {
        self.framer.take_line(end, out)
    }

    /// Bytes buffered by the framer but not yet framed into lines.
    pub fn framer_buffered(&self) -> usize {
        self.framer.buffered()
    }

    /// Sequence number for a line about to be handed to a worker.
    pub fn alloc_seq(&mut self) -> u64 {
        self.seq.alloc()
    }

    /// Record one worker completion; in-order replies move to the write
    /// queue.  An empty reply buffer (a blank input line) advances the
    /// sequence without putting bytes on the wire -- its buffer
    /// recycles immediately.  A shed completion arms the backpressure
    /// pause until the connection drains.
    pub fn complete(&mut self, seq: u64, reply: PooledBuf, shed: bool) {
        let mut ready = std::mem::take(&mut self.ready);
        self.seq.complete(seq, reply, &mut ready);
        for r in ready.drain(..) {
            if r.is_empty() {
                continue; // blank line: no reply; Drop recycles
            }
            self.out_bytes += r.len();
            self.wqueue.push_back(r);
        }
        self.ready = ready;
        if shed {
            self.shed_pause = true;
        }
    }

    /// Write queued output until `WouldBlock` or empty.  Each pass
    /// gathers up to [`MAX_IOV`] reply buffers into one `writev(2)`;
    /// fully written buffers recycle into the pool as they pop.
    pub fn flush(&mut self) -> io::Result<()> {
        while self.out_bytes > 0 {
            match write_queued(&self.stream, &self.wqueue, self.wpos) {
                Ok(0) => {
                    return Err(io::Error::new(
                        io::ErrorKind::WriteZero,
                        "socket write returned 0",
                    ))
                }
                Ok(n) => self.advance(n),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }

    /// Account `n` freshly written bytes: pop (and thereby recycle)
    /// fully drained reply buffers, leave `wpos` mid-buffer otherwise.
    fn advance(&mut self, n: usize) {
        self.out_bytes -= n;
        let mut consumed = self.wpos + n;
        self.wpos = 0;
        while consumed > 0 {
            let front_len =
                self.wqueue.front().expect("advance past queue end").len();
            if consumed >= front_len {
                consumed -= front_len;
                self.wqueue.pop_front(); // Drop recycles into the pool
                REPLIES_WRITTEN.fetch_add(1, Ordering::Relaxed);
            } else {
                self.wpos = consumed;
                break;
            }
        }
    }

    /// Output bytes accepted but not yet written to the socket.
    pub fn buffered_out(&self) -> usize {
        self.out_bytes
    }

    /// Dispatched lines not yet answered in order.
    pub fn outstanding(&self) -> u64 {
        self.seq.outstanding()
    }

    /// Nothing in flight and nothing left to write.
    pub fn idle(&self) -> bool {
        self.outstanding() == 0 && self.buffered_out() == 0
    }

    /// The poller should watch this socket for writability.
    pub fn wants_write(&self) -> bool {
        self.buffered_out() > 0
    }

    /// A shed pause clears once the connection fully drains: the client
    /// has seen the overload reply, so reads may resume.
    pub fn update_shed(&mut self) {
        if self.shed_pause && self.idle() {
            self.shed_pause = false;
        }
    }

    /// The backpressure rule: stop polling for readability?
    pub fn should_pause(&self, bp: &Backpressure) -> bool {
        self.shed_pause
            || self.buffered_out() > bp.write_buf_cap
            || self.outstanding() > bp.max_inflight
    }

    /// Hysteresis: resume reads only once well below the watermarks.
    pub fn may_resume(&self, bp: &Backpressure) -> bool {
        !self.shed_pause
            && self.buffered_out() <= bp.write_buf_cap / 2
            && self.outstanding() <= bp.max_inflight / 2
    }
}

/// One vectored write over the queued reply buffers, starting `wpos`
/// bytes into the front buffer.  Returns bytes accepted by the kernel.
#[cfg(unix)]
fn write_queued(
    stream: &TcpStream,
    queue: &VecDeque<PooledBuf>,
    wpos: usize,
) -> io::Result<usize> {
    use std::os::unix::io::AsRawFd;

    let mut iov = [wv::Iovec { base: std::ptr::null(), len: 0 }; MAX_IOV];
    let mut cnt = 0;
    for b in queue.iter().take(MAX_IOV) {
        let skip = if cnt == 0 { wpos } else { 0 };
        iov[cnt] = wv::Iovec { base: b[skip..].as_ptr(), len: b.len() - skip };
        cnt += 1;
    }
    WRITE_SYSCALLS.fetch_add(1, Ordering::Relaxed);
    let n = unsafe { wv::writev(stream.as_raw_fd(), iov.as_ptr(), cnt as i32) };
    if n < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(n as usize)
}

/// Portable fallback: one plain `write(2)` of the front buffer per
/// pass.  Correct everywhere `TcpStream` works; just more syscalls.
#[cfg(not(unix))]
fn write_queued(
    stream: &TcpStream,
    queue: &VecDeque<PooledBuf>,
    wpos: usize,
) -> io::Result<usize> {
    use std::io::Write;

    let front = queue.front().expect("write_queued on empty queue");
    WRITE_SYSCALLS.fetch_add(1, Ordering::Relaxed);
    (&*stream).write(&front[wpos..])
}

/// Raw `writev(2)` binding (std links libc; the project vendors no
/// crates, same pattern as the reactor's epoll block).
#[cfg(unix)]
mod wv {
    /// `struct iovec` from `<sys/uio.h>`.
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct Iovec {
        pub base: *const u8,
        pub len: usize,
    }

    extern "C" {
        pub fn writev(fd: i32, iov: *const Iovec, iovcnt: i32) -> isize;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bufpool::BufPool;

    fn next_line(f: &mut LineFramer) -> Option<String> {
        let end = f.next_line_end()?;
        let mut out = Vec::new();
        f.take_line(end, &mut out);
        Some(String::from_utf8_lossy(&out).into_owned())
    }

    #[test]
    fn framer_reassembles_lines_across_chunks() {
        let mut f = LineFramer::default();
        f.push(b"{\"id\":1}\n{\"id\"");
        assert_eq!(next_line(&mut f).as_deref(), Some("{\"id\":1}\n"));
        assert_eq!(next_line(&mut f), None);
        f.push(b":2}\n\n{\"id\":3}");
        assert_eq!(next_line(&mut f).as_deref(), Some("{\"id\":2}\n"));
        assert_eq!(next_line(&mut f).as_deref(), Some("\n"), "empty line framed");
        assert_eq!(next_line(&mut f), None);
        assert_eq!(f.buffered(), "{\"id\":3}".len(), "partial tail retained");
        f.push(b"\n");
        assert_eq!(next_line(&mut f).as_deref(), Some("{\"id\":3}\n"));
    }

    #[test]
    fn framer_scan_position_survives_partial_pushes() {
        let mut f = LineFramer::default();
        f.push(b"aaaa");
        assert_eq!(next_line(&mut f), None);
        // scan_from now sits at 4; the newline in the next chunk must
        // still be found even though it is past the first scan window
        f.push(b"bb\ncc");
        assert_eq!(next_line(&mut f).as_deref(), Some("aaaabb\n"));
        assert_eq!(f.buffered(), 2);
    }

    #[test]
    fn framer_takes_into_reused_buffer() {
        let mut f = LineFramer::default();
        f.push(b"one\ntwo\n");
        let mut out = Vec::with_capacity(16);
        let end = f.next_line_end().unwrap();
        f.take_line(end, &mut out);
        assert_eq!(out, b"one\n");
        out.clear();
        let end = f.next_line_end().unwrap();
        f.take_line(end, &mut out);
        assert_eq!(out, b"two\n");
        assert_eq!(f.buffered(), 0);
    }

    #[test]
    fn sequencer_releases_replies_in_dispatch_order() {
        let mut s: ReplySequencer<String> = ReplySequencer::default();
        let a = s.alloc();
        let b = s.alloc();
        let c = s.alloc();
        assert_eq!((a, b, c), (0, 1, 2));
        assert_eq!(s.outstanding(), 3);
        let mut out = Vec::new();
        s.complete(c, "C".into(), &mut out);
        assert!(out.is_empty(), "seq 2 waits for 0 and 1");
        s.complete(a, "A".into(), &mut out);
        assert_eq!(out, vec!["A"], "seq 0 releases alone");
        s.complete(b, "B".into(), &mut out);
        assert_eq!(out, vec!["A", "B", "C"], "seq 1 unblocks the stash");
        assert_eq!(s.outstanding(), 0);
    }

    #[test]
    fn sequencer_in_order_completions_never_stash() {
        let mut s: ReplySequencer<String> = ReplySequencer::default();
        let mut out = Vec::new();
        for i in 0..100 {
            let seq = s.alloc();
            s.complete(seq, format!("r{i}"), &mut out);
            assert_eq!(s.stashed(), 0, "in-order must bypass the BTreeMap");
        }
        assert_eq!(out.len(), 100);
        assert_eq!(s.outstanding(), 0);
    }

    #[test]
    fn backpressure_rule_and_hysteresis() {
        let bp = Backpressure { write_buf_cap: 100, max_inflight: 4 };
        let mut s: ReplySequencer<String> = ReplySequencer::default();
        for _ in 0..5 {
            s.alloc();
        }
        // 5 in flight > 4: pause; resume only at <= 2
        assert!(s.outstanding() > bp.max_inflight);
        let mut out = Vec::new();
        s.complete(0, "r".into(), &mut out);
        s.complete(1, "r".into(), &mut out);
        assert_eq!(s.outstanding(), 3, "3 > max_inflight/2: still paused");
        assert!(s.outstanding() > bp.max_inflight / 2);
        s.complete(2, "r".into(), &mut out);
        assert!(s.outstanding() <= bp.max_inflight / 2, "2 <= 2: may resume");
    }

    #[test]
    fn conn_queues_pooled_replies_and_recycles_blanks() {
        // loopback pair so Conn has a real socket; nothing is written
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server, _) = listener.accept().unwrap();
        drop(client);

        let pool = BufPool::new();
        let mut conn = Conn::new(server);
        let s0 = conn.alloc_seq();
        let s1 = conn.alloc_seq();
        let s2 = conn.alloc_seq();
        // out-of-order completion with a blank (empty) reply between
        let mut r2 = pool.get();
        r2.extend_from_slice(b"{\"id\":2}\n");
        conn.complete(s2, r2, false);
        assert_eq!(conn.buffered_out(), 0, "seq 2 waits for 0 and 1");
        let mut r0 = pool.get();
        r0.extend_from_slice(b"{\"id\":0}\n");
        conn.complete(s0, r0, false);
        assert_eq!(conn.buffered_out(), 9);
        conn.complete(s1, pool.get(), false); // blank line: empty reply
        assert_eq!(
            conn.buffered_out(),
            18,
            "blank released seq 2 but put no bytes on the wire"
        );
        assert_eq!(conn.outstanding(), 0);
        // the blank's buffer went straight back to the pool
        assert_eq!(pool.stats().recycled, 1);
    }
}
