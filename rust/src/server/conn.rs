//! Per-connection state machine for the event-driven frontend
//! (DESIGN.md §15): read buffer -> line framing -> dispatch (tracked by
//! a FIFO reply sequencer) -> write buffer, with pause/resume decisions
//! the reactor turns into poller interest changes.
//!
//! Everything except the socket reads/writes is plain data owned by the
//! reactor thread (no locks, no shared state), so framing, sequencing
//! and the backpressure rule unit-test here without a poller.

use std::collections::BTreeMap;
use std::io::{self, Read, Write};
use std::net::TcpStream;

/// Largest tolerated unterminated line.  A client that streams this much
/// without a newline is broken or hostile; the reactor hangs up instead
/// of buffering without bound.
pub const MAX_LINE: usize = 1 << 20;

/// Backpressure thresholds (DESIGN.md §15).  A connection's reads pause
/// when its un-drained output exceeds `write_buf_cap`, when more than
/// `max_inflight` of its lines are dispatched but unanswered, or after
/// admission control sheds one of its requests; reads resume at half the
/// watermark (hysteresis) so the interest registration doesn't flap.
#[derive(Debug, Clone, Copy)]
pub struct Backpressure {
    pub write_buf_cap: usize,
    pub max_inflight: u64,
}

impl Default for Backpressure {
    fn default() -> Self {
        Backpressure { write_buf_cap: 256 << 10, max_inflight: 128 }
    }
}

/// Accumulates raw socket bytes and yields complete `\n`-terminated
/// lines.  Partial tails survive between reads; `scan_from` remembers
/// how far the newline scan got so repeated pushes of a long partial
/// line stay O(new bytes), not O(buffer).
#[derive(Default)]
pub struct LineFramer {
    buf: Vec<u8>,
    scan_from: usize,
}

impl LineFramer {
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet returned as lines.
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Next complete line (terminator included), if one is buffered.
    pub fn next_line(&mut self) -> Option<String> {
        match self.buf[self.scan_from..].iter().position(|&b| b == b'\n') {
            Some(off) => {
                let raw: Vec<u8> = self.buf.drain(..=self.scan_from + off).collect();
                self.scan_from = 0;
                Some(String::from_utf8_lossy(&raw).into_owned())
            }
            None => {
                self.scan_from = self.buf.len();
                None
            }
        }
    }
}

/// Restores per-connection FIFO reply order over out-of-order worker
/// completions: lines get ascending sequence numbers at dispatch; a
/// completed reply is released only once every earlier one has been.
#[derive(Default)]
pub struct ReplySequencer {
    next_seq: u64,
    next_write: u64,
    stash: BTreeMap<u64, String>,
}

impl ReplySequencer {
    /// Claim the sequence number for a newly dispatched line.
    pub fn alloc(&mut self) -> u64 {
        let s = self.next_seq;
        self.next_seq += 1;
        s
    }

    /// Record one completion; push every reply now releasable (in
    /// sequence order) onto `out`.
    pub fn complete(&mut self, seq: u64, reply: String, out: &mut Vec<String>) {
        self.stash.insert(seq, reply);
        while let Some(r) = self.stash.remove(&self.next_write) {
            out.push(r);
            self.next_write += 1;
        }
    }

    /// Dispatched lines whose replies have not yet been released.
    pub fn outstanding(&self) -> u64 {
        self.next_seq - self.next_write
    }
}

/// One client connection owned by the reactor thread.
pub struct Conn {
    pub stream: TcpStream,
    framer: LineFramer,
    seq: ReplySequencer,
    wbuf: Vec<u8>,
    wpos: usize,
    /// (read, write) interest currently registered with the poller.
    pub registered: (bool, bool),
    /// Reads deliberately stopped by the backpressure rule.
    pub paused: bool,
    /// No more reads (client EOF or server drain); close once idle.
    pub closing: bool,
    /// I/O error observed; close immediately, dropping pending output.
    pub broken: bool,
    shed_pause: bool,
}

impl Conn {
    pub fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            framer: LineFramer::default(),
            seq: ReplySequencer::default(),
            wbuf: Vec::new(),
            wpos: 0,
            registered: (true, false),
            paused: false,
            closing: false,
            broken: false,
            shed_pause: false,
        }
    }

    /// Drain the socket until `WouldBlock` (or EOF, which marks the
    /// connection closing) and push every complete line onto `lines`.
    pub fn on_readable(&mut self, lines: &mut Vec<String>) -> io::Result<()> {
        let mut buf = [0u8; 16 * 1024];
        loop {
            match self.stream.read(&mut buf) {
                Ok(0) => {
                    self.closing = true;
                    break;
                }
                Ok(n) => {
                    self.framer.push(&buf[..n]);
                    if self.framer.buffered() > MAX_LINE {
                        return Err(io::Error::new(
                            io::ErrorKind::InvalidData,
                            "line exceeds MAX_LINE",
                        ));
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        while let Some(line) = self.framer.next_line() {
            lines.push(line);
        }
        Ok(())
    }

    /// Sequence number for a line about to be handed to a worker.
    pub fn alloc_seq(&mut self) -> u64 {
        self.seq.alloc()
    }

    /// Record one worker completion; in-order replies move to the write
    /// buffer (newline-terminated).  A shed completion arms the
    /// backpressure pause until the connection drains.
    pub fn complete(&mut self, seq: u64, reply: String, shed: bool) {
        let mut ready = Vec::new();
        self.seq.complete(seq, reply, &mut ready);
        for r in ready {
            self.wbuf.extend_from_slice(r.as_bytes());
            self.wbuf.push(b'\n');
        }
        if shed {
            self.shed_pause = true;
        }
    }

    /// Write buffered output until `WouldBlock` or empty.
    pub fn flush(&mut self) -> io::Result<()> {
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => {
                    return Err(io::Error::new(
                        io::ErrorKind::WriteZero,
                        "socket write returned 0",
                    ))
                }
                Ok(n) => self.wpos += n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        if self.wpos == self.wbuf.len() {
            self.wbuf.clear();
            self.wpos = 0;
        }
        Ok(())
    }

    /// Output bytes accepted but not yet written to the socket.
    pub fn buffered_out(&self) -> usize {
        self.wbuf.len() - self.wpos
    }

    /// Dispatched lines not yet answered in order.
    pub fn outstanding(&self) -> u64 {
        self.seq.outstanding()
    }

    /// Nothing in flight and nothing left to write.
    pub fn idle(&self) -> bool {
        self.outstanding() == 0 && self.buffered_out() == 0
    }

    /// The poller should watch this socket for writability.
    pub fn wants_write(&self) -> bool {
        self.buffered_out() > 0
    }

    /// A shed pause clears once the connection fully drains: the client
    /// has seen the overload reply, so reads may resume.
    pub fn update_shed(&mut self) {
        if self.shed_pause && self.idle() {
            self.shed_pause = false;
        }
    }

    /// The backpressure rule: stop polling for readability?
    pub fn should_pause(&self, bp: &Backpressure) -> bool {
        self.shed_pause
            || self.buffered_out() > bp.write_buf_cap
            || self.outstanding() > bp.max_inflight
    }

    /// Hysteresis: resume reads only once well below the watermarks.
    pub fn may_resume(&self, bp: &Backpressure) -> bool {
        !self.shed_pause
            && self.buffered_out() <= bp.write_buf_cap / 2
            && self.outstanding() <= bp.max_inflight / 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn framer_reassembles_lines_across_chunks() {
        let mut f = LineFramer::default();
        f.push(b"{\"id\":1}\n{\"id\"");
        assert_eq!(f.next_line().as_deref(), Some("{\"id\":1}\n"));
        assert_eq!(f.next_line(), None);
        f.push(b":2}\n\n{\"id\":3}");
        assert_eq!(f.next_line().as_deref(), Some("{\"id\":2}\n"));
        assert_eq!(f.next_line().as_deref(), Some("\n"), "empty line framed");
        assert_eq!(f.next_line(), None);
        assert_eq!(f.buffered(), "{\"id\":3}".len(), "partial tail retained");
        f.push(b"\n");
        assert_eq!(f.next_line().as_deref(), Some("{\"id\":3}\n"));
    }

    #[test]
    fn framer_scan_position_survives_partial_pushes() {
        let mut f = LineFramer::default();
        f.push(b"aaaa");
        assert_eq!(f.next_line(), None);
        // scan_from now sits at 4; the newline in the next chunk must
        // still be found even though it is past the first scan window
        f.push(b"bb\ncc");
        assert_eq!(f.next_line().as_deref(), Some("aaaabb\n"));
        assert_eq!(f.buffered(), 2);
    }

    #[test]
    fn sequencer_releases_replies_in_dispatch_order() {
        let mut s = ReplySequencer::default();
        let a = s.alloc();
        let b = s.alloc();
        let c = s.alloc();
        assert_eq!((a, b, c), (0, 1, 2));
        assert_eq!(s.outstanding(), 3);
        let mut out = Vec::new();
        s.complete(c, "C".into(), &mut out);
        assert!(out.is_empty(), "seq 2 waits for 0 and 1");
        s.complete(a, "A".into(), &mut out);
        assert_eq!(out, vec!["A"], "seq 0 releases alone");
        s.complete(b, "B".into(), &mut out);
        assert_eq!(out, vec!["A", "B", "C"], "seq 1 unblocks the stash");
        assert_eq!(s.outstanding(), 0);
    }

    #[test]
    fn backpressure_rule_and_hysteresis() {
        let bp = Backpressure { write_buf_cap: 100, max_inflight: 4 };
        let mut s = ReplySequencer::default();
        for _ in 0..5 {
            s.alloc();
        }
        // 5 in flight > 4: pause; resume only at <= 2
        assert!(s.outstanding() > bp.max_inflight);
        let mut out = Vec::new();
        s.complete(0, "r".into(), &mut out);
        s.complete(1, "r".into(), &mut out);
        assert_eq!(s.outstanding(), 3, "3 > max_inflight/2: still paused");
        assert!(s.outstanding() > bp.max_inflight / 2);
        s.complete(2, "r".into(), &mut out);
        assert!(s.outstanding() <= bp.max_inflight / 2, "2 <= 2: may resume");
    }
}
