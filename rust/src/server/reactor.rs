//! Event-driven serving frontend (DESIGN.md §15-§16): N reactor shards,
//! each an independent event loop multiplexing its connections over a
//! readiness poller, plus one shared worker pool sized to cores for
//! parse/infer/render.
//!
//! ```text
//!            accept           handoff (mpsc + wake datagram)
//!   listener ------> shard 0 --------------------------------+
//!                      |  \___ least-loaded / round-robin    |
//!                      v                                     v
//!                  [shard 0 loop]  [shard 1 loop] ... [shard N-1 loop]
//!                      |    ^          |    ^              |    ^
//!            epoll/poll|    |replies   |    |              |    |
//!                      v    |          v    |              v    |
//!                 +---------+----------+----+--------------+----+
//!                 |        shared ThreadPool (cores)            |
//!                 +---------------------------------------------+
//!                    line jobs (token,gen,seq) / completions
//!                    (per-shard mpsc + wake datagram)
//! ```
//!
//! Each shard owns its connection state (slab of [`Conn`]), its poller,
//! its completion channel and its wake socket -- no locks anywhere in
//! the readiness loop (`scripts/check_hotpath_locks.sh` pins `server/`
//! lock-free; the one justified lock is the
//! [`BufPool`](crate::util::bufpool::BufPool) free list).  Only shard 0
//! registers the listener: accepted sockets are handed to the
//! least-loaded shard (round-robin tiebreak) over that shard's handoff
//! channel, followed by a wake datagram -- SO_REUSEPORT semantics
//! without the socket option, which the vendored no-dep constraint
//! rules out.  Workers hand results back over the owning shard's mpsc
//! channel and wake its poller with a datagram on a loopback socket
//! pair; per-connection FIFO reply order is restored by each
//! connection's sequencer, so pipelined clients see answers in send
//! order even though workers finish out of order.
//!
//! The hot path is allocation-free in steady state: framed lines and
//! rendered replies travel in pooled buffers
//! (`scripts/check_hotpath_allocs.sh` freezes this file's allocation
//! count), and each connection's reply queue drains through one
//! `writev(2)` per readiness (see `server/conn.rs`).
//!
//! The poller is raw `epoll` via direct syscalls on Linux (std already
//! links libc; no external crates), with a portable `poll(2)` set as
//! fallback -- selectable for tests via [`ReactorConfig::force_poll`].
//!
//! Backpressure (the §15 rule): a connection whose write queue tops
//! the cap, whose in-flight count tops the limit, or which just got an
//! admission-control shed, is deregistered for readability until it
//! drains -- overload propagates to the client's TCP window instead of
//! unbounded server memory.
//!
//! Shutdown drain: on a `{"cmd":"shutdown"}` completion the observing
//! shard raises the shared stop flag and wakes every shard; each shard
//! stops accepting (shard 0 drops the listener registration), takes one
//! final exhaustive nonblocking read per connection so complete lines
//! already received are still answered, then loops until every
//! dispatched job has completed and every reply is flushed (or the
//! drain deadline passes), mirroring the threaded frontend's semantics
//! within the same ~[`READ_POLL`] bound.

use std::io;
use std::net::{TcpListener, TcpStream, UdpSocket};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::conn::{Backpressure, Conn, READ_SCRATCH};
use super::{dispatch_line_into, DispatchFlags, InferBackend, READ_POLL};
use crate::metrics::Gauge;
use crate::util::bufpool::{BufPool, PooledBuf};
use crate::util::threadpool::ThreadPool;

/// Poller slot for the listening socket (shard 0 only).
const TOKEN_LISTENER: usize = 0;
/// Poller slot for the shard's wake-up socket.
const TOKEN_WAKE: usize = 1;
/// First connection token; token = `TOKEN_CONN0 + slab slot`.
const TOKEN_CONN0: usize = 2;

/// Tuning for [`serve_reactor_with`]; `Default` is what
/// [`crate::server::serve`] runs in production.
#[derive(Debug, Clone, Copy)]
pub struct ReactorConfig {
    /// Worker threads for parse/infer/render, shared by all shards;
    /// 0 sizes to the machine (`available_parallelism`).
    pub workers: usize,
    /// Independent event-loop shards; 0 sizes to the machine
    /// (`min(4, cores/2)`, at least 1).
    pub shards: usize,
    /// Per-connection backpressure thresholds.
    pub backpressure: Backpressure,
    /// Use the portable `poll(2)` backend even where epoll exists.
    pub force_poll: bool,
    /// Upper bound on the shutdown drain (in-flight inference can
    /// legitimately take batching latency to finish).
    pub drain_deadline: Duration,
}

impl Default for ReactorConfig {
    fn default() -> Self {
        ReactorConfig {
            workers: 0,
            shards: 0,
            backpressure: Backpressure::default(),
            force_poll: false,
            drain_deadline: Duration::from_secs(10),
        }
    }
}

/// The shards=0 default: scale with the machine but stay modest -- the
/// event loop is rarely the bottleneck past a few shards, and workers
/// need cores too.
fn default_shards() -> usize {
    let cores =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    (cores / 2).clamp(1, 4)
}

/// One finished worker job on its way back to its shard.
struct Completion {
    token: usize,
    gen: u64,
    seq: u64,
    /// Rendered newline-terminated reply; empty for blank input lines.
    reply: PooledBuf,
    shutdown: bool,
    shed: bool,
}

/// Serve on the event-driven frontend with default tuning.
pub fn serve_reactor(pool: Arc<dyn InferBackend>, port: u16) -> Result<()> {
    serve_reactor_with(pool, port, ReactorConfig::default())
}

/// Serve on the event-driven frontend until a `{"cmd":"shutdown"}`.
/// Shard 0 runs on the calling thread; shards 1..N on spawned threads.
pub fn serve_reactor_with(
    backend: Arc<dyn InferBackend>,
    port: u16,
    cfg: ReactorConfig,
) -> Result<()> {
    let listener = TcpListener::bind(("127.0.0.1", port))?;
    listener.set_nonblocking(true)?;

    let shards = if cfg.shards == 0 { default_shards() } else { cfg.shards };
    let workers = if cfg.workers == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        cfg.workers
    };

    let jobs = Arc::new(ThreadPool::new(workers));
    let bufs = BufPool::new();
    let stop = Arc::new(AtomicBool::new(false));
    let conn_counts: Vec<Arc<AtomicUsize>> =
        (0..shards).map(|_| Arc::new(AtomicUsize::new(0))).collect();

    // workers (and the accept handoff) wake a shard's poller by lobbing
    // a datagram at its socket pair; loopback UDP never blocks the
    // sender, and a dropped datagram under a full buffer is harmless (a
    // full buffer means a wake is already pending)
    let mut wake_rxs = Vec::with_capacity(shards);
    let mut wake_txs = Vec::with_capacity(shards);
    for _ in 0..shards {
        let rx = UdpSocket::bind(("127.0.0.1", 0))?;
        rx.set_nonblocking(true)?;
        let tx = UdpSocket::bind(("127.0.0.1", 0))?;
        tx.connect(rx.local_addr()?)?;
        tx.set_nonblocking(true)?;
        wake_rxs.push(rx);
        wake_txs.push(Arc::new(tx));
    }
    // every shard can wake every other shard (stop propagation) and
    // shard 0 wakes handoff targets
    let wake_all: Arc<Vec<UdpSocket>> = Arc::new(
        wake_txs.iter().map(|t| t.try_clone()).collect::<io::Result<_>>()?,
    );

    let mut handoff_txs = Vec::with_capacity(shards);
    let mut handoff_rxs = Vec::with_capacity(shards);
    for _ in 0..shards {
        let (tx, rx) = channel::<TcpStream>();
        handoff_txs.push(tx);
        handoff_rxs.push(rx);
    }

    let metrics = Arc::clone(backend.metrics());
    let mut built = Vec::with_capacity(shards);
    let mut listener = Some(listener);
    for (id, (wake_rx, handoff_rx)) in
        wake_rxs.into_iter().zip(handoff_rxs).enumerate()
    {
        let mut poller = sys::best(cfg.force_poll)?;
        let shard_listener = if id == 0 { listener.take() } else { None };
        if let Some(l) = &shard_listener {
            poller.add(
                l.as_raw_fd(),
                TOKEN_LISTENER,
                sys::Interest { read: true, write: false },
            )?;
        }
        poller.add(
            wake_rx.as_raw_fd(),
            TOKEN_WAKE,
            sys::Interest { read: true, write: false },
        )?;
        let (comp_tx, comp_rx) = channel::<Completion>();
        built.push(Shard {
            id,
            cfg,
            poller,
            listener: shard_listener,
            wake_rx,
            wake_tx: Arc::clone(&wake_txs[id]),
            wake_all: Arc::clone(&wake_all),
            handoff_rx,
            handoff_txs: if id == 0 { handoff_txs.clone() } else { Vec::new() },
            conn_counts: conn_counts.clone(),
            rr: 0,
            jobs: Arc::clone(&jobs),
            backend: Arc::clone(&backend),
            bufs: Arc::clone(&bufs),
            comp_tx,
            comp_rx,
            conns: Vec::new(),
            gens: Vec::new(),
            free: Vec::new(),
            stop: Arc::clone(&stop),
            outstanding: 0,
            scratch: vec![0u8; READ_SCRATCH],
            conns_open: 0,
            conns_gauge: metrics.gauge(&format!("reactor_{id}_conns")),
            wakes: 0,
            wake_gauge: metrics.gauge(&format!("reactor_{id}_wake_total")),
        });
    }
    drop(handoff_txs); // shard 0 holds the only remaining senders

    let shard0 = built.remove(0);
    let mut handles = Vec::with_capacity(built.len());
    for shard in built {
        handles.push(
            std::thread::Builder::new()
                .name(format!("reactor-{}", shard.id))
                .spawn(move || shard.run_to_stop())?,
        );
    }
    let mut result = shard0.run_to_stop();
    for h in handles {
        match h.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                if result.is_ok() {
                    result = Err(e);
                }
            }
            Err(_) => {
                if result.is_ok() {
                    result = Err(anyhow::anyhow!("reactor shard panicked"));
                }
            }
        }
    }
    result
    // dropping the last Arc<ThreadPool> joins the workers: queued jobs
    // finish, their completions land in closed channels, their pooled
    // buffers recycle or free
}

/// One event-loop shard: its own poller, slab, completion channel and
/// wake socket; shard 0 additionally owns the listener and the handoff
/// senders.
struct Shard {
    id: usize,
    cfg: ReactorConfig,
    poller: Box<dyn sys::Poller>,
    listener: Option<TcpListener>,
    wake_rx: UdpSocket,
    /// This shard's wake sender; workers clone it per job.
    wake_tx: Arc<UdpSocket>,
    /// Wake senders for every shard (indexed by shard id).
    wake_all: Arc<Vec<UdpSocket>>,
    handoff_rx: Receiver<TcpStream>,
    /// Accept-handoff senders (shard 0 only; empty elsewhere).
    handoff_txs: Vec<Sender<TcpStream>>,
    /// Live connections charged to each shard at accept time; the
    /// owning shard releases on close.  Shard 0 reads all of them for
    /// the least-loaded pick.
    conn_counts: Vec<Arc<AtomicUsize>>,
    /// Round-robin cursor breaking least-loaded ties.
    rr: usize,
    jobs: Arc<ThreadPool>,
    backend: Arc<dyn InferBackend>,
    bufs: Arc<BufPool>,
    comp_tx: Sender<Completion>,
    comp_rx: Receiver<Completion>,
    /// Connection slab; the token encodes the slot.
    conns: Vec<Option<Conn>>,
    /// Per-slot generation, bumped on close so completions for a dead
    /// connection never reach a reused slot.
    gens: Vec<u64>,
    free: Vec<usize>,
    /// Shared across shards; any shard's shutdown completion raises it.
    stop: Arc<AtomicBool>,
    /// Jobs dispatched to workers whose completions have not come back
    /// (counted across this shard's connections, including closed ones).
    outstanding: usize,
    /// Read scratch shared by every connection on this shard.
    scratch: Vec<u8>,
    conns_open: usize,
    conns_gauge: Arc<Gauge>,
    wakes: u64,
    wake_gauge: Arc<Gauge>,
}

impl Shard {
    /// Run the shard loop; on the way out (drain finished or error),
    /// raise the shared stop flag and wake the other shards so one
    /// shard's exit can never strand the rest.
    fn run_to_stop(mut self) -> Result<()> {
        let r = self.run();
        self.stop.store(true, Ordering::SeqCst);
        for w in self.wake_all.iter() {
            let _ = w.send(&[1]);
        }
        r
    }

    fn run(&mut self) -> Result<()> {
        let mut events: Vec<sys::Event> = Vec::new();
        let mut stopping_since: Option<Instant> = None;
        loop {
            self.poller.wait(&mut events, READ_POLL)?;
            for ev in &events {
                match ev.token {
                    TOKEN_LISTENER => self.on_accept()?,
                    TOKEN_WAKE => self.drain_wake(),
                    t => self.on_conn_event(t - TOKEN_CONN0, *ev),
                }
            }
            self.install_handoffs();
            self.drain_completions();
            if self.stop.load(Ordering::SeqCst) && stopping_since.is_none() {
                stopping_since = Some(Instant::now());
                self.begin_drain();
            }
            if let Some(t0) = stopping_since {
                self.sweep_closing();
                if self.outstanding == 0 && self.conns.iter().all(Option::is_none)
                {
                    break;
                }
                if t0.elapsed() > self.cfg.drain_deadline {
                    break;
                }
            }
        }
        Ok(())
    }

    /// Accept every pending connection (shard 0 only) and charge each
    /// to the least-loaded shard: installed locally when that is us,
    /// handed over the target's channel (then a wake datagram) when
    /// not.
    fn on_accept(&mut self) -> Result<()> {
        loop {
            match self.listener.as_ref().expect("accept without listener").accept()
            {
                Ok((stream, _addr)) => {
                    if self.stop.load(Ordering::SeqCst) {
                        continue; // accepted post-shutdown: hang up
                    }
                    let target = self.pick_shard();
                    self.conn_counts[target].fetch_add(1, Ordering::Relaxed);
                    if target == self.id {
                        if self.install(stream).is_err() {
                            self.conn_counts[target]
                                .fetch_sub(1, Ordering::Relaxed);
                        }
                    } else if self.handoff_txs[target].send(stream).is_ok() {
                        let _ = self.wake_all[target].send(&[1]);
                    } else {
                        // target shard already exited (draining): hang up
                        self.conn_counts[target].fetch_sub(1, Ordering::Relaxed);
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e.into()),
            }
        }
        Ok(())
    }

    /// Least-loaded shard by charged connection count; ties break by a
    /// rotating scan start so equal shards take turns.
    fn pick_shard(&mut self) -> usize {
        let n = self.conn_counts.len();
        let mut best = self.rr % n;
        let mut best_load = usize::MAX;
        for k in 0..n {
            let i = (self.rr + k) % n;
            let load = self.conn_counts[i].load(Ordering::Relaxed);
            if load < best_load {
                best_load = load;
                best = i;
            }
        }
        self.rr = (best + 1) % n;
        best
    }

    /// Adopt connections handed over by the accepting shard.  Runs
    /// every loop tick: the wake datagram makes it prompt, the tick
    /// makes it certain.  Streams arriving after stop are dropped (hang
    /// up), matching the accepted-post-shutdown rule.
    fn install_handoffs(&mut self) {
        while let Ok(stream) = self.handoff_rx.try_recv() {
            if self.stop.load(Ordering::SeqCst) || self.install(stream).is_err()
            {
                // release the count the acceptor charged to us
                self.conn_counts[self.id].fetch_sub(1, Ordering::Relaxed);
            }
        }
    }

    /// Register one accepted/adopted stream into this shard's slab and
    /// poller.  The caller has already charged `conn_counts`.
    fn install(&mut self, stream: TcpStream) -> io::Result<()> {
        stream.set_nonblocking(true)?;
        // line-RPC: Nagle + delayed-ACK adds ~40-90ms per turn
        stream.set_nodelay(true)?;
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                self.conns.push(None);
                self.gens.push(0);
                self.conns.len() - 1
            }
        };
        let fd = stream.as_raw_fd();
        if let Err(e) = self.poller.add(
            fd,
            TOKEN_CONN0 + slot,
            sys::Interest { read: true, write: false },
        ) {
            self.free.push(slot);
            return Err(e);
        }
        self.conns[slot] = Some(Conn::new(stream));
        self.conns_open += 1;
        self.conns_gauge.set(self.conns_open as f64);
        Ok(())
    }

    fn drain_wake(&mut self) {
        let mut buf = [0u8; 256];
        while self.wake_rx.recv_from(&mut buf).is_ok() {
            self.wakes += 1;
        }
        self.wake_gauge.set(self.wakes as f64);
    }

    fn on_conn_event(&mut self, slot: usize, ev: sys::Event) {
        {
            let Some(conn) = self.conns.get_mut(slot).and_then(Option::as_mut)
            else {
                return;
            };
            if ev.hangup {
                conn.broken = true;
            } else {
                if ev.readable
                    && !conn.paused
                    && !conn.closing
                    && conn.on_readable(&mut self.scratch).is_err()
                {
                    conn.broken = true;
                }
                if ev.writable && conn.flush().is_err() {
                    conn.broken = true;
                }
            }
        }
        self.pump_lines(slot);
        self.after_io(slot);
    }

    /// Move every complete framed line on `slot` into a pooled buffer
    /// and hand it to the worker pool.  Blank lines dispatch too (the
    /// worker answers them with an empty reply, which the connection
    /// drops), so the decode -- including the Unicode-aware trim --
    /// happens off the event loop.
    fn pump_lines(&mut self, slot: usize) {
        loop {
            let line;
            let seq;
            {
                let Some(conn) =
                    self.conns.get_mut(slot).and_then(Option::as_mut)
                else {
                    return;
                };
                if conn.broken {
                    return;
                }
                let Some(end) = conn.next_line_end() else {
                    return;
                };
                let mut l = self.bufs.get();
                conn.take_line(end, &mut l);
                line = l;
                seq = conn.alloc_seq();
            }
            let gen = self.gens[slot];
            let token = TOKEN_CONN0 + slot;
            self.outstanding += 1;
            let backend = Arc::clone(&self.backend);
            let bufs = Arc::clone(&self.bufs);
            let tx = self.comp_tx.clone();
            let wake = Arc::clone(&self.wake_tx);
            self.jobs.execute(move || {
                let mut reply = bufs.get();
                let flags = run_line(backend.as_ref(), &line, &mut reply);
                drop(line); // recycle the request buffer before the wake
                let _ = tx.send(Completion {
                    token,
                    gen,
                    seq,
                    reply,
                    shutdown: flags.shutdown,
                    shed: flags.shed,
                });
                let _ = wake.send(&[1]);
            });
        }
    }

    fn drain_completions(&mut self) {
        while let Ok(c) = self.comp_rx.try_recv() {
            self.outstanding = self.outstanding.saturating_sub(1);
            if c.shutdown && !self.stop.swap(true, Ordering::SeqCst) {
                // first observer wakes the whole fleet into its drain
                for w in self.wake_all.iter() {
                    let _ = w.send(&[1]);
                }
            }
            let slot = c.token - TOKEN_CONN0;
            if self.gens.get(slot).copied() != Some(c.gen) {
                continue; // connection died while the job ran
            }
            {
                let Some(conn) =
                    self.conns.get_mut(slot).and_then(Option::as_mut)
                else {
                    continue;
                };
                conn.complete(c.seq, c.reply, c.shed);
                if conn.flush().is_err() {
                    conn.broken = true;
                }
            }
            self.after_io(slot);
        }
    }

    /// Re-derive pause state and poller interest after any I/O or
    /// completion touched `slot`; close it if finished or broken.
    fn after_io(&mut self, slot: usize) {
        let mut reg_change = None;
        let close;
        {
            let Some(conn) = self.conns.get_mut(slot).and_then(Option::as_mut)
            else {
                return;
            };
            conn.update_shed();
            if conn.broken || (conn.closing && conn.idle()) {
                close = true;
            } else {
                close = false;
                let bp = &self.cfg.backpressure;
                if conn.paused {
                    if conn.may_resume(bp) {
                        conn.paused = false;
                    }
                } else if conn.should_pause(bp) {
                    conn.paused = true;
                }
                let want = (!conn.paused && !conn.closing, conn.wants_write());
                if want != conn.registered {
                    conn.registered = want;
                    reg_change = Some((conn.stream.as_raw_fd(), want));
                }
            }
        }
        if let Some((fd, (read, write))) = reg_change {
            let _ = self.poller.modify(
                fd,
                TOKEN_CONN0 + slot,
                sys::Interest { read, write },
            );
        }
        if close {
            self.close_conn(slot);
        }
    }

    fn close_conn(&mut self, slot: usize) {
        if let Some(conn) = self.conns[slot].take() {
            let _ = self.poller.remove(conn.stream.as_raw_fd());
            self.gens[slot] = self.gens[slot].wrapping_add(1);
            self.free.push(slot);
            self.conn_counts[self.id].fetch_sub(1, Ordering::Relaxed);
            self.conns_open -= 1;
            self.conns_gauge.set(self.conns_open as f64);
            // dropping conn closes the socket and recycles its buffers
        }
    }

    /// Enter the shutdown drain: stop accepting, take one final
    /// exhaustive read per connection (complete lines already received
    /// are still answered), and mark everything closing.
    fn begin_drain(&mut self) {
        if let Some(l) = &self.listener {
            let _ = self.poller.remove(l.as_raw_fd());
        }
        for slot in 0..self.conns.len() {
            {
                let Some(conn) = self.conns[slot].as_mut() else {
                    continue;
                };
                if !conn.paused
                    && !conn.closing
                    && conn.read_all(&mut self.scratch).is_err()
                {
                    conn.broken = true;
                }
                conn.closing = true;
            }
            self.pump_lines(slot);
            self.after_io(slot);
        }
    }

    /// One drain-phase pass: flush what can be flushed, close what is
    /// finished.
    fn sweep_closing(&mut self) {
        for slot in 0..self.conns.len() {
            let done = match self.conns[slot].as_mut() {
                Some(conn) => {
                    if conn.flush().is_err() {
                        conn.broken = true;
                    }
                    conn.broken || conn.idle()
                }
                None => continue,
            };
            if done {
                self.close_conn(slot);
            }
        }
    }
}

/// Decode and answer one framed raw line on a worker thread, rendering
/// into the pooled `reply`.  A blank (whitespace-only) line leaves
/// `reply` empty -- the connection advances its sequence without
/// putting bytes on the wire, matching the threaded frontend's skip.
/// Invalid UTF-8 decodes lossily (cold path) so the parser renders the
/// same error bytes the threaded frontend would.
fn run_line(
    backend: &dyn InferBackend,
    raw: &[u8],
    reply: &mut Vec<u8>,
) -> DispatchFlags {
    let lossy;
    let text = match std::str::from_utf8(raw) {
        Ok(s) => s,
        Err(_) => {
            lossy = String::from_utf8_lossy(raw).into_owned();
            &lossy
        }
    };
    let line = text.trim();
    if line.is_empty() {
        return DispatchFlags { shutdown: false, shed: false };
    }
    let flags = dispatch_line_into(backend, line, reply);
    reply.push(b'\n');
    flags
}

/// Readiness pollers: raw epoll on Linux, portable `poll(2)` elsewhere
/// (and on demand for tests).  Both speak through direct `extern "C"`
/// declarations -- std already links libc, so this adds no dependency.
pub mod sys {
    use std::io;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    /// What a registration wants to hear about.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub struct Interest {
        pub read: bool,
        pub write: bool,
    }

    /// One readiness notification.
    #[derive(Debug, Clone, Copy)]
    pub struct Event {
        pub token: usize,
        pub readable: bool,
        pub writable: bool,
        pub hangup: bool,
    }

    /// A level-triggered readiness poller.
    pub trait Poller: Send {
        fn add(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()>;
        fn modify(
            &mut self,
            fd: RawFd,
            token: usize,
            interest: Interest,
        ) -> io::Result<()>;
        fn remove(&mut self, fd: RawFd) -> io::Result<()>;
        /// Fill `out` with ready events (cleared first); an interrupted
        /// wait returns empty rather than erroring.
        fn wait(&mut self, out: &mut Vec<Event>, timeout: Duration) -> io::Result<()>;
        fn name(&self) -> &'static str;
    }

    /// Best poller for this platform: epoll where available unless
    /// `force_poll` asks for the portable fallback.
    pub fn best(force_poll: bool) -> io::Result<Box<dyn Poller>> {
        #[cfg(target_os = "linux")]
        if !force_poll {
            return Ok(Box::new(epoll::Epoll::new()?));
        }
        let _ = force_poll;
        Ok(Box::new(pollset::PollSet::new()))
    }

    /// Raw epoll via direct syscall wrappers (Linux only).
    #[cfg(target_os = "linux")]
    pub mod epoll {
        use super::{Event, Interest, Poller};
        use std::io;
        use std::os::unix::io::RawFd;
        use std::time::Duration;

        // the kernel ABI packs epoll_event on x86_64 (__EPOLL_PACKED)
        // and aligns it naturally everywhere else
        #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
        #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
        #[derive(Clone, Copy)]
        struct EpollEvent {
            events: u32,
            data: u64,
        }

        extern "C" {
            fn epoll_create1(flags: i32) -> i32;
            fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
            fn epoll_wait(
                epfd: i32,
                events: *mut EpollEvent,
                maxevents: i32,
                timeout_ms: i32,
            ) -> i32;
            fn close(fd: i32) -> i32;
        }

        const EPOLL_CLOEXEC: i32 = 0o2000000;
        const EPOLL_CTL_ADD: i32 = 1;
        const EPOLL_CTL_DEL: i32 = 2;
        const EPOLL_CTL_MOD: i32 = 3;
        const EPOLLIN: u32 = 0x001;
        const EPOLLOUT: u32 = 0x004;
        const EPOLLERR: u32 = 0x008;
        const EPOLLHUP: u32 = 0x010;

        pub struct Epoll {
            epfd: RawFd,
            buf: Vec<EpollEvent>,
        }

        impl Epoll {
            pub fn new() -> io::Result<Epoll> {
                let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
                if epfd < 0 {
                    return Err(io::Error::last_os_error());
                }
                Ok(Epoll { epfd, buf: vec![EpollEvent { events: 0, data: 0 }; 512] })
            }

            fn ctl(
                &self,
                op: i32,
                fd: RawFd,
                token: usize,
                interest: Interest,
            ) -> io::Result<()> {
                let mut ev = EpollEvent { events: mask(interest), data: token as u64 };
                let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
                if rc < 0 {
                    Err(io::Error::last_os_error())
                } else {
                    Ok(())
                }
            }
        }

        fn mask(i: Interest) -> u32 {
            (if i.read { EPOLLIN } else { 0 }) | (if i.write { EPOLLOUT } else { 0 })
        }

        impl Poller for Epoll {
            fn add(
                &mut self,
                fd: RawFd,
                token: usize,
                interest: Interest,
            ) -> io::Result<()> {
                self.ctl(EPOLL_CTL_ADD, fd, token, interest)
            }

            fn modify(
                &mut self,
                fd: RawFd,
                token: usize,
                interest: Interest,
            ) -> io::Result<()> {
                self.ctl(EPOLL_CTL_MOD, fd, token, interest)
            }

            fn remove(&mut self, fd: RawFd) -> io::Result<()> {
                // pre-2.6.9 kernels insist on a non-null event for DEL
                self.ctl(EPOLL_CTL_DEL, fd, 0, Interest { read: false, write: false })
            }

            fn wait(
                &mut self,
                out: &mut Vec<Event>,
                timeout: Duration,
            ) -> io::Result<()> {
                out.clear();
                let ms = timeout.as_millis().min(i32::MAX as u128) as i32;
                let n = unsafe {
                    epoll_wait(self.epfd, self.buf.as_mut_ptr(), self.buf.len() as i32, ms)
                };
                if n < 0 {
                    let e = io::Error::last_os_error();
                    if e.kind() == io::ErrorKind::Interrupted {
                        return Ok(());
                    }
                    return Err(e);
                }
                for ev in &self.buf[..n as usize] {
                    // copy fields out of the (possibly packed) struct
                    let bits = ev.events;
                    let data = ev.data;
                    out.push(Event {
                        token: data as usize,
                        readable: bits & EPOLLIN != 0,
                        writable: bits & EPOLLOUT != 0,
                        hangup: bits & (EPOLLERR | EPOLLHUP) != 0,
                    });
                }
                Ok(())
            }

            fn name(&self) -> &'static str {
                "epoll"
            }
        }

        impl Drop for Epoll {
            fn drop(&mut self) {
                unsafe {
                    close(self.epfd);
                }
            }
        }
    }

    /// Portable fallback: rebuild a pollfd array per wait.  O(n) per
    /// tick where epoll is O(ready), fine as a fallback and for tests.
    pub mod pollset {
        use super::{Event, Interest, Poller};
        use std::io;
        use std::os::unix::io::RawFd;
        use std::time::Duration;

        #[repr(C)]
        #[derive(Clone, Copy)]
        struct PollFd {
            fd: i32,
            events: i16,
            revents: i16,
        }

        #[cfg(target_os = "linux")]
        type Nfds = std::os::raw::c_ulong;
        #[cfg(not(target_os = "linux"))]
        type Nfds = std::os::raw::c_uint;

        extern "C" {
            fn poll(fds: *mut PollFd, nfds: Nfds, timeout_ms: i32) -> i32;
        }

        const POLLIN: i16 = 0x001;
        const POLLOUT: i16 = 0x004;
        const POLLERR: i16 = 0x008;
        const POLLHUP: i16 = 0x010;
        const POLLNVAL: i16 = 0x020;

        struct Entry {
            fd: RawFd,
            token: usize,
            interest: Interest,
        }

        #[derive(Default)]
        pub struct PollSet {
            entries: Vec<Entry>,
            buf: Vec<PollFd>,
        }

        impl PollSet {
            pub fn new() -> PollSet {
                PollSet::default()
            }
        }

        impl Poller for PollSet {
            fn add(
                &mut self,
                fd: RawFd,
                token: usize,
                interest: Interest,
            ) -> io::Result<()> {
                if self.entries.iter().any(|e| e.fd == fd) {
                    return Err(io::Error::new(
                        io::ErrorKind::AlreadyExists,
                        "fd already registered",
                    ));
                }
                self.entries.push(Entry { fd, token, interest });
                Ok(())
            }

            fn modify(
                &mut self,
                fd: RawFd,
                token: usize,
                interest: Interest,
            ) -> io::Result<()> {
                for e in &mut self.entries {
                    if e.fd == fd {
                        e.token = token;
                        e.interest = interest;
                        return Ok(());
                    }
                }
                Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered"))
            }

            fn remove(&mut self, fd: RawFd) -> io::Result<()> {
                let before = self.entries.len();
                self.entries.retain(|e| e.fd != fd);
                if self.entries.len() == before {
                    return Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered"));
                }
                Ok(())
            }

            fn wait(
                &mut self,
                out: &mut Vec<Event>,
                timeout: Duration,
            ) -> io::Result<()> {
                out.clear();
                self.buf.clear();
                for e in &self.entries {
                    // errors/hangups report regardless of the mask, so a
                    // fully paused connection still gets noticed
                    let mut events = 0i16;
                    if e.interest.read {
                        events |= POLLIN;
                    }
                    if e.interest.write {
                        events |= POLLOUT;
                    }
                    self.buf.push(PollFd { fd: e.fd, events, revents: 0 });
                }
                let ms = timeout.as_millis().min(i32::MAX as u128) as i32;
                let n = unsafe {
                    poll(self.buf.as_mut_ptr(), self.buf.len() as Nfds, ms)
                };
                if n < 0 {
                    let e = io::Error::last_os_error();
                    if e.kind() == io::ErrorKind::Interrupted {
                        return Ok(());
                    }
                    return Err(e);
                }
                for (e, p) in self.entries.iter().zip(self.buf.iter()) {
                    if p.revents == 0 {
                        continue;
                    }
                    out.push(Event {
                        token: e.token,
                        readable: p.revents & POLLIN != 0,
                        writable: p.revents & POLLOUT != 0,
                        hangup: p.revents & (POLLERR | POLLHUP | POLLNVAL) != 0,
                    });
                }
                Ok(())
            }

            fn name(&self) -> &'static str {
                "poll"
            }
        }
    }
}
