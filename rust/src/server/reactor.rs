//! Event-driven serving frontend (DESIGN.md §15): one reactor thread
//! multiplexing every connection over a readiness poller, plus a fixed
//! worker pool sized to cores for parse/infer/render.
//!
//! ```text
//!             epoll/poll                    ThreadPool (cores)
//!   sockets ----------------> reactor ----------------------> workers
//!      ^     readable:frame     |   line jobs (token,gen,seq)    |
//!      |     writable:flush     |                                |
//!      +------- replies --------+<---- completions (mpsc) -------+
//!                               ^        + wake datagram
//! ```
//!
//! The reactor thread owns all connection state (slab of [`Conn`]) --
//! no locks anywhere in the readiness loop (`scripts/
//! check_hotpath_locks.sh` pins `server/` lock-free).  Workers hand
//! results back over an mpsc channel and wake the poller with a
//! datagram on a loopback socket pair; per-connection FIFO reply order
//! is restored by each connection's sequencer, so pipelined clients
//! see answers in send order even though workers finish out of order.
//!
//! The poller is raw `epoll` via direct syscalls on Linux (std already
//! links libc; no external crates), with a portable `poll(2)` set as
//! fallback -- selectable for tests via [`ReactorConfig::force_poll`].
//!
//! Backpressure (the §15 rule): a connection whose write buffer tops
//! the cap, whose in-flight count tops the limit, or which just got an
//! admission-control shed, is deregistered for readability until it
//! drains -- overload propagates to the client's TCP window instead of
//! unbounded server memory.
//!
//! Shutdown drain: on a `{"cmd":"shutdown"}` completion the reactor
//! stops accepting, takes one final nonblocking read per connection so
//! complete lines already received are still answered, then loops until
//! every dispatched job has completed and every reply is flushed (or
//! the drain deadline passes), mirroring the threaded frontend's
//! semantics within the same ~[`READ_POLL`] bound.

use std::io;
use std::net::{TcpListener, UdpSocket};
use std::os::unix::io::AsRawFd;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::conn::{Backpressure, Conn};
use super::{dispatch_line, InferBackend, READ_POLL};
use crate::util::threadpool::ThreadPool;

/// Poller slot for the listening socket.
const TOKEN_LISTENER: usize = 0;
/// Poller slot for the worker wake-up socket.
const TOKEN_WAKE: usize = 1;
/// First connection token; token = `TOKEN_CONN0 + slab slot`.
const TOKEN_CONN0: usize = 2;

/// Tuning for [`serve_reactor_with`]; `Default` is what
/// [`crate::server::serve`] runs in production.
#[derive(Debug, Clone, Copy)]
pub struct ReactorConfig {
    /// Worker threads for parse/infer/render; 0 sizes to the machine
    /// (`available_parallelism`).
    pub workers: usize,
    /// Per-connection backpressure thresholds.
    pub backpressure: Backpressure,
    /// Use the portable `poll(2)` backend even where epoll exists.
    pub force_poll: bool,
    /// Upper bound on the shutdown drain (in-flight inference can
    /// legitimately take batching latency to finish).
    pub drain_deadline: Duration,
}

impl Default for ReactorConfig {
    fn default() -> Self {
        ReactorConfig {
            workers: 0,
            backpressure: Backpressure::default(),
            force_poll: false,
            drain_deadline: Duration::from_secs(10),
        }
    }
}

/// One finished worker job on its way back to the reactor.
struct Completion {
    token: usize,
    gen: u64,
    seq: u64,
    reply: String,
    shutdown: bool,
    shed: bool,
}

/// Serve on the event-driven frontend with default tuning.
pub fn serve_reactor(pool: Arc<dyn InferBackend>, port: u16) -> Result<()> {
    serve_reactor_with(pool, port, ReactorConfig::default())
}

/// Serve on the event-driven frontend until a `{"cmd":"shutdown"}`.
pub fn serve_reactor_with(
    backend: Arc<dyn InferBackend>,
    port: u16,
    cfg: ReactorConfig,
) -> Result<()> {
    let listener = TcpListener::bind(("127.0.0.1", port))?;
    listener.set_nonblocking(true)?;

    // workers wake the poller by lobbing a datagram at this socket pair;
    // loopback UDP never blocks the sender, and a dropped datagram under
    // a full buffer is harmless (a full buffer means a wake is already
    // pending)
    let wake_rx = UdpSocket::bind(("127.0.0.1", 0))?;
    wake_rx.set_nonblocking(true)?;
    let wake_tx = UdpSocket::bind(("127.0.0.1", 0))?;
    wake_tx.connect(wake_rx.local_addr()?)?;
    wake_tx.set_nonblocking(true)?;

    let workers = if cfg.workers == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        cfg.workers
    };
    let (comp_tx, comp_rx) = channel::<Completion>();

    let mut poller = sys::best(cfg.force_poll)?;
    poller.add(
        listener.as_raw_fd(),
        TOKEN_LISTENER,
        sys::Interest { read: true, write: false },
    )?;
    poller.add(
        wake_rx.as_raw_fd(),
        TOKEN_WAKE,
        sys::Interest { read: true, write: false },
    )?;

    let mut reactor = Reactor {
        cfg,
        poller,
        listener,
        wake_rx,
        wake_tx: Arc::new(wake_tx),
        jobs: ThreadPool::new(workers),
        backend,
        comp_tx,
        comp_rx,
        conns: Vec::new(),
        gens: Vec::new(),
        free: Vec::new(),
        stop: false,
        outstanding: 0,
    };
    reactor.run()
}

struct Reactor {
    cfg: ReactorConfig,
    poller: Box<dyn sys::Poller>,
    listener: TcpListener,
    wake_rx: UdpSocket,
    wake_tx: Arc<UdpSocket>,
    jobs: ThreadPool,
    backend: Arc<dyn InferBackend>,
    comp_tx: Sender<Completion>,
    comp_rx: Receiver<Completion>,
    /// Connection slab; the token encodes the slot.
    conns: Vec<Option<Conn>>,
    /// Per-slot generation, bumped on close so completions for a dead
    /// connection never reach a reused slot.
    gens: Vec<u64>,
    free: Vec<usize>,
    stop: bool,
    /// Jobs dispatched to workers whose completions have not come back
    /// (counted across all connections, including closed ones).
    outstanding: usize,
}

impl Reactor {
    fn run(&mut self) -> Result<()> {
        let mut events: Vec<sys::Event> = Vec::new();
        let mut stopping_since: Option<Instant> = None;
        loop {
            self.poller.wait(&mut events, READ_POLL)?;
            for ev in &events {
                match ev.token {
                    TOKEN_LISTENER => self.on_accept()?,
                    TOKEN_WAKE => self.drain_wake(),
                    t => self.on_conn_event(t - TOKEN_CONN0, *ev),
                }
            }
            self.drain_completions();
            if self.stop && stopping_since.is_none() {
                stopping_since = Some(Instant::now());
                self.begin_drain();
            }
            if let Some(t0) = stopping_since {
                self.sweep_closing();
                if self.outstanding == 0 && self.conns.iter().all(Option::is_none) {
                    break;
                }
                if t0.elapsed() > self.cfg.drain_deadline {
                    break;
                }
            }
        }
        Ok(())
        // dropping self.jobs joins the workers: queued jobs finish, their
        // completions land in a closed channel and are discarded
    }

    fn on_accept(&mut self) -> Result<()> {
        loop {
            match self.listener.accept() {
                Ok((stream, _addr)) => {
                    if self.stop {
                        continue; // accepted post-shutdown: hang up
                    }
                    stream.set_nonblocking(true)?;
                    // line-RPC: Nagle + delayed-ACK adds ~40-90ms per turn
                    stream.set_nodelay(true)?;
                    let slot = match self.free.pop() {
                        Some(s) => s,
                        None => {
                            self.conns.push(None);
                            self.gens.push(0);
                            self.conns.len() - 1
                        }
                    };
                    let fd = stream.as_raw_fd();
                    if self
                        .poller
                        .add(
                            fd,
                            TOKEN_CONN0 + slot,
                            sys::Interest { read: true, write: false },
                        )
                        .is_err()
                    {
                        self.free.push(slot);
                        continue;
                    }
                    self.conns[slot] = Some(Conn::new(stream));
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e.into()),
            }
        }
        Ok(())
    }

    fn drain_wake(&mut self) {
        let mut buf = [0u8; 256];
        while self.wake_rx.recv_from(&mut buf).is_ok() {}
    }

    fn on_conn_event(&mut self, slot: usize, ev: sys::Event) {
        let mut lines: Vec<String> = Vec::new();
        {
            let Some(conn) = self.conns.get_mut(slot).and_then(Option::as_mut) else {
                return;
            };
            if ev.hangup {
                conn.broken = true;
            } else {
                if ev.readable
                    && !conn.paused
                    && !conn.closing
                    && conn.on_readable(&mut lines).is_err()
                {
                    conn.broken = true;
                }
                if ev.writable && conn.flush().is_err() {
                    conn.broken = true;
                }
            }
        }
        for line in lines {
            self.dispatch(slot, line);
        }
        self.after_io(slot);
    }

    /// Hand one framed line to the worker pool.
    fn dispatch(&mut self, slot: usize, line: String) {
        if line.trim().is_empty() {
            return; // blank keep-alive lines get no reply (both frontends)
        }
        let Some(conn) = self.conns.get_mut(slot).and_then(Option::as_mut) else {
            return;
        };
        let seq = conn.alloc_seq();
        let gen = self.gens[slot];
        let token = TOKEN_CONN0 + slot;
        self.outstanding += 1;
        let backend = Arc::clone(&self.backend);
        let tx = self.comp_tx.clone();
        let wake = Arc::clone(&self.wake_tx);
        self.jobs.execute(move || {
            let d = dispatch_line(backend.as_ref(), line.trim());
            let _ = tx.send(Completion {
                token,
                gen,
                seq,
                reply: d.reply,
                shutdown: d.shutdown,
                shed: d.shed,
            });
            let _ = wake.send(&[1]);
        });
    }

    fn drain_completions(&mut self) {
        while let Ok(c) = self.comp_rx.try_recv() {
            self.outstanding = self.outstanding.saturating_sub(1);
            if c.shutdown {
                self.stop = true;
            }
            let slot = c.token - TOKEN_CONN0;
            if self.gens.get(slot).copied() != Some(c.gen) {
                continue; // connection died while the job ran
            }
            {
                let Some(conn) = self.conns.get_mut(slot).and_then(Option::as_mut) else {
                    continue;
                };
                conn.complete(c.seq, c.reply, c.shed);
                if conn.flush().is_err() {
                    conn.broken = true;
                }
            }
            self.after_io(slot);
        }
    }

    /// Re-derive pause state and poller interest after any I/O or
    /// completion touched `slot`; close it if finished or broken.
    fn after_io(&mut self, slot: usize) {
        let mut reg_change = None;
        let close;
        {
            let Some(conn) = self.conns.get_mut(slot).and_then(Option::as_mut) else {
                return;
            };
            conn.update_shed();
            if conn.broken || (conn.closing && conn.idle()) {
                close = true;
            } else {
                close = false;
                let bp = &self.cfg.backpressure;
                if conn.paused {
                    if conn.may_resume(bp) {
                        conn.paused = false;
                    }
                } else if conn.should_pause(bp) {
                    conn.paused = true;
                }
                let want = (!conn.paused && !conn.closing, conn.wants_write());
                if want != conn.registered {
                    conn.registered = want;
                    reg_change = Some((conn.stream.as_raw_fd(), want));
                }
            }
        }
        if let Some((fd, (read, write))) = reg_change {
            let _ = self.poller.modify(
                fd,
                TOKEN_CONN0 + slot,
                sys::Interest { read, write },
            );
        }
        if close {
            self.close_conn(slot);
        }
    }

    fn close_conn(&mut self, slot: usize) {
        if let Some(conn) = self.conns[slot].take() {
            let _ = self.poller.remove(conn.stream.as_raw_fd());
            self.gens[slot] = self.gens[slot].wrapping_add(1);
            self.free.push(slot);
            // dropping conn closes the socket
        }
    }

    /// Enter the shutdown drain: stop accepting, take one final read per
    /// connection (complete lines already received are still answered),
    /// and mark everything closing.
    fn begin_drain(&mut self) {
        let _ = self.poller.remove(self.listener.as_raw_fd());
        for slot in 0..self.conns.len() {
            let mut lines = Vec::new();
            {
                let Some(conn) = self.conns[slot].as_mut() else {
                    continue;
                };
                if !conn.paused
                    && !conn.closing
                    && conn.on_readable(&mut lines).is_err()
                {
                    conn.broken = true;
                }
                conn.closing = true;
            }
            for line in lines {
                self.dispatch(slot, line);
            }
            self.after_io(slot);
        }
    }

    /// One drain-phase pass: flush what can be flushed, close what is
    /// finished.
    fn sweep_closing(&mut self) {
        for slot in 0..self.conns.len() {
            let done = match self.conns[slot].as_mut() {
                Some(conn) => {
                    if conn.flush().is_err() {
                        conn.broken = true;
                    }
                    conn.broken || conn.idle()
                }
                None => continue,
            };
            if done {
                self.close_conn(slot);
            }
        }
    }
}

/// Readiness pollers: raw epoll on Linux, portable `poll(2)` elsewhere
/// (and on demand for tests).  Both speak through direct `extern "C"`
/// declarations -- std already links libc, so this adds no dependency.
pub mod sys {
    use std::io;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    /// What a registration wants to hear about.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub struct Interest {
        pub read: bool,
        pub write: bool,
    }

    /// One readiness notification.
    #[derive(Debug, Clone, Copy)]
    pub struct Event {
        pub token: usize,
        pub readable: bool,
        pub writable: bool,
        pub hangup: bool,
    }

    /// A level-triggered readiness poller.
    pub trait Poller: Send {
        fn add(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()>;
        fn modify(
            &mut self,
            fd: RawFd,
            token: usize,
            interest: Interest,
        ) -> io::Result<()>;
        fn remove(&mut self, fd: RawFd) -> io::Result<()>;
        /// Fill `out` with ready events (cleared first); an interrupted
        /// wait returns empty rather than erroring.
        fn wait(&mut self, out: &mut Vec<Event>, timeout: Duration) -> io::Result<()>;
        fn name(&self) -> &'static str;
    }

    /// Best poller for this platform: epoll where available unless
    /// `force_poll` asks for the portable fallback.
    pub fn best(force_poll: bool) -> io::Result<Box<dyn Poller>> {
        #[cfg(target_os = "linux")]
        if !force_poll {
            return Ok(Box::new(epoll::Epoll::new()?));
        }
        let _ = force_poll;
        Ok(Box::new(pollset::PollSet::new()))
    }

    /// Raw epoll via direct syscall wrappers (Linux only).
    #[cfg(target_os = "linux")]
    pub mod epoll {
        use super::{Event, Interest, Poller};
        use std::io;
        use std::os::unix::io::RawFd;
        use std::time::Duration;

        // the kernel ABI packs epoll_event on x86_64 (__EPOLL_PACKED)
        // and aligns it naturally everywhere else
        #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
        #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
        #[derive(Clone, Copy)]
        struct EpollEvent {
            events: u32,
            data: u64,
        }

        extern "C" {
            fn epoll_create1(flags: i32) -> i32;
            fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
            fn epoll_wait(
                epfd: i32,
                events: *mut EpollEvent,
                maxevents: i32,
                timeout_ms: i32,
            ) -> i32;
            fn close(fd: i32) -> i32;
        }

        const EPOLL_CLOEXEC: i32 = 0o2000000;
        const EPOLL_CTL_ADD: i32 = 1;
        const EPOLL_CTL_DEL: i32 = 2;
        const EPOLL_CTL_MOD: i32 = 3;
        const EPOLLIN: u32 = 0x001;
        const EPOLLOUT: u32 = 0x004;
        const EPOLLERR: u32 = 0x008;
        const EPOLLHUP: u32 = 0x010;

        pub struct Epoll {
            epfd: RawFd,
            buf: Vec<EpollEvent>,
        }

        impl Epoll {
            pub fn new() -> io::Result<Epoll> {
                let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
                if epfd < 0 {
                    return Err(io::Error::last_os_error());
                }
                Ok(Epoll { epfd, buf: vec![EpollEvent { events: 0, data: 0 }; 512] })
            }

            fn ctl(
                &self,
                op: i32,
                fd: RawFd,
                token: usize,
                interest: Interest,
            ) -> io::Result<()> {
                let mut ev = EpollEvent { events: mask(interest), data: token as u64 };
                let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
                if rc < 0 {
                    Err(io::Error::last_os_error())
                } else {
                    Ok(())
                }
            }
        }

        fn mask(i: Interest) -> u32 {
            (if i.read { EPOLLIN } else { 0 }) | (if i.write { EPOLLOUT } else { 0 })
        }

        impl Poller for Epoll {
            fn add(
                &mut self,
                fd: RawFd,
                token: usize,
                interest: Interest,
            ) -> io::Result<()> {
                self.ctl(EPOLL_CTL_ADD, fd, token, interest)
            }

            fn modify(
                &mut self,
                fd: RawFd,
                token: usize,
                interest: Interest,
            ) -> io::Result<()> {
                self.ctl(EPOLL_CTL_MOD, fd, token, interest)
            }

            fn remove(&mut self, fd: RawFd) -> io::Result<()> {
                // pre-2.6.9 kernels insist on a non-null event for DEL
                self.ctl(EPOLL_CTL_DEL, fd, 0, Interest { read: false, write: false })
            }

            fn wait(
                &mut self,
                out: &mut Vec<Event>,
                timeout: Duration,
            ) -> io::Result<()> {
                out.clear();
                let ms = timeout.as_millis().min(i32::MAX as u128) as i32;
                let n = unsafe {
                    epoll_wait(self.epfd, self.buf.as_mut_ptr(), self.buf.len() as i32, ms)
                };
                if n < 0 {
                    let e = io::Error::last_os_error();
                    if e.kind() == io::ErrorKind::Interrupted {
                        return Ok(());
                    }
                    return Err(e);
                }
                for ev in &self.buf[..n as usize] {
                    // copy fields out of the (possibly packed) struct
                    let bits = ev.events;
                    let data = ev.data;
                    out.push(Event {
                        token: data as usize,
                        readable: bits & EPOLLIN != 0,
                        writable: bits & EPOLLOUT != 0,
                        hangup: bits & (EPOLLERR | EPOLLHUP) != 0,
                    });
                }
                Ok(())
            }

            fn name(&self) -> &'static str {
                "epoll"
            }
        }

        impl Drop for Epoll {
            fn drop(&mut self) {
                unsafe {
                    close(self.epfd);
                }
            }
        }
    }

    /// Portable fallback: rebuild a pollfd array per wait.  O(n) per
    /// tick where epoll is O(ready), fine as a fallback and for tests.
    pub mod pollset {
        use super::{Event, Interest, Poller};
        use std::io;
        use std::os::unix::io::RawFd;
        use std::time::Duration;

        #[repr(C)]
        #[derive(Clone, Copy)]
        struct PollFd {
            fd: i32,
            events: i16,
            revents: i16,
        }

        #[cfg(target_os = "linux")]
        type Nfds = std::os::raw::c_ulong;
        #[cfg(not(target_os = "linux"))]
        type Nfds = std::os::raw::c_uint;

        extern "C" {
            fn poll(fds: *mut PollFd, nfds: Nfds, timeout_ms: i32) -> i32;
        }

        const POLLIN: i16 = 0x001;
        const POLLOUT: i16 = 0x004;
        const POLLERR: i16 = 0x008;
        const POLLHUP: i16 = 0x010;
        const POLLNVAL: i16 = 0x020;

        struct Entry {
            fd: RawFd,
            token: usize,
            interest: Interest,
        }

        #[derive(Default)]
        pub struct PollSet {
            entries: Vec<Entry>,
            buf: Vec<PollFd>,
        }

        impl PollSet {
            pub fn new() -> PollSet {
                PollSet::default()
            }
        }

        impl Poller for PollSet {
            fn add(
                &mut self,
                fd: RawFd,
                token: usize,
                interest: Interest,
            ) -> io::Result<()> {
                if self.entries.iter().any(|e| e.fd == fd) {
                    return Err(io::Error::new(
                        io::ErrorKind::AlreadyExists,
                        "fd already registered",
                    ));
                }
                self.entries.push(Entry { fd, token, interest });
                Ok(())
            }

            fn modify(
                &mut self,
                fd: RawFd,
                token: usize,
                interest: Interest,
            ) -> io::Result<()> {
                for e in &mut self.entries {
                    if e.fd == fd {
                        e.token = token;
                        e.interest = interest;
                        return Ok(());
                    }
                }
                Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered"))
            }

            fn remove(&mut self, fd: RawFd) -> io::Result<()> {
                let before = self.entries.len();
                self.entries.retain(|e| e.fd != fd);
                if self.entries.len() == before {
                    return Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered"));
                }
                Ok(())
            }

            fn wait(
                &mut self,
                out: &mut Vec<Event>,
                timeout: Duration,
            ) -> io::Result<()> {
                out.clear();
                self.buf.clear();
                for e in &self.entries {
                    // errors/hangups report regardless of the mask, so a
                    // fully paused connection still gets noticed
                    let mut events = 0i16;
                    if e.interest.read {
                        events |= POLLIN;
                    }
                    if e.interest.write {
                        events |= POLLOUT;
                    }
                    self.buf.push(PollFd { fd: e.fd, events, revents: 0 });
                }
                let ms = timeout.as_millis().min(i32::MAX as u128) as i32;
                let n = unsafe {
                    poll(self.buf.as_mut_ptr(), self.buf.len() as Nfds, ms)
                };
                if n < 0 {
                    let e = io::Error::last_os_error();
                    if e.kind() == io::ErrorKind::Interrupted {
                        return Ok(());
                    }
                    return Err(e);
                }
                for (e, p) in self.entries.iter().zip(self.buf.iter()) {
                    if p.revents == 0 {
                        continue;
                    }
                    out.push(Event {
                        token: e.token,
                        readable: p.revents & POLLIN != 0,
                        writable: p.revents & POLLOUT != 0,
                        hangup: p.revents & (POLLERR | POLLHUP | POLLNVAL) != 0,
                    });
                }
                Ok(())
            }

            fn name(&self) -> &'static str {
                "poll"
            }
        }
    }
}
