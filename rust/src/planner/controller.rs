//! Moved: the online gear controller is now part of the unified
//! control plane ([`crate::control`]).
//!
//! The `Controller` thread this module used to spawn -- and the
//! `Autoscaler` / `TieredAutoscaler` threads that grew beside it -- are
//! replaced by the single [`crate::control::ControlLoop`], which ticks
//! the same pure state machine ([`crate::control::ControlState`])
//! through the decider stack for monolithic pools and tiered fleets
//! alike.  Spawn one with
//! [`crate::control::ControlConfig::gear_plan`] for the old
//! gear-only behaviour.  These re-exports keep `planner::controller`
//! paths (and `planner::{ControllerConfig, Observation, Sampler,
//! Shift, Trigger}`) compiling.

pub use crate::control::{
    ControlState, ControllerConfig, Observation, Sampler, Shift, Trigger,
};
