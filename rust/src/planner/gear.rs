//! Gear plans: precomputed cascade operating points, switched online.
//!
//! A [`Gear`] binds one cascade configuration -- tier-1 ensemble size
//! `k`, calibrated agreement threshold theta, batch size, replica
//! allocation -- to the accuracy/throughput point it was planned at.  A
//! [`GearPlan`] is the ladder of Pareto-optimal gears the offline
//! planner (`planner::search`) emits, ordered from **most accurate**
//! (index 0, the "top" gear) to **highest sustainable throughput**.  The
//! control plane (`control::ControlLoop`) walks this ladder against
//! observed load: shifting *down* trades accuracy for throughput under
//! pressure, shifting *up* restores accuracy when load recedes
//! (CascadeServe-style gear switching; see DESIGN.md "Gear planning").
//!
//! The runtime half is [`GearHandle`]: an atomically swappable
//! `Arc<GearConfig>` the serving pipeline loads once per batch.  A swap
//! only affects batches formed *after* it -- in-flight requests keep
//! their response channels, so a shift can never drop or duplicate
//! work (asserted in rust/tests/planner_integration.rs).

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use anyhow::{Context, Result};

use crate::cost::rental::Gpu;
use crate::util::json::{Json, JsonObj};

/// One tier's slice of a heterogeneous fleet: which GPU class it rents
/// and how many replicas of it the plan allocates (tentpole of the
/// tiered fleet: the planner emits per-tier `(gpu, replicas)` and
/// `serve --tiered` provisions pools from it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TierAlloc {
    pub gpu: Gpu,
    pub replicas: usize,
}

impl TierAlloc {
    fn to_json(self) -> Json {
        let mut o = JsonObj::new();
        o.insert("gpu", Json::str(self.gpu.name()));
        o.insert("replicas", Json::num(self.replicas as f64));
        Json::Obj(o)
    }

    fn from_json(v: &Json) -> Result<TierAlloc> {
        let name = v
            .get("gpu")
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("tier_fleet.gpu missing"))?;
        Ok(TierAlloc {
            gpu: Gpu::parse(name)
                .ok_or_else(|| anyhow::anyhow!("unknown gpu class {name:?}"))?,
            replicas: v.req_usize("replicas").context("tier_fleet.replicas")?,
        })
    }
}

/// One interior (non-final, non-tier-1) rung of a deeper ladder: the
/// planner's choice of ensemble size + calibrated threshold for that
/// tier.  Two-level gears have no interior tiers (`Gear::mid` empty).
#[derive(Debug, Clone, PartialEq)]
pub struct TierPlan {
    /// Ensemble size at this tier.
    pub k: usize,
    /// Error budget the threshold was calibrated at.
    pub epsilon: f64,
    /// Calibrated agreement threshold (defer when score <= theta).
    pub theta: f32,
}

/// One cascade operating point, planned offline.
#[derive(Debug, Clone, PartialEq)]
pub struct Gear {
    /// Position in the plan's ladder (0 = most accurate).
    pub id: usize,
    /// Tier-1 ensemble size.
    pub k: usize,
    /// Error budget the tier-1 threshold was calibrated at (Appendix B
    /// epsilon).
    pub epsilon: f64,
    /// Calibrated tier-1 agreement threshold (defer when score <= theta).
    pub theta: f32,
    /// Interior tiers between tier 1 and the top model (empty for
    /// two-level cascades); index 0 = tier 2.
    pub mid: Vec<TierPlan>,
    /// Dynamic-batcher flush cap while this gear is active.
    pub max_batch: usize,
    /// Planned replica allocation for this gear at the plan's design
    /// load -- what the autoscaler's rental accounting prices and the
    /// denominator of [`Gear::per_replica_rps`].
    pub replicas: usize,
    /// Per-tier `(gpu, replicas)` for a heterogeneous (tiered) fleet,
    /// tier 1 first; empty when the plan was priced homogeneously
    /// (no `--tier-gpus`).
    pub tier_fleet: Vec<TierAlloc>,
    /// Rental dollars one request costs on the planned fleet (the mixed
    /// fleet's $/request when `tier_fleet` is set, else the whole
    /// cascade priced on the top GPU).  The Pareto cost axis of
    /// heterogeneous plans.
    pub dollar_per_req: f64,
    /// Expected end-to-end accuracy at this operating point.
    pub accuracy: f64,
    /// Expected cost per request relative to always running the top
    /// model (Eq. 1 cost model; 1.0 == top-only).
    pub relative_cost: f64,
    /// Offered load (requests/s) this gear sustains at `replicas`.
    pub sustainable_rps: f64,
}

impl Gear {
    /// Number of cascade levels this gear configures (tier 1 + interior
    /// tiers + the top model).
    pub fn n_levels(&self) -> usize {
        2 + self.mid.len()
    }

    /// Per-non-final-tier thresholds, tier 1 first.
    pub fn thetas(&self) -> Vec<f32> {
        std::iter::once(self.theta)
            .chain(self.mid.iter().map(|t| t.theta))
            .collect()
    }

    /// Requests/s one replica sustains under this gear.
    pub fn per_replica_rps(&self) -> f64 {
        self.sustainable_rps / self.replicas.max(1) as f64
    }

    /// Replica-seconds consumed per request (the rental price of one
    /// request in machine time; `1 / per_replica_rps`).
    pub fn replica_s_per_req(&self) -> f64 {
        self.replicas.max(1) as f64 / self.sustainable_rps.max(1e-12)
    }

    /// The runtime view the serving pipeline reads per batch.
    pub fn config(&self) -> GearConfig {
        GearConfig {
            gear_id: self.id,
            thetas: self.thetas(),
            work_factor: self.relative_cost,
            max_batch: self.max_batch,
        }
    }

    fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("id", Json::num(self.id as f64));
        o.insert("k", Json::num(self.k as f64));
        o.insert("epsilon", Json::num(self.epsilon));
        o.insert("theta", Json::num(self.theta as f64));
        if !self.mid.is_empty() {
            o.insert(
                "mid",
                Json::Arr(
                    self.mid
                        .iter()
                        .map(|t| {
                            let mut m = JsonObj::new();
                            m.insert("k", Json::num(t.k as f64));
                            m.insert("epsilon", Json::num(t.epsilon));
                            m.insert("theta", Json::num(t.theta as f64));
                            Json::Obj(m)
                        })
                        .collect(),
                ),
            );
        }
        o.insert("max_batch", Json::num(self.max_batch as f64));
        o.insert("replicas", Json::num(self.replicas as f64));
        if !self.tier_fleet.is_empty() {
            o.insert(
                "tier_fleet",
                Json::Arr(self.tier_fleet.iter().map(|t| t.to_json()).collect()),
            );
        }
        o.insert("dollar_per_req", Json::num(self.dollar_per_req));
        o.insert("accuracy", Json::num(self.accuracy));
        o.insert("relative_cost", Json::num(self.relative_cost));
        o.insert("sustainable_rps", Json::num(self.sustainable_rps));
        Json::Obj(o)
    }

    fn from_json(v: &Json) -> Result<Gear> {
        // `mid` is optional: plans written before multi-tier ladders
        // (and two-level gears today) simply omit it
        let mid = match v.get("mid").as_arr() {
            None => Vec::new(),
            Some(arr) => arr
                .iter()
                .map(|t| {
                    Ok(TierPlan {
                        k: t.req_usize("k").context("gear.mid.k")?,
                        epsilon: t.req_f64("epsilon").context("gear.mid.epsilon")?,
                        theta: t.req_f64("theta").context("gear.mid.theta")? as f32,
                    })
                })
                .collect::<Result<Vec<_>>>()?,
        };
        // `tier_fleet`/`dollar_per_req` are optional: homogeneous plans
        // (and plans written before tiered fleets) omit or predate them
        let tier_fleet = match v.get("tier_fleet").as_arr() {
            None => Vec::new(),
            Some(arr) => arr
                .iter()
                .map(TierAlloc::from_json)
                .collect::<Result<Vec<_>>>()?,
        };
        Ok(Gear {
            id: v.req_usize("id").context("gear.id")?,
            k: v.req_usize("k").context("gear.k")?,
            epsilon: v.req_f64("epsilon").context("gear.epsilon")?,
            theta: v.req_f64("theta").context("gear.theta")? as f32,
            mid,
            max_batch: v.req_usize("max_batch").context("gear.max_batch")?,
            replicas: v.req_usize("replicas").context("gear.replicas")?,
            tier_fleet,
            dollar_per_req: v.get("dollar_per_req").as_f64().unwrap_or(0.0),
            accuracy: v.req_f64("accuracy").context("gear.accuracy")?,
            relative_cost: v.req_f64("relative_cost").context("gear.relative_cost")?,
            sustainable_rps: v
                .req_f64("sustainable_rps")
                .context("gear.sustainable_rps")?,
        })
    }
}

/// The ladder of gears, most accurate first.
#[derive(Debug, Clone, PartialEq)]
pub struct GearPlan {
    pub version: u32,
    pub gears: Vec<Gear>,
}

pub const PLAN_VERSION: u32 = 1;

impl GearPlan {
    /// Build a plan from gears, enforcing the ladder invariants: at
    /// least one gear, ids re-assigned by position, ordered by strictly
    /// descending accuracy and ascending sustainable throughput.
    pub fn new(mut gears: Vec<Gear>) -> Result<GearPlan> {
        anyhow::ensure!(!gears.is_empty(), "a gear plan needs at least one gear");
        gears.sort_by(|a, b| {
            b.accuracy
                .partial_cmp(&a.accuracy)
                .expect("accuracy is never NaN")
        });
        for w in gears.windows(2) {
            anyhow::ensure!(
                w[0].sustainable_rps <= w[1].sustainable_rps,
                "gear ladder not monotone: accuracy {:.4} sustains {:.0} rps but \
                 accuracy {:.4} sustains {:.0} rps (dominated gear in plan)",
                w[0].accuracy,
                w[0].sustainable_rps,
                w[1].accuracy,
                w[1].sustainable_rps,
            );
        }
        for (i, g) in gears.iter_mut().enumerate() {
            g.id = i;
        }
        Ok(GearPlan { version: PLAN_VERSION, gears })
    }

    pub fn len(&self) -> usize {
        self.gears.len()
    }

    pub fn is_empty(&self) -> bool {
        self.gears.is_empty()
    }

    /// The most accurate gear (ladder index 0).
    pub fn top(&self) -> &Gear {
        &self.gears[0]
    }

    /// The highest-throughput gear (last in the ladder).
    pub fn fastest(&self) -> &Gear {
        self.gears.last().expect("plan is non-empty")
    }

    /// The most accurate gear that sustains `offered_rps` with
    /// `headroom` (e.g. 0.85 targets 85% utilisation).  Falls back to
    /// the fastest gear when nothing sustains the load.  This is the
    /// controller's rate-driven downshift target (`ControlState::step`
    /// calls it with `down_util` as the headroom, clamped to at least
    /// one rung down).
    pub fn gear_for_load(&self, offered_rps: f64, headroom: f64) -> usize {
        self.gears
            .iter()
            .position(|g| offered_rps <= g.sustainable_rps * headroom)
            .unwrap_or(self.gears.len() - 1)
    }

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("version", Json::num(self.version as f64));
        o.insert(
            "gears",
            Json::Arr(self.gears.iter().map(|g| g.to_json()).collect()),
        );
        Json::Obj(o)
    }

    pub fn from_json(v: &Json) -> Result<GearPlan> {
        let version = v.req_usize("version").context("plan.version")? as u32;
        anyhow::ensure!(
            version == PLAN_VERSION,
            "unsupported gear plan version {version} (supported: {PLAN_VERSION})"
        );
        let gears = v
            .req_arr("gears")
            .context("plan.gears")?
            .iter()
            .map(Gear::from_json)
            .collect::<Result<Vec<_>>>()?;
        // re-validate the ladder invariants on load
        GearPlan::new(gears)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_json().to_pretty() + "\n")
            .with_context(|| format!("writing gear plan {}", path.display()))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<GearPlan> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading gear plan {}", path.display()))?;
        let v = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("{e}"))
            .with_context(|| format!("parsing gear plan {}", path.display()))?;
        GearPlan::from_json(&v)
    }
}

/// The runtime slice of a gear: what the serving pipeline consults once
/// per batch.  Deliberately small -- swapped wholesale on a shift.
#[derive(Debug, Clone, PartialEq)]
pub struct GearConfig {
    pub gear_id: usize,
    /// Per-non-final-tier agreement thresholds overriding the cascade's
    /// calibrated policy (index 0 = tier 1).
    pub thetas: Vec<f32>,
    /// Expected per-request compute relative to top-only (1.0); the
    /// synthetic backend scales its service time by this so gears have
    /// real throughput consequences without artifacts.
    pub work_factor: f64,
    /// Batch-size cap while this gear is active.
    pub max_batch: usize,
}

/// Atomically swappable `Arc<GearConfig>` shared between the controller
/// (writer) and every pipeline replica (readers, once per batch).
///
/// Readers pay one `RwLock` read + `Arc` clone per *batch* (not per
/// request), which is noise next to a classifier dispatch.  `generation`
/// counts swaps so tests and the wire `stats` reply can observe shifts
/// without racing the lock.
#[derive(Debug)]
pub struct GearHandle {
    current: RwLock<Arc<GearConfig>>,
    generation: AtomicU64,
}

impl GearHandle {
    pub fn new(cfg: GearConfig) -> Arc<GearHandle> {
        Arc::new(GearHandle {
            current: RwLock::new(Arc::new(cfg)),
            generation: AtomicU64::new(0),
        })
    }

    /// Snapshot the active config (cheap: read lock + Arc clone).
    pub fn load(&self) -> Arc<GearConfig> {
        Arc::clone(&self.current.read().unwrap())
    }

    /// Swap in a new config; visible to every subsequent `load`.
    pub fn store(&self, cfg: GearConfig) {
        *self.current.write().unwrap() = Arc::new(cfg);
        self.generation.fetch_add(1, Ordering::SeqCst);
    }

    /// Number of swaps since creation.
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::SeqCst)
    }

    /// Ladder index of the active gear.
    pub fn gear_id(&self) -> usize {
        self.load().gear_id
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gear(id: usize, acc: f64, rps: f64) -> Gear {
        Gear {
            id,
            k: 3,
            epsilon: 0.03,
            theta: 0.6,
            mid: vec![],
            max_batch: 8,
            replicas: 2,
            tier_fleet: vec![],
            dollar_per_req: 0.0,
            accuracy: acc,
            relative_cost: 1.0 / rps,
            sustainable_rps: rps,
        }
    }

    #[test]
    fn plan_sorts_and_reassigns_ids() {
        let plan = GearPlan::new(vec![
            gear(9, 0.80, 3000.0),
            gear(7, 0.95, 1000.0),
            gear(5, 0.90, 2000.0),
        ])
        .unwrap();
        let accs: Vec<f64> = plan.gears.iter().map(|g| g.accuracy).collect();
        assert_eq!(accs, vec![0.95, 0.90, 0.80]);
        let ids: Vec<usize> = plan.gears.iter().map(|g| g.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        assert_eq!(plan.top().accuracy, 0.95);
        assert_eq!(plan.fastest().sustainable_rps, 3000.0);
    }

    #[test]
    fn plan_rejects_dominated_ladder() {
        // higher accuracy AND higher throughput than the next gear:
        // the "slower" gear is pointless, the plan is malformed
        let err = GearPlan::new(vec![gear(0, 0.95, 3000.0), gear(1, 0.90, 1000.0)]);
        assert!(err.is_err());
        assert!(GearPlan::new(vec![]).is_err());
    }

    #[test]
    fn gear_for_load_walks_the_ladder() {
        let plan = GearPlan::new(vec![
            gear(0, 0.95, 1000.0),
            gear(1, 0.90, 2000.0),
            gear(2, 0.80, 4000.0),
        ])
        .unwrap();
        assert_eq!(plan.gear_for_load(100.0, 1.0), 0);
        assert_eq!(plan.gear_for_load(1500.0, 1.0), 1);
        assert_eq!(plan.gear_for_load(3000.0, 1.0), 2);
        // over everything: fastest gear
        assert_eq!(plan.gear_for_load(99_999.0, 1.0), 2);
        // headroom biases down the ladder
        assert_eq!(plan.gear_for_load(900.0, 0.8), 1);
    }

    #[test]
    fn plan_json_roundtrip() {
        let plan = GearPlan::new(vec![gear(0, 0.95, 1000.0), gear(1, 0.85, 2500.0)])
            .unwrap();
        let v = plan.to_json();
        let back = GearPlan::from_json(&v).unwrap();
        assert_eq!(back, plan);
        // and through text
        let text = v.to_pretty();
        let back2 = GearPlan::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back2, plan);
    }

    #[test]
    fn multi_tier_gear_roundtrips_and_configures_all_thetas() {
        let mut g = gear(0, 0.93, 800.0);
        g.mid = vec![TierPlan { k: 5, epsilon: 0.05, theta: 0.72 }];
        assert_eq!(g.n_levels(), 3);
        assert_eq!(g.thetas(), vec![0.6, 0.72]);
        // the runtime config carries every non-final tier's theta
        let cfg = g.config();
        assert_eq!(cfg.thetas, vec![0.6, 0.72]);
        // JSON roundtrip preserves the interior tier
        let plan = GearPlan::new(vec![g.clone(), gear(1, 0.80, 3000.0)]).unwrap();
        let back = GearPlan::from_json(&plan.to_json()).unwrap();
        assert_eq!(back, plan);
        assert_eq!(back.top().mid.len(), 1);
        assert_eq!(back.top().mid[0].k, 5);
        // a two-level gear omits "mid" entirely and still loads
        let two = gear(0, 0.9, 500.0).to_json();
        assert!(two.get("mid").as_arr().is_none());
        assert!(Gear::from_json(&two).unwrap().mid.is_empty());
    }

    #[test]
    fn tier_fleet_roundtrips_and_stays_optional() {
        let mut g = gear(0, 0.93, 800.0);
        g.tier_fleet = vec![
            TierAlloc { gpu: Gpu::V100, replicas: 3 },
            TierAlloc { gpu: Gpu::H100, replicas: 1 },
        ];
        g.dollar_per_req = 1.25e-6;
        let plan = GearPlan::new(vec![g, gear(1, 0.80, 3000.0)]).unwrap();
        let back = GearPlan::from_json(&plan.to_json()).unwrap();
        assert_eq!(back, plan);
        assert_eq!(back.top().tier_fleet.len(), 2);
        assert_eq!(back.top().tier_fleet[0].gpu, Gpu::V100);
        assert_eq!(back.top().tier_fleet[1].replicas, 1);
        assert!((back.top().dollar_per_req - 1.25e-6).abs() < 1e-18);
        // homogeneous gears omit the field and still load (and plans
        // written before the dollar axis load with 0.0)
        let hom = gear(0, 0.9, 500.0).to_json();
        assert!(hom.get("tier_fleet").as_arr().is_none());
        let legacy = Json::parse(
            r#"{"id":0,"k":3,"epsilon":0.03,"theta":0.6,"max_batch":8,
                "replicas":2,"accuracy":0.9,"relative_cost":1.0,
                "sustainable_rps":500.0}"#,
        )
        .unwrap();
        let loaded = Gear::from_json(&legacy).unwrap();
        assert!(loaded.tier_fleet.is_empty());
        assert_eq!(loaded.dollar_per_req, 0.0);
        // unknown gpu classes are rejected, not silently defaulted
        let bad = Json::parse(
            r#"{"gpu":"tpu-v9","replicas":1}"#,
        )
        .unwrap();
        assert!(TierAlloc::from_json(&bad).is_err());
    }

    #[test]
    fn per_replica_capacity_helpers() {
        let g = gear(0, 0.9, 1000.0); // 2 replicas -> 500 rps each
        assert!((g.per_replica_rps() - 500.0).abs() < 1e-9);
        assert!((g.replica_s_per_req() - 0.002).abs() < 1e-12);
    }

    #[test]
    fn plan_file_roundtrip() {
        let dir =
            std::env::temp_dir().join(format!("abc-plan-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plan.json");
        let plan = GearPlan::new(vec![gear(0, 0.9, 500.0)]).unwrap();
        plan.save(&path).unwrap();
        let back = GearPlan::load(&path).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        assert_eq!(back, plan);
    }

    #[test]
    fn from_json_rejects_bad_versions_and_shapes() {
        assert!(GearPlan::from_json(&Json::parse(r#"{"version":99,"gears":[]}"#).unwrap())
            .is_err());
        assert!(GearPlan::from_json(&Json::parse(r#"{"gears":[]}"#).unwrap()).is_err());
        assert!(
            GearPlan::from_json(&Json::parse(r#"{"version":1,"gears":[{}]}"#).unwrap())
                .is_err()
        );
    }

    #[test]
    fn handle_swaps_atomically_and_counts_generations() {
        let plan =
            GearPlan::new(vec![gear(0, 0.95, 1000.0), gear(1, 0.85, 2500.0)]).unwrap();
        let handle = GearHandle::new(plan.top().config());
        assert_eq!(handle.gear_id(), 0);
        assert_eq!(handle.generation(), 0);
        handle.store(plan.gears[1].config());
        assert_eq!(handle.gear_id(), 1);
        assert_eq!(handle.generation(), 1);
        // a loaded snapshot is immutable across a later swap
        let snap = handle.load();
        handle.store(plan.gears[0].config());
        assert_eq!(snap.gear_id, 1);
        assert_eq!(handle.gear_id(), 0);
    }

    #[test]
    fn concurrent_load_store_never_tears() {
        let handle = GearHandle::new(GearConfig {
            gear_id: 0,
            thetas: vec![0.0],
            work_factor: 0.0,
            max_batch: 1,
        });
        let writer = {
            let h = Arc::clone(&handle);
            std::thread::spawn(move || {
                for i in 0..2000u64 {
                    let id = (i % 3) as usize;
                    h.store(GearConfig {
                        gear_id: id,
                        thetas: vec![id as f32],
                        work_factor: id as f64,
                        max_batch: id + 1,
                    });
                }
            })
        };
        let readers: Vec<_> = (0..4)
            .map(|_| {
                let h = Arc::clone(&handle);
                std::thread::spawn(move || {
                    for _ in 0..2000 {
                        let c = h.load();
                        // every field must be from the same config
                        assert_eq!(c.thetas, vec![c.gear_id as f32]);
                        assert_eq!(c.work_factor, c.gear_id as f64);
                        assert_eq!(c.max_batch, c.gear_id + 1);
                    }
                })
            })
            .collect();
        writer.join().unwrap();
        for r in readers {
            r.join().unwrap();
        }
        assert_eq!(handle.generation(), 2000);
    }
}
