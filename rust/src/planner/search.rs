//! Offline gear planning: enumerate candidate cascade configurations
//! over calibration data, keep the accuracy-vs-throughput Pareto
//! frontier, and emit a [`GearPlan`].
//!
//! For each candidate `(k, epsilon, max_batch)` the planner
//!
//! 1. calibrates a tier-1 threshold with `calib::estimate_theta` on the
//!    `(score, correct)` points observed at ensemble size `k`;
//! 2. prices the operating point with the paper's Eq. 1 cost model
//!    (`cost::model::two_level_relative_cost`): expected per-request
//!    compute relative to always running the top model;
//! 3. converts cost + batching into sustainable offered load for the
//!    deployment's replica allocation;
//! 4. estimates end-to-end accuracy from the calibration set:
//!    `P(select AND correct) + P(defer) * top_accuracy`.
//!
//! Candidates that another candidate beats on both axes are dropped
//! (`analysis::pareto::frontier`), so every gear in the plan is a
//! defensible operating point -- the online controller never has a
//! reason to pick a dominated configuration.
//!
//! Calibration points come from real tier executables in artifact
//! deployments (`calib::collect_points`) or from
//! [`synthetic_cal_points`] for the artifact-free path (`repro plan`,
//! tests, benches).

use anyhow::Result;

use crate::analysis::pareto::{frontier, Point};
use crate::calib::threshold::{estimate_theta, CalPoint};
use crate::cost::model::two_level_relative_cost;
use crate::planner::gear::{Gear, GearPlan};
use crate::types::Parallelism;
use crate::util::rng::Rng;

/// Deployment model + candidate grid for the planner.
#[derive(Debug, Clone)]
pub struct PlannerConfig {
    /// Candidate tier-1 ensemble sizes (must match the calibration data).
    pub ks: Vec<usize>,
    /// Candidate per-tier error budgets (Appendix B epsilon).
    pub epsilons: Vec<f64>,
    /// Candidate dynamic-batcher flush caps.
    pub batches: Vec<usize>,
    /// Replica allocation the plan targets.
    pub replicas: usize,
    /// Cost of one tier-1 member relative to the top model (Eq. 1 gamma).
    pub gamma: f64,
    /// Ensemble execution model (Eq. 1 rho).
    pub rho: Parallelism,
    /// Accuracy of the top model alone (deferred samples get this).
    pub top_accuracy: f64,
    /// Fixed per-batch dispatch overhead of one replica, seconds.
    pub batch_overhead_s: f64,
    /// Per-row service time of the top model on one replica, seconds
    /// (cost 1.0 in the relative model).
    pub top_row_s: f64,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            ks: vec![1, 3, 5],
            epsilons: vec![0.01, 0.03, 0.05, 0.10],
            batches: vec![4, 8, 16, 32],
            replicas: 2,
            gamma: 0.05,
            rho: Parallelism::SEQUENTIAL,
            top_accuracy: 0.95,
            batch_overhead_s: 200e-6,
            top_row_s: 2e-3,
        }
    }
}

/// One evaluated configuration (pre-Pareto).
#[derive(Debug, Clone)]
pub struct Candidate {
    pub k: usize,
    pub epsilon: f64,
    pub max_batch: usize,
    pub theta: f32,
    pub accuracy: f64,
    pub relative_cost: f64,
    pub sustainable_rps: f64,
}

impl Candidate {
    /// Evaluate one grid point against its calibration sample.
    pub fn evaluate(
        cfg: &PlannerConfig,
        k: usize,
        epsilon: f64,
        max_batch: usize,
        points: &[CalPoint],
    ) -> Candidate {
        let est = estimate_theta(points, epsilon);
        let p_defer = 1.0 - est.selection_rate;
        let relative_cost = two_level_relative_cost(k, cfg.gamma, cfg.rho, p_defer);
        // accuracy: accepted samples are right unless they were a
        // calibration failure; deferred samples get the top model
        let accuracy = (est.selection_rate - est.failure_rate)
            + p_defer * cfg.top_accuracy;
        // a replica serves max_batch rows per (overhead + per-row *
        // relative_cost * max_batch) seconds; the pool has `replicas`
        let batch_s =
            cfg.batch_overhead_s + cfg.top_row_s * relative_cost * max_batch as f64;
        let sustainable_rps = if batch_s <= 0.0 {
            f64::INFINITY
        } else {
            cfg.replicas as f64 * max_batch as f64 / batch_s
        };
        Candidate {
            k,
            epsilon,
            max_batch,
            theta: est.theta,
            accuracy,
            relative_cost,
            sustainable_rps,
        }
    }

    fn into_gear(self, cfg: &PlannerConfig) -> Gear {
        Gear {
            id: 0, // assigned by GearPlan::new
            k: self.k,
            epsilon: self.epsilon,
            theta: self.theta,
            max_batch: self.max_batch,
            replicas: cfg.replicas,
            accuracy: self.accuracy,
            relative_cost: self.relative_cost,
            sustainable_rps: self.sustainable_rps,
        }
    }
}

/// Evaluate the full candidate grid.  `cal` maps each candidate `k` to
/// its calibration points; ks missing from `cal` are skipped.
pub fn enumerate_candidates(
    cfg: &PlannerConfig,
    cal: &[(usize, Vec<CalPoint>)],
) -> Vec<Candidate> {
    let mut out = Vec::new();
    for &k in &cfg.ks {
        let Some((_, points)) = cal.iter().find(|(ck, _)| *ck == k) else {
            continue;
        };
        if points.is_empty() {
            continue;
        }
        for &eps in &cfg.epsilons {
            for &b in &cfg.batches {
                out.push(Candidate::evaluate(cfg, k, eps, b, points));
            }
        }
    }
    out
}

/// Keep the Pareto-efficient candidates (accuracy up, capacity up) and
/// assemble them into a ladder.  `1/sustainable_rps` is the Pareto
/// "cost" axis so the existing frontier tooling applies unchanged.
pub fn plan(cfg: &PlannerConfig, cal: &[(usize, Vec<CalPoint>)]) -> Result<GearPlan> {
    let candidates = enumerate_candidates(cfg, cal);
    anyhow::ensure!(
        !candidates.is_empty(),
        "no plannable candidates: empty grid or no calibration data for any k"
    );
    let points: Vec<Point> = candidates
        .iter()
        .enumerate()
        .map(|(i, c)| Point::new(i.to_string(), 1.0 / c.sustainable_rps, c.accuracy))
        .collect();
    // frontier() drops dominated candidates AND dedups identical
    // (cost, value) pairs, so this is already one gear per operating point
    let gears: Vec<Gear> = frontier(&points)
        .iter()
        .map(|p| {
            let idx: usize = p.label.parse().expect("frontier label is an index");
            candidates[idx].clone().into_gear(cfg)
        })
        .collect();
    GearPlan::new(gears)
}

/// Synthetic `(score, correct)` calibration points for ensemble size
/// `k`, artifact-free.  Per sample: difficulty `d ~ U[0,1)` sets each
/// member's independent correctness probability (easy samples near
/// `member_accuracy`'s ceiling, hard ones near chance); `k` members
/// vote, the agreement score is the majority vote fraction (Eq. 3) and
/// the point is correct when the strict majority is.  Larger `k`
/// concentrates the vote, reproducing the paper's ensemble-agreement
/// effect: accuracy and score separation both improve with `k`.
pub fn synthetic_cal_points(
    k: usize,
    n: usize,
    member_accuracy: f64,
    seed: u64,
) -> Vec<CalPoint> {
    assert!(k >= 1, "ensemble size must be >= 1");
    let mut rng = Rng::new(seed ^ (k as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    (0..n)
        .map(|_| {
            let d = rng.f64();
            // easy (d~0): ~min(0.99, member_accuracy + 0.15); hard (d~1): ~0.5
            let p = (member_accuracy + 0.15 - (member_accuracy - 0.35) * d)
                .clamp(0.5, 0.99);
            let votes_correct = (0..k).filter(|_| rng.bool(p)).count();
            let majority = votes_correct.max(k - votes_correct);
            CalPoint {
                score: majority as f32 / k as f32,
                correct: 2 * votes_correct > k,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> PlannerConfig {
        PlannerConfig {
            ks: vec![1, 3, 5],
            epsilons: vec![0.02, 0.05, 0.10],
            batches: vec![4, 16],
            replicas: 2,
            ..PlannerConfig::default()
        }
    }

    fn small_cal(cfg: &PlannerConfig) -> Vec<(usize, Vec<CalPoint>)> {
        cfg.ks
            .iter()
            .map(|&k| (k, synthetic_cal_points(k, 200, 0.8, 7)))
            .collect()
    }

    #[test]
    fn synthetic_points_improve_with_k() {
        let acc_of = |k: usize| {
            let pts = synthetic_cal_points(k, 4000, 0.8, 3);
            pts.iter().filter(|p| p.correct).count() as f64 / pts.len() as f64
        };
        let a1 = acc_of(1);
        let a5 = acc_of(5);
        let a9 = acc_of(9);
        assert!(a5 > a1 + 0.02, "k=5 ({a5}) not better than k=1 ({a1})");
        assert!(a9 >= a5 - 0.01, "k=9 ({a9}) collapsed vs k=5 ({a5})");
        // scores are valid vote fractions
        let pts = synthetic_cal_points(4, 500, 0.8, 1);
        assert!(pts.iter().all(|p| (0.5..=1.0).contains(&p.score)));
        // deterministic
        assert_eq!(
            synthetic_cal_points(3, 50, 0.8, 11)
                .iter()
                .map(|p| p.score)
                .collect::<Vec<_>>(),
            synthetic_cal_points(3, 50, 0.8, 11)
                .iter()
                .map(|p| p.score)
                .collect::<Vec<_>>()
        );
    }

    #[test]
    fn plan_is_pareto_optimal_against_brute_force() {
        let cfg = small_cfg();
        let cal = small_cal(&cfg);
        let plan = plan(&cfg, &cal).unwrap();
        assert!(!plan.is_empty());
        let all = enumerate_candidates(&cfg, &cal);
        // no enumerated candidate may dominate any emitted gear
        for g in &plan.gears {
            for c in &all {
                let dominates = c.accuracy >= g.accuracy
                    && c.sustainable_rps >= g.sustainable_rps
                    && (c.accuracy > g.accuracy
                        || c.sustainable_rps > g.sustainable_rps);
                assert!(
                    !dominates,
                    "candidate k={} eps={} b={} (acc {:.4}, {:.0} rps) dominates \
                     gear {} (acc {:.4}, {:.0} rps)",
                    c.k,
                    c.epsilon,
                    c.max_batch,
                    c.accuracy,
                    c.sustainable_rps,
                    g.id,
                    g.accuracy,
                    g.sustainable_rps
                );
            }
        }
        // and every gear is an enumerated candidate, not an invention
        for g in &plan.gears {
            assert!(all.iter().any(|c| c.k == g.k
                && c.epsilon == g.epsilon
                && c.max_batch == g.max_batch
                && c.accuracy == g.accuracy
                && c.sustainable_rps == g.sustainable_rps));
        }
    }

    #[test]
    fn plan_ladder_trades_accuracy_for_throughput() {
        let cfg = small_cfg();
        let plan = plan(&cfg, &small_cal(&cfg)).unwrap();
        for w in plan.gears.windows(2) {
            assert!(w[0].accuracy >= w[1].accuracy);
            assert!(w[0].sustainable_rps <= w[1].sustainable_rps);
        }
        // the grid spans lax-enough epsilons that the frontier has real
        // spread to control against
        if plan.len() >= 2 {
            assert!(plan.fastest().sustainable_rps > plan.top().sustainable_rps);
        }
    }

    #[test]
    fn plan_errors_without_calibration_data() {
        let cfg = small_cfg();
        assert!(plan(&cfg, &[]).is_err());
        let empty: Vec<(usize, Vec<CalPoint>)> =
            cfg.ks.iter().map(|&k| (k, Vec::new())).collect();
        assert!(plan(&cfg, &empty).is_err());
    }

    #[test]
    fn bigger_batch_raises_capacity_at_fixed_config() {
        let cfg = PlannerConfig::default();
        let pts = synthetic_cal_points(3, 300, 0.8, 5);
        let small = Candidate::evaluate(&cfg, 3, 0.05, 4, &pts);
        let large = Candidate::evaluate(&cfg, 3, 0.05, 32, &pts);
        assert!(large.sustainable_rps > small.sustainable_rps);
        // same cascade config => same accuracy/cost, batching is free
        assert_eq!(small.accuracy, large.accuracy);
        assert_eq!(small.relative_cost, large.relative_cost);
    }

    #[test]
    fn laxer_epsilon_cuts_cost() {
        let cfg = PlannerConfig::default();
        let pts = synthetic_cal_points(3, 300, 0.8, 5);
        let strict = Candidate::evaluate(&cfg, 3, 0.0, 8, &pts);
        let lax = Candidate::evaluate(&cfg, 3, 0.25, 8, &pts);
        assert!(lax.relative_cost <= strict.relative_cost);
        assert!(lax.sustainable_rps >= strict.sustainable_rps);
    }
}
