//! Offline gear planning: enumerate candidate cascade configurations
//! over calibration data, keep the accuracy-vs-rental-cost Pareto
//! frontier, and emit a [`GearPlan`].
//!
//! For each candidate ladder -- two-level `(k, epsilon, max_batch)` or
//! three-level `(k1, eps1, k2, eps2, max_batch)` when `mid_ks` is
//! non-empty -- the planner
//!
//! 1. calibrates per-tier thresholds with `calib::estimate_theta` on
//!    each tier's `(score, correct)` points at its ensemble size;
//! 2. prices the operating point with the paper's cost model
//!    (`cost::model::two_level_relative_cost` /
//!    `multi_level_relative_cost`): expected per-request compute
//!    relative to always running the top model;
//! 3. converts cost + batching into **replica-seconds per request**
//!    (service time of one request's share of a replica, dispatch
//!    overhead included) -- the Pareto cost axis.  This prices
//!    *rental* cost, not just FLOPs: a gear that amortises dispatch
//!    across a bigger batch is genuinely cheaper in machine-hours even
//!    at identical per-request compute;
//! 4. estimates end-to-end accuracy from the calibration sets:
//!    each accepting tier contributes `P(select AND correct)`, the
//!    remainder cascades down, the top model answers the rest.
//!
//! Candidates that another candidate beats on both axes are dropped
//! (`analysis::pareto::frontier`), so every gear in the plan is a
//! defensible operating point -- the online controller never has a
//! reason to pick a dominated configuration.
//!
//! After the frontier is fixed, an **allocation pass** fills each
//! gear's `replicas` from the cost model: the fewest replicas that
//! sustain the plan's design load (`design_rps`, default: the top
//! gear's full-fleet capacity) at `design_util` utilisation --
//! cheaper gears need fewer machines for the same load, which is the
//! paper's cloud-rental claim made concrete.  Each gear's
//! `sustainable_rps` is quoted at its own allocation, and allocations
//! are bumped where needed so the ladder stays strictly monotone
//! (every faster gear really is faster); gears that cannot beat a more
//! accurate gear's capacity even at the full fleet are dropped as
//! runtime-dominated.
//!
//! Calibration points come from real tier executables in artifact
//! deployments (`calib::collect_points`) or from
//! [`synthetic_cal_points`] for the artifact-free path (`repro plan`,
//! tests, benches).

use anyhow::Result;

use crate::analysis::pareto::{frontier, Point};
use crate::calib::threshold::{estimate_theta, CalPoint};
use crate::cost::model::{multi_level_relative_cost, two_level_relative_cost};
use crate::cost::rental::Gpu;
use crate::planner::gear::{Gear, GearPlan, TierAlloc, TierPlan};
use crate::types::Parallelism;
use crate::util::rng::Rng;

/// Deployment model + candidate grid for the planner.
#[derive(Debug, Clone)]
pub struct PlannerConfig {
    /// Candidate tier-1 ensemble sizes (must match the calibration data).
    pub ks: Vec<usize>,
    /// Candidate per-tier error budgets (Appendix B epsilon); shared by
    /// tier 1 and interior tiers.
    pub epsilons: Vec<f64>,
    /// Candidate interior-tier (tier 2) ensemble sizes for three-level
    /// ladders; empty plans two-level cascades only.
    pub mid_ks: Vec<usize>,
    /// Cost of one interior-tier member relative to the top model.
    pub mid_gamma: f64,
    /// Candidate dynamic-batcher flush caps.
    pub batches: Vec<usize>,
    /// Max replica fleet the plan may allocate (the allocation pass
    /// fills per-gear `replicas` in `1..=replicas`).
    pub replicas: usize,
    /// Cost of one tier-1 member relative to the top model (Eq. 1 gamma).
    pub gamma: f64,
    /// Ensemble execution model (Eq. 1 rho).
    pub rho: Parallelism,
    /// Accuracy of the top model alone (deferred samples get this).
    pub top_accuracy: f64,
    /// Fixed per-batch dispatch overhead of one replica, seconds.
    pub batch_overhead_s: f64,
    /// Per-row service time of the top model on one replica, seconds
    /// (cost 1.0 in the relative model).
    pub top_row_s: f64,
    /// Offered load the allocation pass provisions each gear for; 0 =
    /// auto (the top gear's capacity at the full `replicas` fleet).
    pub design_rps: f64,
    /// Utilisation the allocation pass sizes fleets at (headroom).
    pub design_util: f64,
    /// Per-level GPU placement for a heterogeneous (tiered) fleet,
    /// tier 1 first; levels past the list take its last entry.  Empty
    /// plans a homogeneous deployment: everything priced on the top
    /// GPU, the Pareto cost axis stays replica-seconds/request.  Non-
    /// empty switches the Pareto cost axis to **$/request on the mixed
    /// fleet** and makes the allocation pass emit per-tier
    /// `(gpu, replicas)` (`Gear::tier_fleet`).
    pub tier_gpus: Vec<Gpu>,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            ks: vec![1, 3, 5],
            epsilons: vec![0.01, 0.03, 0.05, 0.10],
            mid_ks: vec![],
            mid_gamma: 0.20,
            batches: vec![4, 8, 16, 32],
            replicas: 2,
            gamma: 0.05,
            rho: Parallelism::SEQUENTIAL,
            top_accuracy: 0.95,
            batch_overhead_s: 200e-6,
            top_row_s: 2e-3,
            design_rps: 0.0,
            design_util: crate::types::UTIL_HIGH_WATERMARK,
            tier_gpus: vec![],
        }
    }
}

impl PlannerConfig {
    /// The GPU class level `i` runs on: the placement list entry, its
    /// last entry for deeper levels, or the top of the rental ladder
    /// for homogeneous plans.
    fn gpu_for_level(&self, i: usize) -> Gpu {
        self.tier_gpus
            .get(i)
            .or(self.tier_gpus.last())
            .copied()
            .unwrap_or(*Gpu::LADDER.last().expect("ladder non-empty"))
    }
}

/// One evaluated configuration (pre-Pareto).
#[derive(Debug, Clone)]
pub struct Candidate {
    pub k: usize,
    pub epsilon: f64,
    /// Interior (tier 2) choice for three-level ladders.
    pub mid: Option<TierPlan>,
    pub max_batch: usize,
    pub theta: f32,
    pub accuracy: f64,
    pub relative_cost: f64,
    /// Replica-seconds one request costs (dispatch overhead included):
    /// the Pareto rental-cost axis of homogeneous plans; `1 /`
    /// per-replica capacity.
    pub replica_s_per_req: f64,
    /// Offered load sustained at the full `cfg.replicas` fleet.
    pub sustainable_rps: f64,
    /// Per-level unit execution cost relative to one top-model row
    /// (rho-adjusted ensemble factor x gamma), tier 1 first; the last
    /// entry (the top model) is 1.
    pub tier_costs: Vec<f64>,
    /// P(a request reaches level i); `reach[0] == 1`.
    pub reach: Vec<f64>,
    /// Rental dollars one request costs on `cfg.tier_gpus`' placement:
    /// each level's busy time priced at its own GPU class (the Pareto
    /// cost axis of heterogeneous plans).  Homogeneous plans price the
    /// monolithic layout on the top GPU.
    pub dollar_per_req: f64,
}

impl Candidate {
    /// Evaluate one two-level grid point against its calibration
    /// sample.
    pub fn evaluate(
        cfg: &PlannerConfig,
        k: usize,
        epsilon: f64,
        max_batch: usize,
        points: &[CalPoint],
    ) -> Candidate {
        Candidate::evaluate_ladder(cfg, k, epsilon, max_batch, points, None)
    }

    /// Evaluate a grid point; `mid` adds an interior tier
    /// `(k2, eps2, its calibration points)` for a three-level ladder.
    pub fn evaluate_ladder(
        cfg: &PlannerConfig,
        k: usize,
        epsilon: f64,
        max_batch: usize,
        points: &[CalPoint],
        mid: Option<(usize, f64, &[CalPoint])>,
    ) -> Candidate {
        let est1 = estimate_theta(points, epsilon);
        let p_defer1 = 1.0 - est1.selection_rate;
        let (accuracy, relative_cost, mid_plan, tier_costs, reach) = match mid {
            None => {
                let cost = two_level_relative_cost(k, cfg.gamma, cfg.rho, p_defer1);
                // accuracy: accepted samples are right unless they were
                // a calibration failure; deferred samples get the top
                // model
                let acc = (est1.selection_rate - est1.failure_rate)
                    + p_defer1 * cfg.top_accuracy;
                let tier_costs =
                    vec![cfg.rho.ensemble_factor(k) * cfg.gamma, 1.0];
                let reach = vec![1.0, p_defer1];
                (acc, cost, None, tier_costs, reach)
            }
            Some((k2, eps2, mid_points)) => {
                let est2 = estimate_theta(mid_points, eps2);
                let p_defer2 = 1.0 - est2.selection_rate;
                // tier 2 sees only tier-1 deferrals; its selection and
                // failure rates condition on reaching it (independence
                // approximation -- the mid calibration set stands in
                // for the deferred slice)
                let acc = (est1.selection_rate - est1.failure_rate)
                    + p_defer1
                        * ((est2.selection_rate - est2.failure_rate)
                            + p_defer2 * cfg.top_accuracy);
                let cost = multi_level_relative_cost(
                    &[(k, cfg.gamma), (k2, cfg.mid_gamma), (1, 1.0)],
                    &[1.0, p_defer1, p_defer1 * p_defer2],
                    cfg.rho,
                );
                let tier_costs = vec![
                    cfg.rho.ensemble_factor(k) * cfg.gamma,
                    cfg.rho.ensemble_factor(k2) * cfg.mid_gamma,
                    1.0,
                ];
                let reach = vec![1.0, p_defer1, p_defer1 * p_defer2];
                (
                    acc,
                    cost,
                    Some(TierPlan { k: k2, epsilon: eps2, theta: est2.theta }),
                    tier_costs,
                    reach,
                )
            }
        };
        // a replica serves max_batch rows per (overhead + per-row *
        // relative_cost * max_batch) seconds; replica-seconds per
        // request is that divided by the batch -- the rental price of
        // one request in machine time
        let batch_s =
            cfg.batch_overhead_s + cfg.top_row_s * relative_cost * max_batch as f64;
        let (replica_s_per_req, sustainable_rps) = if batch_s <= 0.0 {
            (0.0, f64::INFINITY)
        } else {
            (
                batch_s / max_batch as f64,
                cfg.replicas as f64 * max_batch as f64 / batch_s,
            )
        };
        // $/request: homogeneous plans price the monolithic layout on
        // the top GPU; heterogeneous plans price each level's share of
        // machine time at its own class (every tier's pool re-batches,
        // so each level pays its own dispatch overhead for the traffic
        // that reaches it)
        let dollar_per_req = if cfg.tier_gpus.is_empty() {
            // gpu_for_level falls back to the ladder top on empty lists
            cfg.gpu_for_level(0).dollars_for(replica_s_per_req)
        } else {
            let overhead_per_row = cfg.batch_overhead_s / max_batch as f64;
            tier_costs
                .iter()
                .zip(&reach)
                .enumerate()
                .map(|(i, (&c, &r))| {
                    let rs = r * (overhead_per_row + cfg.top_row_s * c);
                    cfg.gpu_for_level(i).dollars_for(rs)
                })
                .sum()
        };
        Candidate {
            k,
            epsilon,
            mid: mid_plan,
            max_batch,
            theta: est1.theta,
            accuracy,
            relative_cost,
            replica_s_per_req,
            sustainable_rps,
            tier_costs,
            reach,
            dollar_per_req,
        }
    }

    /// Per-replica rows/s one machine of level `i`'s pool sustains
    /// (its own dispatch overhead amortised over its own batches).
    fn level_capacity_rps(&self, cfg: &PlannerConfig, i: usize) -> f64 {
        let row_s = cfg.batch_overhead_s / self.max_batch as f64
            + cfg.top_row_s * self.tier_costs[i];
        if row_s <= 0.0 {
            f64::INFINITY
        } else {
            1.0 / row_s
        }
    }

    fn into_gear(self, cfg: &PlannerConfig) -> Gear {
        Gear {
            id: 0, // assigned by GearPlan::new
            k: self.k,
            epsilon: self.epsilon,
            theta: self.theta,
            mid: self.mid.into_iter().collect(),
            max_batch: self.max_batch,
            replicas: cfg.replicas,
            tier_fleet: vec![], // filled by the allocation pass
            dollar_per_req: self.dollar_per_req,
            accuracy: self.accuracy,
            relative_cost: self.relative_cost,
            sustainable_rps: self.sustainable_rps,
        }
    }
}

/// Evaluate the full candidate grid.  `cal` maps each tier-1 `k` to its
/// calibration points and `mid_cal` each interior-tier `k` to its own;
/// ks missing from their set are skipped.
pub fn enumerate_candidates(
    cfg: &PlannerConfig,
    cal: &[(usize, Vec<CalPoint>)],
    mid_cal: &[(usize, Vec<CalPoint>)],
) -> Vec<Candidate> {
    let points_for = |set: &'_ [(usize, Vec<CalPoint>)], k: usize| {
        set.iter()
            .find(|(ck, _)| *ck == k)
            .map(|(_, p)| p)
            .filter(|p| !p.is_empty())
    };
    let mut out = Vec::new();
    for &k in &cfg.ks {
        let Some(points) = points_for(cal, k) else {
            continue;
        };
        for &eps in &cfg.epsilons {
            for &b in &cfg.batches {
                out.push(Candidate::evaluate(cfg, k, eps, b, points));
            }
            // three-level ladders: every interior (k2, eps2) choice
            for &k2 in &cfg.mid_ks {
                let Some(mid_points) = points_for(mid_cal, k2) else {
                    continue;
                };
                for &eps2 in &cfg.epsilons {
                    for &b in &cfg.batches {
                        out.push(Candidate::evaluate_ladder(
                            cfg,
                            k,
                            eps,
                            b,
                            points,
                            Some((k2, eps2, mid_points.as_slice())),
                        ));
                    }
                }
            }
        }
    }
    out
}

/// Keep the Pareto-efficient candidates (accuracy up, replica-seconds
/// per request down), allocate replicas per gear, and assemble the
/// ladder.  Two-level-only entry point; see [`plan_with_mid`] for
/// three-level ladders.
pub fn plan(cfg: &PlannerConfig, cal: &[(usize, Vec<CalPoint>)]) -> Result<GearPlan> {
    plan_with_mid(cfg, cal, &[])
}

/// [`plan`] with interior-tier calibration sets: when both
/// `cfg.mid_ks` and `mid_cal` are non-empty, the grid also explores
/// three-level ladders.
pub fn plan_with_mid(
    cfg: &PlannerConfig,
    cal: &[(usize, Vec<CalPoint>)],
    mid_cal: &[(usize, Vec<CalPoint>)],
) -> Result<GearPlan> {
    let candidates = enumerate_candidates(cfg, cal, mid_cal);
    anyhow::ensure!(
        !candidates.is_empty(),
        "no plannable candidates: empty grid or no calibration data for any k"
    );
    // The Pareto cost axis: replica-seconds/request for homogeneous
    // plans, $/request on the mixed fleet when `tier_gpus` places the
    // levels on different classes (the §5.2.2 claim made a planning
    // axis -- a gear that defers little is disproportionately expensive
    // when the deferral target is an H100).
    let cost_axis = |c: &Candidate| {
        if cfg.tier_gpus.is_empty() {
            c.replica_s_per_req
        } else {
            c.dollar_per_req
        }
    };
    let points: Vec<Point> = candidates
        .iter()
        .enumerate()
        .map(|(i, c)| Point::new(i.to_string(), cost_axis(c), c.accuracy))
        .collect();
    // frontier() drops dominated candidates AND dedups identical
    // (cost, value) pairs, so this is already one gear per operating point
    let mut gears: Vec<(Gear, Candidate)> = frontier(&points)
        .iter()
        .map(|p| {
            let idx: usize = p.label.parse().expect("frontier label is an index");
            let c = candidates[idx].clone();
            (c.clone().into_gear(cfg), c)
        })
        .collect();
    allocate_replicas(cfg, &mut gears);
    GearPlan::new(gears.into_iter().map(|(g, _)| g).collect())
}

/// Fill each gear's `replicas` from the cost model: the fewest
/// replicas sustaining the design load at `design_util`, bumped where
/// needed so capacity still strictly ascends down the ladder, and
/// requote `sustainable_rps` at that allocation.  Gears that cannot
/// out-sustain a more accurate gear even at the full fleet are dropped
/// (runtime-dominated: lower accuracy and no capacity win).
///
/// With a heterogeneous placement (`cfg.tier_gpus` non-empty) the pass
/// also emits each gear's per-tier fleet (`Gear::tier_fleet`): level
/// `i` gets the fewest replicas of its own GPU class that carry the
/// design load *thinned by the deferral reach* (`design_rps * reach_i`)
/// at `design_util` -- the §5.2.2 placement as concrete provisioning.
fn allocate_replicas(cfg: &PlannerConfig, gears: &mut Vec<(Gear, Candidate)>) {
    gears.sort_by(|(a, _), (b, _)| {
        b.accuracy
            .partial_cmp(&a.accuracy)
            .expect("accuracy is never NaN")
    });
    // per-replica capacity before any reallocation: quoted at the full
    // fleet, so divide it back out
    let per_replica =
        |g: &Gear| g.sustainable_rps / cfg.replicas.max(1) as f64;
    let design_rps = if cfg.design_rps > 0.0 {
        cfg.design_rps
    } else {
        // auto: what the most accurate gear delivers on the full fleet
        gears
            .first()
            .map(|(g, _)| per_replica(g))
            .unwrap_or(0.0)
            * cfg.replicas as f64
    };
    let util = cfg.design_util.clamp(0.05, 1.0);
    let mut prev_rps = 0.0f64;
    let mut kept: Vec<(Gear, Candidate)> = Vec::with_capacity(gears.len());
    for (mut g, c) in gears.drain(..) {
        if !cfg.tier_gpus.is_empty() {
            g.tier_fleet = c
                .reach
                .iter()
                .enumerate()
                .map(|(i, &r)| {
                    let cap = c.level_capacity_rps(cfg, i);
                    let arrivals = design_rps * r;
                    let replicas = if cap.is_finite() {
                        ((arrivals / (cap * util)).ceil() as usize)
                            .clamp(1, cfg.replicas.max(1))
                    } else {
                        1
                    };
                    TierAlloc { gpu: cfg.gpu_for_level(i), replicas }
                })
                .collect();
        }
        let rps1 = per_replica(&g);
        if !rps1.is_finite() {
            // infinite-capacity degenerate point: one replica suffices
            g.replicas = 1;
            kept.push((g, c));
            continue;
        }
        // fewest replicas covering the design load at target
        // utilisation...
        let needed = (design_rps / (rps1 * util)).ceil() as usize;
        // ...but never fewer than it takes to beat every more accurate
        // gear's capacity (otherwise the ladder loses monotonicity and
        // the gear is pointless at runtime)
        let monotone = (prev_rps / rps1).floor() as usize + 1;
        g.replicas = needed.max(monotone).clamp(1, cfg.replicas.max(1));
        g.sustainable_rps = g.replicas as f64 * rps1;
        if g.sustainable_rps <= prev_rps {
            // even the capped fleet cannot out-sustain the gear above:
            // runtime-dominated, drop it
            continue;
        }
        prev_rps = g.sustainable_rps;
        kept.push((g, c));
    }
    *gears = kept;
}

/// Synthesize a one-gear plan from a MEASURED top-tier capacity: what
/// `serve --autoscale` runs on when no offline plan is given.  The
/// single gear pins the suite's own calibrated cascade (work factor
/// 1.0; `serve` re-grounds its theta on the suite's calibration at
/// `epsilon` like any loaded plan) and quotes `sustainable_rps` at the
/// measured rate -- e.g. `--top-rps` from a `repro loadgen` run -- so
/// the scale decider's sizing math is grounded in this deployment's
/// hardware instead of a cost model.  One gear means the ladder never
/// shifts; elasticity alone adapts to load.
pub fn one_gear_plan(
    top_rps: f64,
    replicas: usize,
    max_batch: usize,
    epsilon: f64,
    top_accuracy: f64,
) -> Result<GearPlan> {
    anyhow::ensure!(
        top_rps > 0.0,
        "a synthesized plan needs a measured capacity (--top-rps > 0)"
    );
    anyhow::ensure!(replicas >= 1, "a synthesized plan needs >= 1 replica");
    anyhow::ensure!(max_batch >= 1, "a synthesized plan needs a batch cap");
    GearPlan::new(vec![Gear {
        id: 0,
        k: 1,
        epsilon,
        // placeholder until serve re-grounds it on the suite's own
        // calibration points (the defer-nothing sentinel would be wrong
        // to serve raw, but gear thetas are always re-grounded)
        theta: 0.0,
        mid: vec![],
        max_batch,
        replicas,
        tier_fleet: vec![],
        dollar_per_req: 0.0,
        accuracy: top_accuracy,
        relative_cost: 1.0,
        sustainable_rps: top_rps,
    }])
}

/// Synthetic `(score, correct)` calibration points for ensemble size
/// `k`, artifact-free.  Per sample: difficulty `d ~ U[0,1)` sets each
/// member's independent correctness probability (easy samples near
/// `member_accuracy`'s ceiling, hard ones near chance); `k` members
/// vote, the agreement score is the majority vote fraction (Eq. 3) and
/// the point is correct when the strict majority is.  Larger `k`
/// concentrates the vote, reproducing the paper's ensemble-agreement
/// effect: accuracy and score separation both improve with `k`.
pub fn synthetic_cal_points(
    k: usize,
    n: usize,
    member_accuracy: f64,
    seed: u64,
) -> Vec<CalPoint> {
    assert!(k >= 1, "ensemble size must be >= 1");
    let mut rng = Rng::new(seed ^ (k as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    (0..n)
        .map(|_| {
            let d = rng.f64();
            // easy (d~0): ~min(0.99, member_accuracy + 0.15); hard (d~1): ~0.5
            let p = (member_accuracy + 0.15 - (member_accuracy - 0.35) * d)
                .clamp(0.5, 0.99);
            let votes_correct = (0..k).filter(|_| rng.bool(p)).count();
            let majority = votes_correct.max(k - votes_correct);
            CalPoint {
                score: majority as f32 / k as f32,
                correct: 2 * votes_correct > k,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> PlannerConfig {
        PlannerConfig {
            ks: vec![1, 3, 5],
            epsilons: vec![0.02, 0.05, 0.10],
            batches: vec![4, 16],
            replicas: 2,
            ..PlannerConfig::default()
        }
    }

    fn small_cal(cfg: &PlannerConfig) -> Vec<(usize, Vec<CalPoint>)> {
        cfg.ks
            .iter()
            .map(|&k| (k, synthetic_cal_points(k, 200, 0.8, 7)))
            .collect()
    }

    #[test]
    fn synthetic_points_improve_with_k() {
        let acc_of = |k: usize| {
            let pts = synthetic_cal_points(k, 4000, 0.8, 3);
            pts.iter().filter(|p| p.correct).count() as f64 / pts.len() as f64
        };
        let a1 = acc_of(1);
        let a5 = acc_of(5);
        let a9 = acc_of(9);
        assert!(a5 > a1 + 0.02, "k=5 ({a5}) not better than k=1 ({a1})");
        assert!(a9 >= a5 - 0.01, "k=9 ({a9}) collapsed vs k=5 ({a5})");
        // scores are valid vote fractions
        let pts = synthetic_cal_points(4, 500, 0.8, 1);
        assert!(pts.iter().all(|p| (0.5..=1.0).contains(&p.score)));
        // deterministic
        assert_eq!(
            synthetic_cal_points(3, 50, 0.8, 11)
                .iter()
                .map(|p| p.score)
                .collect::<Vec<_>>(),
            synthetic_cal_points(3, 50, 0.8, 11)
                .iter()
                .map(|p| p.score)
                .collect::<Vec<_>>()
        );
    }

    #[test]
    fn plan_is_pareto_optimal_against_brute_force() {
        let cfg = small_cfg();
        let cal = small_cal(&cfg);
        let plan = plan(&cfg, &cal).unwrap();
        assert!(!plan.is_empty());
        let all = enumerate_candidates(&cfg, &cal, &[]);
        // no enumerated candidate may dominate any emitted gear on the
        // (accuracy, replica-seconds-per-request) axes the frontier ran
        // over
        for g in &plan.gears {
            for c in &all {
                let dominates = c.accuracy >= g.accuracy
                    && c.replica_s_per_req <= g.replica_s_per_req()
                    && (c.accuracy > g.accuracy
                        || c.replica_s_per_req < g.replica_s_per_req() - 1e-15);
                assert!(
                    !dominates,
                    "candidate k={} eps={} b={} (acc {:.4}, {:.3e} rep-s/req) \
                     dominates gear {} (acc {:.4}, {:.3e} rep-s/req)",
                    c.k,
                    c.epsilon,
                    c.max_batch,
                    c.accuracy,
                    c.replica_s_per_req,
                    g.id,
                    g.accuracy,
                    g.replica_s_per_req()
                );
            }
        }
        // and every gear is an enumerated candidate, not an invention
        // (sustainable_rps is requoted at the gear's allocation, so
        // compare per-replica capacity instead)
        for g in &plan.gears {
            assert!(all.iter().any(|c| c.k == g.k
                && c.epsilon == g.epsilon
                && c.max_batch == g.max_batch
                && c.accuracy == g.accuracy
                && (1.0 / c.replica_s_per_req - g.per_replica_rps()).abs()
                    < 1e-6 * g.per_replica_rps()));
        }
    }

    #[test]
    fn plan_ladder_trades_accuracy_for_throughput() {
        let cfg = small_cfg();
        let plan = plan(&cfg, &small_cal(&cfg)).unwrap();
        for w in plan.gears.windows(2) {
            assert!(w[0].accuracy >= w[1].accuracy);
            assert!(w[0].sustainable_rps <= w[1].sustainable_rps);
        }
        // the grid spans lax-enough epsilons that the frontier has real
        // spread to control against
        if plan.len() >= 2 {
            assert!(plan.fastest().sustainable_rps > plan.top().sustainable_rps);
        }
    }

    #[test]
    fn allocation_prices_cheaper_gears_with_fewer_replicas() {
        let cfg = PlannerConfig { replicas: 8, ..small_cfg() };
        let plan = plan(&cfg, &small_cal(&cfg)).unwrap();
        // the top gear is provisioned at the full fleet (design load ==
        // its own full-fleet capacity at design_util headroom)
        assert_eq!(plan.top().replicas, cfg.replicas);
        for g in &plan.gears {
            assert!(g.replicas >= 1 && g.replicas <= cfg.replicas);
            // quoted capacity is consistent with the allocation
            assert!(
                (g.sustainable_rps - g.replicas as f64 * g.per_replica_rps()).abs()
                    < 1e-6 * g.sustainable_rps
            );
            // every gear covers the design load (the top gear's
            // full-fleet capacity) at <= 1.0 utilisation of its fleet
            assert!(
                g.sustainable_rps * 1.0001
                    >= plan.top().sustainable_rps * cfg.design_util,
                "gear {} underprovisioned: {} rps vs design {}",
                g.id,
                g.sustainable_rps,
                plan.top().sustainable_rps
            );
        }
        if plan.len() >= 2 {
            // at least one cheaper gear needs strictly fewer machines:
            // the rental-cost win the allocation exists for
            assert!(
                plan.fastest().replicas < plan.top().replicas,
                "fastest gear rents as much as the top gear: {:?}",
                plan.gears
                    .iter()
                    .map(|g| (g.id, g.replicas, g.sustainable_rps as u64))
                    .collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn explicit_design_rps_provisions_the_ladder() {
        let mut cfg = PlannerConfig { replicas: 16, ..small_cfg() };
        cfg.design_rps = 500.0;
        let plan = plan(&cfg, &small_cal(&cfg)).unwrap();
        for g in &plan.gears {
            // enough capacity for the design load at headroom, unless
            // capped by the fleet
            if g.replicas < cfg.replicas {
                assert!(
                    g.sustainable_rps * 1.0001 >= cfg.design_rps * cfg.design_util,
                    "gear {} misses design load: {}",
                    g.id,
                    g.sustainable_rps
                );
            }
        }
    }

    #[test]
    fn three_level_grid_emits_multi_tier_gears_on_the_frontier() {
        let cfg = PlannerConfig {
            ks: vec![1, 3],
            mid_ks: vec![3, 5],
            mid_gamma: 0.20,
            epsilons: vec![0.02, 0.05, 0.10],
            batches: vec![8],
            replicas: 2,
            ..PlannerConfig::default()
        };
        let cal = small_cal(&cfg);
        // interior tier: stronger members (it is a bigger model)
        let mid_cal: Vec<_> = cfg
            .mid_ks
            .iter()
            .map(|&k| (k, synthetic_cal_points(k, 200, 0.9, 13)))
            .collect();
        let candidates = enumerate_candidates(&cfg, &cal, &mid_cal);
        // grid: 2 ks x 3 eps x (1 two-level + 2 mid_ks x 3 eps2) x 1 batch
        assert_eq!(candidates.len(), 2 * 3 * (1 + 2 * 3));
        let multi: Vec<_> = candidates.iter().filter(|c| c.mid.is_some()).collect();
        assert!(!multi.is_empty());
        // a three-level candidate defers less to the top than its
        // two-level base, so it must be cheaper than the SAME (k, eps,
        // batch) without the interior tier whenever the interior tier
        // accepts anything
        for c in &multi {
            let base = candidates
                .iter()
                .find(|b| {
                    b.mid.is_none()
                        && b.k == c.k
                        && b.epsilon == c.epsilon
                        && b.max_batch == c.max_batch
                })
                .expect("two-level base exists");
            let k2 = c.mid.as_ref().unwrap().k as f64;
            assert!(
                c.relative_cost <= base.relative_cost + k2 * cfg.mid_gamma + 1e-12,
                "interior tier cost unaccounted"
            );
        }
        // and the full planner accepts the mixed grid
        let plan = plan_with_mid(&cfg, &cal, &mid_cal).unwrap();
        assert!(!plan.is_empty());
        for g in &plan.gears {
            assert_eq!(g.thetas().len(), 1 + g.mid.len());
        }
        // ladder invariants hold across mixed-depth gears
        for w in plan.gears.windows(2) {
            assert!(w[0].accuracy >= w[1].accuracy);
            assert!(w[0].sustainable_rps <= w[1].sustainable_rps);
        }
    }

    #[test]
    fn heterogeneous_placement_prices_dollars_below_all_top() {
        let hom = small_cfg();
        let het = PlannerConfig {
            tier_gpus: vec![Gpu::V100, Gpu::H100],
            ..small_cfg()
        };
        let pts = synthetic_cal_points(3, 300, 0.8, 5);
        let all_top = Candidate::evaluate(&hom, 3, 0.05, 8, &pts);
        let mixed = Candidate::evaluate(&het, 3, 0.05, 8, &pts);
        // placement changes pricing, never the cascade itself
        assert_eq!(mixed.accuracy, all_top.accuracy);
        assert_eq!(mixed.relative_cost, all_top.relative_cost);
        assert_eq!(mixed.replica_s_per_req, all_top.replica_s_per_req);
        // the cheap tier-1 GPU undercuts pricing everything on the top
        assert!(
            mixed.dollar_per_req < all_top.dollar_per_req,
            "{} !< {}",
            mixed.dollar_per_req,
            all_top.dollar_per_req
        );
        // homogeneous $ axis is the monolithic layout on the ladder top
        let top = *Gpu::LADDER.last().unwrap();
        assert!(
            (all_top.dollar_per_req - top.dollars_for(all_top.replica_s_per_req))
                .abs()
                < 1e-15
        );
        // per-level reach/cost bookkeeping is consistent
        assert_eq!(mixed.reach.len(), 2);
        assert_eq!(mixed.tier_costs.len(), 2);
        assert_eq!(mixed.reach[0], 1.0);
        assert_eq!(*mixed.tier_costs.last().unwrap(), 1.0);
    }

    #[test]
    fn heterogeneous_plan_emits_per_tier_fleets_on_the_dollar_axis() {
        let cfg = PlannerConfig {
            tier_gpus: vec![Gpu::V100, Gpu::H100],
            replicas: 8,
            ..small_cfg()
        };
        let plan = plan(&cfg, &small_cal(&cfg)).unwrap();
        assert!(!plan.is_empty());
        for g in &plan.gears {
            // two-level gears: one allocation per level, placed per cfg
            assert_eq!(g.tier_fleet.len(), 2, "gear {}: {:?}", g.id, g.tier_fleet);
            assert_eq!(g.tier_fleet[0].gpu, Gpu::V100);
            assert_eq!(g.tier_fleet[1].gpu, Gpu::H100);
            for t in &g.tier_fleet {
                assert!(t.replicas >= 1 && t.replicas <= cfg.replicas);
            }
            assert!(g.dollar_per_req > 0.0);
        }
        // the frontier ran on $/request: down the ladder (accuracy
        // descending) every gear must be strictly cheaper in dollars,
        // or it would have been dominated
        for w in plan.gears.windows(2) {
            assert!(w[0].accuracy >= w[1].accuracy);
            assert!(
                w[0].dollar_per_req > w[1].dollar_per_req,
                "ladder not $-monotone: {} then {}",
                w[0].dollar_per_req,
                w[1].dollar_per_req
            );
        }
        // homogeneous plans carry no tier fleet
        let hom = plan_with_mid(&small_cfg(), &small_cal(&small_cfg()), &[]).unwrap();
        assert!(hom.gears.iter().all(|g| g.tier_fleet.is_empty()));
        assert!(hom.gears.iter().all(|g| g.dollar_per_req > 0.0));
    }

    #[test]
    fn one_gear_plan_quotes_the_measured_capacity() {
        let plan = one_gear_plan(480.0, 1, 16, 0.03, 0.95).unwrap();
        assert_eq!(plan.len(), 1);
        let g = plan.top();
        assert_eq!(g.sustainable_rps, 480.0);
        assert_eq!(g.replicas, 1);
        assert!((g.per_replica_rps() - 480.0).abs() < 1e-9);
        assert_eq!(g.max_batch, 16);
        assert_eq!(g.relative_cost, 1.0, "the whole cascade runs");
        assert_eq!(g.epsilon, 0.03);
        // the runtime config carries the single theta for re-grounding
        assert_eq!(g.config().thetas.len(), 1);
        // nonsense inputs are rejected, not served
        assert!(one_gear_plan(0.0, 1, 16, 0.03, 0.95).is_err());
        assert!(one_gear_plan(100.0, 0, 16, 0.03, 0.95).is_err());
        assert!(one_gear_plan(100.0, 1, 0, 0.03, 0.95).is_err());
    }

    #[test]
    fn plan_errors_without_calibration_data() {
        let cfg = small_cfg();
        assert!(plan(&cfg, &[]).is_err());
        let empty: Vec<(usize, Vec<CalPoint>)> =
            cfg.ks.iter().map(|&k| (k, Vec::new())).collect();
        assert!(plan(&cfg, &empty).is_err());
    }

    #[test]
    fn bigger_batch_raises_capacity_at_fixed_config() {
        let cfg = PlannerConfig::default();
        let pts = synthetic_cal_points(3, 300, 0.8, 5);
        let small = Candidate::evaluate(&cfg, 3, 0.05, 4, &pts);
        let large = Candidate::evaluate(&cfg, 3, 0.05, 32, &pts);
        assert!(large.sustainable_rps > small.sustainable_rps);
        // and bigger batches amortise dispatch overhead: cheaper in
        // replica-seconds per request, which is exactly what the
        // rental-cost axis must see
        assert!(large.replica_s_per_req < small.replica_s_per_req);
        // same cascade config => same accuracy/cost, batching is free
        assert_eq!(small.accuracy, large.accuracy);
        assert_eq!(small.relative_cost, large.relative_cost);
    }

    #[test]
    fn laxer_epsilon_cuts_cost() {
        let cfg = PlannerConfig::default();
        let pts = synthetic_cal_points(3, 300, 0.8, 5);
        let strict = Candidate::evaluate(&cfg, 3, 0.0, 8, &pts);
        let lax = Candidate::evaluate(&cfg, 3, 0.25, 8, &pts);
        assert!(lax.relative_cost <= strict.relative_cost);
        assert!(lax.sustainable_rps >= strict.sustainable_rps);
    }
}
