//! Load-adaptive cascade configuration ("gear planning").
//!
//! The static cascade has one operating point: ensemble sizes, agreement
//! thresholds and batch sizes fixed at calibration time.  Under bursty
//! traffic that is the wrong trade on both sides of the burst -- too
//! slow during it (shedding work the cheap tiers could have answered),
//! too inaccurate after it if tuned for the peak.  This subsystem
//! precomputes a ladder of Pareto-optimal operating points offline and
//! switches between them online from observed load, CascadeServe-style:
//!
//! * [`gear`] -- the [`gear::GearPlan`] / [`gear::Gear`] data model,
//!   JSON (de)serialisation, and [`gear::GearHandle`], the atomically
//!   swappable runtime config the serving pipeline reads per batch;
//! * [`search`] -- the offline planner: enumerate `(k, epsilon, batch)`
//!   candidates over calibration data, price them with the Eq. 1 cost
//!   model, keep the accuracy-vs-throughput Pareto frontier;
//! * the online half -- the thread that walks the ladder against
//!   observed load -- lives in the unified control plane
//!   ([`crate::control`]); [`controller`] is a thin re-export kept for
//!   its old paths.
//!
//! Entry points: `repro plan` (emit a plan JSON), `repro serve --plan`
//! (serve with the control loop engaged), `benches/bench_gears.rs`
//! (fixed vs adaptive under on-off load) and
//! `rust/tests/planner_integration.rs`.

pub mod controller;
pub mod gear;
pub mod search;

pub use controller::{ControllerConfig, Observation, Sampler, Shift, Trigger};
pub use gear::{Gear, GearConfig, GearHandle, GearPlan, TierPlan};
pub use search::{synthetic_cal_points, PlannerConfig};
