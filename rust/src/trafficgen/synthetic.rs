//! Synthetic batch classifier: a deterministic, artifact-free backend
//! for load generation, replica-pool tests and benches.
//!
//! Models a serial accelerator with an affine batch cost
//! `base + per_row * n` (the same shape as a PJRT dispatch: fixed launch
//! overhead plus per-row compute).  The sleep runs on the pipeline's
//! batcher thread, so one `SyntheticClassifier`-backed pipeline behaves
//! like one serially-executing replica with throughput approaching
//! `max_batch / (base + per_row * max_batch)` rows/s at saturation --
//! which is exactly what the throughput-vs-replicas experiments need.

use std::time::Duration;

use anyhow::Result;

use crate::coordinator::cascade::{BatchClassifier, CascadeResult};

/// Deterministic fake classifier with tunable service time.
#[derive(Debug, Clone)]
pub struct SyntheticClassifier {
    /// Feature dimensionality requests must match.
    pub dim: usize,
    /// Number of simulated cascade levels (exit tiers are 1..=levels).
    pub levels: usize,
    /// Fixed per-batch cost (dispatch overhead).
    pub base: Duration,
    /// Marginal cost per row.
    pub per_row: Duration,
}

impl SyntheticClassifier {
    pub fn new(dim: usize, levels: usize, base: Duration, per_row: Duration) -> Self {
        assert!(dim > 0 && levels > 0);
        SyntheticClassifier { dim, levels, base, per_row }
    }

    /// Rows/second one replica sustains at batch size `b`.
    pub fn capacity_rps(&self, b: usize) -> f64 {
        self.capacity_rps_geared(b, 1.0)
    }

    /// Rows/second one replica sustains at batch size `b` under a gear
    /// with the given `work_factor` (see `classify_batch_geared`).
    pub fn capacity_rps_geared(&self, b: usize, work_factor: f64) -> f64 {
        let batch_s = (self.base + self.per_row * b as u32).as_secs_f64()
            * work_factor.max(0.0);
        if batch_s <= 0.0 {
            f64::INFINITY
        } else {
            b as f64 / batch_s
        }
    }

    /// Shared execution path: sleep `work_factor`-scaled service time,
    /// then produce deterministic results.  `work_factor` 1.0 is the
    /// plain backend; a gear's expected relative cost scales the
    /// per-row compute so cheaper gears genuinely run faster.
    fn run_batch(
        &self,
        features: &[f32],
        n: usize,
        work_factor: f64,
    ) -> Result<Vec<CascadeResult>> {
        anyhow::ensure!(
            features.len() == n * self.dim,
            "feature buffer has {} floats, expected {}",
            features.len(),
            n * self.dim
        );
        let service = self
            .base
            .mul_f64(work_factor.max(0.0))
            .saturating_add(self.per_row.mul_f64(work_factor.max(0.0) * n as f64));
        if !service.is_zero() {
            std::thread::sleep(service);
        }
        Ok((0..n)
            .map(|i| {
                // deterministic pseudo-routing from the first feature so
                // exit tiers vary without an RNG
                let h = (features[i * self.dim].abs() * 997.0) as usize;
                let exit_level = 1 + h % self.levels;
                CascadeResult {
                    prediction: (h % 2) as u32,
                    exit_level,
                    scores: vec![0.9; exit_level],
                }
            })
            .collect())
    }
}

impl BatchClassifier for SyntheticClassifier {
    fn dim(&self) -> usize {
        self.dim
    }

    fn n_levels(&self) -> usize {
        self.levels
    }

    fn classify_batch(&self, features: &[f32], n: usize) -> Result<Vec<CascadeResult>> {
        self.run_batch(features, n, 1.0)
    }

    fn classify_batch_geared(
        &self,
        features: &[f32],
        n: usize,
        gear: &crate::planner::gear::GearConfig,
    ) -> Result<Vec<CascadeResult>> {
        self.run_batch(features, n, gear.work_factor)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_results_and_dim_check() {
        let c = SyntheticClassifier::new(2, 3, Duration::ZERO, Duration::ZERO);
        let a = c.classify_batch(&[0.5, 0.0, 1.5, 0.0], 2).unwrap();
        let b = c.classify_batch(&[0.5, 0.0, 1.5, 0.0], 2).unwrap();
        assert_eq!(a.len(), 2);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prediction, y.prediction);
            assert_eq!(x.exit_level, y.exit_level);
            assert!(x.exit_level >= 1 && x.exit_level <= 3);
        }
        assert!(c.classify_batch(&[0.0; 3], 2).is_err());
    }

    #[test]
    fn geared_work_factor_scales_service_time() {
        use crate::planner::gear::GearConfig;
        let c = SyntheticClassifier::new(1, 2, Duration::ZERO, Duration::from_millis(4));
        let cheap = GearConfig {
            gear_id: 1,
            thetas: vec![0.5],
            work_factor: 0.25,
            max_batch: 8,
        };
        let t0 = std::time::Instant::now();
        let r = c.classify_batch_geared(&[0.5; 4], 4, &cheap).unwrap();
        let cheap_dt = t0.elapsed();
        assert_eq!(r.len(), 4);
        // 4 rows * 4ms * 0.25 = 4ms; the ungeared path sleeps 16ms
        assert!(cheap_dt >= Duration::from_millis(3), "slept only {cheap_dt:?}");
        let t0 = std::time::Instant::now();
        c.classify_batch(&[0.5; 4], 4).unwrap();
        let full_dt = t0.elapsed();
        assert!(full_dt >= Duration::from_millis(15), "slept only {full_dt:?}");
        // results are identical either way: gears change cost, not routing
        let a = c.classify_batch(&[0.5; 2], 2).unwrap();
        let b = c.classify_batch_geared(&[0.5; 2], 2, &cheap).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prediction, y.prediction);
            assert_eq!(x.exit_level, y.exit_level);
        }
    }

    #[test]
    fn service_time_scales_with_batch() {
        let c = SyntheticClassifier::new(
            1,
            1,
            Duration::from_millis(1),
            Duration::from_millis(2),
        );
        let t0 = std::time::Instant::now();
        c.classify_batch(&[0.0; 4], 4).unwrap();
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_millis(9), "slept only {dt:?}");
        // capacity: 4 rows / 9ms
        let cap = c.capacity_rps(4);
        assert!((cap - 4.0 / 0.009).abs() < 1.0, "cap {cap}");
    }
}
