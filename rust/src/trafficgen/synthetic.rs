//! Synthetic batch classifier: a deterministic, artifact-free backend
//! for load generation, replica-pool tests and benches.
//!
//! Models a serial accelerator with an affine batch cost
//! `base + per_row * n` (the same shape as a PJRT dispatch: fixed launch
//! overhead plus per-row compute).  The sleep runs on the pipeline's
//! batcher thread, so one `SyntheticClassifier`-backed pipeline behaves
//! like one serially-executing replica with throughput approaching
//! `max_batch / (base + per_row * max_batch)` rows/s at saturation --
//! which is exactly what the throughput-vs-replicas experiments need.

use std::time::Duration;

use anyhow::Result;

use crate::coordinator::cascade::{
    BatchClassifier, CascadeResult, StageClassifier, StageResult,
};

/// Deterministic fake classifier with tunable service time.
#[derive(Debug, Clone)]
pub struct SyntheticClassifier {
    /// Feature dimensionality requests must match.
    pub dim: usize,
    /// Number of simulated cascade levels (exit tiers are 1..=levels).
    pub levels: usize,
    /// Fixed per-batch cost (dispatch overhead).
    pub base: Duration,
    /// Marginal cost per row.
    pub per_row: Duration,
}

impl SyntheticClassifier {
    pub fn new(dim: usize, levels: usize, base: Duration, per_row: Duration) -> Self {
        assert!(dim > 0 && levels > 0);
        SyntheticClassifier { dim, levels, base, per_row }
    }

    /// Rows/second one replica sustains at batch size `b`.
    pub fn capacity_rps(&self, b: usize) -> f64 {
        self.capacity_rps_geared(b, 1.0)
    }

    /// Rows/second one replica sustains at batch size `b` under a gear
    /// with the given `work_factor` (see `classify_batch_geared`).
    pub fn capacity_rps_geared(&self, b: usize, work_factor: f64) -> f64 {
        let batch_s = (self.base + self.per_row * b as u32).as_secs_f64()
            * work_factor.max(0.0);
        if batch_s <= 0.0 {
            f64::INFINITY
        } else {
            b as f64 / batch_s
        }
    }

    /// Shared execution path: sleep `work_factor`-scaled service time,
    /// then produce deterministic results.  `work_factor` 1.0 is the
    /// plain backend; a gear's expected relative cost scales the
    /// per-row compute so cheaper gears genuinely run faster.
    fn run_batch(
        &self,
        features: &[f32],
        n: usize,
        work_factor: f64,
    ) -> Result<Vec<CascadeResult>> {
        anyhow::ensure!(
            features.len() == n * self.dim,
            "feature buffer has {} floats, expected {}",
            features.len(),
            n * self.dim
        );
        let service = self
            .base
            .mul_f64(work_factor.max(0.0))
            .saturating_add(self.per_row.mul_f64(work_factor.max(0.0) * n as f64));
        if !service.is_zero() {
            std::thread::sleep(service);
        }
        Ok((0..n)
            .map(|i| {
                // deterministic pseudo-routing from the first feature so
                // exit tiers vary without an RNG
                let (prediction, exit_level) = self.route(features[i * self.dim]);
                CascadeResult {
                    prediction,
                    exit_level,
                    scores: vec![0.9; exit_level],
                }
            })
            .collect())
    }
}

impl SyntheticClassifier {
    /// Deterministic pseudo-routing from the first feature (shared by
    /// the monolithic and stage-wise paths so both produce identical
    /// predictions and exit levels).
    fn route(&self, first_feature: f32) -> (u32, usize) {
        let h = (first_feature.abs() * 997.0) as usize;
        (
            (h % 2) as u32,
            1 + h % self.levels, // 1-based exit level
        )
    }
}

/// Stage-wise synthetic backend for tiered-fleet tests and benches: the
/// same deterministic routing as [`SyntheticClassifier`], but each tier
/// is independently executable ([`StageClassifier`]) with its own share
/// of the per-row cost.
///
/// `weights[t]` scales tier `t`'s per-row service time relative to the
/// monolithic `per_row` (cheap early tiers, expensive top model -- the
/// paper's §5.2.2 fleet shape).  Each stage batch pays the full `base`
/// dispatch overhead: a tiered fleet genuinely re-batches per tier.
/// Stage-wise results are byte-identical to the monolithic path
/// (property-tested in rust/tests/coordinator_props.rs); only the cost
/// layout differs.
///
/// **Drift mode** ([`StagedSynthetic::with_drift`]): a deterministic
/// fraction of rows becomes *unfaithful* -- at exactly its routed exit
/// tier the row's prediction flips and its reported score collapses to
/// the constant `0.9 * frac`, while the deeper tiers still answer
/// canonically.
/// This is the distribution-shift fixture the drift observatory needs:
/// under a stale fixed policy the drifted rows exit early and WRONG
/// (the shadow path sees downstream disagree), and because their
/// scores form one tie-group strictly below the faithful 0.9 band,
/// re-running `estimate_theta` on the live window refuses the group
/// atomically and lands on theta == `0.9 * frac` exactly -- with the
/// strict `score > theta` exit rule the re-ground then blocks every
/// drifted row (and, since a drifted row's agreement spread at other
/// tiers also sits below `0.9 * frac`, none sneaks out early at a
/// shallower tier) without deferring the faithful population.
/// `drift_frac == 0.0` (the default) is byte-identical to the
/// historical behaviour.
#[derive(Debug, Clone)]
pub struct StagedSynthetic {
    inner: SyntheticClassifier,
    weights: Vec<f64>,
    /// Fraction of rows (by deterministic pseudo-lane) that drift;
    /// 0.0 disables drift mode entirely.
    drift_frac: f64,
}

impl StagedSynthetic {
    /// Per-tier cost weights; `weights.len()` must equal the inner
    /// classifier's `levels`.
    pub fn new(inner: SyntheticClassifier, weights: Vec<f64>) -> StagedSynthetic {
        assert_eq!(weights.len(), inner.levels, "one weight per tier");
        assert!(weights.iter().all(|w| *w >= 0.0), "weights must be >= 0");
        StagedSynthetic { inner, weights, drift_frac: 0.0 }
    }

    /// Uniform weights: every tier costs `1/levels` of the monolithic
    /// per-row time.
    pub fn uniform(inner: SyntheticClassifier) -> StagedSynthetic {
        let w = 1.0 / inner.levels as f64;
        let weights = vec![w; inner.levels];
        StagedSynthetic { inner, weights, drift_frac: 0.0 }
    }

    /// Enable drift mode: `frac` of the row population (in `[0, 1]`)
    /// answers unfaithfully at its routed exit tier (see the type
    /// docs).  The selection and the drifted scores are deterministic
    /// in the row's features, so runs are reproducible.
    pub fn with_drift(mut self, frac: f64) -> StagedSynthetic {
        assert!((0.0..=1.0).contains(&frac), "drift fraction in [0, 1]");
        self.drift_frac = frac;
        self
    }

    pub fn drift_frac(&self) -> f64 {
        self.drift_frac
    }

    pub fn weights(&self) -> &[f64] {
        &self.weights
    }

    /// Rows/second ONE replica of tier `level0`'s pool sustains at
    /// batch size `b` (its share of the per-row cost + full dispatch
    /// overhead per batch).
    pub fn stage_capacity_rps(&self, level0: usize, b: usize) -> f64 {
        let batch_s = self.inner.base.as_secs_f64()
            + self.inner.per_row.as_secs_f64() * self.weights[level0] * b as f64;
        if batch_s <= 0.0 {
            f64::INFINITY
        } else {
            b as f64 / batch_s
        }
    }

    /// Deterministic pseudo-agreement of a row at tier `level0`: rows
    /// the default routing exits at or before this tier agree at the
    /// reported score (0.9); rows that would defer get a spread of
    /// lower values in [0, 0.9).  A theta override therefore works like
    /// the real agreement rule -- defer when agreement <= theta -- and
    /// lowering theta below 0.9 pulls progressively more would-defer
    /// rows into an early exit.  This is the knob the control plane's
    /// per-tier gear shifting turns (`TieredFleet::set_tier_gear`);
    /// with no override the routing is exactly the historical
    /// byte-identical-to-monolithic behaviour.
    fn agreement(&self, first_feature: f32, level0: usize) -> f32 {
        let h = (first_feature.abs() * 997.0) as usize;
        let exit_level = 1 + h % self.inner.levels;
        if exit_level <= level0 + 1 {
            return 0.9;
        }
        0.9 * (self.lane(h) as f32 / 1000.0)
    }

    /// Deterministic per-row pseudo-lane in [0, 1000): the Fibonacci
    /// hash the agreement spread already uses.  Drift mode reuses the
    /// SAME lane for drift selection, which is what keeps the drifted
    /// population threshold-separable: a drifted row (lane <
    /// frac * 1000) exits with the constant [`Self::drift_score`], and
    /// its agreement spread at the tiers it does NOT exit at
    /// (`0.9 * lane / 1000`) also sits below that constant -- both
    /// strictly below every faithful exit's 0.9.
    fn lane(&self, h: usize) -> usize {
        (h / self.inner.levels).wrapping_mul(2_654_435_761) % 1000
    }

    /// Whether drift mode claims this row.
    fn drifted(&self, h: usize) -> bool {
        self.drift_frac > 0.0 && (self.lane(h) as f64) < self.drift_frac * 1000.0
    }

    /// The score every drifted row exits with: the constant
    /// `0.9 * drift_frac`.  A constant (rather than a per-row spread)
    /// makes the wrong population one tie-group for
    /// [`crate::calib::threshold::estimate_theta`], which refuses or
    /// admits a tie-group atomically: the re-estimated theta lands on
    /// exactly this value, admits zero drifted exits, and -- because
    /// acceptance is strict `score > theta` -- blocks the entire
    /// drifted population on re-ground.
    fn drift_score(&self) -> f32 {
        0.9 * self.drift_frac as f32
    }
}

impl BatchClassifier for StagedSynthetic {
    fn dim(&self) -> usize {
        self.inner.dim
    }

    fn n_levels(&self) -> usize {
        self.inner.levels
    }

    fn classify_batch(&self, features: &[f32], n: usize) -> Result<Vec<CascadeResult>> {
        self.inner.classify_batch(features, n)
    }
}

impl StageClassifier for StagedSynthetic {
    fn classify_stage(
        &self,
        level0: usize,
        features: &[f32],
        n: usize,
        theta: Option<f32>,
    ) -> Result<Vec<StageResult>> {
        anyhow::ensure!(level0 < self.inner.levels, "stage {level0} out of range");
        anyhow::ensure!(
            features.len() == n * self.inner.dim,
            "feature buffer has {} floats, expected {}",
            features.len(),
            n * self.inner.dim
        );
        let service = self.inner.base.saturating_add(
            self.inner
                .per_row
                .mul_f64(self.weights[level0] * n as f64),
        );
        if !service.is_zero() && n > 0 {
            std::thread::sleep(service);
        }
        let last = level0 + 1 == self.inner.levels;
        Ok((0..n)
            .map(|i| {
                let first = features[i * self.inner.dim];
                let h = (first.abs() * 997.0) as usize;
                let (prediction, exit_level) = self.inner.route(first);
                // drift mode: a drifted row goes wrong exactly at its
                // routed exit tier (flipped prediction, low score); the
                // final tier and every other tier stay canonical, so a
                // deferred drifted row is still answered correctly
                // downstream -- the shape real drift has, and the one a
                // theta re-ground can actually fix.
                let drifts_here =
                    !last && exit_level == level0 + 1 && self.drifted(h);
                let agree = if drifts_here {
                    self.drift_score()
                } else {
                    self.agreement(first, level0)
                };
                // default policy: a row exits at its routed level; a
                // theta override applies the agreement rule instead
                // (defer when agreement <= theta).  The final tier
                // accepts whatever reaches it either way.
                let exits = match theta {
                    None => exit_level <= level0 + 1 || last,
                    Some(t) => last || agree > t,
                };
                let prediction =
                    if drifts_here { prediction ^ 1 } else { prediction };
                StageResult {
                    // outside drift mode the score is the historical
                    // constant 0.9 (byte-identity with the monolithic
                    // path); in drift mode it is the effective
                    // agreement, so exit scores carry the signal the
                    // observatory thresholds on
                    score: if self.drift_frac > 0.0 { agree } else { 0.9 },
                    decision: exits.then_some(prediction),
                }
            })
            .collect())
    }
}

impl BatchClassifier for SyntheticClassifier {
    fn dim(&self) -> usize {
        self.dim
    }

    fn n_levels(&self) -> usize {
        self.levels
    }

    fn classify_batch(&self, features: &[f32], n: usize) -> Result<Vec<CascadeResult>> {
        self.run_batch(features, n, 1.0)
    }

    fn classify_batch_geared(
        &self,
        features: &[f32],
        n: usize,
        gear: &crate::planner::gear::GearConfig,
    ) -> Result<Vec<CascadeResult>> {
        self.run_batch(features, n, gear.work_factor)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_results_and_dim_check() {
        let c = SyntheticClassifier::new(2, 3, Duration::ZERO, Duration::ZERO);
        let a = c.classify_batch(&[0.5, 0.0, 1.5, 0.0], 2).unwrap();
        let b = c.classify_batch(&[0.5, 0.0, 1.5, 0.0], 2).unwrap();
        assert_eq!(a.len(), 2);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prediction, y.prediction);
            assert_eq!(x.exit_level, y.exit_level);
            assert!(x.exit_level >= 1 && x.exit_level <= 3);
        }
        assert!(c.classify_batch(&[0.0; 3], 2).is_err());
    }

    #[test]
    fn geared_work_factor_scales_service_time() {
        use crate::planner::gear::GearConfig;
        let c = SyntheticClassifier::new(1, 2, Duration::ZERO, Duration::from_millis(4));
        let cheap = GearConfig {
            gear_id: 1,
            thetas: vec![0.5],
            work_factor: 0.25,
            max_batch: 8,
        };
        let t0 = std::time::Instant::now();
        let r = c.classify_batch_geared(&[0.5; 4], 4, &cheap).unwrap();
        let cheap_dt = t0.elapsed();
        assert_eq!(r.len(), 4);
        // 4 rows * 4ms * 0.25 = 4ms; the ungeared path sleeps 16ms
        assert!(cheap_dt >= Duration::from_millis(3), "slept only {cheap_dt:?}");
        let t0 = std::time::Instant::now();
        c.classify_batch(&[0.5; 4], 4).unwrap();
        let full_dt = t0.elapsed();
        assert!(full_dt >= Duration::from_millis(15), "slept only {full_dt:?}");
        // results are identical either way: gears change cost, not routing
        let a = c.classify_batch(&[0.5; 2], 2).unwrap();
        let b = c.classify_batch_geared(&[0.5; 2], 2, &cheap).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prediction, y.prediction);
            assert_eq!(x.exit_level, y.exit_level);
        }
    }

    #[test]
    fn staged_results_match_monolithic_exactly() {
        use crate::coordinator::cascade::classify_batch_staged;
        let inner = SyntheticClassifier::new(2, 3, Duration::ZERO, Duration::ZERO);
        let staged = StagedSynthetic::new(inner.clone(), vec![0.1, 0.3, 0.6]);
        let n = 25;
        let feats: Vec<f32> = (0..n * 2).map(|i| i as f32 * 0.37 - 3.0).collect();
        let mono = inner.classify_batch(&feats, n).unwrap();
        let st = classify_batch_staged(&staged, &feats, n, None).unwrap();
        assert_eq!(mono.len(), st.len());
        for (a, b) in mono.iter().zip(&st) {
            assert_eq!(a.prediction, b.prediction);
            assert_eq!(a.exit_level, b.exit_level);
            assert_eq!(a.scores, b.scores);
        }
    }

    #[test]
    fn stage_weights_shape_cost_not_routing() {
        let inner =
            SyntheticClassifier::new(1, 2, Duration::ZERO, Duration::from_millis(4));
        let staged = StagedSynthetic::new(inner.clone(), vec![0.25, 0.75]);
        // tier 0 at weight 0.25: 4 rows x 4ms x 0.25 = 4ms
        let t0 = std::time::Instant::now();
        let r = staged.classify_stage(0, &[0.5; 4], 4, None).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(3));
        assert_eq!(r.len(), 4);
        // the final tier always exits
        for s in staged.classify_stage(1, &[0.5; 4], 4, None).unwrap() {
            assert!(s.decision.is_some());
        }
        // capacity helper agrees with the weight split
        let cap0 = staged.stage_capacity_rps(0, 8);
        let cap1 = staged.stage_capacity_rps(1, 8);
        assert!((cap0 / cap1 - 3.0).abs() < 1e-9, "{cap0} vs {cap1}");
        // uniform weights sum to 1
        let u = StagedSynthetic::uniform(inner);
        assert!((u.weights().iter().sum::<f64>() - 1.0).abs() < 1e-12);
        // out-of-range stage errors
        assert!(u.classify_stage(7, &[0.5], 1, None).is_err());
    }

    #[test]
    fn theta_override_monotonically_widens_early_exits() {
        let inner = SyntheticClassifier::new(1, 3, Duration::ZERO, Duration::ZERO);
        let staged = StagedSynthetic::new(inner, vec![0.2, 0.3, 0.5]);
        let n = 400;
        let feats: Vec<f32> = (0..n).map(|i| i as f32 * 0.61 - 7.0).collect();
        let exits_at = |theta: Option<f32>| {
            staged
                .classify_stage(0, &feats, n, theta)
                .unwrap()
                .iter()
                .filter(|r| r.decision.is_some())
                .count()
        };
        let default = exits_at(None);
        // default-routed exits all carry agreement 0.9, would-defer rows
        // spread below it: lowering theta pulls more rows into tier 1
        let lax = exits_at(Some(0.45));
        let laxer = exits_at(Some(0.1));
        assert!(lax > default, "theta 0.45 exits {lax} <= default {default}");
        assert!(laxer > lax, "theta 0.1 exits {laxer} <= {lax}");
        // a theta override never flips a prediction, only the exit split
        let want = staged.classify_stage(0, &feats, n, None).unwrap();
        let got = staged.classify_stage(0, &feats, n, Some(0.45)).unwrap();
        for (w, g) in want.iter().zip(&got) {
            if let (Some(a), Some(b)) = (w.decision, g.decision) {
                assert_eq!(a, b);
            }
        }
        // the final tier exits everything regardless of theta
        let finals = staged.classify_stage(2, &feats, n, Some(5.0)).unwrap();
        assert!(finals.iter().all(|r| r.decision.is_some()));
    }

    #[test]
    fn drift_zero_is_byte_identical_to_default() {
        let inner = SyntheticClassifier::new(1, 3, Duration::ZERO, Duration::ZERO);
        let plain = StagedSynthetic::new(inner.clone(), vec![0.2, 0.3, 0.5]);
        let zero = plain.clone().with_drift(0.0);
        assert_eq!(zero.drift_frac(), 0.0);
        let n = 200;
        let feats: Vec<f32> = (0..n).map(|i| i as f32 * 0.61 - 7.0).collect();
        for level0 in 0..3 {
            for theta in [None, Some(0.45_f32)] {
                let a = plain.classify_stage(level0, &feats, n, theta).unwrap();
                let b = zero.classify_stage(level0, &feats, n, theta).unwrap();
                for (x, y) in a.iter().zip(&b) {
                    assert_eq!(x.decision, y.decision);
                    assert_eq!(x.score, y.score);
                }
            }
        }
    }

    #[test]
    fn drift_flips_only_at_the_routed_exit_tier_with_separable_scores() {
        let inner = SyntheticClassifier::new(1, 3, Duration::ZERO, Duration::ZERO);
        let faithful = StagedSynthetic::new(inner, vec![0.2, 0.3, 0.5]);
        let drifting = faithful.clone().with_drift(0.4);
        let n = 400;
        let feats: Vec<f32> = (0..n).map(|i| i as f32 * 0.61 - 7.0).collect();
        let mut flipped = 0;
        for level0 in 0..2 {
            let a = faithful.classify_stage(level0, &feats, n, None).unwrap();
            let b = drifting.classify_stage(level0, &feats, n, None).unwrap();
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                let h = (feats[i].abs() * 997.0) as usize;
                let exit_level = 1 + h % 3;
                // drift never changes WHO exits under the default policy
                assert_eq!(x.decision.is_some(), y.decision.is_some());
                if exit_level != level0 + 1 {
                    assert_eq!(
                        x.decision, y.decision,
                        "rows not at their exit tier stay canonical"
                    );
                } else if x.decision != y.decision {
                    flipped += 1;
                    assert_eq!(y.decision, x.decision.map(|p| p ^ 1));
                    // every drifted exit reports the same constant
                    // score -- one tie-group for estimate_theta --
                    // strictly below the faithful 0.9 band
                    assert_eq!(y.score, 0.9 * 0.4, "score {}", y.score);
                }
            }
        }
        assert!(flipped > 0, "drift 0.4 flipped nothing");
        // the final tier always answers canonically
        let a = faithful.classify_stage(2, &feats, n, None).unwrap();
        let b = drifting.classify_stage(2, &feats, n, None).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.decision, y.decision);
        }
        // theta at exactly the drifted constant blocks every drifted
        // exit (acceptance is strict score > theta): whatever still
        // exits at tier 0 answers canonically
        let blocked = drifting
            .classify_stage(0, &feats, n, Some(0.9 * 0.4))
            .unwrap();
        let mut survived = 0;
        for (i, g) in blocked.iter().enumerate() {
            if let Some(p) = g.decision {
                survived += 1;
                let canonical = ((feats[i].abs() * 997.0) as usize % 2) as u32;
                assert_eq!(p, canonical, "surviving exits are faithful");
            }
        }
        assert!(survived > 0, "theta 0.36 must not defer everything");
    }

    #[test]
    fn service_time_scales_with_batch() {
        let c = SyntheticClassifier::new(
            1,
            1,
            Duration::from_millis(1),
            Duration::from_millis(2),
        );
        let t0 = std::time::Instant::now();
        c.classify_batch(&[0.0; 4], 4).unwrap();
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_millis(9), "slept only {dt:?}");
        // capacity: 4 rows / 9ms
        let cap = c.capacity_rps(4);
        assert!((cap - 4.0 / 0.009).abs() < 1.0, "cap {cap}");
    }
}
