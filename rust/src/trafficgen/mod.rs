//! Open-loop load generation against the serving stack.
//!
//! The generator fires requests at their *scheduled* arrival times
//! regardless of completions (open-loop), which is the regime where
//! queueing delay and load shedding actually show up -- a closed-loop
//! client self-throttles and hides the saturation knee.  Per-request
//! latency is measured from the scheduled arrival to the verdict, so
//! time spent waiting for serving capacity counts against the target.
//!
//! Pieces:
//! * [`Trace`] (`trace.rs`) -- the replayable schedule + feature rows,
//!   serialised via the ABDS format in `data::format`;
//! * arrival processes -- `data::workload::Arrival` (constant, Poisson,
//!   bursty, on-off) feeding [`Trace::synth`];
//! * [`LoadTarget`] -- what is being load-tested: an in-process
//!   [`ReplicaPool`] or a TCP server ([`TcpTarget`]);
//! * [`SyntheticClassifier`] (`synthetic.rs`) -- an artifact-free
//!   backend so saturation experiments run anywhere;
//! * [`LoadGen::run`] -- the clock + worker pool, recording into the
//!   log-bucketed histograms of a `Metrics` registry and returning a
//!   [`LoadReport`] (goodput, shed count, p50/p99/p999).
//!
//! A worker blocks on one in-flight call, so `workers` bounds the
//! concurrency the generator itself can sustain; size it above the
//! pool's total admission capacity (`replicas * max_queue`) or the
//! generator, not the server, becomes the bottleneck.

pub mod synthetic;
pub mod trace;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::replica::{PoolError, ReplicaPool};
use crate::coordinator::router::TieredFleet;
use crate::metrics::{Histogram, Metrics};
use crate::server::{Client, InferReply};
use crate::types::{Class, Request, Verdict};
use crate::util::json::{Json, JsonObj};

pub use synthetic::{StagedSynthetic, SyntheticClassifier};
pub use trace::Trace;

/// Outcome of one fired request.
pub enum CallOutcome {
    /// Answered with a verdict.
    Done(Verdict),
    /// Explicitly shed by admission control (the `Overloaded` verdict).
    Shed,
}

/// A system under load test.  `session()` is called once per worker so
/// targets can hold per-worker state (e.g. one TCP connection each).
pub trait LoadTarget: Send + Sync {
    fn session(&self) -> Result<Box<dyn LoadSession>, String>;
}

/// One worker's handle onto the target; `call` blocks until the request
/// is answered, shed, or failed.
pub trait LoadSession: Send {
    fn call(&mut self, request: Request) -> Result<CallOutcome, String>;
}

impl LoadTarget for Arc<ReplicaPool> {
    fn session(&self) -> Result<Box<dyn LoadSession>, String> {
        Ok(Box::new(PoolSession(Arc::clone(self))))
    }
}

struct PoolSession(Arc<ReplicaPool>);

impl LoadSession for PoolSession {
    fn call(&mut self, request: Request) -> Result<CallOutcome, String> {
        match self.0.infer(request) {
            Ok(v) => Ok(CallOutcome::Done(v)),
            Err(PoolError::Overloaded { .. }) => Ok(CallOutcome::Shed),
            Err(e) => Err(e.to_string()),
        }
    }
}

impl LoadTarget for Arc<TieredFleet> {
    fn session(&self) -> Result<Box<dyn LoadSession>, String> {
        Ok(Box::new(FleetSession(Arc::clone(self))))
    }
}

struct FleetSession(Arc<TieredFleet>);

impl LoadSession for FleetSession {
    fn call(&mut self, request: Request) -> Result<CallOutcome, String> {
        match self.0.infer(request) {
            Ok(v) => Ok(CallOutcome::Done(v)),
            Err(PoolError::Overloaded { .. }) => Ok(CallOutcome::Shed),
            Err(e) => Err(e.to_string()),
        }
    }
}

/// Load-test a line-JSON TCP server (see `server`); each worker opens
/// its own connection.
pub struct TcpTarget {
    pub port: u16,
}

impl LoadTarget for TcpTarget {
    fn session(&self) -> Result<Box<dyn LoadSession>, String> {
        let client = Client::connect(self.port).map_err(|e| format!("connect: {e:#}"))?;
        Ok(Box::new(TcpSession(client)))
    }
}

struct TcpSession(Client);

impl LoadSession for TcpSession {
    fn call(&mut self, request: Request) -> Result<CallOutcome, String> {
        // the wire protocol lives in server::Client; this is just the
        // outcome mapping (the class tag rides the infer line)
        match self.0.infer_reply_class(
            request.id,
            &request.features,
            Some(request.class),
        ) {
            Ok(InferReply::Verdict(v)) => Ok(CallOutcome::Done(v)),
            Ok(InferReply::Overloaded { .. }) => Ok(CallOutcome::Shed),
            Err(e) => Err(format!("{e:#}")),
        }
    }
}

/// Open-loop generator configuration.
#[derive(Debug, Clone, Copy)]
pub struct LoadGen {
    /// Concurrent in-flight request slots (worker threads).
    pub workers: usize,
    /// SLO-class mix (fractions by [`Class::index`], e.g.
    /// `[0.7, 0.2, 0.1]` = 70% premium / 20% standard / 10% batch),
    /// realized deterministically per request id by
    /// [`class_for_mix`]; `None` sends everything untagged (standard).
    pub class_mix: Option<[f64; Class::COUNT]>,
}

impl Default for LoadGen {
    fn default() -> Self {
        LoadGen { workers: 64, class_mix: None }
    }
}

/// Deterministic class assignment for request `i` under `mix`: the id
/// is mapped through a 100-slot wheel permuted by a stride coprime to
/// 100, so every 100 consecutive ids realize the (percent-resolution)
/// mix exactly while the classes stay interleaved rather than arriving
/// in class-sorted bursts.  Replaying the same trace therefore tags the
/// same requests identically -- class assignment is part of the
/// schedule, not of the run.
pub fn class_for_mix(mix: &[f64; Class::COUNT], i: u64) -> Class {
    let total: f64 = mix.iter().map(|w| w.max(0.0)).sum::<f64>().max(1e-12);
    let slot = (i.wrapping_mul(37) % 100) as f64 / 100.0;
    let mut acc = 0.0;
    for c in Class::ALL {
        acc += mix[c.index()].max(0.0) / total;
        if slot < acc {
            return c;
        }
    }
    Class::Batch // rounding tail (acc summed to just under 1.0)
}

/// Aggregate result of one load-generation run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub n: usize,
    pub completed: u64,
    pub shed: u64,
    pub errors: u64,
    pub elapsed_s: f64,
    pub offered_rps: f64,
    pub goodput_rps: f64,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub p999_s: f64,
}

impl LoadReport {
    /// Table row cells (pairs with [`LoadReport::header`]).
    pub fn header() -> &'static [&'static str] {
        &["offered rps", "goodput rps", "done", "shed", "err", "p50", "p99", "p999"]
    }

    pub fn row_cells(&self) -> Vec<String> {
        use crate::benchkit::fmt_time;
        vec![
            format!("{:.0}", self.offered_rps),
            format!("{:.0}", self.goodput_rps),
            self.completed.to_string(),
            self.shed.to_string(),
            self.errors.to_string(),
            fmt_time(self.p50_s),
            fmt_time(self.p99_s),
            fmt_time(self.p999_s),
        ]
    }

    /// Machine-readable form for `BENCH_<name>.json` emission.
    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("n", Json::num(self.n as f64));
        o.insert("completed", Json::num(self.completed as f64));
        o.insert("shed", Json::num(self.shed as f64));
        o.insert("errors", Json::num(self.errors as f64));
        o.insert("elapsed_s", Json::num(self.elapsed_s));
        o.insert("offered_rps", Json::num(self.offered_rps));
        o.insert("goodput_rps", Json::num(self.goodput_rps));
        o.insert("mean_s", Json::num(self.mean_s));
        o.insert("p50_s", Json::num(self.p50_s));
        o.insert("p99_s", Json::num(self.p99_s));
        o.insert("p999_s", Json::num(self.p999_s));
        Json::Obj(o)
    }
}

impl LoadGen {
    /// Replay `trace` against `target`, open loop.  Blocks until every
    /// request is answered, shed, or failed.  Latencies land in the
    /// registry's `loadgen_e2e_s` histogram (plus `loadgen_done` /
    /// `loadgen_shed` / `loadgen_err` counters) and in the returned
    /// report.
    pub fn run(
        &self,
        target: &dyn LoadTarget,
        trace: Arc<Trace>,
        metrics: &Arc<Metrics>,
    ) -> Result<LoadReport, String> {
        let n = trace.len();
        if n == 0 {
            return Err("empty trace".to_string());
        }
        let workers = self.workers.max(1);
        let completed = Arc::new(AtomicU64::new(0));
        let shed = Arc::new(AtomicU64::new(0));
        let errors = Arc::new(AtomicU64::new(0));
        // fresh histogram for this run's report; the shared registry
        // histogram accumulates across runs.  Counters are resolved once
        // here so workers never touch the registry lock per request.
        let local_hist = Arc::new(Histogram::default());
        let reg_hist = metrics.histogram("loadgen_e2e_s");
        let done_counter = metrics.counter("loadgen_done");
        let shed_counter = metrics.counter("loadgen_shed");
        let err_counter = metrics.counter("loadgen_err");

        let (tx, rx) = channel::<(usize, Instant)>();
        let rx = Arc::new(Mutex::new(rx));
        let class_mix = self.class_mix;
        let mut joins = Vec::with_capacity(workers);
        for w in 0..workers {
            let mut session = target
                .session()
                .map_err(|e| format!("worker {w} session: {e}"))?;
            let rx = Arc::clone(&rx);
            let trace = Arc::clone(&trace);
            let completed = Arc::clone(&completed);
            let shed = Arc::clone(&shed);
            let errors = Arc::clone(&errors);
            let local_hist = Arc::clone(&local_hist);
            let reg_hist = Arc::clone(&reg_hist);
            let done_counter = Arc::clone(&done_counter);
            let shed_counter = Arc::clone(&shed_counter);
            let err_counter = Arc::clone(&err_counter);
            joins.push(
                std::thread::Builder::new()
                    .name(format!("loadgen-{w}"))
                    .spawn(move || loop {
                        // standard mutex-guarded mpsc work queue; exactly
                        // one idle worker owns the receiver at a time
                        let msg = { rx.lock().unwrap().recv() };
                        let (i, scheduled) = match msg {
                            Ok(m) => m,
                            Err(_) => break, // clock hung up, queue drained
                        };
                        let request = Request {
                            id: i as u64,
                            features: trace.row(i).to_vec(),
                            arrival_s: trace.arrivals[i],
                            class: class_mix
                                .map(|m| class_for_mix(&m, i as u64))
                                .unwrap_or_default(),
                        };
                        match session.call(request) {
                            Ok(CallOutcome::Done(_)) => {
                                let e2e = Instant::now()
                                    .saturating_duration_since(scheduled)
                                    .as_secs_f64();
                                local_hist.record(e2e);
                                reg_hist.record(e2e);
                                completed.fetch_add(1, Ordering::Relaxed);
                                done_counter.inc();
                            }
                            Ok(CallOutcome::Shed) => {
                                shed.fetch_add(1, Ordering::Relaxed);
                                shed_counter.inc();
                            }
                            Err(_) => {
                                errors.fetch_add(1, Ordering::Relaxed);
                                err_counter.inc();
                            }
                        }
                    })
                    .map_err(|e| format!("spawn worker: {e}"))?,
            );
        }

        // the clock: fire each request at its scheduled arrival time
        let start = Instant::now();
        for i in 0..n {
            let due = start + Duration::from_secs_f64(trace.arrivals[i].max(0.0));
            let now = Instant::now();
            if due > now {
                std::thread::sleep(due - now);
            }
            if tx.send((i, due)).is_err() {
                break; // every worker died; counted as errors below
            }
        }
        drop(tx); // workers drain the queue then exit
        for j in joins {
            let _ = j.join();
        }
        let elapsed_s = start.elapsed().as_secs_f64();

        let completed = completed.load(Ordering::Relaxed);
        let shed = shed.load(Ordering::Relaxed);
        let errors = errors.load(Ordering::Relaxed);
        // anything neither answered, shed, nor failed was never fired
        // (all workers died mid-run) -- count it as an error
        let unaccounted = (n as u64).saturating_sub(completed + shed + errors);
        Ok(LoadReport {
            n,
            completed,
            shed,
            errors: errors + unaccounted,
            elapsed_s,
            offered_rps: trace.offered_rps(),
            goodput_rps: completed as f64 / elapsed_s.max(1e-9),
            mean_s: local_hist.mean(),
            p50_s: local_hist.p50(),
            p99_s: local_hist.p99(),
            p999_s: local_hist.p999(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatcherConfig;
    use crate::coordinator::replica::{PoolConfig, ReplicaPool};
    use crate::data::workload::Arrival;

    #[test]
    fn loadgen_completes_under_light_load() {
        let pool = Arc::new(ReplicaPool::spawn(
            Arc::new(SyntheticClassifier::new(
                3,
                2,
                Duration::ZERO,
                Duration::from_micros(200),
            )),
            PoolConfig {
                replicas: 2,
                max_queue: 32,
                batcher: BatcherConfig {
                    max_batch: 8,
                    max_wait: Duration::from_micros(500),
                },
                ..PoolConfig::default()
            },
            Metrics::new(),
        ));
        let trace = Arc::new(Trace::synth(Arrival::Uniform { rate: 500.0 }, 100, 3, 4));
        let metrics = Metrics::new();
        let report = LoadGen { workers: 16, class_mix: None }
            .run(&pool, Arc::clone(&trace), &metrics)
            .unwrap();
        assert_eq!(report.n, 100);
        assert_eq!(report.completed, 100, "report {report:?}");
        assert_eq!(report.shed, 0);
        assert_eq!(report.errors, 0);
        assert!(report.goodput_rps > 0.0);
        assert!(report.p50_s >= 0.0);
        assert_eq!(metrics.counter("loadgen_done").get(), 100);
        assert_eq!(metrics.histogram("loadgen_e2e_s").count(), 100);
        assert_eq!(pool.total_outstanding(), 0);
    }

    #[test]
    fn class_mix_is_exact_per_hundred_and_interleaved() {
        let mix = [0.7, 0.2, 0.1];
        let mut counts = [0usize; Class::COUNT];
        for i in 0..200u64 {
            counts[class_for_mix(&mix, i).index()] += 1;
        }
        assert_eq!(counts, [140, 40, 20], "exact per 100-block");
        // deterministic: the same id always lands in the same class
        assert_eq!(class_for_mix(&mix, 42), class_for_mix(&mix, 42));
        // interleaved, not class-sorted bursts: the first 10 ids must
        // already touch more than one class under a 70/20/10 mix
        let first: std::collections::HashSet<usize> =
            (0..10).map(|i| class_for_mix(&mix, i).index()).collect();
        assert!(first.len() > 1, "first 10 ids all one class");
        // a degenerate one-class mix tags everything that class
        for i in 0..50 {
            assert_eq!(class_for_mix(&[0.0, 0.0, 1.0], i), Class::Batch);
        }
    }
}
