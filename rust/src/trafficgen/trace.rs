//! Replayable request traces, stored in the existing ABDS binary format.
//!
//! A trace is "a dataset with timestamps": per-request feature rows plus
//! arrival times.  Rather than inventing a second container we reuse
//! `data::format` -- features go in `x`, labels are zeroed, and the
//! optional `difficulty` field carries the arrival time in seconds (f32,
//! which is plenty for the sub-hour traces the loadgen replays).  Any
//! ABDS reader/tooling therefore works on traces unchanged.

use std::path::Path;

use crate::data::format::{self, Dataset};
use crate::data::workload::Arrival;
use crate::util::rng::Rng;

/// An in-memory request trace: row-major features + sorted arrival times.
#[derive(Debug, Clone)]
pub struct Trace {
    /// Arrival time of request `i`, seconds from run start, ascending.
    pub arrivals: Vec<f64>,
    /// Row-major `n x dim` feature matrix.
    pub features: Vec<f32>,
    pub dim: usize,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.features[i * self.dim..(i + 1) * self.dim]
    }

    /// Duration of the schedule (time of the last arrival).
    pub fn span_s(&self) -> f64 {
        self.arrivals.last().copied().unwrap_or(0.0)
    }

    /// Mean offered rate over the schedule.
    pub fn offered_rps(&self) -> f64 {
        let span = self.span_s();
        if span <= 0.0 {
            0.0
        } else {
            self.len() as f64 / span
        }
    }

    /// Build a synthetic trace: `n` arrivals from `arrival`, features
    /// uniform in [-1, 1) -- deterministic from `seed`.
    pub fn synth(arrival: Arrival, n: usize, dim: usize, seed: u64) -> Trace {
        assert!(dim > 0);
        let mut rng = Rng::new(seed);
        let arrivals = arrival.generate(n, &mut rng);
        let features = (0..n * dim).map(|_| rng.f32() * 2.0 - 1.0).collect();
        Trace { arrivals, features, dim }
    }

    /// Lower a trace into an ABDS dataset (arrival times in `difficulty`).
    pub fn to_dataset(&self) -> Dataset {
        Dataset {
            x: self.features.clone(),
            y: vec![0; self.len()],
            difficulty: Some(self.arrivals.iter().map(|&t| t as f32).collect()),
            n: self.len(),
            dim: self.dim,
            classes: 1,
        }
    }

    /// Reconstruct a trace from an ABDS dataset.  Requires the
    /// `difficulty` field (the arrival times) to be present, non-negative
    /// and sorted.
    pub fn from_dataset(ds: &Dataset) -> Result<Trace, String> {
        let diff = ds
            .difficulty
            .as_ref()
            .ok_or("trace dataset has no difficulty field (arrival times)")?;
        let arrivals: Vec<f64> = diff.iter().map(|&t| t as f64).collect();
        if arrivals.iter().any(|&t| t < 0.0) {
            return Err("trace has negative arrival times".to_string());
        }
        if arrivals.windows(2).any(|w| w[0] > w[1]) {
            return Err("trace arrival times are not sorted".to_string());
        }
        Ok(Trace { arrivals, features: ds.x.clone(), dim: ds.dim })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), String> {
        format::write_file(path, &self.to_dataset()).map_err(|e| e.to_string())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Trace, String> {
        let ds = format::read_file(path).map_err(|e| e.to_string())?;
        Trace::from_dataset(&ds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_shape_and_determinism() {
        let a = Trace::synth(Arrival::Poisson { rate: 50.0 }, 30, 5, 9);
        let b = Trace::synth(Arrival::Poisson { rate: 50.0 }, 30, 5, 9);
        assert_eq!(a.len(), 30);
        assert_eq!(a.features.len(), 150);
        assert_eq!(a.arrivals, b.arrivals);
        assert_eq!(a.features, b.features);
        assert!(a.span_s() > 0.0);
        assert!(a.offered_rps() > 0.0);
        assert_eq!(a.row(2).len(), 5);
    }

    #[test]
    fn abds_roundtrip() {
        let t = Trace::synth(Arrival::Uniform { rate: 100.0 }, 20, 3, 1);
        let ds = t.to_dataset();
        let back = Trace::from_dataset(&ds).unwrap();
        assert_eq!(back.len(), 20);
        assert_eq!(back.dim, 3);
        assert_eq!(back.features, t.features);
        for (a, b) in back.arrivals.iter().zip(&t.arrivals) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn from_dataset_validates() {
        let t = Trace::synth(Arrival::Uniform { rate: 10.0 }, 5, 2, 2);
        let mut ds = t.to_dataset();
        ds.difficulty = None;
        assert!(Trace::from_dataset(&ds).is_err());
        let mut ds = t.to_dataset();
        ds.difficulty.as_mut().unwrap()[0] = 99.0; // unsorted
        assert!(Trace::from_dataset(&ds).is_err());
        let mut ds = t.to_dataset();
        ds.difficulty.as_mut().unwrap()[0] = -1.0;
        assert!(Trace::from_dataset(&ds).is_err());
    }
}
