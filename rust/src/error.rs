pub type Result<T> = anyhow::Result<T>;
