//! abc-serve: Agreement-Based Cascading for Efficient Inference.
//!
//! Reproduction of Kolawole et al. 2024 as a three-layer serving stack:
//! Pallas kernels (L1) and a JAX ensemble model (L2) AOT-compiled to HLO
//! text at build time, executed by this Rust coordinator (L3) via PJRT.
//! See DESIGN.md for the system inventory and experiment index.

pub mod analysis;
pub mod baselines;
pub mod benchkit;
pub mod calib;
pub mod control;
pub mod cost;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod obs;
pub mod planner;
pub mod error;
pub mod types;
pub mod util;
pub mod runtime;
pub mod experiments;
pub mod server;
pub mod sim;
pub mod trafficgen;
pub mod zoo;
