//! GPU rental pricing (paper Table 4, Lambda Cloud, September 2024) and
//! the heterogeneous-placement cost accounting of §5.2.2.

/// A rentable GPU class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Gpu {
    V100,
    A6000,
    A100,
    H100,
}

impl Gpu {
    /// $/hour (Table 4).
    pub fn dollars_per_hour(&self) -> f64 {
        match self {
            Gpu::V100 => 0.50,
            Gpu::A6000 => 0.80,
            Gpu::A100 => 1.29,
            Gpu::H100 => 2.49,
        }
    }

    /// Rated dense f32-equivalent tensor throughput used for the paper's
    /// throughput-vs-price argument (TFLOPs; §5.2.2 quotes 312 for A100
    /// and 125 for V100).
    pub fn rated_tflops(&self) -> f64 {
        match self {
            Gpu::V100 => 125.0,
            Gpu::A6000 => 155.0,
            Gpu::A100 => 312.0,
            Gpu::H100 => 989.0,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Gpu::V100 => "V100",
            Gpu::A6000 => "A6000",
            Gpu::A100 => "A100",
            Gpu::H100 => "H100",
        }
    }

    /// Ladder in ascending sophistication, as placed per tier in §5.2.2.
    pub const LADDER: [Gpu; 4] = [Gpu::V100, Gpu::A6000, Gpu::A100, Gpu::H100];

    /// Parse a class name (case-insensitive), e.g. for `--tier-gpus`.
    pub fn parse(s: &str) -> Option<Gpu> {
        match s.to_ascii_lowercase().as_str() {
            "v100" => Some(Gpu::V100),
            "a6000" => Some(Gpu::A6000),
            "a100" => Some(Gpu::A100),
            "h100" => Some(Gpu::H100),
            _ => None,
        }
    }

    /// Default §5.2.2-style placement for an `n`-tier cascade: cheap
    /// classes on the early tiers, the top model on the most expensive
    /// one.  The first `n - 1` tiers take the cheapest rungs of
    /// [`Gpu::LADDER`] (repeating the last rung when the cascade is
    /// deeper than the ladder); the final tier always gets the top GPU.
    pub fn spread(n: usize) -> Vec<Gpu> {
        assert!(n >= 1, "a cascade has at least one tier");
        let mut out: Vec<Gpu> = (0..n.saturating_sub(1))
            .map(|i| Gpu::LADDER[i.min(Gpu::LADDER.len() - 2)])
            .collect();
        out.push(*Gpu::LADDER.last().expect("ladder is non-empty"));
        out
    }

    /// Price `seconds` of one rented machine of this class.
    pub fn dollars_for(&self, seconds: f64) -> f64 {
        seconds / 3600.0 * self.dollars_per_hour()
    }
}

/// §5.2.2 accounting: tier i lives on its own GPU; the fleet serves a
/// uniform request rate, so each tier's node must be provisioned for the
/// fraction of traffic that REACHES it.  Dollars are attributed as
/// (GPU $/h) x (fraction of the hour the node is actually busy), where
/// busy time scales with reach fraction x tier compute / GPU throughput.
#[derive(Debug, Clone)]
pub struct RentalModel {
    /// (gpu, tier ensemble FLOPs per sample) per level, ascending.
    pub levels: Vec<(Gpu, f64)>,
}

impl RentalModel {
    /// Effective $/hour of the cascade fleet given per-level exit
    /// fractions, normalised so the TOP-tier-only deployment (the "best
    /// single model" on the best GPU) defines the workload's busy-hour.
    ///
    /// Returns (per-level $ contributions, cascade total $, single-model $).
    pub fn dollars(&self, exit_frac: &[f64]) -> (Vec<f64>, f64, f64) {
        assert_eq!(exit_frac.len(), self.levels.len());
        let (top_gpu, top_flops) = *self.levels.last().unwrap();
        // busy-hour normaliser: the single-model deployment runs 100% of
        // traffic on the top GPU for one full hour.
        let single_rate = top_flops / top_gpu.rated_tflops();
        let mut reach = 1.0;
        let mut per_level = Vec::with_capacity(self.levels.len());
        for ((gpu, flops), &exit) in self.levels.iter().zip(exit_frac) {
            // node busy fraction relative to the single-model hour
            let busy = reach * (flops / gpu.rated_tflops()) / single_rate;
            per_level.push(gpu.dollars_per_hour() * busy.min(1.0));
            reach -= exit;
        }
        let total = per_level.iter().sum();
        (per_level, total, top_gpu.dollars_per_hour())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_prices() {
        assert_eq!(Gpu::V100.dollars_per_hour(), 0.50);
        assert_eq!(Gpu::A6000.dollars_per_hour(), 0.80);
        assert_eq!(Gpu::A100.dollars_per_hour(), 1.29);
        assert_eq!(Gpu::H100.dollars_per_hour(), 2.49);
        // the paper's 25x H100-vs-V100 claim is about cost ladders; check
        // the price disparity exceeds the throughput disparity per $:
        let price_ratio = Gpu::H100.dollars_per_hour() / Gpu::V100.dollars_per_hour();
        let thpt_ratio = Gpu::H100.rated_tflops() / Gpu::V100.rated_tflops();
        assert!(price_ratio < thpt_ratio, "throughput/$ favors placement games");
    }

    #[test]
    fn cascade_cheaper_than_single_when_exits_early() {
        // 4 tiers with 50x FLOPs ladder, 73% exiting at tier 1 (paper's
        // CIFAR-10 row): cascade must be ~3x cheaper than the single H100.
        let m = RentalModel {
            levels: vec![
                (Gpu::V100, 1.6e7),
                (Gpu::A6000, 7.0e7),
                (Gpu::A100, 3.5e8),
                (Gpu::H100, 7.4e8),
            ],
        };
        let (per, total, single) = m.dollars(&[0.73, 0.09, 0.08, 0.10]);
        assert_eq!(per.len(), 4);
        assert!(total < single, "cascade {total} vs single {single}");
        assert!(single / total > 2.0, "expected ~3x, got {}", single / total);
    }

    #[test]
    fn all_defer_costs_more_than_single() {
        // pathological: everything reaches the top anyway
        let m = RentalModel {
            levels: vec![(Gpu::V100, 5e8), (Gpu::H100, 7.4e8)],
        };
        let (_, total, single) = m.dollars(&[0.0, 1.0]);
        assert!(total > single);
    }

    #[test]
    fn mixed_classes_price_per_level_busy_fractions() {
        // three levels on three different classes; check every per-level
        // contribution against the §5.2.2 formula by hand:
        //   busy_i = reach_i * (flops_i / tflops_i) / (flops_top / tflops_top)
        //   per_i  = $_i/h * min(1, busy_i)
        let levels = vec![
            (Gpu::V100, 2.0e7),
            (Gpu::A100, 1.5e8),
            (Gpu::H100, 9.0e8),
        ];
        let exits = [0.6, 0.25, 0.15];
        let m = RentalModel { levels: levels.clone() };
        let (per, total, single) = m.dollars(&exits);
        assert_eq!(per.len(), 3);
        let single_rate = 9.0e8 / Gpu::H100.rated_tflops();
        let mut reach = 1.0;
        for (i, (gpu, flops)) in levels.iter().enumerate() {
            let busy = reach * (flops / gpu.rated_tflops()) / single_rate;
            let expect = gpu.dollars_per_hour() * busy.min(1.0);
            assert!(
                (per[i] - expect).abs() < 1e-12,
                "level {i}: {} vs {expect}",
                per[i]
            );
            reach -= exits[i];
        }
        assert!((total - per.iter().sum::<f64>()).abs() < 1e-12);
        assert_eq!(single, Gpu::H100.dollars_per_hour());
        // the mixed fleet beats the single top deployment here: most
        // traffic exits on the cheap classes
        assert!(total < single);
    }

    #[test]
    fn busy_fraction_clamps_at_a_full_hour_per_node() {
        // a cheap level with pathological compute cannot bill more than
        // its own full-hour price, no matter how "busy" the model says
        // it is relative to the top node
        let m = RentalModel {
            levels: vec![(Gpu::V100, 1.0e12), (Gpu::H100, 1.0e8)],
        };
        let (per, _, _) = m.dollars(&[0.5, 0.5]);
        assert_eq!(per[0], Gpu::V100.dollars_per_hour(), "clamp at 1.0 busy");
    }

    #[test]
    fn parse_spread_and_seconds_pricing() {
        assert_eq!(Gpu::parse("v100"), Some(Gpu::V100));
        assert_eq!(Gpu::parse("H100"), Some(Gpu::H100));
        assert_eq!(Gpu::parse("a6000"), Some(Gpu::A6000));
        assert_eq!(Gpu::parse("tpu"), None);
        // spread: cheap classes first, top model on the top GPU
        assert_eq!(Gpu::spread(1), vec![Gpu::H100]);
        assert_eq!(Gpu::spread(2), vec![Gpu::V100, Gpu::H100]);
        assert_eq!(
            Gpu::spread(4),
            vec![Gpu::V100, Gpu::A6000, Gpu::A100, Gpu::H100]
        );
        // deeper than the ladder: repeat the second-best interior rung
        assert_eq!(
            Gpu::spread(6),
            vec![Gpu::V100, Gpu::A6000, Gpu::A100, Gpu::A100, Gpu::A100, Gpu::H100]
        );
        // seconds pricing matches the hourly rate
        assert!((Gpu::V100.dollars_for(3600.0) - 0.50).abs() < 1e-12);
        assert!((Gpu::H100.dollars_for(1800.0) - 1.245).abs() < 1e-12);
    }
}
