//! Cost models (paper §4.1, §4.4, §5.2).
//!
//! * `model`  -- the analytic ensemble/cascade cost of Eq. 1 and
//!              Proposition 4.1 (drives Fig. 3 and the fig8 sweeps).
//! * `comm`   -- edge-to-cloud communication delay model (§5.2.1).
//! * `rental` -- GPU $/hour pricing, Table 4 (§5.2.2).
//! * `api`    -- $/Mtok API pricing, Table 1 (§5.2.3).

pub mod api;
pub mod comm;
pub mod model;
pub mod rental;
