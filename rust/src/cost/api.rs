//! Black-box API pricing (paper Table 1, together.ai, September 2024).

/// One hosted model endpoint with its price.
#[derive(Debug, Clone)]
pub struct ApiModel {
    pub name: &'static str,
    pub tier: usize,
    /// $ per million tokens (input+output blended, as the paper quotes).
    pub usd_per_mtok: f64,
}

/// Table 1: the cascade tiers, their models, and $/Mtok.
pub fn table1_models() -> Vec<ApiModel> {
    vec![
        ApiModel { name: "LlaMA 3.1 8B-Instruct Turbo", tier: 1, usd_per_mtok: 0.18 },
        ApiModel { name: "Gemma 2 9B IT", tier: 1, usd_per_mtok: 0.30 },
        ApiModel { name: "LlaMA 3 8B Instruct Lite", tier: 1, usd_per_mtok: 0.10 },
        ApiModel { name: "LlaMA 3.1 70B Instruct Turbo", tier: 2, usd_per_mtok: 0.88 },
        ApiModel { name: "Gemma 2 27B Instruct", tier: 2, usd_per_mtok: 0.80 },
        ApiModel { name: "Qwen 2 72B-Instruct", tier: 2, usd_per_mtok: 0.90 },
        ApiModel { name: "LlaMA 3.1 405B Instruct Turbo", tier: 3, usd_per_mtok: 5.00 },
    ]
}

/// Cost (in dollars) of a call consuming `tokens` tokens.
pub fn call_cost(model: &ApiModel, tokens: u64) -> f64 {
    model.usd_per_mtok * tokens as f64 / 1e6
}

/// Best (cheapest..?) -- the paper picks the best-*performing* singular
/// model per tier for the baselines; we expose tier groupings for that.
pub fn tier_models(tier: usize) -> Vec<ApiModel> {
    table1_models().into_iter().filter(|m| m.tier == tier).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_shape() {
        let models = table1_models();
        assert_eq!(models.len(), 7);
        assert_eq!(tier_models(1).len(), 3);
        assert_eq!(tier_models(2).len(), 3);
        assert_eq!(tier_models(3).len(), 1);
    }

    #[test]
    fn cost_ratio_matches_paper_25x() {
        // 405B at $5.00 vs the 8B range at $0.20: the paper's 25x claim
        let small = 0.20;
        let big = tier_models(3)[0].usd_per_mtok;
        assert!((big / small - 25.0).abs() < 1e-9);
    }

    #[test]
    fn call_cost_scales_with_tokens() {
        let m = &table1_models()[0];
        assert!((call_cost(m, 1_000_000) - 0.18).abs() < 1e-12);
        assert!((call_cost(m, 500) - 0.18 * 500.0 / 1e6).abs() < 1e-15);
    }
}
