//! Edge-to-cloud communication cost model (paper §5.2.1).
//!
//! The paper adopts the delay model of Zhu et al. 2021 / Lai et al. 2022:
//! a fixed per-transition delay applied at cascade exit points, swept over
//! delay classes [1 us, 10 ms, 100 ms, 1000 ms].  Local (on-device)
//! inference pays only local IPC (~1 us); any deferral past the edge tier
//! pays the uplink delay (plus the cloud tier's compute, which the paper
//! treats as dominated by communication).

/// Delay classes from the paper (seconds).
pub const DELAY_CLASSES: [(f64, &str); 4] = [
    (1e-6, "1us"),
    (10e-3, "10ms"),
    (100e-3, "100ms"),
    (1000e-3, "1000ms"),
];

/// Where a cascade level physically lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    Edge,
    Cloud,
}

/// Communication cost model for a placed cascade.
#[derive(Debug, Clone)]
pub struct CommModel {
    /// Delay paid when a sample crosses edge -> cloud (s).
    pub uplink_s: f64,
    /// Local IPC delay on-device (s).
    pub local_s: f64,
    /// Per-level placement, ascending tiers.
    pub placement: Vec<Placement>,
}

impl CommModel {
    pub fn new(uplink_s: f64, placement: Vec<Placement>) -> CommModel {
        CommModel { uplink_s, local_s: 1e-6, placement }
    }

    /// Communication time for a sample that exits at `exit_level`
    /// (1-based): one local hop per edge level visited, plus one uplink
    /// the first time it crosses to a cloud level.
    pub fn comm_time(&self, exit_level: usize) -> f64 {
        assert!(exit_level >= 1 && exit_level <= self.placement.len());
        let mut t = 0.0;
        let mut crossed = false;
        for (i, p) in self.placement[..exit_level].iter().enumerate() {
            match p {
                Placement::Edge => t += self.local_s,
                Placement::Cloud => {
                    if !crossed {
                        // cross once; response path is included in the
                        // delay class figure (round-trip characterised).
                        t += self.uplink_s;
                        crossed = true;
                    } else {
                        t += self.local_s; // cloud-internal IPC
                    }
                    let _ = i;
                }
            }
        }
        t
    }

    /// Mean communication time given per-level exit fractions.
    pub fn mean_comm_time(&self, exit_frac: &[f64]) -> f64 {
        assert_eq!(exit_frac.len(), self.placement.len());
        exit_frac
            .iter()
            .enumerate()
            .map(|(i, &f)| f * self.comm_time(i + 1))
            .sum()
    }

    /// The all-cloud baseline: every request pays the uplink.
    pub fn cloud_only_time(&self) -> f64 {
        self.uplink_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use Placement::*;

    #[test]
    fn edge_exit_is_local() {
        let m = CommModel::new(0.1, vec![Edge, Cloud]);
        assert!(m.comm_time(1) <= 2e-6);
        assert!((m.comm_time(2) - (1e-6 + 0.1)).abs() < 1e-9);
    }

    #[test]
    fn uplink_paid_once() {
        let m = CommModel::new(0.1, vec![Edge, Cloud, Cloud]);
        let t2 = m.comm_time(2);
        let t3 = m.comm_time(3);
        assert!((t3 - t2 - 1e-6).abs() < 1e-9, "second cloud hop is IPC only");
    }

    #[test]
    fn mean_time_reduction_matches_paper_shape() {
        // 80% handled at the edge -> ~5x reduction vs cloud-only
        let m = CommModel::new(0.1, vec![Edge, Cloud]);
        let mean = m.mean_comm_time(&[0.8, 0.2]);
        let reduction = m.cloud_only_time() / mean;
        assert!(reduction > 4.0 && reduction < 6.0, "reduction {reduction}");
        // 93% at the edge (paper's SST-2 exit fraction) -> ~14x
        let mean93 = m.mean_comm_time(&[0.93, 0.07]);
        let red93 = m.cloud_only_time() / mean93;
        assert!(red93 > 12.0 && red93 < 15.0, "reduction {red93}");
    }

    #[test]
    fn delay_classes_span_paper_range() {
        assert_eq!(DELAY_CLASSES.len(), 4);
        assert!(DELAY_CLASSES[0].0 < DELAY_CLASSES[3].0);
    }
}
