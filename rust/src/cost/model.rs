//! The paper's analytic cost model.
//!
//! Eq. 1: `C(H^k) = c0 * k^(1-rho)`.
//! Prop 4.1.2 (two-level cascade {H1^k, h2}):
//!
//! ```text
//! E[C(M_r)] = ( k^rho * gamma + P(defer) ) * C(h2)
//! ```
//!
//! NOTE on the exponent: the paper's Prop 4.1 statement prints `k^rho`;
//! consistency with Eq. 1 (and with Figure 3's plotted curves, where
//! rho=0 must give the k-times sequential cost) requires `k^(1-rho)`,
//! i.e. E[C] = (k^(1-rho) * gamma + P(defer)) * C(h2).  We implement the
//! Eq.-1-consistent form and regenerate Fig. 3's shape with it.

use crate::types::Parallelism;

/// Expected relative cost of a two-level drop-in cascade vs. always
/// running the large model (cost 1.0 == C(h2)).
pub fn two_level_relative_cost(
    k: usize,
    gamma: f64,
    rho: Parallelism,
    p_defer: f64,
) -> f64 {
    assert!((0.0..=1.0).contains(&p_defer));
    assert!(gamma >= 0.0);
    rho.ensemble_factor(k) * gamma + p_defer
}

/// Fraction of inference cost SAVED by the cascade (Fig. 3's y-axis).
pub fn two_level_savings(k: usize, gamma: f64, rho: Parallelism, p_defer: f64) -> f64 {
    1.0 - two_level_relative_cost(k, gamma, rho, p_defer)
}

/// Multi-level generalisation: expected relative cost of an n-level
/// cascade given per-level (k_i, gamma_i = C(member_i)/C(top member),
/// reach_i = P(sample reaches level i)).  The top level's gamma is 1.
pub fn multi_level_relative_cost(
    levels: &[(usize, f64)], // (k, gamma) per level, ascending cost
    reach: &[f64],           // P(reach level i); reach[0] == 1
    rho: Parallelism,
) -> f64 {
    assert_eq!(levels.len(), reach.len());
    assert!(!levels.is_empty());
    let mut total = 0.0;
    for ((k, gamma), &r) in levels.iter().zip(reach) {
        total += r * rho.ensemble_factor(*k) * gamma;
    }
    total
}

/// Worst-case bound of §4.4: every sample visits everything sequentially.
pub fn worst_case_bound(levels: &[(usize, f64)]) -> f64 {
    levels.iter().map(|(k, g)| *k as f64 * g).sum()
}

/// Per-sample expected cost from measured exit fractions (Table 5's
/// aggregation): `exit_frac[i]` of samples exit at level i having paid
/// levels 0..=i.
pub fn cost_from_exits(
    levels: &[(usize, f64)],
    exit_frac: &[f64],
    rho: Parallelism,
) -> f64 {
    assert_eq!(levels.len(), exit_frac.len());
    // P(reach level i) = 1 - sum of exits below i
    let mut reach = vec![0.0; levels.len()];
    let mut acc = 0.0;
    for i in 0..levels.len() {
        reach[i] = 1.0 - acc;
        acc += exit_frac[i];
    }
    multi_level_relative_cost(levels, &reach, rho)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_prop_4_1_limits() {
        // gamma = 0 (free small model): cost = P(defer)
        let c = two_level_relative_cost(3, 0.0, Parallelism::FULL, 0.25);
        assert!((c - 0.25).abs() < 1e-12);
        // full parallel, gamma=1, defer always: cost = 1 + 1 = 2x
        let c = two_level_relative_cost(5, 1.0, Parallelism::FULL, 1.0);
        assert!((c - 2.0).abs() < 1e-12);
        // sequential, defer always: (k*gamma + 1) -> paper's (k+1) worst case at gamma=1
        let c = two_level_relative_cost(5, 1.0, Parallelism::SEQUENTIAL, 1.0);
        assert!((c - 6.0).abs() < 1e-12);
    }

    #[test]
    fn savings_monotone_in_rho() {
        // more parallelism can only help
        let mut last = -1.0;
        for rho in [0.0, 0.25, 0.5, 0.75, 1.0] {
            let s = two_level_savings(4, 0.1, Parallelism(rho), 0.3);
            assert!(s >= last);
            last = s;
        }
    }

    #[test]
    fn savings_decrease_with_gamma() {
        let mut last = 2.0;
        for gamma in [0.001, 0.01, 0.1, 0.2, 1.0] {
            let s = two_level_savings(3, gamma, Parallelism::SEQUENTIAL, 0.2);
            assert!(s < last);
            last = s;
        }
    }

    #[test]
    fn fig3_crossover_small_gamma_closes_rho_gap() {
        // Paper Fig. 3: for gamma <= 1/50, sequential ~ parallel.
        let p_defer = 0.3;
        let gap_at = |gamma: f64| {
            two_level_savings(3, gamma, Parallelism::FULL, p_defer)
                - two_level_savings(3, gamma, Parallelism::SEQUENTIAL, p_defer)
        };
        assert!(gap_at(1.0 / 5.0) > 0.25, "big gap for similar models");
        assert!(gap_at(1.0 / 50.0) < 0.05, "gap closes at 50x disparity");
    }

    #[test]
    fn multi_level_consistency_with_two_level() {
        // two-level multi == closed form
        let k = 3;
        let gamma = 0.05;
        let p_defer = 0.4;
        let got = multi_level_relative_cost(
            &[(k, gamma), (1, 1.0)],
            &[1.0, p_defer],
            Parallelism::SEQUENTIAL,
        );
        let want =
            two_level_relative_cost(k, gamma, Parallelism::SEQUENTIAL, p_defer);
        assert!((got - want).abs() < 1e-12);
    }

    #[test]
    fn cost_from_exits_reach_computation() {
        let levels = [(2usize, 0.1), (2, 0.3), (1, 1.0)];
        let exits = [0.5, 0.3, 0.2];
        let c = cost_from_exits(&levels, &exits, Parallelism::FULL);
        // reach = [1.0, 0.5, 0.2]; cost = 0.1 + 0.5*0.3 + 0.2*1 = 0.45
        assert!((c - 0.45).abs() < 1e-12);
    }

    #[test]
    fn worst_case_is_k_plus_one_like() {
        let wc = worst_case_bound(&[(3, 1.0), (1, 1.0)]);
        assert!((wc - 4.0).abs() < 1e-12);
    }
}
