//! Table 5: detailed per-tier cost breakdown for every suite -- fraction
//! of samples per tier, GPU dollars, measured latency, FLOPs; ABC
//! aggregate vs the best single model (paper Appendix E.2).

use std::time::Instant;

use anyhow::Result;

use crate::cost::rental::{Gpu, RentalModel};
use crate::experiments::common::{ExpContext, EPSILON};
use crate::types::RuleKind;
use crate::util::table::{fnum, human, Table};

pub fn run(ctx: &ExpContext) -> Result<()> {
    let mut table = Table::new(
        "Table 5: per-tier cost breakdown",
        &[
            "suite",
            "metric",
            "tier1",
            "tier2",
            "tier3",
            "tier4",
            "ABC",
            "best single",
        ],
    );
    for suite in ctx.benchmark_suites() {
        let (rt, _cal, report) = ctx.run_abc(&suite, RuleKind::MeanScore, EPSILON)?;
        let test = ctx.test_set(&suite)?;
        let n_tiers = rt.suite.tiers.len();

        // measured per-tier ensemble latency (batch-128 amortised, s/sample)
        let mut tier_latency = Vec::new();
        let bench_n = 256.min(test.n);
        for tier in &rt.tiers {
            let t0 = Instant::now();
            tier.run(&test.x[..bench_n * test.dim], bench_n)?;
            tier_latency.push(t0.elapsed().as_secs_f64() / bench_n as f64);
        }
        // single-model latency at the top tier
        let t0 = Instant::now();
        rt.singles.last().unwrap().run_single(&test.x[..bench_n * test.dim], bench_n)?;
        let single_latency = t0.elapsed().as_secs_f64() / bench_n as f64;

        // rental dollars
        let gpu_ladder = &Gpu::LADDER[Gpu::LADDER.len() - n_tiers..];
        let rental = RentalModel {
            levels: rt
                .suite
                .tiers
                .iter()
                .zip(gpu_ladder)
                .map(|(t, &g)| (g, t.flops_ensemble() as f64))
                .collect(),
        };
        let (per_tier_usd, abc_usd, single_usd) = rental.dollars(&report.exit_fractions);

        // ABC mean latency: sum over levels of reach * tier latency
        let mut reach = 1.0;
        let mut abc_latency = 0.0;
        let mut abc_flops = 0.0;
        for (i, tier) in rt.suite.tiers.iter().enumerate() {
            abc_latency += reach * tier_latency[i];
            abc_flops += reach * tier.flops_ensemble() as f64;
            reach -= report.exit_fractions[i];
        }
        let single_flops =
            rt.suite.tiers.last().unwrap().flops_per_sample_member as f64;

        let pad = |mut v: Vec<String>| {
            v.resize(4, "-".into());
            v
        };
        let frac_cells = pad(report
            .exit_fractions
            .iter()
            .map(|f| fnum(*f, 2))
            .collect());
        table.row(
            vec![
                suite.clone(),
                format!("Frac. samples (n={})", test.n),
            ]
            .into_iter()
            .chain(frac_cells)
            .chain([String::from("1.00"), String::from("1.00")])
            .collect::<Vec<_>>(),
        );
        table.row(
            vec![suite.clone(), "GPU cost ($/h)".to_string()]
                .into_iter()
                .chain(pad(per_tier_usd.iter().map(|d| fnum(*d, 2)).collect()))
                .chain([fnum(abc_usd, 2), fnum(single_usd, 2)])
                .collect::<Vec<_>>(),
        );
        table.row(
            vec![suite.clone(), "Avg latency (ms)".to_string()]
                .into_iter()
                .chain(pad(tier_latency.iter().map(|l| fnum(l * 1e3, 3)).collect()))
                .chain([fnum(abc_latency * 1e3, 3), fnum(single_latency * 1e3, 3)])
                .collect::<Vec<_>>(),
        );
        table.row(
            vec![suite.clone(), "Avg FLOPs".to_string()]
                .into_iter()
                .chain(pad(rt
                    .suite
                    .tiers
                    .iter()
                    .map(|t| human(t.flops_ensemble() as f64))
                    .collect()))
                .chain([human(abc_flops), human(single_flops)])
                .collect::<Vec<_>>(),
        );
        table.row(
            vec![suite.clone(), "Accuracy".to_string()]
                .into_iter()
                .chain(pad(rt
                    .suite
                    .tiers
                    .iter()
                    .map(|t| fnum(t.test_acc_ensemble, 3))
                    .collect()))
                .chain([fnum(report.accuracy, 3), {
                    let outs = rt
                        .singles
                        .last()
                        .unwrap()
                        .run_single(&test.x, test.n)?;
                    let acc = outs
                        .iter()
                        .zip(&test.y)
                        .filter(|(o, &y)| o.pred == y)
                        .count() as f64
                        / test.n as f64;
                    fnum(acc, 3)
                }])
                .collect::<Vec<_>>(),
        );
    }
    ctx.emit("table5_breakdown", &table)
}
