//! Figure 5 + Table 1: black-box API cascades -- ABC vs FrugalGPT,
//! AutoMix(+T/+P), MoT, and the best single models, on the four
//! generation tasks, for 3-level and budget-friendly 2-level cascades
//! (§5.2.3).

use anyhow::Result;

use crate::baselines::api_policies::{
    run_abc_voting, run_automix, run_frugal_gpt, run_mot, run_single_model,
    AutoMixKind, PolicyRun,
};
use crate::cost::api::table1_models;
use crate::experiments::common::ExpContext;
use crate::sim::api_llm::{best_of_tier, build_agents, default_tasks, generate_samples};
use crate::util::rng::Rng;
use crate::util::table::{fnum, Table};

pub fn run(ctx: &ExpContext) -> Result<()> {
    // Table 1 echo.
    let mut t1 = Table::new(
        "Table 1: API cascade tiers ($ per Mtok, together.ai Sep 2024)",
        &["tier", "model", "$/Mtok"],
    );
    for m in table1_models() {
        t1.row(vec![m.tier.to_string(), m.name.to_string(), fnum(m.usd_per_mtok, 2)]);
    }
    ctx.emit("table1_api_pricing", &t1)?;

    let mut table = Table::new(
        "Figure 5: API-based inference, accuracy vs $ per query",
        &[
            "task",
            "levels",
            "policy",
            "accuracy",
            "$/query",
            "tokens/query",
            "exit fractions",
        ],
    );
    for task in default_tasks() {
        let mut samples = generate_samples(&task);
        if ctx.quick {
            samples.truncate(300);
        }
        let agents = build_agents(&task);
        for tier_set in [vec![1usize, 2, 3], vec![1, 2]] {
            let levels = format!("{}", tier_set.len());
            let mut runs: Vec<PolicyRun> = Vec::new();
            // deterministic per (task, tier-set) randomness
            let seed = task.seed ^ (tier_set.len() as u64) << 32;
            // majority rule (the headline config) + unanimity ablation
            runs.push(run_abc_voting(
                &task, &samples, &agents, &tier_set, 0.34, &mut Rng::new(seed + 1),
            ));
            runs.push(run_abc_voting(
                &task, &samples, &agents, &tier_set, 0.67, &mut Rng::new(seed + 6),
            ));
            runs.push(run_frugal_gpt(
                &task, &samples, &agents, &tier_set, 0.60, &mut Rng::new(seed + 2),
            ));
            runs.push(run_automix(
                &task, &samples, &agents, &tier_set,
                AutoMixKind::Threshold, &mut Rng::new(seed + 3),
            ));
            runs.push(run_automix(
                &task, &samples, &agents, &tier_set,
                AutoMixKind::Pomdp, &mut Rng::new(seed + 4),
            ));
            runs.push(run_mot(
                &task, &samples, &agents, &tier_set, 5, 0.8, &mut Rng::new(seed + 5),
            ));
            // single-model reference points (best of each tier in play)
            for &tier in &tier_set {
                runs.push(run_single_model(
                    &task,
                    &samples,
                    best_of_tier(&agents, tier),
                    &mut Rng::new(seed + 10 + tier as u64),
                ));
            }
            for r in &runs {
                table.row(vec![
                    task.name.to_string(),
                    levels.clone(),
                    r.policy.clone(),
                    fnum(r.accuracy, 4),
                    format!("{:.6}", r.usd_per_query),
                    fnum(r.tokens_per_query, 0),
                    r.exit_fractions
                        .iter()
                        .map(|f| fnum(*f, 2))
                        .collect::<Vec<_>>()
                        .join("/"),
                ]);
            }
        }
    }
    ctx.emit("fig5_api_cascades", &table)
}
