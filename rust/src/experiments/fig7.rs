//! Figure 7: existence of safe deferral rules -- selection rate as a
//! function of model accuracy (left) and FLOPs (right) for error
//! tolerances 1%, 3%, 5% (paper Appendix C; ImageNet analog).

use anyhow::Result;

use crate::calib::collect_points;
use crate::calib::threshold::{estimate_theta, evaluate_theta};
use crate::experiments::common::{ExpContext, N_CAL};
use crate::types::RuleKind;
use crate::util::table::{fnum, human, Table};

pub fn run(ctx: &ExpContext) -> Result<()> {
    let suite = "synth-imagenet";
    let rt = ctx.runtime(suite)?;
    let val = ctx.dataset(suite, "val")?;

    let mut table = Table::new(
        "Figure 7: selection rate vs accuracy / FLOPs at error tolerances",
        &[
            "tier",
            "model acc",
            "flops",
            "epsilon",
            "theta",
            "selection rate",
            "realized failure",
        ],
    );
    for (idx, tier_exe) in rt.tiers.iter().enumerate() {
        let entry = &rt.suite.tiers[idx];
        // the continuous Eq. 4 score gives the fine-grained thresholds the
        // paper's figure shows; the coarse vote rule is in the CSV too
        let points = collect_points(tier_exe, RuleKind::MeanScore, &val, val.n)?;
        let (cal, eval) = points.split_at(N_CAL);
        for eps in [0.01, 0.03, 0.05] {
            let est = estimate_theta(cal, eps);
            let (fail, sel) = evaluate_theta(eval, est.theta);
            table.row(vec![
                format!("t{}", entry.tier),
                fnum(entry.val_acc_ensemble, 3),
                human(entry.flops_per_sample_member as f64),
                fnum(eps, 2),
                fnum(est.theta as f64, 4),
                fnum(sel, 3),
                fnum(fail, 4),
            ]);
        }
    }
    ctx.emit("fig7_selection_rates", &table)
}
