//! Figure 3: fraction of inference cost saved as a function of the
//! relative cost gamma, for parallelism rho in {0, 0.5, 0.75, 1} and
//! ensemble sizes k -- the analytic Eq. 1 / Prop 4.1 landscape.
//!
//! Fixed selection rate P(r=0) as in the paper's figure.

use anyhow::Result;

use crate::cost::model::two_level_savings;
use crate::experiments::common::ExpContext;
use crate::types::Parallelism;
use crate::util::table::{fnum, Table};

pub const SELECTION_RATE: f64 = 0.7;

pub fn run(ctx: &ExpContext) -> Result<()> {
    let p_defer = 1.0 - SELECTION_RATE;
    let rhos = [0.0, 0.5, 0.75, 1.0];
    let ks = [2usize, 3, 5];
    // log-spaced gamma from 1e-3 to 1 (the paper's x-axis)
    let gammas: Vec<f64> = (0..=30)
        .map(|i| 10f64.powf(-3.0 + i as f64 * 0.1))
        .collect();

    let mut table = Table::new(
        format!(
            "Figure 3: cost savings vs gamma (selection rate {})",
            SELECTION_RATE
        ),
        &["k", "rho", "gamma", "savings"],
    );
    for &k in &ks {
        for &rho in &rhos {
            for &gamma in &gammas {
                let s = two_level_savings(k, gamma, Parallelism(rho), p_defer);
                table.row(vec![
                    k.to_string(),
                    fnum(rho, 2),
                    format!("{gamma:.4}"),
                    fnum(s, 4),
                ]);
            }
        }
    }
    ctx.emit("fig3_costmodel", &table)?;

    // Headline check rows (the paper's annotations): gamma = 1/5 vs 1/50.
    let mut summary = Table::new(
        "Figure 3 summary: sequential-vs-parallel gap",
        &["k", "gamma", "savings rho=0", "savings rho=1", "gap"],
    );
    for &k in &ks {
        for gamma in [1.0 / 5.0, 1.0 / 10.0, 1.0 / 50.0] {
            let s0 = two_level_savings(k, gamma, Parallelism::SEQUENTIAL, p_defer);
            let s1 = two_level_savings(k, gamma, Parallelism::FULL, p_defer);
            summary.row(vec![
                k.to_string(),
                format!("1/{:.0}", 1.0 / gamma),
                fnum(s0, 3),
                fnum(s1, 3),
                fnum(s1 - s0, 3),
            ]);
        }
    }
    ctx.emit("fig3_summary", &summary)
}
