//! Figure 8: parallel vs sequential execution, cascade lengths 2-4 and
//! ensemble sizes 2-5, on the CIFAR-10 analog (paper Appendix E.1).
//!
//! Ensemble sizes come from the k=5 ablation zoo
//! (`synth-cifar10-k5`): the artifact returns all five members' logits,
//! and the host-side agreement twin (coordinator::agreement) votes over
//! the first m members -- so every (length, m) cell reuses the same
//! compiled executables.

use std::sync::Arc;

use anyhow::Result;

use crate::calib::threshold::{estimate_theta, CalPoint};
use crate::coordinator::agreement::agree_logits;
use crate::data::format::Dataset;
use crate::experiments::common::{ExpContext, EPSILON, N_CAL};
use crate::runtime::executable::TierExecutable;
use crate::types::TierOutput;
use crate::util::table::{fnum, human, Table};
use crate::zoo::registry::SuiteRuntime;

/// Run one tier's ensemble and reduce agreement over the first m members
/// on the host.
fn run_subset(
    tier: &Arc<TierExecutable>,
    data: &[f32],
    n: usize,
    m: usize,
) -> Result<Vec<TierOutput>> {
    let (_, logits) = tier.run_with_logits(data, n)?;
    let c = tier.classes;
    let k = tier.k;
    assert!(m >= 1 && m <= k);
    let mut out = Vec::with_capacity(n);
    let mut sample_logits = vec![0.0f32; m * c];
    for i in 0..n {
        for mem in 0..m {
            let off = (mem * n + i) * c;
            sample_logits[mem * c..(mem + 1) * c]
                .copy_from_slice(&logits[off..off + c]);
        }
        out.push(agree_logits(&sample_logits, m, c));
    }
    Ok(out)
}

/// Calibrate + evaluate an m-member, L-level subset cascade.
fn subset_cascade(
    rt: &SuiteRuntime,
    val: &Dataset,
    test: &Dataset,
    levels: &[usize], // 0-based tier indices
    m: usize,
) -> Result<(f64, Vec<f64>)> {
    // calibrate each non-final level on N_CAL val samples
    let mut thetas = Vec::new();
    for &lvl in &levels[..levels.len() - 1] {
        let n = N_CAL.min(val.n);
        let outs = run_subset(&rt.tiers[lvl], &val.x[..n * val.dim], n, m)?;
        let points: Vec<CalPoint> = outs
            .iter()
            .zip(&val.y[..n])
            .map(|(o, &y)| CalPoint { score: o.mean_score, correct: o.majority == y })
            .collect();
        thetas.push(estimate_theta(&points, EPSILON).theta);
    }
    // sieve execution over the test set
    let n = test.n;
    let dim = test.dim;
    let mut prediction = vec![0u32; n];
    let mut exit_level = vec![0usize; n];
    let mut active: Vec<usize> = (0..n).collect();
    for (pos, &lvl) in levels.iter().enumerate() {
        if active.is_empty() {
            break;
        }
        let mut sub = Vec::with_capacity(active.len() * dim);
        for &i in &active {
            sub.extend_from_slice(test.row(i));
        }
        let outs = run_subset(&rt.tiers[lvl], &sub, active.len(), m)?;
        let last = pos + 1 == levels.len();
        let mut still = Vec::new();
        for (j, &i) in active.iter().enumerate() {
            if last || outs[j].mean_score > thetas[pos] {
                prediction[i] = outs[j].majority;
                exit_level[i] = pos + 1;
            } else {
                still.push(i);
            }
        }
        active = still;
    }
    let acc = prediction
        .iter()
        .zip(&test.y)
        .filter(|(p, y)| p == y)
        .count() as f64
        / n as f64;
    let mut exits = vec![0.0; levels.len()];
    for &e in &exit_level {
        exits[e - 1] += 1.0 / n as f64;
    }
    Ok((acc, exits))
}

pub fn run(ctx: &ExpContext) -> Result<()> {
    let suite = "synth-cifar10-k5";
    let rt = ctx.runtime(suite)?;
    let val = ctx.dataset(suite, "val")?;
    let test = ctx.test_set(suite)?;
    let n_tiers = rt.tiers.len();

    let mut table = Table::new(
        "Figure 8: cascade length x ensemble size, parallel vs sequential cost",
        &[
            "levels",
            "members",
            "accuracy",
            "flops rho=1",
            "flops rho=0",
            "exit fractions",
        ],
    );

    // single best model reference
    let best = rt.singles.last().unwrap();
    let outs = best.run_single(&test.x, test.n)?;
    let best_acc = outs
        .iter()
        .zip(&test.y)
        .filter(|(o, &y)| o.pred == y)
        .count() as f64
        / test.n as f64;
    let best_flops = rt.suite.tiers.last().unwrap().flops_per_sample_member as f64;
    table.row(vec![
        "single-best".to_string(),
        "1".to_string(),
        fnum(best_acc, 4),
        human(best_flops),
        human(best_flops),
        String::new(),
    ]);

    let member_sizes: &[usize] = if ctx.quick { &[2, 5] } else { &[2, 3, 4, 5] };
    for len in 2..=n_tiers {
        // ladder suffix of the given length always ends at the top tier
        let levels: Vec<usize> = (n_tiers - len..n_tiers).collect();
        for &m in member_sizes {
            let (acc, exits) = subset_cascade(&rt, &val, &test, &levels, m)?;
            // rho=1: each visited level costs one member's FLOPs;
            // rho=0: each visited level costs m members' FLOPs.
            let mut reach = 1.0;
            let (mut f_par, mut f_seq) = (0.0, 0.0);
            for (pos, &lvl) in levels.iter().enumerate() {
                let f = rt.suite.tiers[lvl].flops_per_sample_member as f64;
                f_par += reach * f;
                f_seq += reach * f * m as f64;
                reach -= exits[pos];
            }
            table.row(vec![
                format!("L{len}"),
                m.to_string(),
                fnum(acc, 4),
                human(f_par),
                human(f_seq),
                exits.iter().map(|f| fnum(*f, 2)).collect::<Vec<_>>().join("/"),
            ]);
        }
    }
    ctx.emit("fig8_parallel_ablation", &table)
}
