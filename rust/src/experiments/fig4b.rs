//! Figure 4b + Table 4: GPU rental cost of ABC on heterogeneous hardware
//! vs the best single model on the best GPU (§5.2.2).
//!
//! Tier i is placed on GPU ladder rung i (V100 -> A6000 -> A100 -> H100,
//! Table 4 prices); exit fractions come from the real calibrated cascade
//! run, so the dollars are a cost-model aggregation of measured routing.

use anyhow::Result;

use crate::cost::rental::{Gpu, RentalModel};
use crate::experiments::common::{ExpContext, EPSILON};
use crate::types::RuleKind;
use crate::util::table::{fnum, Table};

pub fn run(ctx: &ExpContext) -> Result<()> {
    // Table 4 first (config echo, keeps the paper table regenerable).
    let mut t4 = Table::new(
        "Table 4: GPU rental pricing (Lambda, Sep 2024)",
        &["GPU", "$/hour", "rated TFLOPs"],
    );
    for gpu in Gpu::LADDER {
        t4.row(vec![
            gpu.name().to_string(),
            fnum(gpu.dollars_per_hour(), 2),
            fnum(gpu.rated_tflops(), 0),
        ]);
    }
    ctx.emit("table4_gpu_pricing", &t4)?;

    let mut table = Table::new(
        "Figure 4b: total GPU usage cost, ABC vs best single model",
        &[
            "suite",
            "abc acc",
            "single acc",
            "abc $/h",
            "single $/h",
            "saving",
            "exit fractions",
        ],
    );
    for suite in ctx.benchmark_suites() {
        let (rt, _cal, report) = ctx.run_abc(&suite, RuleKind::MeanScore, EPSILON)?;
        let test = ctx.test_set(&suite)?;

        // best single model = top tier member-0 on the top GPU
        let single = rt.singles.last().unwrap();
        let outs = single.run_single(&test.x, test.n)?;
        let single_acc = outs
            .iter()
            .zip(&test.y)
            .filter(|(o, &y)| o.pred == y)
            .count() as f64
            / test.n as f64;

        let n_tiers = rt.suite.tiers.len();
        let gpu_ladder = &Gpu::LADDER[Gpu::LADDER.len() - n_tiers..];
        let model = RentalModel {
            levels: rt
                .suite
                .tiers
                .iter()
                .zip(gpu_ladder)
                .map(|(t, &g)| (g, t.flops_ensemble() as f64))
                .collect(),
        };
        let (_, abc_usd, single_usd) = model.dollars(&report.exit_fractions);
        table.row(vec![
            suite.clone(),
            fnum(report.accuracy, 4),
            fnum(single_acc, 4),
            fnum(abc_usd, 2),
            fnum(single_usd, 2),
            format!("{:.1}x", single_usd / abc_usd.max(1e-9)),
            report
                .exit_fractions
                .iter()
                .map(|f| fnum(*f, 2))
                .collect::<Vec<_>>()
                .join("/"),
        ]);
    }
    ctx.emit("fig4b_gpu_rental", &table)
}
