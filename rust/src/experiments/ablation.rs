//! Ablations beyond the paper's figures (DESIGN.md process step 5):
//!
//! * `ablation_calibration` -- marginal (paper App. B) vs conditional
//!   threshold estimation, and vote (Eq. 3) vs score (Eq. 4) rules, on
//!   every suite.
//! * `ablation_queueing` -- the discrete-event edge-cloud simulator:
//!   does the §5.2.1 communication win survive edge contention?

use anyhow::Result;

use crate::calib::{calibrate, calibrate_conditional};
use crate::coordinator::cascade::Cascade;
use crate::experiments::common::{ExpContext, EPSILON, N_CAL};
use crate::sim::edge_cloud::{simulate_abc, simulate_cloud_only, EdgeCloudParams};
use crate::types::RuleKind;
use crate::util::table::{fnum, Table};

pub fn run_calibration(ctx: &ExpContext) -> Result<()> {
    let mut table = Table::new(
        "Ablation: calibration mode x deferral rule",
        &["suite", "mode", "rule", "accuracy", "tier-1 exits", "mean levels"],
    );
    for suite in ctx.benchmark_suites() {
        let rt = ctx.runtime(&suite)?;
        let val = ctx.dataset(&suite, "val")?;
        let test = ctx.test_set(&suite)?;
        for rule in [RuleKind::MeanScore, RuleKind::Vote] {
            for conditional in [false, true] {
                let cal = if conditional {
                    calibrate_conditional(&rt.tiers, rule, &val, 4 * N_CAL, EPSILON)?
                } else {
                    calibrate(&rt.tiers, rule, &val, N_CAL, EPSILON)?
                };
                let cascade = Cascade::new(rt.tiers.clone(), cal.policy.clone());
                let (_, report) = cascade.evaluate(&test.x, &test.y, test.n)?;
                table.row(vec![
                    suite.clone(),
                    if conditional { "conditional" } else { "marginal" }.to_string(),
                    rule.name().to_string(),
                    fnum(report.accuracy, 4),
                    fnum(report.exit_fractions[0], 3),
                    fnum(report.mean_levels_visited, 2),
                ]);
            }
        }
    }
    ctx.emit("ablation_calibration", &table)
}

pub fn run_queueing(ctx: &ExpContext) -> Result<()> {
    // ground the simulator in measured quantities: tier-1 / tier-4
    // per-sample latency from the real PJRT executables, exit fraction
    // from the calibrated cascade.
    let suite = "synth-cifar10";
    let (rt, _cal, report) = ctx.run_abc(suite, RuleKind::MeanScore, EPSILON)?;
    let test = ctx.test_set(suite)?;
    // single-request service times: Fig. 4a is a single-instance,
    // real-time regime ("predictions as new data becomes available")
    let reps = 20;
    let t0 = std::time::Instant::now();
    for i in 0..reps {
        rt.tiers[0].run(test.row(i), 1)?;
    }
    let edge_service = t0.elapsed().as_secs_f64() / reps as f64;
    let t0 = std::time::Instant::now();
    for i in 0..reps {
        rt.tiers.last().unwrap().run(test.row(i), 1)?;
    }
    let cloud_service = t0.elapsed().as_secs_f64() / reps as f64;
    let edge_exit = report.exit_fractions[0];

    let mut table = Table::new(
        "Ablation: edge-to-cloud with queueing (discrete-event sim)",
        &[
            "uplink",
            "rate (rps)",
            "abc mean (ms)",
            "abc p99 (ms)",
            "cloud mean (ms)",
            "reduction",
            "edge util",
        ],
    );
    for (uplink, label) in [(0.010, "10ms"), (0.100, "100ms")] {
        for rate in [50.0, 200.0, 800.0] {
            let p = EdgeCloudParams {
                edge_service_s: edge_service,
                cloud_service_s: cloud_service,
                uplink_s: uplink,
                cloud_servers: 8,
                edge_exit_frac: edge_exit,
                rate,
                n_requests: if ctx.quick { 5_000 } else { 30_000 },
                seed: 99,
            };
            let abc = simulate_abc(&p);
            let cloud = simulate_cloud_only(&p);
            table.row(vec![
                label.to_string(),
                fnum(rate, 0),
                fnum(abc.mean_latency_s * 1e3, 2),
                fnum(abc.p99_s * 1e3, 2),
                fnum(cloud.mean_latency_s * 1e3, 2),
                format!("{:.1}x", cloud.mean_latency_s / abc.mean_latency_s),
                fnum(abc.edge_utilisation, 2),
            ]);
        }
    }
    ctx.emit("ablation_queueing", &table)
}
