//! Shared experiment context: loaded suites, calibration, result output.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::calib::{calibrate, Calibration};
use crate::coordinator::cascade::{Cascade, CascadeReport};
use crate::data::format::Dataset;
use crate::runtime::engine::Engine;
use crate::types::RuleKind;
use crate::util::table::Table;
use crate::zoo::manifest::Manifest;
use crate::zoo::registry::SuiteRuntime;

/// Calibration samples per tier (paper App. B: ~100).
pub const N_CAL: usize = 100;
/// Default safe-deferral tolerance.
pub const EPSILON: f64 = 0.05;

/// Everything an experiment needs.
pub struct ExpContext {
    pub manifest: Manifest,
    pub engine: Arc<Engine>,
    pub out_dir: PathBuf,
    /// Quick mode: fewer samples / sweeps (CI-friendly).
    pub quick: bool,
    runtimes: std::sync::Mutex<BTreeMap<String, Arc<SuiteRuntime>>>,
}

impl ExpContext {
    pub fn new(artifacts: impl Into<PathBuf>, out_dir: impl Into<PathBuf>, quick: bool) -> Result<ExpContext> {
        let artifacts = artifacts.into();
        let manifest = Manifest::load(&artifacts)
            .with_context(|| format!("loading manifest from {}", artifacts.display()))?;
        let engine = Arc::new(Engine::cpu()?);
        let out_dir = out_dir.into();
        std::fs::create_dir_all(&out_dir)?;
        Ok(ExpContext {
            manifest,
            engine,
            out_dir,
            quick,
            runtimes: std::sync::Mutex::new(BTreeMap::new()),
        })
    }

    /// Load (and cache) a suite's executables.  Singles are always loaded
    /// (WoC and single-model baselines need them).
    pub fn runtime(&self, suite: &str) -> Result<Arc<SuiteRuntime>> {
        if let Some(rt) = self.runtimes.lock().unwrap().get(suite) {
            return Ok(Arc::clone(rt));
        }
        let rt = Arc::new(SuiteRuntime::load(
            Arc::clone(&self.engine),
            &self.manifest,
            suite,
            true,
        )?);
        self.runtimes
            .lock()
            .unwrap()
            .insert(suite.to_string(), Arc::clone(&rt));
        Ok(rt)
    }

    /// Dataset split of a suite.
    pub fn dataset(&self, suite: &str, split: &str) -> Result<Dataset> {
        self.runtime(suite)?.dataset(&self.manifest, split)
    }

    /// Test set, truncated in quick mode.
    pub fn test_set(&self, suite: &str) -> Result<Dataset> {
        let ds = self.dataset(suite, "test")?;
        Ok(if self.quick { ds.slice(0, ds.n.min(500)) } else { ds })
    }

    /// Calibrate a suite's full ladder with the paper's recipe: N_CAL
    /// validation samples, tolerance epsilon, given rule kind.
    pub fn calibrate_suite(
        &self,
        suite: &str,
        rule: RuleKind,
        epsilon: f64,
    ) -> Result<(Arc<SuiteRuntime>, Calibration)> {
        let rt = self.runtime(suite)?;
        let val = self.dataset(suite, "val")?;
        let cal = calibrate(&rt.tiers, rule, &val, N_CAL, epsilon)?;
        Ok((rt, cal))
    }

    /// Build + evaluate the calibrated ABC cascade of a suite on test.
    pub fn run_abc(
        &self,
        suite: &str,
        rule: RuleKind,
        epsilon: f64,
    ) -> Result<(Arc<SuiteRuntime>, Calibration, CascadeReport)> {
        let (rt, cal) = self.calibrate_suite(suite, rule, epsilon)?;
        let test = self.test_set(suite)?;
        let cascade = Cascade::new(rt.tiers.clone(), cal.policy.clone());
        let (_, report) = cascade.evaluate(&test.x, &test.y, test.n)?;
        Ok((rt, cal, report))
    }

    /// Persist a table as ASCII (stdout) + CSV (results dir).
    pub fn emit(&self, exp: &str, table: &Table) -> Result<()> {
        println!("{}", table.render());
        let csv_path = self.out_dir.join(format!("{exp}.csv"));
        std::fs::write(&csv_path, table.to_csv())?;
        println!("[{exp}] csv -> {}\n", csv_path.display());
        Ok(())
    }

    /// Suites used by the classifier experiments (excludes the k=5
    /// ablation zoo, which only fig8 touches).
    pub fn benchmark_suites(&self) -> Vec<String> {
        self.manifest
            .suite_names()
            .into_iter()
            .filter(|s| *s != "synth-cifar10-k5")
            .map(String::from)
            .collect()
    }
}

/// Mean per-sample ensemble FLOPs of a cascade run, from exit fractions.
/// `rho1` uses the parallel-equivalent cost (one member per tier, §5.1.1);
/// otherwise the full k-member FLOPs are charged.
pub fn cascade_mean_flops(
    rt: &SuiteRuntime,
    exit_fractions: &[f64],
    rho1: bool,
) -> f64 {
    let mut reach = 1.0;
    let mut total = 0.0;
    for (tier, &exit) in rt.suite.tiers.iter().zip(exit_fractions) {
        let per_sample = if rho1 {
            tier.flops_per_sample_member as f64
        } else {
            tier.flops_ensemble() as f64
        };
        total += reach * per_sample;
        reach -= exit;
    }
    total
}
