//! Paper experiment regenerators: one module per figure/table
//! (DESIGN.md §5 experiment index).

pub mod ablation;
pub mod common;
pub mod fig2;
pub mod fig3;
pub mod fig4a;
pub mod fig4b;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod table5;

use anyhow::{bail, Result};
use common::ExpContext;

/// All experiment ids, in the order `exp all` runs them.
pub const ALL: [&str; 11] = [
    "fig2", "fig3", "fig4a", "fig4b", "fig5", "fig6", "fig7", "fig8", "table5",
    "ablation_calibration", "ablation_queueing",
];

/// Run one experiment by id.
pub fn run(id: &str, ctx: &ExpContext) -> Result<()> {
    match id {
        "fig2" => fig2::run(ctx),
        "fig3" => fig3::run(ctx),
        "fig4a" => fig4a::run(ctx),
        "fig4b" => fig4b::run(ctx),
        "fig5" => fig5::run(ctx),
        "fig6" => fig6::run(ctx),
        "fig7" => fig7::run(ctx),
        "fig8" => fig8::run(ctx),
        "table5" => table5::run(ctx),
        "ablation_calibration" => ablation::run_calibration(ctx),
        "ablation_queueing" => ablation::run_queueing(ctx),
        "all" => {
            for exp in ALL {
                println!("==== running {exp} ====");
                run(exp, ctx)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment {other:?}; known: {ALL:?} or 'all'"),
    }
}
