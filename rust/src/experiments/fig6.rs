//! Figure 6: stability of the agreement-threshold estimate vs the number
//! of calibration samples, across models of different accuracy levels
//! (paper Appendix B; synth-imagenet tiers play the accuracy levels).

use anyhow::Result;

use crate::calib::threshold::{estimate_theta, evaluate_theta};
use crate::calib::collect_points;
use crate::experiments::common::{ExpContext, EPSILON};
use crate::types::RuleKind;
use crate::util::table::{fnum, Table};

pub fn run(ctx: &ExpContext) -> Result<()> {
    let suite = "synth-imagenet";
    let rt = ctx.runtime(suite)?;
    let val = ctx.dataset(suite, "val")?;
    let sizes: &[usize] = if ctx.quick {
        &[100, 500, 2000]
    } else {
        &[100, 200, 500, 1000, 2000, 5000]
    };

    let mut table = Table::new(
        "Figure 6: theta estimate vs calibration sample count (ImageNet analog)",
        &[
            "tier",
            "model acc",
            "rule",
            "n",
            "theta",
            "selection rate",
            "holdout failure",
        ],
    );
    for (idx, tier_exe) in rt.tiers.iter().enumerate() {
        let acc = rt.suite.tiers[idx].val_acc_ensemble;
        for rule in [RuleKind::Vote, RuleKind::MeanScore] {
            // one pass over the full val set, reused for all n
            let all = collect_points(tier_exe, rule, &val, val.n)?;
            // hold out the tail for stability evaluation
            let holdout = &all[all.len() / 2..];
            for &n in sizes {
                let n = n.min(all.len() / 2);
                let est = estimate_theta(&all[..n], EPSILON);
                let (h_fail, _h_sel) = evaluate_theta(holdout, est.theta);
                table.row(vec![
                    format!("t{}", rt.suite.tiers[idx].tier),
                    fnum(acc, 3),
                    rule.name().to_string(),
                    n.to_string(),
                    fnum(est.theta as f64, 4),
                    fnum(est.selection_rate, 3),
                    fnum(h_fail, 4),
                ]);
            }
        }
    }
    ctx.emit("fig6_threshold_stability", &table)
}
