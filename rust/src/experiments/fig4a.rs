//! Figure 4a: edge-to-cloud inference -- communication cost of ABC
//! (tier 1 ensemble on-device, top tier in the cloud) vs the cloud-only
//! single best model, across the paper's delay classes (§5.2.1).

use anyhow::Result;

use crate::coordinator::cascade::Cascade;
use crate::cost::comm::{CommModel, Placement, DELAY_CLASSES};
use crate::experiments::common::{ExpContext, EPSILON, N_CAL};
use crate::types::RuleKind;
use crate::util::table::{fnum, Table};

pub fn run(ctx: &ExpContext) -> Result<()> {
    let mut table = Table::new(
        "Figure 4a: edge-to-cloud communication cost",
        &[
            "suite",
            "delay",
            "edge exit frac",
            "abc acc",
            "cloud acc",
            "abc comm (s)",
            "cloud comm (s)",
            "reduction",
        ],
    );
    for suite in ctx.benchmark_suites() {
        let rt = ctx.runtime(&suite)?;
        let val = ctx.dataset(&suite, "val")?;
        let test = ctx.test_set(&suite)?;

        // 2-level cascade: tier 1 (edge) -> top tier (cloud)
        let tiers = vec![rt.tiers[0].clone(), rt.tiers.last().unwrap().clone()];
        let cal =
            crate::calib::calibrate(&tiers, RuleKind::MeanScore, &val, N_CAL, EPSILON)?;
        let cascade = Cascade::new(tiers, cal.policy);
        let (_, report) = cascade.evaluate(&test.x, &test.y, test.n)?;

        // cloud-only baseline: top-tier ensemble accuracy
        let top = rt.tiers.last().unwrap();
        let outs = top.run(&test.x, test.n)?;
        let cloud_acc = outs
            .iter()
            .zip(&test.y)
            .filter(|(o, &y)| o.majority == y)
            .count() as f64
            / test.n as f64;

        for (delay_s, label) in DELAY_CLASSES {
            let comm = CommModel::new(delay_s, vec![Placement::Edge, Placement::Cloud]);
            let abc_t = comm.mean_comm_time(&report.exit_fractions);
            let cloud_t = comm.cloud_only_time();
            table.row(vec![
                suite.clone(),
                label.to_string(),
                fnum(report.exit_fractions[0], 3),
                fnum(report.accuracy, 4),
                fnum(cloud_acc, 4),
                format!("{abc_t:.6}"),
                format!("{cloud_t:.6}"),
                format!("{:.1}x", cloud_t / abc_t.max(1e-12)),
            ]);
        }
    }
    ctx.emit("fig4a_edge_cloud", &table)
}
