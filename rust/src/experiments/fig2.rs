//! Figure 2: Pareto curves -- ABC vs WoC confidence cascades vs single
//! models, accuracy vs FLOPs, per benchmark suite (rho = 1, §5.1.1).
//!
//! Series per suite:
//! * `single-tN`    -- each tier's member-0 model alone;
//! * `ensemble-tN`  -- each tier's full ensemble (majority vote, no cascade);
//! * `ABC-LN`       -- calibrated agreement cascades of length N (prefixes
//!                     of the ladder ending at tier N);
//! * `WoC`          -- tuned confidence cascade over the single models.

use anyhow::Result;

use crate::baselines::woc;
use crate::coordinator::cascade::Cascade;
use crate::experiments::common::{cascade_mean_flops, ExpContext, EPSILON, N_CAL};
use crate::types::RuleKind;
use crate::util::table::{fnum, human, Table};

pub fn run(ctx: &ExpContext) -> Result<()> {
    let mut table = Table::new(
        "Figure 2: accuracy vs FLOPs Pareto (rho=1)",
        &["suite", "series", "accuracy", "flops/sample", "exit fractions"],
    );
    for suite in ctx.benchmark_suites() {
        let rt = ctx.runtime(&suite)?;
        let test = ctx.test_set(&suite)?;
        let val = ctx.dataset(&suite, "val")?;

        // -- single models & plain ensembles (accuracy straight from a
        //    PJRT evaluation so the numbers are measured, not manifest)
        for (idx, tier) in rt.suite.tiers.iter().enumerate() {
            let single = &rt.singles[idx];
            let outs = single.run_single(&test.x, test.n)?;
            let acc = outs
                .iter()
                .zip(&test.y)
                .filter(|(o, &y)| o.pred == y)
                .count() as f64
                / test.n as f64;
            table.row(vec![
                suite.clone(),
                format!("single-t{}", tier.tier),
                fnum(acc, 4),
                human(tier.flops_per_sample_member as f64),
                String::new(),
            ]);
            let ens = &rt.tiers[idx];
            let outs = ens.run(&test.x, test.n)?;
            let acc = outs
                .iter()
                .zip(&test.y)
                .filter(|(o, &y)| o.majority == y)
                .count() as f64
                / test.n as f64;
            table.row(vec![
                suite.clone(),
                format!("ensemble-t{}", tier.tier),
                fnum(acc, 4),
                // rho=1: ensemble latency-equivalent FLOPs = one member
                human(tier.flops_per_sample_member as f64),
                String::new(),
            ]);
        }

        // -- ABC cascades: ladder prefixes of length 2..=n
        for len in 2..=rt.tiers.len() {
            let tiers = rt.tiers[..len].to_vec();
            let cal = crate::calib::calibrate(
                &tiers,
                RuleKind::MeanScore,
                &val,
                N_CAL,
                EPSILON,
            )?;
            let cascade = Cascade::new(tiers, cal.policy);
            let (_, report) = cascade.evaluate(&test.x, &test.y, test.n)?;
            let mut exit_padded = report.exit_fractions.clone();
            exit_padded.resize(rt.tiers.len(), 0.0);
            let flops = cascade_mean_flops(&rt, &exit_padded, true);
            table.row(vec![
                suite.clone(),
                format!("ABC-L{len}"),
                fnum(report.accuracy, 4),
                human(flops),
                report
                    .exit_fractions
                    .iter()
                    .map(|f| fnum(*f, 2))
                    .collect::<Vec<_>>()
                    .join("/"),
            ]);
        }

        // -- WoC tuned confidence cascade over single models
        let flops_levels: Vec<f64> = rt
            .suite
            .tiers
            .iter()
            .map(|t| t.flops_per_sample_member as f64)
            .collect();
        let rep = woc::tune_and_run(&rt.singles, &val, &test, &flops_levels)?;
        table.row(vec![
            suite.clone(),
            format!("WoC(tau={})", rep.tau),
            fnum(rep.accuracy, 4),
            human(rep.mean_flops),
            rep.exit_fractions
                .iter()
                .map(|f| fnum(*f, 2))
                .collect::<Vec<_>>()
                .join("/"),
        ]);
    }
    ctx.emit("fig2_pareto", &table)
}
