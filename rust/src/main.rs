//! abc-serve leader binary: serve / classify / calibrate / plan / exp.
//!
//! ```text
//! repro info        [--artifacts DIR]
//! repro calibrate   --suite S [--rule vote|score] [--epsilon E] [--n N]
//! repro classify    --suite S [--split test] [--rule vote|score] [--epsilon E]
//! repro plan        [--out plan.json] [--ks 1,3,5] [--epsilons 0.01,...]
//!                   [--mid-ks 3,5] [--mid-gamma 0.2] [--mid-member-acc 0.9]
//!                   [--batches 4,8,16,32] [--replicas 2] [--gamma 0.05]
//!                   [--rho 0.0] [--top-acc 0.95] [--cal-n 400]
//!                   [--design-rps R] [--design-util 0.85]
//!                   [--tier-gpus v100,h100]  (heterogeneous fleet: the
//!                   Pareto cost axis becomes $/request and gears carry
//!                   per-tier (gpu, replicas) allocations)
//!                   (synthetic calibration: no artifacts needed;
//!                   --mid-ks adds three-level ladders to the grid)
//! repro serve       --suite S [--port 7878] [--max-batch 32] [--max-wait-ms 2]
//!                   [--replicas 1] [--max-queue 256]
//!                   [--frontend reactor|threads]  (default reactor:
//!                   sharded epoll/poll event loops + a worker pool
//!                   sized to cores; threads keeps the old
//!                   thread-per-connection path for differential
//!                   testing)
//!                   [--shards N]  (reactor event-loop shards; 0 =
//!                   auto-size to min(4, cores/2))
//!                   [--plan plan.json] [--top-rps R]  (adaptive gears; thetas
//!                   re-calibrated on the suite, ladder rescaled to R)
//!                   [--autoscale --min-replicas 1 --max-replicas N
//!                    --warmup-ms 0 --max-dollars-hour D] (elastic
//!                   replicas; without --plan, synthesizes a one-gear
//!                   plan from --top-rps, the measured per-replica
//!                   capacity; --tier-rps when tiered)
//!                   [--tiered [--tier-gpus v100,a6000,h100]
//!                    [--tier-replicas 2,2,1] [--tier-rps 3000,2000,800]
//!                    [--max-dollars-hour D]]  (one pool per cascade level,
//!                   deferral routed between pools, per-tier GPU pricing;
//!                   with --autoscale the control loop also shifts
//!                   per-tier gears: theta rungs derived from the
//!                   suite's calibrated thresholds)
//!                   [--events-file events.jsonl]
//!                   [--trace-sample N] [--trace-file trace.jsonl]
//!                   (trace 1-in-N requests through the serving path;
//!                   a file without --trace-sample implies N=1)
//!                   [--shadow-sample N [--drift-window W]
//!                    [--drift-epsilon E] [--recalibrate]]  (tiered only:
//!                   shadow 1-in-N early exits through the next tier off
//!                   the critical path, estimate live agreement/theta per
//!                   tier, raise drift alarms; --recalibrate additionally
//!                   lets the control loop re-ground a breached tier's
//!                   theta from the live estimate -- needs --autoscale)
//!                   [--slo-targets P,S,B [--slo-goal 0.95]] (SLO
//!                   observatory: per-class latency targets in seconds
//!                   for premium,standard,batch; windowed attainment,
//!                   goodput and error-budget burn alarms per class)
//!                   [--class-weights P,S,B] (weighted-fair admission:
//!                   per-class queue shares, work-conserving borrowing)
//!                   [--slo-boost M] (with --autoscale + a budget:
//!                   multiply --max-dollars-hour by M while the premium
//!                   class's burn alarm is latched Breach)
//! repro stats       [--port 7878] [--events] [--traces] [--drift]
//!                   [--slo] [--prom]
//!                   (query a running server; --prom prints the
//!                   Prometheus text exposition instead of the
//!                   pretty snapshot, --traces dumps sampled trace
//!                   spans grouped per request as JSONL, --drift the
//!                   drift observatory's per-tier statuses, --slo the
//!                   per-class SLO attainment/burn table)
//! repro loadgen     [--rate 500] [--requests 2000] [--arrival poisson]
//!                   [--replicas 1] [--max-queue 64] [--workers 128]
//!                   [--class-mix P,S,B] (tag requests premium/standard/
//!                   batch in exact proportions, interleaved)
//!                   (synthetic backend: no artifacts needed)
//! repro exp         <fig2|fig3|fig4a|fig4b|fig5|fig6|fig7|fig8|table5|all>
//!                   [--out artifacts/results] [--quick]
//! repro selftest    (loads every artifact and runs a smoke batch)
//! ```

use std::sync::Arc;
use std::time::Duration;

use abc_serve::calib;
use abc_serve::control::{
    ControlConfig, ControlLoop, ControlTarget, ControllerConfig, ScaleConfig,
    TierControl, TierRung,
};
use abc_serve::coordinator::batcher::BatcherConfig;
use abc_serve::coordinator::cascade::{Cascade, StageClassifier};
use abc_serve::coordinator::replica::{PoolConfig, ReplicaPool};
use abc_serve::coordinator::router::{TierSpec, TieredFleet, TieredFleetConfig};
use abc_serve::cost::rental::Gpu;
use abc_serve::data::workload::Arrival;
use abc_serve::experiments::{self, common::ExpContext};
use abc_serve::metrics::Metrics;
use abc_serve::obs::{
    DriftConfig, JsonlSink, ObsHook, SloConfig, SloObservatory, Tracer,
};
use abc_serve::planner::{search, GearHandle, GearPlan, PlannerConfig};
use abc_serve::runtime::engine::Engine;
use abc_serve::trafficgen::{LoadGen, LoadReport, SyntheticClassifier, Trace};
use abc_serve::types::{Class, Parallelism, RuleKind};
use abc_serve::util::cli::Args;
use abc_serve::util::table::{fnum, human, Table};
use abc_serve::zoo::manifest::Manifest;
use abc_serve::zoo::registry::SuiteRuntime;
use anyhow::{bail, Context, Result};

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    let Some((cmd, rest)) = args.subcommand() else {
        print_usage();
        return Ok(());
    };
    match cmd {
        "info" => cmd_info(&rest),
        "calibrate" => cmd_calibrate(&rest),
        "classify" => cmd_classify(&rest),
        "plan" => cmd_plan(&rest),
        "serve" => cmd_serve(&rest),
        "stats" => cmd_stats(&rest),
        "loadgen" => cmd_loadgen(&rest),
        "exp" => cmd_exp(&rest),
        "selftest" => cmd_selftest(&rest),
        "help" | "--help" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command {other:?} (try `repro help`)"),
    }
}

fn print_usage() {
    println!(
        "abc-serve: Agreement-Based Cascading serving framework\n\n\
         commands:\n\
         \x20 info                          show manifest / zoo summary\n\
         \x20 calibrate --suite S           estimate per-tier thetas (App. B)\n\
         \x20 classify  --suite S           run the calibrated cascade on a split\n\
         \x20 plan      [--out plan.json]   emit a Pareto gear plan (synthetic\n\
         \x20                               calibration; no artifacts needed)\n\
         \x20 serve     --suite S           line-JSON TCP serving (port 7878)\n\
         \x20                               [--replicas N] [--max-queue Q]\n\
         \x20                               [--plan plan.json] (adaptive gears)\n\
         \x20                               [--autoscale --min-replicas A\n\
         \x20                               --max-replicas B] (elastic replicas)\n\
         \x20                               [--tiered --tier-gpus v100,...,h100]\n\
         \x20                               (pool per tier, routed deferral)\n\
         \x20                               [--trace-sample N] [--trace-file F]\n\
         \x20                               (trace 1-in-N requests)\n\
         \x20                               [--shadow-sample N [--recalibrate]]\n\
         \x20                               (drift observatory: shadow 1-in-N\n\
         \x20                               early exits, live theta gauges)\n\
         \x20                               [--slo-targets P,S,B] [--slo-goal G]\n\
         \x20                               (per-class SLO books + burn alarms)\n\
         \x20                               [--class-weights P,S,B]\n\
         \x20                               (weighted-fair admission)\n\
         \x20 stats     [--port P]          stats snapshot of a running server\n\
         \x20                               [--events] (+ controller event JSONL)\n\
         \x20                               [--traces] (+ trace-span JSONL)\n\
         \x20                               [--drift] (drift observatory status)\n\
         \x20                               [--slo] (per-class SLO attainment)\n\
         \x20                               [--prom] (Prometheus exposition)\n\
         \x20 loadgen                       open-loop load test on the synthetic\n\
         \x20                               backend (no artifacts needed)\n\
         \x20                               [--class-mix P,S,B] (tag requests)\n\
         \x20 exp <id|all>                  regenerate paper figures/tables\n\
         \x20 selftest                      load + smoke every artifact\n\n\
         common flags: --artifacts DIR (default ./artifacts), --rule vote|score,\n\
         \x20 --epsilon E (default 0.03), --quick"
    );
}

fn artifacts_dir(args: &Args) -> String {
    args.str_or("artifacts", "artifacts").to_string()
}

fn rule_of(args: &Args) -> Result<RuleKind> {
    let name = args.str_or("rule", "score");
    RuleKind::parse(name).with_context(|| format!("bad --rule {name:?}"))
}

/// Wire `--events-file` (when given) as a JSONL sink on the registry's
/// event log; `who` names the decision source in the announcement.
fn events_file_sink(args: &Args, metrics: &Metrics, who: &str) -> Result<()> {
    if let Some(path) = args.get("events-file") {
        metrics
            .events()
            .set_file_sink(path)
            .with_context(|| format!("opening --events-file {path}"))?;
        println!("{who} events mirrored to {path} (JSONL)");
    }
    Ok(())
}

/// Build the request tracer from `--trace-sample N` / `--trace-file
/// PATH`: 1-in-N deterministic sampling into the bounded span ring,
/// optionally mirrored to a JSONL file.  A file without an explicit
/// sample rate implies N=1 (trace everything).
fn trace_config(args: &Args) -> Result<Option<Arc<Tracer>>> {
    let mut sample = args.u64_or("trace-sample", 0)?;
    let file = args.get("trace-file");
    if sample == 0 && file.is_some() {
        sample = 1;
    }
    if sample == 0 {
        return Ok(None);
    }
    Ok(Some(match file {
        Some(path) => {
            let sink = JsonlSink::open(path)
                .with_context(|| format!("opening --trace-file {path}"))?;
            println!(
                "tracing 1-in-{sample} requests, spans mirrored to {path} (JSONL)"
            );
            Tracer::with_sink(sample, sink)
        }
        None => {
            println!("tracing 1-in-{sample} requests");
            Tracer::new(sample)
        }
    }))
}

/// Parse a `--flag P,S,B` triple in premium,standard,batch order;
/// `None` when absent.  Every entry must satisfy `check`.
fn class_triple(
    args: &Args,
    name: &str,
    check: fn(f64) -> bool,
    what: &str,
) -> Result<Option<[f64; Class::COUNT]>> {
    let listed = args.f64_list_or(name, &[])?;
    if listed.is_empty() {
        return Ok(None);
    }
    anyhow::ensure!(
        listed.len() == Class::COUNT,
        "--{name} needs {} entries (premium,standard,batch), got {}",
        Class::COUNT,
        listed.len()
    );
    anyhow::ensure!(
        listed.iter().all(|v| check(*v)),
        "--{name} entries must be {what}"
    );
    Ok(Some([listed[0], listed[1], listed[2]]))
}

/// Build the SLO observatory config from `--slo-targets P,S,B` /
/// `--slo-goal G`; `None` when neither flag is given (a bare
/// `--slo-goal` uses the default per-class targets).
fn slo_config(args: &Args) -> Result<Option<SloConfig>> {
    let targets = class_triple(args, "slo-targets", |t| t > 0.0, "> 0 seconds")?;
    let goal = args.f64_or("slo-goal", 0.0)?;
    if targets.is_none() && goal == 0.0 {
        return Ok(None);
    }
    let mut cfg = SloConfig::default();
    if let Some(t) = targets {
        cfg.targets_s = t;
    }
    if goal > 0.0 {
        anyhow::ensure!(goal < 1.0, "--slo-goal must be in (0, 1)");
        cfg.goal = goal;
    }
    Ok(Some(cfg))
}

/// Parse `--tier-gpus v100,a6000,h100`; empty when the flag is absent.
fn gpu_list(args: &Args, name: &str) -> Result<Vec<Gpu>> {
    args.list_or(name, &[])
        .iter()
        .map(|s| {
            Gpu::parse(s).with_context(|| {
                format!("bad --{name} entry {s:?} (v100|a6000|a100|h100)")
            })
        })
        .collect()
}

fn cmd_info(args: &Args) -> Result<()> {
    let manifest = Manifest::load(artifacts_dir(args))?;
    let mut table = Table::new(
        "model zoo",
        &["suite", "paper dataset", "classes", "tier", "k", "hidden", "flops/member", "val acc (ens)"],
    );
    for s in &manifest.suites {
        for t in &s.tiers {
            table.row(vec![
                s.name.clone(),
                s.paper_dataset.clone(),
                s.classes.to_string(),
                t.tier.to_string(),
                t.k.to_string(),
                format!("{:?}", t.hidden),
                human(t.flops_per_sample_member as f64),
                fnum(t.val_acc_ensemble, 3),
            ]);
        }
    }
    println!("{}", table.render());
    println!(
        "ensemble buckets: {:?}   single buckets: {:?}",
        manifest.ensemble_buckets, manifest.single_buckets
    );
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let suite = args.req_str("suite")?;
    let rule = rule_of(args)?;
    let epsilon = args.f64_or("epsilon", 0.03)?;
    let n_cal = args.usize_or("n", 100)?;
    let manifest = Manifest::load(artifacts_dir(args))?;
    let engine = Arc::new(Engine::cpu()?);
    let rt = SuiteRuntime::load(engine, &manifest, suite, false)?;
    let val = rt.dataset(&manifest, "val")?;
    let cal = calib::calibrate(&rt.tiers, rule, &val, n_cal, epsilon)?;
    let mut table = Table::new(
        format!("calibration: {suite} rule={} eps={epsilon} n={n_cal}", rule.name()),
        &["tier", "theta", "selection rate", "failure rate"],
    );
    for (i, est) in cal.estimates.iter().enumerate() {
        table.row(vec![
            format!("t{}", rt.suite.tiers[i].tier),
            fnum(est.theta as f64, 4),
            fnum(est.selection_rate, 3),
            fnum(est.failure_rate, 4),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}

fn cmd_classify(args: &Args) -> Result<()> {
    let suite = args.req_str("suite")?;
    let split = args.str_or("split", "test");
    let rule = rule_of(args)?;
    let epsilon = args.f64_or("epsilon", 0.03)?;
    let manifest = Manifest::load(artifacts_dir(args))?;
    let engine = Arc::new(Engine::cpu()?);
    let rt = Arc::new(SuiteRuntime::load(engine, &manifest, suite, false)?);
    let val = rt.dataset(&manifest, "val")?;
    let data = rt.dataset(&manifest, split)?;
    let cal = calib::calibrate(&rt.tiers, rule, &val, 100, epsilon)?;
    let cascade = Cascade::new(rt.tiers.clone(), cal.policy);
    let t0 = std::time::Instant::now();
    let (_, report) = cascade.evaluate(&data.x, &data.y, data.n)?;
    let dt = t0.elapsed().as_secs_f64();
    let mut table = Table::new(
        format!("cascade run: {suite}/{split}"),
        &["metric", "value"],
    );
    table.row(vec!["samples".to_string(), report.n.to_string()]);
    table.row(vec!["accuracy".to_string(), fnum(report.accuracy, 4)]);
    table.row(vec![
        "exit fractions".to_string(),
        report
            .exit_fractions
            .iter()
            .map(|f| fnum(*f, 3))
            .collect::<Vec<_>>()
            .join(" / "),
    ]);
    table.row(vec![
        "mean levels visited".to_string(),
        fnum(report.mean_levels_visited, 3),
    ]);
    table.row(vec!["wallclock (s)".to_string(), fnum(dt, 2)]);
    table.row(vec![
        "throughput (samples/s)".to_string(),
        fnum(report.n as f64 / dt, 0),
    ]);
    println!("{}", table.render());
    Ok(())
}

/// Emit a Pareto-optimal gear plan over synthetic calibration data
/// (artifact-free; see planner::search for the candidate model).
/// `--mid-ks` adds three-level ladders (tier-1 -> interior ensemble ->
/// top) to the candidate grid.
fn cmd_plan(args: &Args) -> Result<()> {
    let out = args.str_or("out", "plan.json");
    let cfg = PlannerConfig {
        ks: args.usize_list_or("ks", &[1, 3, 5])?,
        epsilons: args.f64_list_or("epsilons", &[0.01, 0.03, 0.05, 0.10])?,
        mid_ks: args.usize_list_or("mid-ks", &[])?,
        mid_gamma: args.f64_or("mid-gamma", 0.20)?,
        batches: args.usize_list_or("batches", &[4, 8, 16, 32])?,
        replicas: args.usize_or("replicas", 2)?,
        gamma: args.f64_or("gamma", 0.05)?,
        rho: Parallelism(args.f64_or("rho", 0.0)?.clamp(0.0, 1.0)),
        top_accuracy: args.f64_or("top-acc", 0.95)?,
        batch_overhead_s: args.u64_or("base-us", 200)? as f64 * 1e-6,
        top_row_s: args.u64_or("row-us", 2000)? as f64 * 1e-6,
        design_rps: args.f64_or("design-rps", 0.0)?,
        design_util: args.f64_or("design-util", 0.85)?,
        tier_gpus: gpu_list(args, "tier-gpus")?,
    };
    let cal_n = args.usize_or("cal-n", 400)?;
    let member_acc = args.f64_or("member-acc", 0.80)?;
    let mid_member_acc = args.f64_or("mid-member-acc", 0.90)?;
    let seed = args.u64_or("seed", 42)?;
    anyhow::ensure!(cfg.replicas > 0, "--replicas must be > 0");
    anyhow::ensure!(cal_n > 0, "--cal-n must be > 0");
    let cal: Vec<_> = cfg
        .ks
        .iter()
        .map(|&k| (k, search::synthetic_cal_points(k, cal_n, member_acc, seed)))
        .collect();
    // interior tiers are bigger models: stronger members, distinct seed
    let mid_cal: Vec<_> = cfg
        .mid_ks
        .iter()
        .map(|&k| {
            (k, search::synthetic_cal_points(k, cal_n, mid_member_acc, seed ^ 0x9E37))
        })
        .collect();
    let plan = search::plan_with_mid(&cfg, &cal, &mid_cal)?;
    let n_candidates = cfg.ks.len()
        * cfg.epsilons.len()
        * cfg.batches.len()
        * (1 + cfg.mid_ks.len() * cfg.epsilons.len());
    let mut table = Table::new(
        format!(
            "gear plan: {} gears over {} candidates (cal-n {cal_n})",
            plan.len(),
            n_candidates
        ),
        &["gear", "ks", "eps", "thetas", "batch", "replicas", "fleet",
          "accuracy", "rel cost", "$/1k req", "sustainable rps"],
    );
    for g in &plan.gears {
        let ks = std::iter::once(g.k.to_string())
            .chain(g.mid.iter().map(|t| t.k.to_string()))
            .collect::<Vec<_>>()
            .join("+");
        let epss = std::iter::once(fnum(g.epsilon, 3))
            .chain(g.mid.iter().map(|t| fnum(t.epsilon, 3)))
            .collect::<Vec<_>>()
            .join("/");
        let thetas = g
            .thetas()
            .iter()
            .map(|&t| fnum(t as f64, 3))
            .collect::<Vec<_>>()
            .join("/");
        // per-tier fleet, e.g. "2xV100+1xH100"; "-" for homogeneous plans
        let fleet = if g.tier_fleet.is_empty() {
            "-".to_string()
        } else {
            g.tier_fleet
                .iter()
                .map(|t| format!("{}x{}", t.replicas, t.gpu.name()))
                .collect::<Vec<_>>()
                .join("+")
        };
        table.row(vec![
            g.id.to_string(),
            ks,
            epss,
            thetas,
            g.max_batch.to_string(),
            g.replicas.to_string(),
            fleet,
            fnum(g.accuracy, 4),
            fnum(g.relative_cost, 3),
            fnum(g.dollar_per_req * 1000.0, 5),
            fnum(g.sustainable_rps, 0),
        ]);
    }
    println!("{}", table.render());
    plan.save(out)?;
    println!("wrote {out}");
    Ok(())
}

/// Parse `--frontend reactor|threads` (default: the event-driven
/// reactor; `threads` keeps the old thread-per-connection path for
/// differential testing).
fn frontend_of(args: &Args) -> Result<abc_serve::server::Frontend> {
    let s = args.str_or("frontend", "reactor");
    abc_serve::server::Frontend::parse(s)
        .ok_or_else(|| anyhow::anyhow!("unknown --frontend {s:?} (reactor|threads)"))
}

fn cmd_serve(args: &Args) -> Result<()> {
    let suite = args.req_str("suite")?;
    let port = args.u16_or("port", 7878)?;
    let frontend = frontend_of(args)?;
    let shards = args.usize_or("shards", 0)?;
    let rule = rule_of(args)?;
    let epsilon = args.f64_or("epsilon", 0.03)?;
    let max_batch = args.usize_or("max-batch", 32)?;
    let max_wait_ms = args.u64_or("max-wait-ms", 2)?;
    let replicas = args.usize_or("replicas", 1)?;
    let max_queue = args.usize_or("max-queue", 256)?;
    let autoscale = args.flag("autoscale");
    let tiered = args.flag("tiered");
    let min_replicas = args.usize_or("min-replicas", 1)?;
    let max_replicas = args.usize_or("max-replicas", replicas.max(min_replicas))?;
    let warmup = Duration::from_millis(args.u64_or("warmup-ms", 0)?);
    anyhow::ensure!(replicas > 0, "--replicas must be > 0");
    anyhow::ensure!(max_queue > 0, "--max-queue must be > 0");
    anyhow::ensure!(
        !(tiered && args.get("plan").is_some()),
        "--tiered serves the suite's calibrated cascade per tier; gear \
         plans are monolithic-only (drop --plan)"
    );
    if autoscale {
        if tiered {
            anyhow::ensure!(
                args.get("tier-rps").is_some(),
                "--tiered --autoscale needs --tier-rps R1,R2,...: each \
                 tier's per-replica capacity (rows/s of that STAGE), \
                 e.g. measured with `repro loadgen`"
            );
        }
        anyhow::ensure!(min_replicas >= 1, "--min-replicas must be >= 1");
        anyhow::ensure!(
            min_replicas <= max_replicas,
            "--min-replicas {min_replicas} > --max-replicas {max_replicas}"
        );
    }
    // when a one-gear plan is synthesized it is already grounded at
    // measured capacity: the --top-rps ladder rescale must not reapply
    let mut synthesized = false;
    let plan = match args.get("plan") {
        Some(path) => Some(GearPlan::load(path)?),
        // --autoscale without a plan: synthesize a one-gear plan from
        // the MEASURED top-tier capacity so the scale decider has a
        // grounded per-replica quote (the ladder never shifts -- one
        // gear -- but elasticity adapts to load).  The gear is quoted
        // at --replicas machines so serving starts at the requested
        // fleet, not the floor.
        None if autoscale && !tiered => {
            let top_rps = args.f64_or("top-rps", 0.0)?;
            anyhow::ensure!(
                top_rps > 0.0,
                "--autoscale without --plan synthesizes a one-gear plan \
                 from measured capacity: pass --top-rps R, this suite's \
                 per-replica rows/s (e.g. from `repro loadgen --replicas 1`)"
            );
            println!(
                "no --plan: synthesized a one-gear plan at the measured \
                 {top_rps:.0} rows/s per replica"
            );
            synthesized = true;
            Some(search::one_gear_plan(
                top_rps * replicas as f64,
                replicas,
                max_batch,
                epsilon,
                args.f64_or("top-acc", 0.95)?,
            )?)
        }
        None => None,
    };
    let manifest = Manifest::load(artifacts_dir(args))?;
    let engine = Arc::new(Engine::cpu()?);
    let rt = Arc::new(SuiteRuntime::load(engine, &manifest, suite, false)?);
    let val = rt.dataset(&manifest, "val")?;
    let cal = calib::calibrate(&rt.tiers, rule, &val, 100, epsilon)?;
    let cascade = Arc::new(Cascade::new(rt.tiers.clone(), cal.policy));
    if tiered {
        return serve_tiered(args, suite, port, cascade);
    }
    // A plan's thetas were calibrated on the PLAN's data (synthetic vote
    // fractions for `repro plan`), not this suite's score scale.
    // Re-ground every gear's thetas -- tier 1 AND any interior tiers the
    // suite actually has -- on this cascade's per-tier calibration
    // points at each tier's stored epsilon, so the Appendix-B failure
    // bound the threshold encodes actually holds for this deployment.
    // The gear's k/replicas stay advisory: serving uses the suite's
    // tiers and the --replicas flags.
    let plan = match plan {
        Some(mut plan) => {
            // one calibration-point set per non-final suite tier,
            // collected lazily (interior tiers only matter when some
            // gear plans that deep)
            let mut tier_points: Vec<Option<Vec<calib::threshold::CalPoint>>> =
                vec![None; rt.tiers.len().saturating_sub(1)];
            type CalPoints = Vec<calib::threshold::CalPoint>;
            let mut points_for = |tier: usize| -> Result<CalPoints> {
                if tier_points[tier].is_none() {
                    tier_points[tier] =
                        Some(calib::collect_points(&rt.tiers[tier], rule, &val, 100)?);
                }
                Ok(tier_points[tier].clone().expect("just filled"))
            };
            for g in &mut plan.gears {
                let points = points_for(0)?;
                let est = calib::threshold::estimate_theta(&points, g.epsilon);
                g.theta = est.theta;
                for (i, m) in g.mid.iter_mut().enumerate() {
                    let tier = i + 1;
                    if tier + 1 >= rt.tiers.len() {
                        break; // deeper than this suite's ladder: advisory
                    }
                    let points = points_for(tier)?;
                    m.theta =
                        calib::threshold::estimate_theta(&points, m.epsilon).theta;
                }
            }
            println!(
                "gear thetas re-calibrated on {suite}/val (rule {}); \
                 plan k/replicas columns are advisory here",
                rule.name()
            );
            // The controller's utilisation watermarks divide by
            // sustainable_rps, which the plan priced with ITS deployment
            // model.  --top-rps (this deployment's measured top-gear
            // capacity, e.g. from `repro loadgen`) rescales the whole
            // ladder; without it the planned absolute throughputs stand
            // and only the queue-pressure/SLO triggers are model-free.
            // a synthesized plan is already quoted at the measured
            // per-replica rate; rescaling it against --top-rps again
            // would divide capacity by the start-fleet size
            let top_rps = args.f64_or("top-rps", 0.0)?;
            if top_rps > 0.0 && !synthesized {
                let f = top_rps / plan.top().sustainable_rps;
                for g in &mut plan.gears {
                    g.sustainable_rps *= f;
                }
                println!(
                    "gear ladder rescaled to measured top capacity {top_rps:.0} rps"
                );
            } else if !synthesized {
                println!(
                    "warning: no --top-rps given; utilisation watermarks use the \
                     plan's modelled throughputs, which may not match this \
                     hardware (queue-pressure shifting still applies)"
                );
            }
            Some(plan)
        }
        None => None,
    };
    let metrics = Metrics::new();
    events_file_sink(args, &metrics, "control")?;
    let tracer = trace_config(args)?;
    let weights = class_triple(args, "class-weights", |w| w > 0.0, "> 0")?;
    let slo_cfg = slo_config(args)?;
    let slo_boost = args.f64_or("slo-boost", 1.0)?;
    anyhow::ensure!(slo_boost >= 1.0, "--slo-boost must be >= 1.0");
    let pool_cfg = |max_batch: usize, replicas: usize| PoolConfig {
        replicas,
        max_queue,
        batcher: BatcherConfig {
            max_batch,
            max_wait: Duration::from_millis(max_wait_ms),
        },
        class_weights: weights,
        ..PoolConfig::default()
    };
    // keep the control loop alive for the lifetime of serve():
    // dropping it stops the (single) decider thread
    let _control: Option<ControlLoop>;
    let pool = match plan {
        Some(plan) => {
            let top = plan.top();
            // elastic pools start at the top gear's planned allocation
            // (clamped to the fleet bounds); fixed pools at --replicas
            let start_replicas = if autoscale {
                top.replicas.clamp(min_replicas, max_replicas)
            } else {
                replicas
            };
            let handle = GearHandle::new(top.config());
            let pool = Arc::new(ReplicaPool::spawn_with_obs(
                cascade,
                pool_cfg(top.max_batch, start_replicas),
                Arc::clone(&metrics),
                Some(Arc::clone(&handle)),
                ObsHook::monolithic(tracer.clone()),
            ));
            println!(
                "gear plan: {} gears, top sustains {:.0} rps at accuracy {:.4}",
                plan.len(),
                top.sustainable_rps,
                top.accuracy
            );
            let mut cfg = if autoscale {
                let budget = args.f64_or("max-dollars-hour", 0.0)?;
                println!(
                    "autoscale: elastic fleet {min_replicas}..{max_replicas} \
                     replicas (starting at {start_replicas}, warm-up \
                     {warmup:?}{})",
                    if budget > 0.0 {
                        format!(", budget ${budget:.2}/h")
                    } else {
                        String::new()
                    }
                );
                ControlConfig::autoscaled(
                    plan,
                    ControllerConfig::default(),
                    ScaleConfig {
                        min_replicas,
                        max_replicas,
                        warmup,
                        ..ScaleConfig::default()
                    },
                    budget,
                )
            } else {
                ControlConfig::gear_plan(plan, ControllerConfig::default())
            };
            cfg.slo_boost = slo_boost;
            _control = Some(ControlLoop::spawn(
                Arc::clone(&pool) as Arc<dyn ControlTarget>,
                cfg,
            ));
            pool
        }
        None => {
            _control = None;
            Arc::new(ReplicaPool::spawn_with_obs(
                cascade,
                pool_cfg(max_batch, replicas),
                Arc::clone(&metrics),
                None,
                ObsHook::monolithic(tracer.clone()),
            ))
        }
    };
    if let Some(sc) = slo_cfg {
        let goal = sc.goal;
        pool.attach_slo(SloObservatory::new(sc, &metrics));
        println!(
            "slo observatory: per-class books on (goal {goal:.2}{})",
            if weights.is_some() {
                ", weighted-fair admission"
            } else {
                ""
            }
        );
    }
    println!(
        "serving {suite} on 127.0.0.1:{port} (line-JSON protocol, \
         {} frontend, {} replicas, max-queue {max_queue}/replica)",
        frontend.name(),
        pool.n_replicas()
    );
    abc_serve::server::serve_sharded(pool, port, frontend, shards)
}

/// `serve --tiered`: one ReplicaPool per cascade level with deferral
/// routed between pools, each level on its own GPU class (the §5.2.2
/// placement).  `--tier-gpus v100,a6000,h100` picks classes (default:
/// `Gpu::spread` over the rental ladder), `--tier-replicas N1,N2,...`
/// sets per-tier starting fleets (default: `--replicas` everywhere),
/// and `--autoscale` engages the unified control loop: every tier is
/// sized independently against its own deferral-driven arrival rate
/// (needs `--tier-rps`, each tier's measured per-replica stage
/// capacity; `--max-dollars-hour` caps the fleet's burn rate) AND
/// shifts per-tier gears -- theta rungs derived from the suite's
/// calibrated thresholds, walked by each tier's downstream observer.
/// `--shadow-sample N` attaches the drift observatory (shadow 1-in-N
/// early exits through the next tier, off the critical path), and
/// `--recalibrate` arms the control loop's drift decider: a tier whose
/// alarm latches Breach gets its theta re-grounded from the live
/// windowed estimate.  `--slo-targets`/`--slo-goal` attach the SLO
/// observatory (fleet-level per-class books), `--class-weights` turns
/// on weighted-fair admission in every tier's pool, and `--slo-boost`
/// (with a budget) raises the burn cap while premium is breached.
fn serve_tiered(
    args: &Args,
    suite: &str,
    port: u16,
    cascade: Arc<Cascade>,
) -> Result<()> {
    let n_levels = cascade.n_levels();
    let max_batch = args.usize_or("max-batch", 32)?;
    let max_wait_ms = args.u64_or("max-wait-ms", 2)?;
    let max_queue = args.usize_or("max-queue", 256)?;
    let replicas = args.usize_or("replicas", 1)?;
    let autoscale = args.flag("autoscale");
    let min_replicas = args.usize_or("min-replicas", 1)?;
    let warmup = Duration::from_millis(args.u64_or("warmup-ms", 0)?);
    let shadow_sample = args.u64_or("shadow-sample", 0)?;
    let drift_window = args.usize_or("drift-window", 512)?;
    let drift_epsilon = args.f64_or("drift-epsilon", 0.05)?;
    let recalibrate = args.flag("recalibrate");
    anyhow::ensure!(
        !recalibrate || autoscale,
        "--recalibrate needs --autoscale (the control loop applies regrounds)"
    );
    anyhow::ensure!(
        !recalibrate || shadow_sample > 0,
        "--recalibrate needs --shadow-sample N (the drift observatory \
         supplies the live estimates it re-grounds from)"
    );
    anyhow::ensure!(
        drift_epsilon > 0.0 && drift_epsilon < 1.0,
        "--drift-epsilon must be in (0, 1)"
    );

    let gpus = {
        let listed = gpu_list(args, "tier-gpus")?;
        if listed.is_empty() {
            Gpu::spread(n_levels)
        } else {
            anyhow::ensure!(
                listed.len() == n_levels,
                "--tier-gpus lists {} classes but {suite} has {n_levels} tiers",
                listed.len()
            );
            listed
        }
    };
    let start_replicas = {
        let listed = args.usize_list_or("tier-replicas", &[])?;
        if listed.is_empty() {
            vec![replicas; n_levels]
        } else {
            anyhow::ensure!(
                listed.len() == n_levels,
                "--tier-replicas lists {} fleets but {suite} has {n_levels} tiers",
                listed.len()
            );
            listed
        }
    };
    // the ceiling defaults to covering every explicitly requested start
    // fleet -- otherwise `--tier-replicas 4,2,1 --autoscale` without an
    // explicit --max-replicas would silently clamp to the 1-replica
    // default and pin every tier
    let default_max = start_replicas
        .iter()
        .copied()
        .max()
        .unwrap_or(replicas)
        .max(min_replicas);
    let max_replicas = args.usize_or("max-replicas", default_max)?;
    anyhow::ensure!(
        start_replicas.iter().all(|&n| n.max(1) <= max_replicas),
        "--tier-replicas {start_replicas:?} exceeds --max-replicas \
         {max_replicas}"
    );

    let specs: Vec<TierSpec> = gpus
        .iter()
        .zip(&start_replicas)
        .map(|(&gpu, &n)| {
            let n = n.max(1);
            TierSpec {
                gpu,
                replicas: if autoscale {
                    n.clamp(min_replicas, max_replicas)
                } else {
                    n
                },
                min_replicas: if autoscale { min_replicas } else { n },
                max_replicas: if autoscale { max_replicas } else { n },
                max_queue,
                theta: None, // the cascade's policy is already calibrated
            }
        })
        .collect();

    // the calibrated per-tier thresholds seed each tier's theta ladder
    // (None for the final tier: it always exits)
    let tier_thetas: Vec<Option<f32>> = (0..n_levels)
        .map(|i| cascade.policy().rule(i).map(|r| r.theta))
        .collect();

    let metrics = Metrics::new();
    events_file_sink(args, &metrics, "control")?;
    let tracer = trace_config(args)?;
    let weights = class_triple(args, "class-weights", |w| w > 0.0, "> 0")?;
    let slo_cfg = slo_config(args)?;
    let slo_boost = args.f64_or("slo-boost", 1.0)?;
    anyhow::ensure!(slo_boost >= 1.0, "--slo-boost must be >= 1.0");
    let drift_cfg = (shadow_sample > 0).then(|| DriftConfig {
        sample_every: shadow_sample,
        window: drift_window,
        epsilon: drift_epsilon,
        ..DriftConfig::default()
    });
    let fleet = Arc::new(TieredFleet::spawn_with_slo(
        cascade as Arc<dyn StageClassifier>,
        TieredFleetConfig {
            tiers: specs,
            batcher: BatcherConfig {
                max_batch,
                max_wait: Duration::from_millis(max_wait_ms),
            },
            class_weights: weights,
        },
        Arc::clone(&metrics),
        tracer,
        drift_cfg,
        slo_cfg,
    )?);
    if let Some(slo) = fleet.slo() {
        println!(
            "slo observatory: per-class books on (goal {:.2}{})",
            slo.config().goal,
            if weights.is_some() {
                ", weighted-fair admission"
            } else {
                ""
            }
        );
    }
    if let Some(monitor) = fleet.drift() {
        // the specs carry theta: None (the cascade policy is already
        // calibrated), so ground the theta_cal reference gauges from
        // the policy's own thresholds here
        for (i, t) in tier_thetas.iter().enumerate() {
            monitor.set_theta_cal(i, *t);
        }
        println!(
            "drift observatory: shadowing 1-in-{shadow_sample} early exits \
             (window {drift_window}, epsilon {drift_epsilon})"
        );
    }

    // keep the control loop alive for the lifetime of serve(): ONE
    // thread decides per-tier scaling AND per-tier gear shifting
    let _control: Option<ControlLoop> = if autoscale {
        let tier_rps = args.f64_list_or("tier-rps", &[])?;
        anyhow::ensure!(
            tier_rps.len() == n_levels,
            "--tier-rps lists {} capacities but {suite} has {n_levels} tiers",
            tier_rps.len()
        );
        let budget = args.f64_or("max-dollars-hour", 0.0)?;
        let tiers: Vec<TierControl> = tier_rps
            .iter()
            .enumerate()
            .map(|(i, &rps)| {
                // theta rungs: the calibrated policy first, then
                // progressively laxer fractions of its threshold --
                // each rung exits more requests at this tier instead of
                // deferring them to the pricier tier below
                let rungs = match tier_thetas[i] {
                    Some(t) if i + 1 < n_levels => vec![
                        TierRung { theta: None, max_batch },
                        TierRung { theta: Some(t * 0.75), max_batch },
                        TierRung { theta: Some(t * 0.5), max_batch },
                    ],
                    _ => Vec::new(),
                };
                TierControl {
                    per_replica_rps: rps,
                    scale: Some(ScaleConfig {
                        min_replicas,
                        max_replicas,
                        warmup,
                        ..ScaleConfig::default()
                    }),
                    rungs,
                }
            })
            .collect();
        println!(
            "tiered control plane: {min_replicas}..{max_replicas} replicas \
             per tier, per-tier gear shifting (warm-up {warmup:?}{}{})",
            if budget > 0.0 {
                format!(", budget ${budget:.2}/h")
            } else {
                String::new()
            },
            if recalibrate { ", drift recalibration armed" } else { "" }
        );
        let mut control_cfg =
            ControlConfig::tiered(tiers, ControllerConfig::default(), budget);
        control_cfg.recalibrate = recalibrate;
        control_cfg.slo_boost = slo_boost;
        Some(ControlLoop::spawn(
            Arc::clone(&fleet) as Arc<dyn ControlTarget>,
            control_cfg,
        ))
    } else {
        None
    };

    let placement = fleet
        .tiers()
        .iter()
        .zip(&start_replicas)
        .map(|(t, &n)| format!("{}x{}", n, t.gpu().name()))
        .collect::<Vec<_>>()
        .join(" + ");
    fleet.refresh_gauges();
    println!(
        "serving {suite} TIERED on 127.0.0.1:{port} ({placement}, \
         max-queue {max_queue}/replica, ${:.2}/h at spawn)",
        fleet.dollars_per_hour()
    );
    abc_serve::server::serve_sharded(
        fleet,
        port,
        frontend_of(args)?,
        args.usize_or("shards", 0)?,
    )
}

/// Query a running server's stats snapshot; with `--events`, also dump
/// the controller event log as JSONL (gear shifts + scale actions);
/// with `--traces`, the sampled trace spans grouped per request; with
/// `--drift`, the drift observatory's per-tier statuses (live
/// agreement, failure rate vs epsilon, theta_live vs theta_cal, alarm);
/// with `--slo`, the per-class SLO attainment/burn-alarm table; with
/// `--prom`, print the Prometheus text exposition INSTEAD of the
/// pretty snapshot (scrape-friendly: nothing else on stdout).
fn cmd_stats(args: &Args) -> Result<()> {
    let port = args.u16_or("port", 7878)?;
    let mut client = abc_serve::server::Client::connect(port)
        .with_context(|| format!("connecting to 127.0.0.1:{port}"))?;
    if args.flag("prom") {
        print!("{}", client.prom()?);
    } else {
        let v = client.stats()?;
        println!("{}", v.get("stats").to_pretty());
    }
    if args.flag("events") {
        let reply = client.events()?;
        for e in reply.get("events").as_arr().unwrap_or(&[]) {
            println!("{e}");
        }
        let dropped = reply.get("dropped").as_u64().unwrap_or(0);
        if dropped > 0 {
            eprintln!("({dropped} older events evicted from the ring)");
        }
    }
    if args.flag("traces") {
        let reply = client.traces()?;
        for t in reply.get("traces").as_arr().unwrap_or(&[]) {
            println!("{t}");
        }
        let sample = reply.get("sample_every").as_u64().unwrap_or(0);
        if sample == 0 {
            eprintln!("(server is not tracing: start it with --trace-sample N)");
        }
        let dropped = reply.get("dropped").as_u64().unwrap_or(0);
        if dropped > 0 {
            eprintln!("({dropped} older spans evicted from the ring)");
        }
    }
    if args.flag("drift") {
        let reply = client.drift()?;
        let drift = reply.get("drift");
        println!("{}", drift.to_pretty());
        if drift.get("sample_every").as_u64().unwrap_or(0) == 0 {
            eprintln!(
                "(server has no drift observatory: start it tiered with \
                 --shadow-sample N)"
            );
        }
    }
    if args.flag("slo") {
        let reply = client.slo()?;
        let slo = reply.get("slo");
        println!("{}", slo.to_pretty());
        if slo.get("classes").as_arr().map(|c| c.len()).unwrap_or(0) == 0 {
            eprintln!(
                "(server has no SLO observatory: start it with \
                 --slo-targets P,S,B)"
            );
        }
    }
    Ok(())
}

/// Open-loop load generation against a synthetic replica pool -- the
/// zero-artifact path for exploring throughput/latency/shedding.
fn cmd_loadgen(args: &Args) -> Result<()> {
    let rate = args.f64_or("rate", 500.0)?;
    let requests = args.usize_or("requests", 2000)?;
    let replicas = args.usize_or("replicas", 1)?;
    let max_queue = args.usize_or("max-queue", 64)?;
    let workers = args.usize_or("workers", 128)?;
    let dim = args.usize_or("dim", 16)?;
    let levels = args.usize_or("levels", 3)?;
    let base_us = args.u64_or("base-us", 200)?;
    let row_us = args.u64_or("row-us", 100)?;
    let max_batch = args.usize_or("max-batch", 32)?;
    let max_wait_ms = args.u64_or("max-wait-ms", 1)?;
    let burst = args.usize_or("burst", 16)?;
    let seed = args.u64_or("seed", 42)?;
    let class_mix = class_triple(args, "class-mix", |p| p >= 0.0, ">= 0")?;
    anyhow::ensure!(rate > 0.0, "--rate must be > 0");
    anyhow::ensure!(requests > 0, "--requests must be > 0");
    if let Some(m) = class_mix {
        anyhow::ensure!(
            m.iter().sum::<f64>() > 0.0,
            "--class-mix must not be all zeros"
        );
    }
    let arrival = match args.str_or("arrival", "poisson") {
        "poisson" => Arrival::Poisson { rate },
        "constant" | "uniform" => Arrival::Uniform { rate },
        "bursty" => Arrival::Bursty { rate: rate / burst as f64, burst },
        "onoff" => Arrival::OnOff { rate: rate * 2.0, on_s: 0.5, off_s: 0.5 },
        other => bail!("bad --arrival {other:?} (poisson|constant|bursty|onoff)"),
    };

    let classifier = SyntheticClassifier::new(
        dim,
        levels,
        Duration::from_micros(base_us),
        Duration::from_micros(row_us),
    );
    let capacity = replicas as f64 * classifier.capacity_rps(max_batch);
    let pool = Arc::new(ReplicaPool::spawn(
        Arc::new(classifier),
        PoolConfig {
            replicas,
            max_queue,
            batcher: BatcherConfig {
                max_batch,
                max_wait: Duration::from_millis(max_wait_ms),
            },
            ..PoolConfig::default()
        },
        Metrics::new(),
    ));
    let trace = Arc::new(Trace::synth(arrival, requests, dim, seed));
    println!(
        "loadgen: {requests} requests at ~{rate:.0} rps ({}), {replicas} \
         replica(s) x max-queue {max_queue}, est. pool capacity {capacity:.0} rows/s",
        args.str_or("arrival", "poisson"),
    );
    let report = LoadGen { workers, class_mix }
        .run(&pool, trace, pool.metrics())
        .map_err(|e| anyhow::anyhow!(e))?;

    let mut table = Table::new("loadgen report", LoadReport::header());
    table.row(report.row_cells());
    println!("{}", table.render());
    let mut smetrics = Table::new("serving metrics", &["metric", "value"]);
    for (name, value) in pool.metrics().snapshot() {
        smetrics.row(vec![name, value]);
    }
    println!("{}", smetrics.render());
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let id = args
        .positional()
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let out = args.str_or("out", "artifacts/results");
    let ctx = ExpContext::new(artifacts_dir(args), out, args.flag("quick"))?;
    experiments::run(id, &ctx)
}

fn cmd_selftest(args: &Args) -> Result<()> {
    let manifest = Manifest::load(artifacts_dir(args))?;
    let engine = Arc::new(Engine::cpu()?);
    println!("platform: {} devices: {}", engine.platform(), engine.device_count());
    for suite_name in manifest.suite_names() {
        let rt = SuiteRuntime::load(Arc::clone(&engine), &manifest, suite_name, true)?;
        let test = rt.dataset(&manifest, "test")?;
        let n = 8.min(test.n);
        for tier in &rt.tiers {
            let outs = tier.run(&test.x[..n * test.dim], n)?;
            anyhow::ensure!(outs.len() == n, "tier output size");
        }
        for single in &rt.singles {
            let outs = single.run_single(&test.x[..n * test.dim], n)?;
            anyhow::ensure!(outs.len() == n, "single output size");
        }
        println!("{suite_name}: {} tiers OK", rt.tiers.len());
    }
    println!("selftest OK");
    Ok(())
}
