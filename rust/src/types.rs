//! Core domain types shared across the coordinator, runtime, simulators
//! and experiments.

/// Identifies a cascade tier (1-based, matching the paper's Tier 1..n).
pub type TierId = usize;

/// Shared capacity-controller watermarks.  Every hysteretic controller
/// in the stack (gear controller downshift/upshift, replica autoscaler
/// scale-up/scale-down, planner design utilisation) acts above the HIGH
/// mark and relaxes only below the LOW mark; defining them once keeps
/// the hysteresis band identical everywhere so coupled controllers
/// cannot fight across a gap in their bands.
pub const UTIL_HIGH_WATERMARK: f64 = 0.85;

/// See [`UTIL_HIGH_WATERMARK`]; the relax-below mark of the band.
pub const UTIL_LOW_WATERMARK: f64 = 0.60;

/// A class label.
pub type Label = u32;

/// SLO class a request belongs to (the tenant taxonomy of the SLO
/// observatory).  Three fixed classes keep the per-class bookkeeping
/// arrays `[_; Class::COUNT]` -- no allocation, no string interning on
/// the hot path -- while covering the spectrum that matters for
/// weighted-fair admission: `Premium` (tight SLO, protected share),
/// `Standard` (the default for untagged traffic, so the single-class
/// path is byte-identical to the historical untagged one) and `Batch`
/// (best-effort; first to shed under quota pressure).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Class {
    Premium,
    #[default]
    Standard,
    Batch,
}

impl Class {
    /// Number of classes (sizes per-class bookkeeping arrays).
    pub const COUNT: usize = 3;

    /// All classes in index order ([`Class::index`] positions).
    pub const ALL: [Class; Class::COUNT] =
        [Class::Premium, Class::Standard, Class::Batch];

    /// Stable array index (`Class::ALL[c.index()] == c`).
    pub fn index(&self) -> usize {
        match self {
            Class::Premium => 0,
            Class::Standard => 1,
            Class::Batch => 2,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Class::Premium => "premium",
            Class::Standard => "standard",
            Class::Batch => "batch",
        }
    }

    pub fn parse(s: &str) -> Option<Class> {
        match s {
            "premium" => Some(Class::Premium),
            "standard" => Some(Class::Standard),
            "batch" => Some(Class::Batch),
            _ => None,
        }
    }
}

/// One inference request flowing through the serving stack.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Feature vector (the suite's `dim` floats).
    pub features: Vec<f32>,
    /// Arrival time in seconds since run start (workload-generator time).
    pub arrival_s: f64,
    /// SLO class; untagged wire requests default to [`Class::Standard`].
    pub class: Class,
}

/// The deferral decision a tier made for one sample.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// Agreement reached: answer locally with the tier's prediction.
    Accept,
    /// Disagreement: defer to the next tier.
    Defer,
}

/// Per-sample output of one tier's ensemble (what the AOT artifact
/// returns, see python/compile/model.py tier_forward).
#[derive(Debug, Clone, Copy)]
pub struct TierOutput {
    pub majority: Label,
    /// Fraction of members voting for the majority label (Eq. 3 score).
    pub vote_frac: f32,
    /// Mean softmax score of the majority label across members (Eq. 4).
    pub mean_score: f32,
}

/// Final cascade verdict for one sample.
#[derive(Debug, Clone)]
pub struct Verdict {
    pub request_id: u64,
    pub prediction: Label,
    /// Tier that produced the answer (1-based).
    pub exit_tier: TierId,
    /// Scores observed at each visited tier, in order.
    pub tier_scores: Vec<f32>,
    /// End-to-end latency in seconds (serving paths; 0 for offline eval).
    pub latency_s: f64,
}

/// Which agreement score drives deferral (paper Eq. 3 vs Eq. 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RuleKind {
    /// `vote(x; H^k) <= theta` defers (black-box friendly).
    Vote,
    /// `s(x; H^k) <= theta` defers (needs prediction scores).
    MeanScore,
}

impl RuleKind {
    pub fn score_of(&self, out: &TierOutput) -> f32 {
        match self {
            RuleKind::Vote => out.vote_frac,
            RuleKind::MeanScore => out.mean_score,
        }
    }

    pub fn parse(s: &str) -> Option<RuleKind> {
        match s {
            "vote" => Some(RuleKind::Vote),
            "score" | "mean_score" => Some(RuleKind::MeanScore),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RuleKind::Vote => "vote",
            RuleKind::MeanScore => "score",
        }
    }
}

/// Execution model for ensemble cost accounting (paper Eq. 1):
/// `C(H^k) = c0 * k^(1-rho)`; rho = 1 fully parallel, rho = 0 sequential.
#[derive(Debug, Clone, Copy)]
pub struct Parallelism(pub f64);

impl Parallelism {
    pub const SEQUENTIAL: Parallelism = Parallelism(0.0);
    pub const FULL: Parallelism = Parallelism(1.0);

    /// Cost multiplier for a k-member ensemble relative to one member.
    pub fn ensemble_factor(&self, k: usize) -> f64 {
        (k as f64).powf(1.0 - self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_kind_selects_score() {
        let out = TierOutput { majority: 3, vote_frac: 0.67, mean_score: 0.9 };
        assert_eq!(RuleKind::Vote.score_of(&out), 0.67);
        assert_eq!(RuleKind::MeanScore.score_of(&out), 0.9);
    }

    #[test]
    fn rule_kind_parse() {
        assert_eq!(RuleKind::parse("vote"), Some(RuleKind::Vote));
        assert_eq!(RuleKind::parse("score"), Some(RuleKind::MeanScore));
        assert_eq!(RuleKind::parse("zz"), None);
        assert_eq!(RuleKind::Vote.name(), "vote");
    }

    #[test]
    fn class_roundtrips_and_indexes() {
        for (i, c) in Class::ALL.iter().enumerate() {
            assert_eq!(c.index(), i);
            assert_eq!(Class::parse(c.name()), Some(*c));
        }
        assert_eq!(Class::parse("zz"), None);
        assert_eq!(Class::default(), Class::Standard);
    }

    #[test]
    fn parallelism_ensemble_factor() {
        assert!((Parallelism::FULL.ensemble_factor(5) - 1.0).abs() < 1e-12);
        assert!((Parallelism::SEQUENTIAL.ensemble_factor(5) - 5.0).abs() < 1e-12);
        assert!((Parallelism(0.5).ensemble_factor(4) - 2.0).abs() < 1e-12);
    }
}
