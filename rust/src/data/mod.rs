//! Datasets (ABDS format) and workload generation.

pub mod format;
pub mod workload;
