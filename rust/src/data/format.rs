//! ABDS binary dataset format reader/writer.
//!
//! Mirrors python/compile/datagen.py:
//!
//! ```text
//! magic   b"ABDS"
//! version u32 = 1
//! n       u32
//! dim     u32
//! classes u32
//! flags   u32   bit0: has difficulty field
//! x       f32[n*dim] row-major
//! y       u32[n]
//! diff    f32[n]     iff flags&1
//! ```
//!
//! All integers little-endian.

use std::io::{Read, Write};
use std::path::Path;

pub const MAGIC: &[u8; 4] = b"ABDS";
pub const VERSION: u32 = 1;
pub const FLAG_DIFFICULTY: u32 = 1;

#[derive(Debug)]
pub enum FormatError {
    Io(std::io::Error),
    BadMagic([u8; 4]),
    BadVersion(u32),
    Truncated { what: &'static str, wanted: usize, got: usize },
    LabelRange { label: u32, classes: u32 },
}

impl std::fmt::Display for FormatError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FormatError::Io(e) => write!(f, "io error: {e}"),
            FormatError::BadMagic(m) => write!(f, "bad magic {m:?} (expected \"ABDS\")"),
            FormatError::BadVersion(v) => write!(f, "unsupported ABDS version {v}"),
            FormatError::Truncated { what, wanted, got } => {
                write!(f, "truncated file: wanted {wanted} bytes for {what}, got {got}")
            }
            FormatError::LabelRange { label, classes } => {
                write!(f, "label {label} out of range for {classes} classes")
            }
        }
    }
}

impl std::error::Error for FormatError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FormatError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for FormatError {
    fn from(e: std::io::Error) -> FormatError {
        FormatError::Io(e)
    }
}

/// An in-memory dataset split.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub x: Vec<f32>, // row-major [n, dim]
    pub y: Vec<u32>,
    pub difficulty: Option<Vec<f32>>,
    pub n: usize,
    pub dim: usize,
    pub classes: usize,
}

impl Dataset {
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.dim..(i + 1) * self.dim]
    }

    /// A shallow slice view materialised as a new Dataset (used to carve
    /// out calibration sets).
    pub fn slice(&self, start: usize, end: usize) -> Dataset {
        let end = end.min(self.n);
        let start = start.min(end);
        Dataset {
            x: self.x[start * self.dim..end * self.dim].to_vec(),
            y: self.y[start..end].to_vec(),
            difficulty: self
                .difficulty
                .as_ref()
                .map(|d| d[start..end].to_vec()),
            n: end - start,
            dim: self.dim,
            classes: self.classes,
        }
    }
}

fn read_exact_vec<R: Read>(
    r: &mut R,
    bytes: usize,
    what: &'static str,
) -> Result<Vec<u8>, FormatError> {
    let mut buf = vec![0u8; bytes];
    let mut read = 0;
    while read < bytes {
        let n = r.read(&mut buf[read..])?;
        if n == 0 {
            return Err(FormatError::Truncated { what, wanted: bytes, got: read });
        }
        read += n;
    }
    Ok(buf)
}

fn u32_le(b: &[u8]) -> u32 {
    u32::from_le_bytes([b[0], b[1], b[2], b[3]])
}

fn bytes_to_f32(b: &[u8]) -> Vec<f32> {
    b.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

fn bytes_to_u32(b: &[u8]) -> Vec<u32> {
    b.chunks_exact(4).map(u32_le).collect()
}

/// Read an ABDS file from any reader.
pub fn read_from<R: Read>(r: &mut R) -> Result<Dataset, FormatError> {
    let head = read_exact_vec(r, 24, "header")?;
    let mut magic = [0u8; 4];
    magic.copy_from_slice(&head[..4]);
    if &magic != MAGIC {
        return Err(FormatError::BadMagic(magic));
    }
    let version = u32_le(&head[4..8]);
    if version != VERSION {
        return Err(FormatError::BadVersion(version));
    }
    let n = u32_le(&head[8..12]) as usize;
    let dim = u32_le(&head[12..16]) as usize;
    let classes = u32_le(&head[16..20]);
    let flags = u32_le(&head[20..24]);

    let x = bytes_to_f32(&read_exact_vec(r, 4 * n * dim, "features")?);
    let y = bytes_to_u32(&read_exact_vec(r, 4 * n, "labels")?);
    for &label in &y {
        if label >= classes.max(1) {
            return Err(FormatError::LabelRange { label, classes });
        }
    }
    let difficulty = if flags & FLAG_DIFFICULTY != 0 {
        Some(bytes_to_f32(&read_exact_vec(r, 4 * n, "difficulty")?))
    } else {
        None
    };
    Ok(Dataset { x, y, difficulty, n, dim, classes: classes as usize })
}

pub fn read_file(path: impl AsRef<Path>) -> Result<Dataset, FormatError> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    read_from(&mut f)
}

/// Write an ABDS file (used by tests and the trace tooling).
pub fn write_file(path: impl AsRef<Path>, ds: &Dataset) -> Result<(), FormatError> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(ds.n as u32).to_le_bytes())?;
    f.write_all(&(ds.dim as u32).to_le_bytes())?;
    f.write_all(&(ds.classes as u32).to_le_bytes())?;
    let flags = if ds.difficulty.is_some() { FLAG_DIFFICULTY } else { 0 };
    f.write_all(&flags.to_le_bytes())?;
    for v in &ds.x {
        f.write_all(&v.to_le_bytes())?;
    }
    for v in &ds.y {
        f.write_all(&v.to_le_bytes())?;
    }
    if let Some(d) = &ds.difficulty {
        for v in d {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_ds() -> Dataset {
        Dataset {
            x: vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0],
            y: vec![0, 2, 1],
            difficulty: Some(vec![0.1, 0.9, 0.5]),
            n: 3,
            dim: 2,
            classes: 3,
        }
    }

    #[test]
    fn roundtrip_via_tempfile() {
        let dir = std::env::temp_dir().join(format!("abds-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.abds");
        let ds = sample_ds();
        write_file(&p, &ds).unwrap();
        let got = read_file(&p).unwrap();
        assert_eq!(got.n, 3);
        assert_eq!(got.dim, 2);
        assert_eq!(got.classes, 3);
        assert_eq!(got.x, ds.x);
        assert_eq!(got.y, ds.y);
        assert_eq!(got.difficulty, ds.difficulty);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn roundtrip_no_difficulty() {
        let mut ds = sample_ds();
        ds.difficulty = None;
        let mut buf = Vec::new();
        {
            use std::io::Cursor;
            // write through a memory buffer by reusing write_file via temp
            let dir = std::env::temp_dir()
                .join(format!("abds-test2-{}", std::process::id()));
            std::fs::create_dir_all(&dir).unwrap();
            let p = dir.join("t.abds");
            write_file(&p, &ds).unwrap();
            buf = std::fs::read(&p).unwrap();
            std::fs::remove_dir_all(&dir).ok();
            let got = read_from(&mut Cursor::new(&buf)).unwrap();
            assert!(got.difficulty.is_none());
        }
        assert!(!buf.is_empty());
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = vec![b'N', b'O', b'P', b'E'];
        bytes.extend_from_slice(&[0u8; 20]);
        let err = read_from(&mut std::io::Cursor::new(bytes)).unwrap_err();
        assert!(matches!(err, FormatError::BadMagic(_)));
    }

    #[test]
    fn truncated_rejected() {
        let ds = sample_ds();
        let dir = std::env::temp_dir().join(format!("abds-test3-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.abds");
        write_file(&p, &ds).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        let err = read_from(&mut std::io::Cursor::new(&bytes[..30])).unwrap_err();
        assert!(matches!(err, FormatError::Truncated { .. }));
    }

    #[test]
    fn label_out_of_range_rejected() {
        let mut ds = sample_ds();
        ds.y[1] = 99;
        let dir = std::env::temp_dir().join(format!("abds-test4-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.abds");
        write_file(&p, &ds).unwrap();
        let err = read_file(&p).unwrap_err();
        std::fs::remove_dir_all(&dir).ok();
        assert!(matches!(err, FormatError::LabelRange { label: 99, .. }));
    }

    #[test]
    fn row_and_slice() {
        let ds = sample_ds();
        assert_eq!(ds.row(1), &[2.0, 3.0]);
        let s = ds.slice(1, 3);
        assert_eq!(s.n, 2);
        assert_eq!(s.row(0), &[2.0, 3.0]);
        assert_eq!(s.y, vec![2, 1]);
        assert_eq!(s.difficulty.as_ref().unwrap(), &vec![0.9, 0.5]);
        // degenerate slices clamp
        assert_eq!(ds.slice(5, 9).n, 0);
    }
}
