//! Workload generation: arrival processes for the serving benchmarks.

use crate::util::rng::Rng;

/// An arrival process producing request times (seconds from start).
#[derive(Debug, Clone, Copy)]
pub enum Arrival {
    /// Poisson process at `rate` requests/second.
    Poisson { rate: f64 },
    /// Fixed inter-arrival gap.
    Uniform { rate: f64 },
    /// Bursts of `burst` back-to-back requests, bursts Poisson at `rate`.
    Bursty { rate: f64, burst: usize },
    /// On-off process: Poisson at `rate` during `on_s`-long active
    /// windows, each followed by `off_s` of silence (diurnal/batchy
    /// traffic for saturation tests).
    OnOff { rate: f64, on_s: f64, off_s: f64 },
}

impl Arrival {
    /// Generate `n` arrival timestamps, sorted ascending.
    pub fn generate(&self, n: usize, rng: &mut Rng) -> Vec<f64> {
        let mut out = Vec::with_capacity(n);
        match *self {
            Arrival::Poisson { rate } => {
                let mut t = 0.0;
                for _ in 0..n {
                    t += rng.exp(rate);
                    out.push(t);
                }
            }
            Arrival::Uniform { rate } => {
                let gap = 1.0 / rate;
                for i in 0..n {
                    out.push(gap * (i + 1) as f64);
                }
            }
            Arrival::Bursty { rate, burst } => {
                let mut t = 0.0;
                while out.len() < n {
                    t += rng.exp(rate);
                    for _ in 0..burst.min(n - out.len()) {
                        out.push(t);
                    }
                }
            }
            Arrival::OnOff { rate, on_s, off_s } => {
                // `t` accumulates *active* (on-window) time; wall-clock
                // time inserts `off_s` of silence after every `on_s` of
                // activity, which keeps the output sorted by
                // construction.
                let mut t = 0.0;
                for _ in 0..n {
                    t += rng.exp(rate);
                    let completed_windows = (t / on_s).floor();
                    out.push(t + completed_windows * off_s);
                }
            }
        }
        out
    }

    /// Mean offered rate in requests/second (accounting for off time).
    pub fn offered_rate(&self) -> f64 {
        match *self {
            Arrival::Poisson { rate } | Arrival::Uniform { rate } => rate,
            Arrival::Bursty { rate, burst } => rate * burst as f64,
            Arrival::OnOff { rate, on_s, off_s } => rate * on_s / (on_s + off_s),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_approximate() {
        let mut rng = Rng::new(1);
        let times = Arrival::Poisson { rate: 100.0 }.generate(10_000, &mut rng);
        assert_eq!(times.len(), 10_000);
        assert!(times.windows(2).all(|w| w[0] <= w[1]), "sorted");
        let span = times.last().unwrap() - times[0];
        let rate = 10_000.0 / span;
        assert!((rate - 100.0).abs() < 5.0, "rate {rate}");
    }

    #[test]
    fn uniform_fixed_gap() {
        let mut rng = Rng::new(2);
        let times = Arrival::Uniform { rate: 10.0 }.generate(5, &mut rng);
        assert_eq!(times, vec![0.1, 0.2, 0.30000000000000004, 0.4, 0.5]);
    }

    #[test]
    fn onoff_sorted_with_gaps() {
        let mut rng = Rng::new(7);
        let a = Arrival::OnOff { rate: 200.0, on_s: 0.05, off_s: 0.5 };
        let times = a.generate(40, &mut rng);
        assert_eq!(times.len(), 40);
        assert!(times.windows(2).all(|w| w[0] <= w[1]), "sorted");
        // ~200 req/s over 0.05s windows => ~10 per window; 40 requests
        // span several windows, so at least one inter-arrival gap must be
        // close to the 0.5s silence.
        let max_gap = times
            .windows(2)
            .map(|w| w[1] - w[0])
            .fold(0.0f64, f64::max);
        assert!(max_gap > 0.4, "expected an off-window gap, max {max_gap}");
        // effective rate matches the duty-cycled offered rate (~18 rps)
        let rate = a.offered_rate();
        assert!((rate - 200.0 * 0.05 / 0.55).abs() < 1e-9);
    }

    #[test]
    fn bursty_groups() {
        let mut rng = Rng::new(3);
        let times = Arrival::Bursty { rate: 5.0, burst: 4 }.generate(12, &mut rng);
        assert_eq!(times.len(), 12);
        // first 4 arrivals share a timestamp
        assert_eq!(times[0], times[3]);
        assert!(times[4] > times[3]);
    }
}
