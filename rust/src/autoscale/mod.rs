//! Elastic replica autoscaling: gear-coupled scale-up/down with
//! graceful drain and rental-cost accounting.
//!
//! The paper's cloud-serving claim is about **rental** cost --
//! replica-hours, not per-request FLOPs.  A fixed-size `ReplicaPool`
//! can only cash in the per-request savings: a gear shift retunes
//! thetas and batch sizes, but the idle machines keep billing.  This
//! subsystem closes that gap:
//!
//! * [`policy`] -- [`ScaleConfig`]: the pure policy mapping the
//!   controller's arrival EWMA + the active gear's per-replica
//!   capacity to a target replica count, with distinct scale-up /
//!   scale-down watermarks for hysteresis;
//! * [`autoscaler`] -- [`Autoscaler`]: ONE sampling thread that makes
//!   the gear decision (reusing `planner::controller::ControlState`)
//!   and the scale decision from the same observation in the same
//!   tick, sharing a single dwell clock -- a gear shift and a scale
//!   action are one atomic capacity decision, never two fighting
//!   control loops.  Rate-driven gear downshifts are evaluated against
//!   the *maximum* fleet (`ControlState::step_fleet`), so the coupled
//!   controller prefers renting replicas over trading accuracy and
//!   only downshifts when even the full fleet cannot carry the load;
//! * [`tiered`] -- [`TieredAutoscaler`]: the heterogeneous-fleet loop
//!   for `coordinator::router::TieredFleet` -- each cascade level's
//!   pool is sized independently against its own arrival rate (tier
//!   N's arrivals are tier N-1's deferrals), and decisions are priced
//!   in dollars via `cost::rental` (per-tier GPU classes, optional
//!   fleet-wide $/hour budget granted cheapest-tier-first).
//!
//! The replica lifecycle itself (`Warming -> Live -> Draining ->
//! Retired`, graceful drain, exactly-once guarantees, the
//! `replica_seconds` rental clock) lives in
//! `coordinator::replica::ReplicaPool`; the autoscaler drives it via
//! `scale_up` / `drain` / `advance`.
//!
//! Telemetry: `replicas_live` / `replicas_warming` /
//! `replicas_draining` / `replica_seconds` gauges, `scale_up_total` /
//! `scale_down_total` counters, and one `EventLog` entry per decision
//! (`{"cmd":"events"}` on the wire, `repro stats --events` offline).
//!
//! Entry points: `repro serve --plan P --autoscale --min-replicas A
//! --max-replicas B`, `rust/tests/autoscale_integration.rs`, and
//! `benches/bench_autoscale.rs` (fixed-N vs elastic under on-off
//! load: goodput, p99 and replica-hours).

pub mod autoscaler;
pub mod policy;
pub mod tiered;

pub use autoscaler::Autoscaler;
pub use policy::ScaleConfig;
pub use tiered::{FleetScaleConfig, TierScale, TieredAutoscaler};
