//! Per-tier autoscaling for the [`TieredFleet`], priced in dollars.
//!
//! The monolithic [`crate::autoscale::Autoscaler`] sizes ONE pool whose
//! replicas all run the whole cascade.  A tiered fleet has one pool per
//! cascade level, and each level sees a different arrival process:
//! tier N's arrivals ARE tier N-1's deferrals.  Because every tier pool
//! keeps its own metrics registry, a per-tier [`Sampler`] measures
//! exactly that deferral stream (submitted + shed deltas on the tier's
//! own pool), and each tier is sized independently against its own
//! load with the shared [`ScaleConfig`] policy -- same watermarks, same
//! hysteresis band, separate dwell clocks (tier fleets are independent
//! capacity pools; serialising their decisions through one clock would
//! starve the deep tiers behind the busy front tier).
//!
//! What IS global is money.  Decisions are priced in dollars via
//! `cost::rental`: every provisioned slot (Warming, Live, *and*
//! Draining -- a machine bills until it is returned) burns its tier's
//! GPU class rate, and an optional fleet-wide budget
//! ([`FleetScaleConfig::max_dollars_per_hour`]) caps the total burn.
//! Scale-ups are granted tier-ascending -- under the §5.2.2 placement
//! that is cheapest-first, so budget pressure starves the expensive top
//! pool last-rented-first rather than the cheap capacity that serves
//! most traffic.  Drains are always allowed (they only return money).
//!
//! The decision core is [`decide_fleet`], a pure function of (states,
//! config, per-tier observations and counts, dt) -- unit-tested below
//! without threads; the thread half samples, applies, and records one
//! `EventLog` entry per action (the event's gear fields carry the tier
//! index; a tiered fleet has no gears).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::autoscale::policy::ScaleConfig;
use crate::coordinator::router::TieredFleet;
use crate::cost::rental::Gpu;
use crate::metrics::EventKind;
use crate::planner::controller::{Observation, Sampler, Trigger};

/// Scaling knobs for one tier's pool.
#[derive(Debug, Clone, Copy)]
pub struct TierScale {
    /// Bounds + watermarks + warmup for this tier (min/max here should
    /// match the pool's own `PoolConfig` bounds).
    pub scale: ScaleConfig,
    /// Offered load one replica of this tier sustains (rows/s of the
    /// TIER's stage, not the whole cascade).  The rate-driven sizing
    /// divides by it; measure it (e.g. `StagedSynthetic::
    /// stage_capacity_rps`) or take it from a plan's per-tier quote.
    pub per_replica_rps: f64,
}

/// Fleet-wide autoscaler configuration.
#[derive(Debug, Clone)]
pub struct FleetScaleConfig {
    /// One entry per cascade level, tier 1 first.
    pub tiers: Vec<TierScale>,
    /// Fleet burn-rate budget in $/hour; 0 disables the cap.  Warming,
    /// Live and Draining slots all count against it (a rented machine
    /// bills until returned).
    pub max_dollars_per_hour: f64,
    /// Metrics sampling period.
    pub sample_every: Duration,
    /// Minimum time between scale actions PER TIER.
    pub dwell: Duration,
    /// Queue-pressure watermark (fraction of a tier's admission
    /// capacity) that forces a one-replica kicker.
    pub queue_pressure: f64,
    /// Per-sample EWMA smoothing factor in (0, 1].
    pub ewma_alpha: f64,
}

impl FleetScaleConfig {
    pub fn validate(&self) {
        assert!(!self.tiers.is_empty(), "fleet scale config needs tiers");
        for t in &self.tiers {
            t.scale.validate();
        }
        assert!(self.max_dollars_per_hour >= 0.0);
        assert!(self.ewma_alpha > 0.0 && self.ewma_alpha <= 1.0);
        assert!(self.queue_pressure > 0.0);
    }
}

/// One tier's controller state (EWMA + its own dwell clock).
#[derive(Debug, Clone, Copy)]
pub struct TierState {
    ewma_rps: f64,
    since_action_s: f64,
}

impl TierState {
    /// Dwell starts satisfied, like `ControlState::new`: a fleet dropped
    /// into an overload reacts on the first sample.
    pub fn new(cfg: &FleetScaleConfig) -> TierState {
        TierState {
            ewma_rps: 0.0,
            since_action_s: cfg.dwell.as_secs_f64(),
        }
    }

    pub fn ewma_rps(&self) -> f64 {
        self.ewma_rps
    }
}

/// One applied-or-proposed resize.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TierDecision {
    /// Cascade level (0-based).
    pub tier: usize,
    /// Target fleet size (Warming + Live) for that tier's pool.
    pub target: usize,
    pub trigger: Trigger,
}

/// Per-tier slot counts the decision consumes: (warming, live,
/// draining), as returned by `ReplicaPool::counts`.
pub type TierCounts = (usize, usize, usize);

/// The pure fleet decision: fold one observation per tier into its
/// state, size each tier independently, then clamp scale-ups to the
/// dollar budget tier-ascending.  Mutates `states` (EWMA, dwell) the
/// way the thread would.
pub fn decide_fleet(
    states: &mut [TierState],
    cfg: &FleetScaleConfig,
    gpus: &[Gpu],
    obs: &[Observation],
    counts: &[TierCounts],
    dt_s: f64,
) -> Vec<TierDecision> {
    assert_eq!(states.len(), cfg.tiers.len());
    assert_eq!(obs.len(), cfg.tiers.len());
    assert_eq!(counts.len(), cfg.tiers.len());
    assert_eq!(gpus.len(), cfg.tiers.len());
    // current burn: every provisioned slot bills, draining included
    let mut bill: f64 = counts
        .iter()
        .zip(gpus)
        .map(|(&(w, l, d), g)| (w + l + d) as f64 * g.dollars_per_hour())
        .sum();
    let dwell_s = cfg.dwell.as_secs_f64();
    let mut out = Vec::new();
    for i in 0..cfg.tiers.len() {
        let (warming, live, _) = counts[i];
        let state = &mut states[i];
        state.ewma_rps = cfg.ewma_alpha * obs[i].arrival_rps
            + (1.0 - cfg.ewma_alpha) * state.ewma_rps;
        state.since_action_s += dt_s.max(0.0);
        if state.since_action_s < dwell_s {
            continue;
        }
        let tier = &cfg.tiers[i];
        let fleet = live + warming;
        // the pressure kicker only fires when nothing is already
        // warming (capacity in flight will relieve the same debt)
        let pressured =
            obs[i].outstanding_frac > cfg.queue_pressure && warming == 0;
        let mut target =
            tier.scale
                .target(state.ewma_rps, tier.per_replica_rps, fleet, pressured);
        if target > fleet && cfg.max_dollars_per_hour > 0.0 {
            // grant what the budget affords; earlier (cheaper, under the
            // §5.2.2 placement) tiers were served first and already
            // consumed their share of `bill`
            let price = gpus[i].dollars_per_hour();
            let headroom = (cfg.max_dollars_per_hour - bill).max(0.0);
            let affordable = (headroom / price).floor() as usize;
            target = fleet + (target - fleet).min(affordable);
        }
        if target > fleet {
            bill += (target - fleet) as f64 * gpus[i].dollars_per_hour();
            let trigger = if pressured { Trigger::Pressure } else { Trigger::Rate };
            out.push(TierDecision { tier: i, target, trigger });
            state.since_action_s = 0.0;
        } else if target < live {
            // drains return money only once the replica retires; do not
            // discount `bill` yet -- the next tick sees the real counts
            out.push(TierDecision { tier: i, target, trigger: Trigger::Rate });
            state.since_action_s = 0.0;
        }
    }
    out
}

/// Handle to a running tiered-autoscaler thread; stops and joins on
/// drop.
pub struct TieredAutoscaler {
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

impl TieredAutoscaler {
    /// Spawn the per-tier control loop over a fleet.  `cfg.tiers` must
    /// match the fleet's tier count.
    pub fn spawn(fleet: Arc<TieredFleet>, cfg: FleetScaleConfig) -> TieredAutoscaler {
        cfg.validate();
        assert_eq!(
            cfg.tiers.len(),
            fleet.n_tiers(),
            "scale config has {} tiers, fleet has {}",
            cfg.tiers.len(),
            fleet.n_tiers()
        );
        let stop = Arc::new(AtomicBool::new(false));
        let stopf = Arc::clone(&stop);
        let join = std::thread::Builder::new()
            .name("abc-tiered-autoscaler".into())
            .spawn(move || scale_loop(&fleet, &cfg, &stopf))
            .expect("spawn tiered autoscaler");
        TieredAutoscaler { stop, join: Some(join) }
    }

    /// Ask the thread to exit and wait for it.  Idempotent.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for TieredAutoscaler {
    fn drop(&mut self) {
        self.stop();
    }
}

fn scale_loop(fleet: &TieredFleet, cfg: &FleetScaleConfig, stop: &AtomicBool) {
    let metrics = Arc::clone(fleet.metrics());
    let scale_ups = metrics.counter("scale_up_total");
    let scale_downs = metrics.counter("scale_down_total");
    let gpus: Vec<Gpu> = fleet.tiers().iter().map(|t| t.gpu()).collect();
    // one sampler per tier, over the TIER's registry: its submitted +
    // shed deltas are exactly the upstream tier's deferral stream
    let mut samplers: Vec<Sampler> = fleet
        .tiers()
        .iter()
        .map(|t| Sampler::new(t.pool().metrics()))
        .collect();
    let mut states: Vec<TierState> =
        (0..fleet.n_tiers()).map(|_| TierState::new(cfg)).collect();
    while !stop.load(Ordering::SeqCst) {
        std::thread::sleep(cfg.sample_every);
        // lifecycle first so this tick's counts are current
        fleet.advance(Instant::now());
        let mut obs = Vec::with_capacity(fleet.n_tiers());
        let mut counts = Vec::with_capacity(fleet.n_tiers());
        let mut dt_s = 0.0f64;
        for (i, t) in fleet.tiers().iter().enumerate() {
            let (o, dt) = samplers[i].sample(t.pool());
            obs.push(o);
            counts.push(t.pool().counts());
            dt_s = dt_s.max(dt);
        }
        let decisions = decide_fleet(&mut states, cfg, &gpus, &obs, &counts, dt_s);
        for d in &decisions {
            let (warming, live, _) = counts[d.tier];
            let fleet_size = warming + live;
            let tier_pool = fleet.tier(d.tier).pool();
            if d.target > fleet_size {
                tier_pool.scale_up(
                    d.target - fleet_size,
                    cfg.tiers[d.tier].scale.warmup,
                );
                scale_ups.inc();
            } else {
                tier_pool.drain(live - d.target);
                scale_downs.inc();
            }
            // the event's gear fields carry the tier index (no gears in
            // a tiered fleet)
            metrics.events().record(
                EventKind::Scale,
                d.trigger.name(),
                d.tier,
                d.tier,
                fleet_size,
                d.target,
            );
        }
        fleet.refresh_gauges();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg3() -> FleetScaleConfig {
        let tier = |min: usize, max: usize, rps: f64| TierScale {
            scale: ScaleConfig {
                min_replicas: min,
                max_replicas: max,
                warmup: Duration::ZERO,
                ..ScaleConfig::default()
            },
            per_replica_rps: rps,
        };
        FleetScaleConfig {
            // cheap fast front tier, midsize interior, slow top
            tiers: vec![tier(1, 4, 2000.0), tier(1, 4, 1000.0), tier(1, 4, 400.0)],
            max_dollars_per_hour: 0.0,
            sample_every: Duration::from_millis(10),
            dwell: Duration::from_millis(100),
            queue_pressure: 0.5,
            ewma_alpha: 1.0,
        }
    }

    fn gpus() -> Vec<Gpu> {
        vec![Gpu::V100, Gpu::A6000, Gpu::H100]
    }

    fn obs(rps: f64) -> Observation {
        Observation { arrival_rps: rps, outstanding_frac: 0.0, p99_s: f64::NAN }
    }

    fn states(cfg: &FleetScaleConfig) -> Vec<TierState> {
        (0..cfg.tiers.len()).map(|_| TierState::new(cfg)).collect()
    }

    #[test]
    fn tiers_size_independently_against_their_own_arrivals() {
        let cfg = cfg3();
        let mut st = states(&cfg);
        // tier arrivals thin out down the cascade: 3000 offered, 40%
        // defer to tier 2, a third of that reaches the top
        let o = [obs(3000.0), obs(1200.0), obs(400.0)];
        let c = [(0, 1, 0), (0, 1, 0), (0, 1, 0)];
        let d = decide_fleet(&mut st, &cfg, &gpus(), &o, &c, 0.2);
        // 3000/(2000*0.85) -> 2; 1200/(1000*0.85) -> 2; 400/(400*0.85) -> 2
        assert_eq!(
            d,
            vec![
                TierDecision { tier: 0, target: 2, trigger: Trigger::Rate },
                TierDecision { tier: 1, target: 2, trigger: Trigger::Rate },
                TierDecision { tier: 2, target: 2, trigger: Trigger::Rate },
            ]
        );
        // a calm interior tier is left alone while the top grows
        let mut st = states(&cfg);
        let o = [obs(1000.0), obs(100.0), obs(700.0)];
        let d = decide_fleet(&mut st, &cfg, &gpus(), &o, &c, 0.2);
        assert_eq!(
            d,
            vec![TierDecision { tier: 2, target: 3, trigger: Trigger::Rate }]
        );
    }

    #[test]
    fn dwell_gates_each_tier_separately() {
        let cfg = cfg3();
        let mut st = states(&cfg);
        let c = [(0, 1, 0), (0, 1, 0), (0, 1, 0)];
        // first decision consumes tier 0's dwell only
        let o = [obs(3000.0), obs(0.0), obs(0.0)];
        let d = decide_fleet(&mut st, &cfg, &gpus(), &o, &c, 0.2);
        assert_eq!(d.len(), 1);
        // immediately after, tier 0 is blocked but tier 2 can still act
        let o = [obs(3000.0), obs(0.0), obs(700.0)];
        let c2 = [(0, 2, 0), (0, 1, 0), (0, 1, 0)];
        let d = decide_fleet(&mut st, &cfg, &gpus(), &o, &c2, 0.01);
        assert_eq!(
            d,
            vec![TierDecision { tier: 2, target: 3, trigger: Trigger::Rate }]
        );
    }

    #[test]
    fn queue_pressure_kicks_a_tier_without_rate_evidence() {
        let cfg = cfg3();
        let mut st = states(&cfg);
        let jammed =
            Observation { arrival_rps: 5.0, outstanding_frac: 0.9, p99_s: f64::NAN };
        let o = [obs(5.0), jammed, obs(5.0)];
        let c = [(0, 1, 0), (0, 1, 0), (0, 1, 0)];
        let d = decide_fleet(&mut st, &cfg, &gpus(), &o, &c, 0.2);
        assert_eq!(
            d,
            vec![TierDecision { tier: 1, target: 2, trigger: Trigger::Pressure }]
        );
        // warming capacity suppresses the kicker
        let mut st = states(&cfg);
        let c = [(0, 1, 0), (1, 1, 0), (0, 1, 0)];
        let d = decide_fleet(&mut st, &cfg, &gpus(), &o, &c, 0.2);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn dollar_budget_clamps_cheapest_first() {
        let mut cfg = cfg3();
        // current bill: 1xV100 + 1xA6000 + 1xH100 = 3.79 $/h.  Budget
        // leaves 1.60 of headroom: tier 0 can afford 3 more V100s
        // (1.50), then nothing is left for the H100 the top tier wants.
        cfg.max_dollars_per_hour = 5.39;
        let mut st = states(&cfg);
        let o = [obs(6000.0), obs(0.0), obs(3000.0)];
        let c = [(0, 1, 0), (0, 1, 0), (0, 1, 0)];
        let d = decide_fleet(&mut st, &cfg, &gpus(), &o, &c, 0.2);
        assert_eq!(
            d,
            vec![TierDecision { tier: 0, target: 4, trigger: Trigger::Rate }],
            "cheap tier funded, expensive tier starved"
        );
        // drains are always allowed: they only return money
        let mut st = states(&cfg);
        let o = [obs(0.0), obs(0.0), obs(0.0)];
        let c = [(0, 4, 0), (0, 1, 0), (0, 2, 0)];
        let d = decide_fleet(&mut st, &cfg, &gpus(), &o, &c, 0.2);
        assert_eq!(d.len(), 2);
        assert!(d.iter().all(|x| x.target == 1));
        // draining slots still count against the budget: with 3 slots
        // draining elsewhere the headroom is gone entirely
        let mut cfg2 = cfg3();
        cfg2.max_dollars_per_hour = 4.0;
        let mut st = states(&cfg2);
        let o = [obs(6000.0), obs(0.0), obs(0.0)];
        let c = [(0, 1, 0), (0, 1, 3), (0, 1, 0)]; // 3 A6000s draining
        let d = decide_fleet(&mut st, &cfg2, &gpus(), &o, &c, 0.2);
        assert!(d.is_empty(), "budget must count draining slots: {d:?}");
    }

    #[test]
    fn unbounded_budget_never_clamps() {
        let cfg = cfg3(); // max_dollars_per_hour = 0
        let mut st = states(&cfg);
        let o = [obs(1e9), obs(1e9), obs(1e9)];
        let c = [(0, 1, 0), (0, 1, 0), (0, 1, 0)];
        let d = decide_fleet(&mut st, &cfg, &gpus(), &o, &c, 0.2);
        assert_eq!(d.len(), 3);
        assert!(d.iter().all(|x| x.target == 4), "max bound still applies");
    }
}
