//! The autoscaler thread: gear control + replica scaling as one
//! atomic decision per sample tick.
//!
//! Replaces `planner::Controller` when elasticity is on (`repro serve
//! --autoscale`): ONE thread samples the pool (`Sampler`), advances
//! the replica lifecycle (`ReplicaPool::advance`), runs the gear state
//! machine fleet-aware (`ControlState::step_fleet` at `max_replicas`,
//! so renting machines is preferred over trading accuracy), and then
//! sizes the fleet for the -- possibly new -- gear with
//! [`ScaleConfig::target`].  Shift and scale share the state machine's
//! dwell clock: neither happens within `dwell` of the other, except
//! that a shift and its matching resize land together in the same tick
//! (shifting to a cheaper gear without releasing the machines it no
//! longer needs would waste exactly the rent the shift saved).
//!
//! The decision core is [`decide`], a pure function of (state, plan,
//! configs, observation, live + warming counts, dt), unit-tested below
//! without threads; the thread half only samples, applies, and records.
//!
//! Applying a decision:
//! * shift: swap the shared `GearHandle`, retune batcher caps -- only
//!   batches formed later are affected;
//! * scale up: `ReplicaPool::scale_up` (Warming with the configured
//!   warm-up; the rental clock starts immediately);
//! * scale down: `ReplicaPool::drain` -- graceful: the drained
//!   replicas stop admitting, finish their queues, and are retired by
//!   a later tick's `advance`.  No request is dropped or duplicated.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::autoscale::policy::ScaleConfig;
use crate::coordinator::replica::ReplicaPool;
use crate::metrics::EventKind;
use crate::planner::controller::{
    ControlState, ControllerConfig, Observation, Sampler, Shift, Trigger,
};
use crate::planner::gear::{GearHandle, GearPlan};

/// One joint (gear, fleet) decision.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Decision {
    /// Gear shift to apply, with its trigger.
    pub shift: Option<(Shift, Trigger)>,
    /// Fleet resize to apply: (target, trigger).  Absent when the
    /// current fleet already matches the policy target or the dwell
    /// clock blocks action.
    pub scale: Option<(usize, Trigger)>,
}

/// The pure joint decision: fold one observation into the gear state
/// machine (fleet-aware at `scale.max_replicas`), then size the fleet
/// for whatever gear is now active.  `live` is the admitting replica
/// count, `warming` the replicas already provisioned but not yet
/// serving -- the policy sizes against `live + warming` so a slow
/// warm-up cannot trick it into re-provisioning the same capacity
/// every dwell.  A resize is actionable only when the target exceeds
/// the provisioned fleet (scale up) or undercuts the live count
/// (drain); a target inside `[live, live + warming]` just means
/// "wait for the warm-ups".  Mutates `state` exactly as the
/// controller would.
pub fn decide(
    state: &mut ControlState,
    plan: &GearPlan,
    ctrl: &ControllerConfig,
    scale: &ScaleConfig,
    obs: Observation,
    live: usize,
    warming: usize,
    dt_s: f64,
) -> Decision {
    let shift = state.step_fleet(plan, ctrl, obs, dt_s, Some(scale.max_replicas));
    // a shift already consumed the dwell; it still gets its matching
    // resize this tick (one atomic capacity decision)
    let may_scale = shift.is_some() || state.dwell_ok(ctrl);
    let mut scale_action = None;
    if may_scale {
        let fleet = live + warming;
        let gear = &plan.gears[state.current()];
        // the pressure kicker rents one extra machine for queue debt the
        // rate EWMA cannot see -- but only when nothing is already
        // warming: capacity in flight will relieve the same debt, and
        // kicking every dwell until it goes Live would re-rent it
        let pressured =
            obs.outstanding_frac > ctrl.queue_pressure && warming == 0;
        let target =
            scale.target(state.ewma_rps(), gear.per_replica_rps(), fleet, pressured);
        if target > fleet || target < live {
            let trigger = if pressured && target > fleet {
                Trigger::Pressure
            } else {
                Trigger::Rate
            };
            scale_action = Some((target, trigger));
            state.note_action();
        }
    }
    Decision { shift, scale: scale_action }
}

/// Handle to a running autoscaler thread; stops and joins on drop.
pub struct Autoscaler {
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

impl Autoscaler {
    /// Spawn the coupled control loop over a geared pool + plan.  The
    /// pool must have been spawned with the same `handle`
    /// (`ReplicaPool::spawn_geared`); the handle's active gear id
    /// picks the starting ladder position.
    pub fn spawn(
        pool: Arc<ReplicaPool>,
        plan: GearPlan,
        handle: Arc<GearHandle>,
        ctrl: ControllerConfig,
        scale: ScaleConfig,
    ) -> Autoscaler {
        assert!(
            handle.gear_id() < plan.len(),
            "gear handle points past the plan's ladder"
        );
        assert!(
            ctrl.up_util < ctrl.down_util,
            "hysteresis requires up_util < down_util"
        );
        assert!(ctrl.ewma_alpha > 0.0 && ctrl.ewma_alpha <= 1.0);
        scale.validate();
        let stop = Arc::new(AtomicBool::new(false));
        let stopf = Arc::clone(&stop);
        let join = std::thread::Builder::new()
            .name("abc-autoscaler".into())
            .spawn(move || autoscale_loop(&pool, &plan, &handle, ctrl, scale, &stopf))
            .expect("spawn autoscaler");
        Autoscaler { stop, join: Some(join) }
    }

    /// Ask the thread to exit and wait for it.  Idempotent.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Autoscaler {
    fn drop(&mut self) {
        self.stop();
    }
}

fn autoscale_loop(
    pool: &ReplicaPool,
    plan: &GearPlan,
    handle: &GearHandle,
    ctrl: ControllerConfig,
    scale: ScaleConfig,
    stop: &AtomicBool,
) {
    let metrics = Arc::clone(pool.metrics());
    let shifts_up = metrics.counter("gear_shift_up");
    let shifts_down = metrics.counter("gear_shift_down");
    let scale_ups = metrics.counter("scale_up_total");
    let scale_downs = metrics.counter("scale_down_total");
    let gear_gauge = metrics.gauge("gear_current");
    let ewma_gauge = metrics.gauge("arrival_ewma_rps");
    let p99_gauge = metrics.gauge("latency_p99_s");
    let live_gauge = metrics.gauge("replicas_live");
    let warming_gauge = metrics.gauge("replicas_warming");
    let draining_gauge = metrics.gauge("replicas_draining");
    let seconds_gauge = metrics.gauge("replica_seconds");

    let mut state = ControlState::new(handle.gear_id(), &ctrl);
    gear_gauge.set(state.current() as f64);
    let mut sampler = Sampler::new(&metrics);
    while !stop.load(Ordering::SeqCst) {
        std::thread::sleep(ctrl.sample_every);
        // lifecycle first: promote warmed replicas / retire drained
        // ones, so this tick's live count and capacity are current
        pool.advance(Instant::now());
        let (obs, dt_s) = sampler.sample(pool);
        let (warming, live, _) = pool.counts();
        let old_gear = state.current();
        let decision =
            decide(&mut state, plan, &ctrl, &scale, obs, live, warming, dt_s);
        ewma_gauge.set(state.ewma_rps());
        if obs.p99_s.is_finite() {
            p99_gauge.set(obs.p99_s);
        }
        if let Some((shift, trigger)) = decision.shift {
            let gear = &plan.gears[state.current()];
            handle.store(gear.config());
            pool.set_max_batch(gear.max_batch);
            gear_gauge.set(gear.id as f64);
            match shift {
                Shift::Up => shifts_up.inc(),
                Shift::Down => shifts_down.inc(),
            }
            metrics.events().record(
                EventKind::Shift,
                trigger.name(),
                old_gear,
                gear.id,
                live,
                live,
            );
        }
        if let Some((target, trigger)) = decision.scale {
            let fleet = live + warming;
            if target > fleet {
                pool.scale_up(target - fleet, scale.warmup);
                scale_ups.inc();
            } else {
                pool.drain(live - target);
                scale_downs.inc();
            }
            metrics.events().record(
                EventKind::Scale,
                trigger.name(),
                state.current(),
                state.current(),
                fleet,
                target,
            );
        }
        // rental + lifecycle telemetry every tick
        let (warming, live_now, draining) = pool.counts();
        live_gauge.set(live_now as f64);
        warming_gauge.set(warming as f64);
        draining_gauge.set(draining as f64);
        seconds_gauge.set(pool.replica_seconds());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::gear::Gear;
    use std::time::Duration;

    fn plan2() -> GearPlan {
        // per-replica: top 500 rps, fast 2000 rps (quoted at 2 replicas)
        let gear = |acc: f64, work: f64, rps: f64| Gear {
            id: 0,
            k: 3,
            epsilon: 0.03,
            theta: 0.6,
            mid: vec![],
            max_batch: 8,
            replicas: 2,
            tier_fleet: vec![],
            dollar_per_req: 0.0,
            accuracy: acc,
            relative_cost: work,
            sustainable_rps: rps,
        };
        GearPlan::new(vec![gear(0.95, 1.0, 1000.0), gear(0.85, 0.25, 4000.0)])
            .unwrap()
    }

    fn ctrl() -> ControllerConfig {
        ControllerConfig {
            dwell: Duration::from_millis(100),
            ewma_alpha: 1.0,
            ..ControllerConfig::default()
        }
    }

    fn scale() -> ScaleConfig {
        ScaleConfig { min_replicas: 1, max_replicas: 4, ..ScaleConfig::default() }
    }

    fn obs(rps: f64) -> Observation {
        Observation { arrival_rps: rps, outstanding_frac: 0.0, p99_s: f64::NAN }
    }

    #[test]
    fn rising_load_rents_replicas_before_trading_accuracy() {
        let plan = plan2();
        let (ctrl, scale) = (ctrl(), scale());
        let mut s = ControlState::new(0, &ctrl);
        // 1500 rps: the max fleet of the top gear sustains 4*500=2000,
        // so no shift -- but the 1-replica fleet must grow to 4
        let d = decide(&mut s, &plan, &ctrl, &scale, obs(1500.0), 1, 0, 0.2);
        assert_eq!(d.shift, None);
        assert_eq!(d.scale, Some((4, Trigger::Rate)));
        assert_eq!(s.current(), 0, "accuracy held while machines are cheap");
    }

    #[test]
    fn drowning_load_shifts_and_resizes_in_one_tick() {
        let plan = plan2();
        let (ctrl, scale) = (ctrl(), scale());
        let mut s = ControlState::new(0, &ctrl);
        // 3000 rps drowns even 4x top (1700 effective): downshift to the
        // fast gear AND size its fleet in the same decision -- the fast
        // gear (2000 rps/replica) releases down to 3 machines (the
        // conservative scale_down_util sizing; 2 would run at 75%)
        let d = decide(&mut s, &plan, &ctrl, &scale, obs(3000.0), 4, 0, 0.2);
        assert_eq!(d.shift, Some((Shift::Down, Trigger::Rate)));
        assert_eq!(s.current(), 1);
        assert_eq!(d.scale, Some((3, Trigger::Rate)));
    }

    #[test]
    fn calm_load_upshifts_then_drains_the_surplus() {
        let plan = plan2();
        let (ctrl, scale) = (ctrl(), scale());
        let mut s = ControlState::new(1, &ctrl);
        // 300 rps on the fast gear: upshift (top's max fleet runs at
        // 0.15) and size the top-gear fleet for 300 rps (1 replica)
        let d = decide(&mut s, &plan, &ctrl, &scale, obs(300.0), 4, 0, 0.2);
        assert_eq!(d.shift, Some((Shift::Up, Trigger::Rate)));
        assert_eq!(d.scale, Some((1, Trigger::Rate)));
    }

    #[test]
    fn dwell_blocks_lone_scale_actions_but_not_the_shift_resize_pair() {
        let plan = plan2();
        let (ctrl, scale) = (ctrl(), scale());
        let mut s = ControlState::new(0, &ctrl);
        // consume the dwell with an action
        s.note_action();
        let d = decide(&mut s, &plan, &ctrl, &scale, obs(1500.0), 1, 0, 0.02);
        assert_eq!(d.shift, None);
        assert_eq!(d.scale, None, "dwell must gate scale actions too");
        // once the dwell expires the pending resize applies
        let d = decide(&mut s, &plan, &ctrl, &scale, obs(1500.0), 1, 0, 0.2);
        assert_eq!(d.scale, Some((4, Trigger::Rate)));
        // and the next decision's dwell is consumed by that scale action
        let d = decide(&mut s, &plan, &ctrl, &scale, obs(3000.0), 4, 0, 0.02);
        assert_eq!(d.shift, None);
        assert_eq!(d.scale, None);
    }

    #[test]
    fn queue_pressure_scales_up_even_at_calm_ewma() {
        let plan = plan2();
        let (ctrl, scale) = (ctrl(), scale());
        let mut s = ControlState::new(1, &ctrl);
        // rate looks idle but queues are jammed: the gear machine steps
        // down if it can (it cannot: already fastest), the fleet grows
        let jammed =
            Observation { arrival_rps: 5.0, outstanding_frac: 0.9, p99_s: f64::NAN };
        let d = decide(&mut s, &plan, &ctrl, &scale, jammed, 2, 0, 0.2);
        assert_eq!(d.shift, None, "already in the fastest gear");
        assert_eq!(d.scale, Some((3, Trigger::Pressure)));
    }

    #[test]
    fn warming_replicas_count_against_reprovisioning() {
        let plan = plan2();
        let (ctrl, scale) = (ctrl(), scale());
        let mut s = ControlState::new(0, &ctrl);
        // first decision provisions 3 more machines (slow warm-up: they
        // stay Warming)
        let d = decide(&mut s, &plan, &ctrl, &scale, obs(1500.0), 1, 0, 0.2);
        assert_eq!(d.scale, Some((4, Trigger::Rate)));
        // while they warm, the same load must NOT re-provision: the
        // in-flight capacity already covers the target
        let d = decide(&mut s, &plan, &ctrl, &scale, obs(1500.0), 1, 3, 0.2);
        assert_eq!(d.scale, None, "re-provisioned capacity already in flight");
        // even a jammed queue doesn't kick the fleet past the in-flight
        // capacity: the warm-ups will relieve the same debt
        let jammed = Observation {
            arrival_rps: 1500.0,
            outstanding_frac: 0.9,
            p99_s: f64::NAN,
        };
        let d = decide(&mut s, &plan, &ctrl, &scale, jammed, 1, 3, 0.2);
        assert_eq!(d.scale, None, "pressure re-rented warming capacity");
        // once they go live nothing changes either
        let d = decide(&mut s, &plan, &ctrl, &scale, obs(1500.0), 4, 0, 0.2);
        assert_eq!(d.scale, None);
    }

    #[test]
    fn steady_state_decides_nothing() {
        let plan = plan2();
        let (ctrl, scale) = (ctrl(), scale());
        let mut s = ControlState::new(0, &ctrl);
        // 600 rps on 2 live top-gear replicas: util 0.6, inside every band
        for _ in 0..10 {
            let d = decide(&mut s, &plan, &ctrl, &scale, obs(600.0), 2, 0, 0.2);
            assert_eq!(d, Decision { shift: None, scale: None });
        }
    }
}
