//! Micro-benchmark harness (no criterion in the offline registry).
//!
//! `Bench::new("name")` -> `.run("case", || work)` measures warmup +
//! timed iterations, reports mean / p50 / p99 / throughput, and renders a
//! criterion-style summary table.  Used by every `benches/*.rs`
//! (harness = false targets).
//!
//! Every bench also writes a machine-readable `BENCH_<name>.json` via
//! [`emit_json`] / [`Bench::emit_json`] so CI can upload the numbers
//! as artifacts and chart the perf trajectory across commits.  The
//! output directory defaults to the working directory and is
//! overridable with the `BENCH_OUT` env var.

use std::io;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::util::json::{Json, JsonObj};
use crate::util::stats::Samples;
use crate::util::table::Table;

/// Tuning knobs for one bench run.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop after this much measured time even if < max_iters.
    pub max_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 200,
            max_time: Duration::from_secs(5),
        }
    }
}

/// One measured case.
#[derive(Debug, Clone)]
pub struct CaseResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub min_s: f64,
}

/// A named group of benchmark cases.
pub struct Bench {
    name: String,
    cfg: BenchConfig,
    results: Vec<CaseResult>,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Bench {
        Bench { name: name.into(), cfg: BenchConfig::default(), results: Vec::new() }
    }

    pub fn with_config(mut self, cfg: BenchConfig) -> Bench {
        self.cfg = cfg;
        self
    }

    /// Measure `f` repeatedly; the closure's return value is black-boxed.
    pub fn run<R, F: FnMut() -> R>(&mut self, case: impl Into<String>, mut f: F) -> &CaseResult {
        let case = case.into();
        for _ in 0..self.cfg.warmup_iters {
            black_box(f());
        }
        let mut samples = Samples::new();
        let started = Instant::now();
        let mut iters = 0;
        while iters < self.cfg.min_iters
            || (iters < self.cfg.max_iters && started.elapsed() < self.cfg.max_time)
        {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
            iters += 1;
        }
        let result = CaseResult {
            name: case,
            iters,
            mean_s: samples.mean(),
            p50_s: samples.p50(),
            p99_s: samples.p99(),
            min_s: samples.quantile(0.0),
        };
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Render and print the summary table.
    pub fn report(&self) {
        let mut table = Table::new(
            format!("bench: {}", self.name),
            &["case", "iters", "mean", "p50", "p99", "min"],
        );
        for r in &self.results {
            table.row(vec![
                r.name.clone(),
                r.iters.to_string(),
                fmt_time(r.mean_s),
                fmt_time(r.p50_s),
                fmt_time(r.p99_s),
                fmt_time(r.min_s),
            ]);
        }
        println!("{}", table.render());
    }

    pub fn results(&self) -> &[CaseResult] {
        &self.results
    }

    /// Machine-readable form: `{"bench": name, "cases": [{name, iters,
    /// mean_s, p50_s, p99_s, min_s}, ...]}`.
    pub fn to_json(&self) -> Json {
        let cases = self
            .results
            .iter()
            .map(|r| {
                let mut o = JsonObj::new();
                o.insert("name", Json::str(r.name.as_str()));
                o.insert("iters", Json::num(r.iters as f64));
                o.insert("mean_s", Json::num(r.mean_s));
                o.insert("p50_s", Json::num(r.p50_s));
                o.insert("p99_s", Json::num(r.p99_s));
                o.insert("min_s", Json::num(r.min_s));
                Json::Obj(o)
            })
            .collect();
        let mut o = JsonObj::new();
        o.insert("bench", Json::str(self.name.as_str()));
        o.insert("cases", Json::Arr(cases));
        Json::Obj(o)
    }

    /// Write `BENCH_<name>.json` (see [`emit_json`]).
    pub fn emit_json(&self) -> io::Result<PathBuf> {
        emit_json(&self.name, self.to_json())
    }
}

/// Where `BENCH_<name>.json` lands: `$BENCH_OUT/` when set, else the
/// working directory (CI sets `BENCH_OUT` and uploads the directory).
pub fn bench_out_path(name: &str) -> PathBuf {
    let dir = std::env::var_os("BENCH_OUT")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("."));
    dir.join(format!("BENCH_{name}.json"))
}

/// Write a bench's machine-readable result as pretty JSON (trailing
/// newline) to [`bench_out_path`] and announce the path on stdout.
/// Benches with domain metrics beyond timings (goodput, $/1k, ...)
/// build their own `Json` and call this directly.
pub fn emit_json(name: &str, payload: Json) -> io::Result<PathBuf> {
    let path = bench_out_path(name);
    std::fs::write(&path, format!("{}\n", payload.to_pretty()))?;
    println!("wrote {}", path.display());
    Ok(path)
}

/// Pretty time formatting (ns/us/ms/s).
pub fn fmt_time(s: f64) -> String {
    if !s.is_finite() {
        "n/a".to_string()
    } else if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Optimization barrier (stable-rust version).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_reports() {
        let mut b = Bench::new("t").with_config(BenchConfig {
            warmup_iters: 1,
            min_iters: 5,
            max_iters: 10,
            max_time: Duration::from_millis(200),
        });
        let r = b.run("sleep", || std::thread::sleep(Duration::from_millis(1)));
        assert!(r.iters >= 5);
        assert!(r.mean_s >= 0.001);
        assert!(r.p50_s >= 0.0009);
        b.report(); // must not panic
    }

    #[test]
    fn json_emission_roundtrip() {
        let mut b = Bench::new("unit").with_config(BenchConfig {
            warmup_iters: 0,
            min_iters: 2,
            max_iters: 2,
            max_time: Duration::from_millis(50),
        });
        b.run("noop", || 1 + 1);
        let j = b.to_json();
        assert_eq!(j.get("bench").as_str(), Some("unit"));
        let cases = j.get("cases").as_arr().unwrap();
        assert_eq!(cases.len(), 1);
        assert_eq!(cases[0].get("name").as_str(), Some("noop"));
        assert_eq!(cases[0].get("iters").as_u64(), Some(2));

        let dir = std::env::temp_dir().join(format!("bench_out_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::env::set_var("BENCH_OUT", &dir);
        let path = b.emit_json().unwrap();
        std::env::remove_var("BENCH_OUT");
        assert_eq!(path, dir.join("BENCH_unit.json"));
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("bench").as_str(), Some("unit"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(5e-9).ends_with("ns"));
        assert!(fmt_time(5e-6).ends_with("us"));
        assert!(fmt_time(5e-3).ends_with("ms"));
        assert!(fmt_time(5.0).ends_with('s'));
    }
}
