//! Micro-benchmark harness (no criterion in the offline registry).
//!
//! `Bench::new("name")` -> `.run("case", || work)` measures warmup +
//! timed iterations, reports mean / p50 / p99 / throughput, and renders a
//! criterion-style summary table.  Used by every `benches/*.rs`
//! (harness = false targets).

use std::time::{Duration, Instant};

use crate::util::stats::Samples;
use crate::util::table::Table;

/// Tuning knobs for one bench run.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop after this much measured time even if < max_iters.
    pub max_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 200,
            max_time: Duration::from_secs(5),
        }
    }
}

/// One measured case.
#[derive(Debug, Clone)]
pub struct CaseResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub min_s: f64,
}

/// A named group of benchmark cases.
pub struct Bench {
    name: String,
    cfg: BenchConfig,
    results: Vec<CaseResult>,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Bench {
        Bench { name: name.into(), cfg: BenchConfig::default(), results: Vec::new() }
    }

    pub fn with_config(mut self, cfg: BenchConfig) -> Bench {
        self.cfg = cfg;
        self
    }

    /// Measure `f` repeatedly; the closure's return value is black-boxed.
    pub fn run<R, F: FnMut() -> R>(&mut self, case: impl Into<String>, mut f: F) -> &CaseResult {
        let case = case.into();
        for _ in 0..self.cfg.warmup_iters {
            black_box(f());
        }
        let mut samples = Samples::new();
        let started = Instant::now();
        let mut iters = 0;
        while iters < self.cfg.min_iters
            || (iters < self.cfg.max_iters && started.elapsed() < self.cfg.max_time)
        {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
            iters += 1;
        }
        let result = CaseResult {
            name: case,
            iters,
            mean_s: samples.mean(),
            p50_s: samples.p50(),
            p99_s: samples.p99(),
            min_s: samples.quantile(0.0),
        };
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Render and print the summary table.
    pub fn report(&self) {
        let mut table = Table::new(
            format!("bench: {}", self.name),
            &["case", "iters", "mean", "p50", "p99", "min"],
        );
        for r in &self.results {
            table.row(vec![
                r.name.clone(),
                r.iters.to_string(),
                fmt_time(r.mean_s),
                fmt_time(r.p50_s),
                fmt_time(r.p99_s),
                fmt_time(r.min_s),
            ]);
        }
        println!("{}", table.render());
    }

    pub fn results(&self) -> &[CaseResult] {
        &self.results
    }
}

/// Pretty time formatting (ns/us/ms/s).
pub fn fmt_time(s: f64) -> String {
    if !s.is_finite() {
        "n/a".to_string()
    } else if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Optimization barrier (stable-rust version).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_reports() {
        let mut b = Bench::new("t").with_config(BenchConfig {
            warmup_iters: 1,
            min_iters: 5,
            max_iters: 10,
            max_time: Duration::from_millis(200),
        });
        let r = b.run("sleep", || std::thread::sleep(Duration::from_millis(1)));
        assert!(r.iters >= 5);
        assert!(r.mean_s >= 0.001);
        assert!(r.p50_s >= 0.0009);
        b.report(); // must not panic
    }

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(5e-9).ends_with("ns"));
        assert!(fmt_time(5e-6).ends_with("us"));
        assert!(fmt_time(5e-3).ends_with("ms"));
        assert!(fmt_time(5.0).ends_with('s'));
    }
}
