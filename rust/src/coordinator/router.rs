//! Tiered fleet: one [`ReplicaPool`] per cascade level, deferral as an
//! explicit routed handoff between pools.
//!
//! The monolithic serving path runs the ENTIRE cascade inside every
//! replica (`Cascade::classify_batch` on one `ReplicaPool` slot), so
//! every machine must be provisioned for the top model even though most
//! requests exit at tier 1.  The paper's §5.2.2 rental-cost win comes
//! from the opposite layout: cheap GPUs serve the cheap tiers, one
//! small expensive pool serves the rarely-reached top model.  This
//! module is that layout:
//!
//! ```text
//!             +--------------+  defer  +--------------+  defer  +-------------+
//!  request -> | tier-1 pool  | ------> | tier-2 pool  | ------> | top pool    |
//!             | V100 x N1    |  exit   | A6000 x N2   |  exit   | H100 x N3   |
//!             +--------------+   |     +--------------+   |     +-------------+
//!                                v                        v            |
//!                              verdict                  verdict      verdict
//! ```
//!
//! Each tier pool is an ordinary [`ReplicaPool`] (bounded queues,
//! least-outstanding dispatch, Warming/Live/Draining lifecycle, its own
//! `replica_seconds` rental clock priced at its own [`Gpu`] class) over
//! a [`StageAdapter`]: a `BatchClassifier` that runs exactly ONE
//! [`StageClassifier`] stage and encodes "defer" as the reserved exit
//! level [`DEFERRED`].  The fleet's router submits a request to tier
//! 1's pool, reads the stage verdict, and forwards only non-exited
//! requests (with their ids and accumulated scores) to the next tier's
//! pool -- the distributed form of the same sieve
//! `classify_batch_staged` drives in-process, so both layouts produce
//! identical results (rust/tests/tiered_integration.rs).
//!
//! Accounting is exactly-once at the fleet boundary: every submitted
//! request is either completed (exited at some tier) or shed (refused
//! by some tier's admission control), never both, never lost --
//! including across mid-run drains of interior pools (a draining
//! replica still answers everything it admitted) and shedding at any
//! depth.  `fleet_submitted == fleet_completed + fleet_shed` holds at
//! quiescence.
//!
//! Telemetry (fleet registry): `fleet_submitted` / `fleet_completed` /
//! `fleet_shed` counters, per-tier `tier_{i}_exited` /
//! `tier_{i}_deferred` counters, `request_latency_s` histogram (routed
//! end-to-end), and -- via [`TieredFleet::refresh_gauges`] -- per-tier
//! queue depth / live replicas, per-tier exit fractions, and the fleet
//! rental bill in dollars (`fleet_dollars`, `fleet_dollars_per_hour`).
//! Each tier pool additionally keeps its own private registry so the
//! control plane (`control`) can sample per-tier arrival rates: tier
//! N's arrivals ARE tier N-1's deferrals.  The control plane also
//! actuates per-tier gears through [`TieredFleet::set_tier_gear`]
//! (runtime theta/batch retuning; see `control::decider`).
//!
//! **Shadow sampling** (the drift observatory's feed): when spawned
//! with a [`DriftConfig`], the router forwards a deterministic 1-in-N
//! fraction of early-exited requests to the downstream tiers OFF the
//! critical path.  The client already got the early answer; the shadow
//! copy rides a bounded `sync_channel` (`try_send`: a full queue drops
//! the observation, never blocks serving) to one worker thread that
//! routes it through the remaining tiers and records `(score,
//! agree-with-downstream)` into the fleet's [`DriftMonitor`].  Shadow
//! rows never touch the fleet's exactly-once counters (`fleet_*`,
//! `tier_{i}_exited/deferred`, `request_latency_s`); they DO run on
//! the real tier pools, so the per-tier private registries the
//! autoscaler samples see them as genuinely offered load.  Shadow
//! telemetry: `shadow_submitted` / `shadow_dropped` (queue full) /
//! `shadow_shed` (downstream refused the shadow copy).

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::calib::threshold::CalPoint;
use crate::coordinator::batcher::BatcherConfig;
use crate::coordinator::cascade::{
    BatchClassifier, CascadeResult, StageClassifier,
};
use crate::coordinator::replica::{
    Lifecycle, PoolConfig, PoolError, ReplicaPool,
};
use crate::cost::rental::Gpu;
use crate::metrics::Metrics;
use crate::obs::drift::{DriftConfig, DriftMonitor};
use crate::obs::slo::{SloConfig, SloObservatory};
use crate::obs::{ObsHook, SpanKind, Tracer};
use crate::types::{Class, Request, Verdict};

/// Reserved exit level a [`StageAdapter`] reports for "defer to the
/// next tier".  Real exit levels are 1-based, so 0 can never collide;
/// the sentinel never escapes the fleet -- the router either forwards
/// the request or answers with a real tier.
pub const DEFERRED: usize = 0;

/// `BatchClassifier` view of ONE stage of a [`StageClassifier`]: what a
/// tier's `ReplicaPool` executes.  Accepted rows report the tier's
/// 1-based global level; deferred rows report [`DEFERRED`] and carry
/// only this tier's score.
///
/// The threshold override is runtime-adjustable (f32 bits in an atomic;
/// NaN encodes "no override, use the stage's own calibrated policy"):
/// the control plane's per-tier gear shifting writes it through
/// [`TieredFleet::set_tier_gear`], and every batch reads it once -- a
/// swap only affects batches formed later, like a monolithic gear
/// shift.
pub struct StageAdapter {
    stage: Arc<dyn StageClassifier>,
    level0: usize,
    theta_bits: AtomicU32,
}

impl StageAdapter {
    pub fn new(
        stage: Arc<dyn StageClassifier>,
        level0: usize,
        theta: Option<f32>,
    ) -> StageAdapter {
        assert!(level0 < stage.n_levels(), "stage index out of range");
        let adapter = StageAdapter {
            stage,
            level0,
            theta_bits: AtomicU32::new(0),
        };
        adapter.set_theta(theta);
        adapter
    }

    /// The active threshold override (None = the stage's own policy).
    pub fn theta(&self) -> Option<f32> {
        let t = f32::from_bits(self.theta_bits.load(Ordering::Relaxed));
        if t.is_nan() {
            None
        } else {
            Some(t)
        }
    }

    /// Swap the threshold override; visible to every later batch.
    pub fn set_theta(&self, theta: Option<f32>) {
        let bits = theta.unwrap_or(f32::NAN).to_bits();
        self.theta_bits.store(bits, Ordering::Relaxed);
    }
}

impl BatchClassifier for StageAdapter {
    fn dim(&self) -> usize {
        self.stage.dim()
    }

    fn n_levels(&self) -> usize {
        self.stage.n_levels()
    }

    fn classify_batch(&self, features: &[f32], n: usize) -> Result<Vec<CascadeResult>> {
        Ok(self
            .stage
            .classify_stage(self.level0, features, n, self.theta())?
            .into_iter()
            .map(|r| CascadeResult {
                prediction: r.decision.unwrap_or(0),
                exit_level: match r.decision {
                    Some(_) => self.level0 + 1,
                    None => DEFERRED,
                },
                scores: vec![r.score],
            })
            .collect())
    }
}

/// One tier of a [`TieredFleet`]: which GPU class it rents and how many
/// replicas it starts with / may scale between.
#[derive(Debug, Clone, Copy)]
pub struct TierSpec {
    /// GPU class every replica of this tier's pool rents.
    pub gpu: Gpu,
    /// Replicas at spawn.
    pub replicas: usize,
    /// Autoscaling floor (Live replicas; >= 1).
    pub min_replicas: usize,
    /// Autoscaling ceiling (total slots).
    pub max_replicas: usize,
    /// Max outstanding requests per replica before this tier sheds.
    pub max_queue: usize,
    /// Per-tier threshold override (None = the stage's own policy).
    pub theta: Option<f32>,
}

impl TierSpec {
    /// A fixed-size tier: `replicas` pinned (min == max == replicas,
    /// floored at 1 -- a tier cannot be empty).
    pub fn fixed(gpu: Gpu, replicas: usize, max_queue: usize) -> TierSpec {
        let replicas = replicas.max(1);
        TierSpec {
            gpu,
            replicas,
            min_replicas: replicas,
            max_replicas: replicas,
            max_queue,
            theta: None,
        }
    }

    /// An elastic tier scaling between `min` and `max`, starting at
    /// `min`.
    pub fn elastic(gpu: Gpu, min: usize, max: usize, max_queue: usize) -> TierSpec {
        TierSpec {
            gpu,
            replicas: min.max(1),
            min_replicas: min.max(1),
            max_replicas: max.max(min.max(1)),
            max_queue,
            theta: None,
        }
    }
}

/// Fleet-wide knobs.
#[derive(Debug, Clone)]
pub struct TieredFleetConfig {
    /// One spec per cascade level, tier 1 first; the length must match
    /// the stage classifier's `n_levels`.
    pub tiers: Vec<TierSpec>,
    /// Batching policy shared by every tier's replicas.
    pub batcher: BatcherConfig,
    /// Weighted-fair class quotas applied to EVERY tier's pool (see
    /// [`PoolConfig::class_weights`]); `None` keeps untagged FIFO
    /// admission everywhere.
    pub class_weights: Option<[f64; Class::COUNT]>,
}

/// One tier's pool + its fleet-level accounting handles.  Counters and
/// gauges are resolved once at spawn so the routing hot path and the
/// per-tick gauge publish never pay a format!/registry-lock.
pub struct TierPool {
    gpu: Gpu,
    pool: Arc<ReplicaPool>,
    adapter: Arc<StageAdapter>,
    exited: Arc<crate::metrics::Counter>,
    deferred: Arc<crate::metrics::Counter>,
    outstanding_gauge: Arc<crate::metrics::Gauge>,
    live_gauge: Arc<crate::metrics::Gauge>,
    exit_frac_gauge: Arc<crate::metrics::Gauge>,
    exit_frac_window_gauge: Arc<crate::metrics::Gauge>,
    /// `exited` as of the previous `refresh_gauges` tick (the windowed
    /// exit-fraction delta base).
    prev_exited: AtomicU64,
}

impl TierPool {
    /// The underlying replica pool (scale_up / drain / advance /
    /// replica_seconds all apply per tier).
    pub fn pool(&self) -> &Arc<ReplicaPool> {
        &self.pool
    }

    pub fn gpu(&self) -> Gpu {
        self.gpu
    }

    /// Requests that exited the cascade at this tier.
    pub fn exited(&self) -> u64 {
        self.exited.get()
    }

    /// Requests this tier deferred onward.
    pub fn deferred(&self) -> u64 {
        self.deferred.get()
    }
}

/// One early-exited request's shadow copy: enough to replay it against
/// the downstream tiers and score the early answer.
struct ShadowJob {
    /// Tier the request exited at (the monitored tier).
    tier: usize,
    /// The score it exited with -- the [`CalPoint`] x-coordinate.
    score: f32,
    /// The early answer the client received.
    prediction: u32,
    /// The request itself, re-submitted downstream.
    request: Request,
}

/// The router's side of the shadow path: a bounded non-blocking sender
/// plus pre-resolved accounting (fleet registry).
struct ShadowHandle {
    tx: SyncSender<ShadowJob>,
    submitted: Arc<crate::metrics::Counter>,
    dropped: Arc<crate::metrics::Counter>,
}

/// Bounded shadow queue: serving never blocks on the observatory; a
/// full queue costs one dropped observation (`shadow_dropped`).
const SHADOW_QUEUE: usize = 256;

/// The single off-path worker: drains shadow jobs, routes each through
/// the tiers BELOW its exit tier, and records the agreement outcome.
/// Exits when the fleet (the only sender) is dropped.
fn shadow_worker(
    rx: Receiver<ShadowJob>,
    pools: Vec<Arc<ReplicaPool>>,
    monitor: Arc<DriftMonitor>,
    shed: Arc<crate::metrics::Counter>,
) {
    while let Ok(job) = rx.recv() {
        let mut agreed = None;
        for pool in pools.iter().skip(job.tier + 1) {
            match pool.infer(job.request.clone()) {
                Ok(v) if v.exit_tier != DEFERRED => {
                    agreed = Some(v.prediction == job.prediction);
                    break;
                }
                Ok(_) => continue, // deferred: ask the next tier down
                Err(_) => break,   // refused: no observation, not an exit
            }
        }
        match agreed {
            Some(correct) => {
                monitor.record(job.tier, CalPoint { score: job.score, correct });
            }
            None => shed.inc(),
        }
    }
}

/// The tiered fleet: one pool per cascade level plus the deferral
/// router.  See the module docs for layout and guarantees.
pub struct TieredFleet {
    tiers: Vec<TierPool>,
    metrics: Arc<Metrics>,
    submitted: Arc<crate::metrics::Counter>,
    completed: Arc<crate::metrics::Counter>,
    shed: Arc<crate::metrics::Counter>,
    latency: Arc<crate::metrics::Histogram>,
    dollars_gauge: Arc<crate::metrics::Gauge>,
    dollars_per_hour_gauge: Arc<crate::metrics::Gauge>,
    /// `completed` as of the previous `refresh_gauges` tick (the
    /// windowed exit-fraction delta base).
    prev_completed: AtomicU64,
    /// Shared tracer (when tracing is on): the router owns each
    /// request's terminal spans; tier pools record the per-hop ones.
    tracer: Option<Arc<Tracer>>,
    /// Shadow path into the drift observatory (None when not spawned
    /// with a [`DriftConfig`], or when its sampling is off).
    shadow: Option<ShadowHandle>,
    drift: Option<Arc<DriftMonitor>>,
    /// Per-class SLO observatory (None when not spawned with an
    /// [`SloConfig`]).  The fleet keeps the class books itself in
    /// [`TieredFleet::infer`] -- tier pools never double-count.
    slo: Option<Arc<SloObservatory>>,
}

impl TieredFleet {
    /// Spawn one pool per cascade level over a shared stage classifier.
    /// `metrics` is the FLEET registry (router counters, gauges, event
    /// log); each tier pool gets its own private registry so per-tier
    /// arrival rates stay separable for the autoscaler.
    pub fn spawn(
        stage: Arc<dyn StageClassifier>,
        cfg: TieredFleetConfig,
        metrics: Arc<Metrics>,
    ) -> Result<TieredFleet> {
        TieredFleet::spawn_with_obs(stage, cfg, metrics, None)
    }

    /// Spawn with an optional shared tracer: the router emits each
    /// sampled request's terminal spans (enqueue / defer hops / shed /
    /// complete) and every tier pool records its queue-wait / infer
    /// spans tagged with its tier index.  Each tier's private
    /// `queue_wait_s` / `service_s` histograms are also ALIASED into
    /// the fleet registry as `tier_{i}_queue_wait_s` /
    /// `tier_{i}_service_s` -- same atomics, second name -- so the
    /// per-tier latency breakdown is scrapeable from the fleet without
    /// any extra hot-path work.
    pub fn spawn_with_obs(
        stage: Arc<dyn StageClassifier>,
        cfg: TieredFleetConfig,
        metrics: Arc<Metrics>,
        tracer: Option<Arc<Tracer>>,
    ) -> Result<TieredFleet> {
        TieredFleet::spawn_with_drift(stage, cfg, metrics, tracer, None)
    }

    /// Spawn with the drift observatory attached: 1-in-N early exits
    /// are shadow-routed through the downstream tiers off the critical
    /// path and scored into a [`DriftMonitor`] (see the module docs).
    /// Each tier's spec theta seeds the monitor's `theta_cal` gauge.
    /// `None` (or `sample_every == 0`, or a single-tier fleet) spawns
    /// no shadow machinery at all.
    pub fn spawn_with_drift(
        stage: Arc<dyn StageClassifier>,
        cfg: TieredFleetConfig,
        metrics: Arc<Metrics>,
        tracer: Option<Arc<Tracer>>,
        drift_cfg: Option<DriftConfig>,
    ) -> Result<TieredFleet> {
        TieredFleet::spawn_with_slo(stage, cfg, metrics, tracer, drift_cfg, None)
    }

    /// Spawn with the per-class SLO observatory attached: the fleet
    /// books every request into its class ledger (submitted / completed
    /// / shed / deferred) alongside the fleet-level counters, so
    /// `class_{c}_submitted == class_{c}_completed + class_{c}_shed`
    /// holds per class AND the class ledgers sum to the fleet identity.
    /// `None` spawns no per-class machinery at all.
    pub fn spawn_with_slo(
        stage: Arc<dyn StageClassifier>,
        cfg: TieredFleetConfig,
        metrics: Arc<Metrics>,
        tracer: Option<Arc<Tracer>>,
        drift_cfg: Option<DriftConfig>,
        slo_cfg: Option<SloConfig>,
    ) -> Result<TieredFleet> {
        anyhow::ensure!(
            cfg.tiers.len() == stage.n_levels(),
            "fleet has {} tier specs but the cascade has {} levels",
            cfg.tiers.len(),
            stage.n_levels()
        );
        let tiers = cfg
            .tiers
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                let adapter = Arc::new(StageAdapter::new(
                    Arc::clone(&stage),
                    i,
                    spec.theta,
                ));
                let tier_metrics = Metrics::new();
                metrics.register_histogram(
                    &format!("tier_{i}_queue_wait_s"),
                    tier_metrics.histogram("queue_wait_s"),
                );
                metrics.register_histogram(
                    &format!("tier_{i}_service_s"),
                    tier_metrics.histogram("service_s"),
                );
                let pool = Arc::new(ReplicaPool::spawn_with_obs(
                    Arc::clone(&adapter) as Arc<dyn BatchClassifier>,
                    PoolConfig {
                        replicas: spec.replicas,
                        max_queue: spec.max_queue,
                        batcher: cfg.batcher,
                        gpu: spec.gpu,
                        min_replicas: spec.min_replicas,
                        max_replicas: spec.max_replicas,
                        class_weights: cfg.class_weights,
                    },
                    tier_metrics,
                    None,
                    ObsHook::for_tier(tracer.clone(), i),
                ));
                TierPool {
                    gpu: spec.gpu,
                    pool,
                    adapter,
                    exited: metrics.counter(&format!("tier_{i}_exited")),
                    deferred: metrics.counter(&format!("tier_{i}_deferred")),
                    outstanding_gauge: metrics
                        .gauge(&format!("tier_{i}_outstanding")),
                    live_gauge: metrics.gauge(&format!("tier_{i}_live")),
                    exit_frac_gauge: metrics.gauge(&format!("tier_{i}_exit_frac")),
                    exit_frac_window_gauge: metrics
                        .gauge(&format!("tier_{i}_exit_frac_window")),
                    prev_exited: AtomicU64::new(0),
                }
            })
            .collect::<Vec<TierPool>>();
        let (shadow, drift) = match drift_cfg {
            Some(dc) if dc.sample_every > 0 && tiers.len() > 1 => {
                let thetas: Vec<Option<f32>> =
                    cfg.tiers.iter().map(|s| s.theta).collect();
                let monitor = DriftMonitor::new(dc, &thetas, &metrics);
                let (tx, rx) = sync_channel::<ShadowJob>(SHADOW_QUEUE);
                let pools: Vec<Arc<ReplicaPool>> =
                    tiers.iter().map(|t| Arc::clone(&t.pool)).collect();
                let mon = Arc::clone(&monitor);
                let shed = metrics.counter("shadow_shed");
                std::thread::Builder::new()
                    .name("abc-shadow".into())
                    .spawn(move || shadow_worker(rx, pools, mon, shed))
                    .expect("spawn shadow worker");
                (
                    Some(ShadowHandle {
                        tx,
                        submitted: metrics.counter("shadow_submitted"),
                        dropped: metrics.counter("shadow_dropped"),
                    }),
                    Some(monitor),
                )
            }
            _ => (None, None),
        };
        Ok(TieredFleet {
            tiers,
            submitted: metrics.counter("fleet_submitted"),
            completed: metrics.counter("fleet_completed"),
            shed: metrics.counter("fleet_shed"),
            latency: metrics.histogram("request_latency_s"),
            dollars_gauge: metrics.gauge("fleet_dollars"),
            dollars_per_hour_gauge: metrics.gauge("fleet_dollars_per_hour"),
            prev_completed: AtomicU64::new(0),
            slo: slo_cfg.map(|sc| SloObservatory::new(sc, &metrics)),
            metrics,
            tracer,
            shadow,
            drift,
        })
    }

    /// The drift observatory, when the fleet was spawned with one.
    pub fn drift(&self) -> Option<&Arc<DriftMonitor>> {
        self.drift.as_ref()
    }

    /// The per-class SLO observatory, when the fleet was spawned with
    /// one.
    pub fn slo(&self) -> Option<&Arc<SloObservatory>> {
        self.slo.as_ref()
    }

    /// The attached tracer, when sampling is enabled.
    pub fn tracer(&self) -> Option<&Arc<Tracer>> {
        self.tracer.as_ref().filter(|t| t.sample_every() > 0)
    }

    pub fn n_tiers(&self) -> usize {
        self.tiers.len()
    }

    pub fn tier(&self, i: usize) -> &TierPool {
        &self.tiers[i]
    }

    pub fn tiers(&self) -> &[TierPool] {
        &self.tiers
    }

    /// The fleet-level registry (router counters, gauges, event log).
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Actuate one tier's gear: swap its deferral-threshold override
    /// (None restores the stage's own calibrated policy) and retune its
    /// pool's batch cap.  Both only affect batches formed later, so a
    /// per-tier shift never drops or duplicates in-flight requests --
    /// the tiered form of a monolithic `GearHandle` swap.  The control
    /// plane (`control::ControlLoop`) drives this from the DOWNSTREAM
    /// tier's load: lowering tier N's theta exits more requests at tier
    /// N, thinning tier N+1's arrival stream.  The final tier's theta
    /// is ignored by the stage contract (it always exits).
    pub fn set_tier_gear(&self, tier: usize, theta: Option<f32>, max_batch: usize) {
        let t = &self.tiers[tier];
        t.adapter.set_theta(theta);
        t.pool.set_max_batch(max_batch);
    }

    /// The active threshold override of one tier (diagnostics/tests).
    pub fn tier_theta(&self, tier: usize) -> Option<f32> {
        self.tiers[tier].adapter.theta()
    }

    /// Theta-only actuation: swap one tier's deferral threshold and
    /// leave its batch cap alone.  This is what the control plane's
    /// drift re-grounding drives -- unlike [`TieredFleet::set_tier_gear`]
    /// it is not a ladder rung, it is the live estimate replacing a
    /// stale calibration.
    pub fn set_tier_theta(&self, tier: usize, theta: Option<f32>) {
        self.tiers[tier].adapter.set_theta(theta);
    }

    /// Route one request through the cascade: submit to tier 1's pool,
    /// forward on deferral, answer at the first exit.  Blocks until the
    /// verdict (the serving front end and loadgen both call through
    /// worker/handler threads).  Exactly one of completed/shed is
    /// counted per call; an `Overloaded` from ANY tier sheds the whole
    /// request (work already done at earlier tiers is sunk cost -- the
    /// paper's queueing model makes the same call).
    pub fn infer(&self, request: Request) -> Result<Verdict, PoolError> {
        let t0 = Instant::now();
        self.submitted.inc();
        // the class ledger mirrors the fleet counters exactly: one
        // submitted here, exactly one completed/shed at the terminal
        // outcome below, so the per-class identities sum to the fleet's
        if let Some(slo) = &self.slo {
            slo.record_submitted(request.class);
        }
        // one sampling decision covers the whole routed path; the tier
        // pools make the same deterministic call for their own spans
        let span_tracer = self.tracer().filter(|t| t.sampled(request.id));
        if let Some(t) = span_tracer {
            t.record(request.id, SpanKind::Enqueue, 0, 0.0);
        }
        let mut scores: Vec<f32> = Vec::with_capacity(self.tiers.len());
        for (i, tier) in self.tiers.iter().enumerate() {
            let hop_t0 = Instant::now();
            let hop = match tier.pool.infer(request.clone()) {
                Ok(v) => v,
                Err(e) => {
                    // any refusal (overloaded / rejected / failed) is the
                    // request's single terminal outcome; counting it keeps
                    // submitted == completed + shed exact.  The error
                    // itself tells the caller which tier refused and why.
                    self.shed.inc();
                    if let Some(slo) = &self.slo {
                        slo.record_shed(request.class);
                    }
                    if let Some(t) = span_tracer {
                        t.record_with_class(
                            request.id,
                            SpanKind::Shed,
                            i,
                            0.0,
                            Some(request.class.name()),
                        );
                    }
                    return Err(e);
                }
            };
            scores.extend(hop.tier_scores);
            if hop.exit_tier != DEFERRED {
                tier.exited.inc();
                self.completed.inc();
                let latency_s = t0.elapsed().as_secs_f64();
                self.latency.record(latency_s);
                if let Some(slo) = &self.slo {
                    slo.record_completed(request.class, latency_s);
                }
                if let Some(t) = span_tracer {
                    t.record_with_class(
                        request.id,
                        SpanKind::Complete,
                        i,
                        latency_s,
                        Some(request.class.name()),
                    );
                }
                // shadow-sample this early exit into the drift
                // observatory: the client gets the answer below either
                // way; a full shadow queue drops the observation (one
                // counter bump), never blocks serving.  The final tier
                // has no downstream to agree with.
                if let (Some(sh), Some(mon)) = (&self.shadow, &self.drift) {
                    if i + 1 < self.tiers.len() && mon.sampled(request.id) {
                        let job = ShadowJob {
                            tier: i,
                            score: scores.last().copied().unwrap_or(0.0),
                            prediction: hop.prediction,
                            request: request.clone(),
                        };
                        match sh.tx.try_send(job) {
                            Ok(()) => sh.submitted.inc(),
                            Err(_) => sh.dropped.inc(),
                        }
                    }
                }
                return Ok(Verdict {
                    request_id: hop.request_id,
                    prediction: hop.prediction,
                    exit_tier: hop.exit_tier,
                    tier_scores: scores,
                    latency_s,
                });
            }
            tier.deferred.inc();
            if let Some(slo) = &self.slo {
                slo.record_deferred(request.class);
            }
            if let Some(t) = span_tracer {
                // the defer hop's duration is the full stay at this tier
                t.record(
                    request.id,
                    SpanKind::Defer,
                    i,
                    hop_t0.elapsed().as_secs_f64(),
                );
            }
        }
        // unreachable by the StageClassifier contract (the final tier
        // never defers); fail loudly rather than silently dropping
        self.shed.inc();
        if let Some(slo) = &self.slo {
            slo.record_shed(request.class);
        }
        Err(PoolError::Failed(format!(
            "request {} deferred past the final tier",
            request.id
        )))
    }

    /// Advance every tier pool's replica lifecycle (promote warmed,
    /// retire drained).  Returns the aggregate transitions.
    pub fn advance(&self, now: Instant) -> Lifecycle {
        let mut total = Lifecycle::default();
        for t in &self.tiers {
            let l = t.pool.advance(now);
            total.warmed += l.warmed;
            total.retired += l.retired;
        }
        total
    }

    /// Total outstanding requests across every tier's pool.
    pub fn total_outstanding(&self) -> usize {
        self.tiers.iter().map(|t| t.pool.total_outstanding()).sum()
    }

    /// The fleet rental bill so far: every tier's `replica_seconds`
    /// priced at its own GPU class (paper Table 4).
    pub fn dollars(&self) -> f64 {
        self.tiers.iter().map(|t| t.pool.dollars()).sum()
    }

    /// Current burn rate: every provisioned slot at its tier's price.
    pub fn dollars_per_hour(&self) -> f64 {
        self.tiers.iter().map(|t| t.pool.dollars_per_hour()).sum()
    }

    /// Per-tier live replica counts (diagnostics / benches).
    pub fn replicas_per_tier(&self) -> Vec<usize> {
        self.tiers.iter().map(|t| t.pool.n_replicas()).collect()
    }

    /// Publish the fleet's derived telemetry as gauges in the fleet
    /// registry: per-tier queue depth and live replicas, per-tier exit
    /// fractions, and the rental bill.  Called by the tiered autoscaler
    /// every tick and by the serving front end before a `stats`
    /// snapshot.
    ///
    /// Two exit-fraction gauges per tier: `tier_{i}_exit_frac` is the
    /// ALL-TIME cumulative ratio (stable, but an hour of history masks
    /// a shift that happened a minute ago), and
    /// `tier_{i}_exit_frac_window` is the delta since the previous
    /// refresh tick -- the drift observatory's exit-rate signal.  With
    /// no completions since the last tick the window gauge keeps its
    /// previous value (no traffic is not evidence of a shift).  The
    /// counters are read racily against in-flight completions, so a
    /// window fraction can transiently misattribute a completion by
    /// one tick; both gauges are telemetry, not accounting.
    pub fn refresh_gauges(&self) {
        let done_now = self.completed.get();
        let done = done_now.max(1) as f64;
        let done_prev = self.prev_completed.swap(done_now, Ordering::Relaxed);
        let delta_done = done_now.saturating_sub(done_prev);
        for t in &self.tiers {
            t.outstanding_gauge.set(t.pool.total_outstanding() as f64);
            t.live_gauge.set(t.pool.n_replicas() as f64);
            let e_now = t.exited.get();
            t.exit_frac_gauge.set(e_now as f64 / done);
            let e_prev = t.prev_exited.swap(e_now, Ordering::Relaxed);
            if delta_done > 0 {
                t.exit_frac_window_gauge.set(
                    e_now.saturating_sub(e_prev) as f64 / delta_done as f64,
                );
            }
        }
        self.dollars_gauge.set(self.dollars());
        self.dollars_per_hour_gauge.set(self.dollars_per_hour());
        if let Some(slo) = &self.slo {
            slo.refresh();
        }
    }

    /// Gracefully wind the fleet down: begin draining every pool to its
    /// `min_replicas` floor, then advance until nothing is left
    /// Draining (bounded wait).  In-flight work still completes; no
    /// request is dropped.
    pub fn quiesce(&self, timeout: Duration) {
        for t in &self.tiers {
            // the pool's own floor bounds how far this can go
            t.pool.drain(usize::MAX);
        }
        let deadline = Instant::now() + timeout;
        loop {
            self.advance(Instant::now());
            let draining: usize =
                self.tiers.iter().map(|t| t.pool.counts().2).sum();
            if draining == 0 || Instant::now() >= deadline {
                return;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cascade::classify_batch_staged;
    use crate::trafficgen::{StagedSynthetic, SyntheticClassifier};

    const DIM: usize = 3;
    const LEVELS: usize = 3;

    fn staged(per_row_us: u64) -> Arc<StagedSynthetic> {
        Arc::new(StagedSynthetic::new(
            SyntheticClassifier::new(
                DIM,
                LEVELS,
                Duration::ZERO,
                Duration::from_micros(per_row_us),
            ),
            vec![0.15, 0.25, 0.60],
        ))
    }

    fn fleet_cfg(replicas: usize, max_queue: usize) -> TieredFleetConfig {
        TieredFleetConfig {
            tiers: vec![
                TierSpec::fixed(Gpu::V100, replicas, max_queue),
                TierSpec::fixed(Gpu::A6000, replicas, max_queue),
                TierSpec::fixed(Gpu::H100, replicas, max_queue),
            ],
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_micros(200),
            },
            class_weights: None,
        }
    }

    fn req(id: u64) -> Request {
        Request {
            id,
            features: vec![id as f32 * 0.37 - 3.0, 0.0, 0.0],
            arrival_s: 0.0,
            class: Class::Standard,
        }
    }

    #[test]
    fn routed_results_match_the_inprocess_sieve() {
        let stage = staged(50);
        let fleet = TieredFleet::spawn(
            Arc::clone(&stage) as Arc<dyn StageClassifier>,
            fleet_cfg(1, 64),
            Metrics::new(),
        )
        .unwrap();
        let n = 40;
        let mut feats = Vec::with_capacity(n * DIM);
        for id in 0..n as u64 {
            feats.extend_from_slice(&req(id).features);
        }
        let want = classify_batch_staged(stage.as_ref(), &feats, n, None).unwrap();
        for id in 0..n as u64 {
            let v = fleet.infer(req(id)).unwrap();
            let w = &want[id as usize];
            assert_eq!(v.prediction, w.prediction, "id {id}");
            assert_eq!(v.exit_tier, w.exit_level, "id {id}");
            assert_eq!(v.tier_scores, w.scores, "id {id}");
        }
        // conservation + routing counters
        assert_eq!(fleet.metrics().counter("fleet_completed").get(), n as u64);
        assert_eq!(fleet.metrics().counter("fleet_shed").get(), 0);
        let exited: u64 = (0..LEVELS).map(|i| fleet.tier(i).exited()).sum();
        assert_eq!(exited, n as u64);
        // tier-2 arrivals == tier-1 deferrals (the autoscaler's signal)
        assert_eq!(
            fleet.tier(0).deferred(),
            fleet.tier(1).exited() + fleet.tier(1).deferred()
        );
        assert_eq!(fleet.total_outstanding(), 0);
    }

    #[test]
    fn interior_shed_counts_once_and_propagates() {
        // tier 2 (A6000) has a tiny queue and a slow stage: deferred
        // requests shed there while tier-1 exits still complete
        let stage = Arc::new(StagedSynthetic::new(
            SyntheticClassifier::new(
                DIM,
                LEVELS,
                Duration::ZERO,
                Duration::from_millis(30),
            ),
            vec![0.0, 1.0, 1.0], // tier 1 free, deeper tiers slow
        ));
        let fleet = TieredFleet::spawn(
            stage as Arc<dyn StageClassifier>,
            TieredFleetConfig {
                tiers: vec![
                    TierSpec::fixed(Gpu::V100, 1, 64),
                    TierSpec::fixed(Gpu::A6000, 1, 1),
                    TierSpec::fixed(Gpu::H100, 1, 1),
                ],
                batcher: BatcherConfig {
                    max_batch: 1,
                    max_wait: Duration::from_micros(100),
                },
                class_weights: None,
            },
            Metrics::new(),
        )
        .unwrap();
        let n = 24u64;
        let mut done = 0u64;
        let mut shed = 0u64;
        let fleet_ref = &fleet;
        std::thread::scope(|s| {
            let results: Vec<_> = (0..n)
                .map(|id| s.spawn(move || fleet_ref.infer(req(id))))
                .collect();
            for h in results {
                match h.join().unwrap() {
                    Ok(_) => done += 1,
                    Err(PoolError::Overloaded { .. }) => shed += 1,
                    Err(e) => panic!("unexpected {e:?}"),
                }
            }
        });
        assert_eq!(done + shed, n, "exactly-once at the fleet boundary");
        assert!(shed > 0, "tiny interior queue never shed");
        assert_eq!(fleet.metrics().counter("fleet_completed").get(), done);
        assert_eq!(fleet.metrics().counter("fleet_shed").get(), shed);
        assert_eq!(
            fleet.metrics().counter("fleet_submitted").get(),
            done + shed
        );
        assert_eq!(fleet.total_outstanding(), 0);
    }

    #[test]
    fn dollars_sum_per_tier_prices_and_gauges_publish() {
        let fleet = TieredFleet::spawn(
            staged(10) as Arc<dyn StageClassifier>,
            fleet_cfg(2, 16),
            Metrics::new(),
        )
        .unwrap();
        std::thread::sleep(Duration::from_millis(20));
        for id in 0..10 {
            let _ = fleet.infer(req(id));
        }
        let d = fleet.dollars();
        assert!(d > 0.0);
        let by_hand: f64 = (0..LEVELS).map(|i| fleet.tier(i).pool().dollars()).sum();
        assert!((d - by_hand).abs() < 1e-6);
        // burn rate: 2 replicas per tier at V100+A6000+H100 prices
        let burn = fleet.dollars_per_hour();
        assert!((burn - 2.0 * (0.50 + 0.80 + 2.49)).abs() < 1e-9, "{burn}");
        fleet.refresh_gauges();
        assert!(fleet.metrics().gauge("fleet_dollars").get() > 0.0);
        assert!(fleet.metrics().gauge("fleet_dollars_per_hour").get() > 0.0);
        let fracs: f64 = (0..LEVELS)
            .map(|i| fleet.metrics().gauge(&format!("tier_{i}_exit_frac")).get())
            .sum();
        assert!((fracs - 1.0).abs() < 1e-9, "exit fractions sum to 1: {fracs}");
        assert_eq!(fleet.replicas_per_tier(), vec![2, 2, 2]);
    }

    #[test]
    fn set_tier_gear_swaps_theta_and_widens_tier1_exits() {
        let fleet = TieredFleet::spawn(
            staged(20) as Arc<dyn StageClassifier>,
            fleet_cfg(1, 256),
            Metrics::new(),
        )
        .unwrap();
        assert_eq!(fleet.tier_theta(0), None, "specs start at the policy");
        let n = 120u64;
        for id in 0..n {
            fleet.infer(req(id)).unwrap();
        }
        let exits_default = fleet.tier(0).exited();
        // lower tier 1's theta: would-defer rows now exit early, so the
        // SAME request population exits tier 1 strictly more often
        fleet.set_tier_gear(0, Some(0.2), 8);
        assert_eq!(fleet.tier_theta(0), Some(0.2));
        for id in 0..n {
            fleet.infer(req(id)).unwrap();
        }
        let exits_shifted = fleet.tier(0).exited() - exits_default;
        assert!(
            exits_shifted > exits_default,
            "theta 0.2 exits {exits_shifted} <= default {exits_default}"
        );
        // restoring the policy restores the default split exactly
        fleet.set_tier_gear(0, None, 8);
        assert_eq!(fleet.tier_theta(0), None);
        for id in 0..n {
            fleet.infer(req(id)).unwrap();
        }
        let exits_restored =
            fleet.tier(0).exited() - exits_default - exits_shifted;
        assert_eq!(exits_restored, exits_default);
        // exactly-once accounting held across the swaps
        assert_eq!(
            fleet.metrics().counter("fleet_completed").get(),
            3 * n,
        );
        assert_eq!(fleet.metrics().counter("fleet_shed").get(), 0);
    }

    #[test]
    fn interior_drain_never_loses_requests() {
        let fleet = Arc::new(
            TieredFleet::spawn(
                staged(2_000) as Arc<dyn StageClassifier>,
                TieredFleetConfig {
                    tiers: vec![
                        TierSpec::elastic(Gpu::V100, 1, 2, 32),
                        TierSpec::elastic(Gpu::A6000, 1, 2, 32),
                        TierSpec::fixed(Gpu::H100, 1, 32),
                    ],
                    batcher: BatcherConfig {
                        max_batch: 2,
                        max_wait: Duration::from_micros(200),
                    },
                    class_weights: None,
                },
                Metrics::new(),
            )
            .unwrap(),
        );
        // grow the interior tier, park work everywhere, then drain it
        fleet.tier(1).pool().scale_up(1, Duration::ZERO);
        std::thread::scope(|s| {
            let submitters: Vec<_> = (0..30u64)
                .map(|id| {
                    let f = Arc::clone(&fleet);
                    s.spawn(move || f.infer(req(id)))
                })
                .collect();
            // mid-run: drain the interior tier back to one replica
            std::thread::sleep(Duration::from_millis(5));
            let drained = fleet.tier(1).pool().drain(1);
            assert_eq!(drained.len(), 1);
            let mut done = 0u64;
            let mut shed = 0u64;
            for h in submitters {
                match h.join().unwrap() {
                    Ok(_) => done += 1,
                    Err(PoolError::Overloaded { .. }) => shed += 1,
                    Err(e) => panic!("unexpected {e:?}"),
                }
            }
            assert_eq!(done + shed, 30);
            assert_eq!(
                fleet.metrics().counter("fleet_completed").get()
                    + fleet.metrics().counter("fleet_shed").get(),
                30
            );
        });
        // the drained replica retires once idle; the fleet still serves
        fleet.quiesce(Duration::from_secs(5));
        assert_eq!(fleet.tier(1).pool().counts().2, 0, "nothing left draining");
        fleet.infer(req(999)).unwrap();
        assert_eq!(fleet.total_outstanding(), 0);
    }

    #[test]
    fn shadow_sampling_observes_without_double_counting() {
        use crate::obs::drift::DriftConfig;
        let metrics = Metrics::new();
        let fleet = TieredFleet::spawn_with_drift(
            staged(20) as Arc<dyn StageClassifier>,
            fleet_cfg(1, 256),
            Arc::clone(&metrics),
            None,
            Some(DriftConfig {
                sample_every: 1, // shadow every early exit
                min_samples: 1,
                ..DriftConfig::default()
            }),
        )
        .unwrap();
        let n = 80u64;
        for id in 0..n {
            fleet.infer(req(id)).unwrap();
        }
        // exactly-once at the fleet boundary is UNTOUCHED by shadowing
        assert_eq!(metrics.counter("fleet_submitted").get(), n);
        assert_eq!(metrics.counter("fleet_completed").get(), n);
        assert_eq!(metrics.counter("fleet_shed").get(), 0);
        let exited: u64 = (0..LEVELS).map(|i| fleet.tier(i).exited()).sum();
        assert_eq!(exited, n, "tier exit counters see only client rows");
        // every early exit was shadow-submitted (sample_every 1, and
        // 80 jobs cannot overflow the 256-slot queue)
        let early = n - fleet.tier(LEVELS - 1).exited();
        assert_eq!(metrics.counter("shadow_submitted").get(), early);
        assert_eq!(metrics.counter("shadow_dropped").get(), 0);
        // wait for the worker to drain the queue
        let deadline = Instant::now() + Duration::from_secs(10);
        let recorded = || {
            (0..LEVELS - 1)
                .map(|i| metrics.counter(&format!("tier_{i}_shadow_samples")).get())
                .sum::<u64>()
                + metrics.counter("shadow_shed").get()
        };
        while recorded() < early && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(recorded(), early, "every shadow job observed or shed");
        // the default synthetic stage is faithful: downstream always
        // agrees with an early exit, so the live window is all-correct
        let mon = fleet.drift().expect("monitor attached");
        let s = mon.status(0).expect("tier 0 monitored");
        assert!(s.samples > 0);
        assert_eq!(s.agreement, 1.0);
        assert_eq!(s.failure_rate, 0.0);
        // and STILL no fleet-counter movement from the shadow traffic
        assert_eq!(metrics.counter("fleet_completed").get(), n);
        assert_eq!(fleet.total_outstanding(), 0);
    }

    #[test]
    fn windowed_exit_frac_tracks_recent_traffic_only() {
        let fleet = TieredFleet::spawn(
            staged(20) as Arc<dyn StageClassifier>,
            fleet_cfg(1, 256),
            Metrics::new(),
        )
        .unwrap();
        let n = 120u64;
        for id in 0..n {
            fleet.infer(req(id)).unwrap();
        }
        fleet.refresh_gauges();
        let g = |name: &str| fleet.metrics().gauge(name).get();
        // first tick: the window IS all time
        assert!((g("tier_0_exit_frac_window") - g("tier_0_exit_frac")).abs() < 1e-9);
        // drop tier 1's theta so the SAME population exits tier 0 more
        fleet.set_tier_gear(0, Some(0.2), 4);
        for id in 0..n {
            fleet.infer(req(id)).unwrap();
        }
        fleet.refresh_gauges();
        let all_time = g("tier_0_exit_frac");
        let window = g("tier_0_exit_frac_window");
        assert!(
            window > all_time + 1e-9,
            "window {window} must outrun the cumulative {all_time} after a shift"
        );
        // no traffic between ticks: the window gauge holds its value
        fleet.refresh_gauges();
        assert!((g("tier_0_exit_frac_window") - window).abs() < 1e-9);
    }

    #[test]
    fn class_ledgers_sum_to_the_fleet_identity() {
        use crate::obs::slo::SloConfig;
        let metrics = Metrics::new();
        let fleet = TieredFleet::spawn_with_slo(
            staged(20) as Arc<dyn StageClassifier>,
            fleet_cfg(1, 256),
            Arc::clone(&metrics),
            None,
            None,
            Some(SloConfig::default()),
        )
        .unwrap();
        let n = 30u64;
        for id in 0..n {
            let class = Class::ALL[(id % 3) as usize];
            fleet.infer(Request { class, ..req(id) }).unwrap();
        }
        let slo = fleet.slo().expect("observatory attached");
        let mut sub = 0u64;
        let mut done = 0u64;
        let mut shed = 0u64;
        for class in Class::ALL {
            let s = slo.status(class);
            assert_eq!(s.submitted, 10, "{} submitted", class.name());
            assert_eq!(s.submitted, s.completed + s.shed, "{}", class.name());
            sub += s.submitted;
            done += s.completed;
            shed += s.shed;
        }
        // the per-class ledgers ARE the fleet counters, partitioned
        assert_eq!(sub, metrics.counter("fleet_submitted").get());
        assert_eq!(done, metrics.counter("fleet_completed").get());
        assert_eq!(shed, metrics.counter("fleet_shed").get());
        // refresh_gauges folds the slo refresh into the normal publish
        // path (no panic, no double counting); a direct tick then
        // registers and publishes the class gauges deterministically
        fleet.refresh_gauges();
        slo.tick(1.0);
        let g = metrics.gauge("class_premium_slo_attainment").get();
        assert!(g > 0.0 && g <= 1.0, "attainment gauge {g}");
    }
}
