//! The cascade controller (paper Algorithm 1).
//!
//! Owns the tier ladder and a `DeferralPolicy`; drives a batch of samples
//! through the cascade: run tier 1's ensemble on everything, apply the
//! agreement rule, gather the deferred subset, run tier 2 on it, and so
//! on -- the final tier answers whatever reaches it.  This "sieve"
//! execution is the batch-friendly equivalent of per-sample cascading and
//! is what the serving pipeline and all experiments use.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::deferral::DeferralPolicy;
use crate::runtime::executable::TierExecutable;
use crate::types::{Decision, Label};

/// Per-sample cascade outcome.
#[derive(Debug, Clone)]
pub struct CascadeResult {
    pub prediction: Label,
    /// 1-based position in the cascade ladder at which the sample exited.
    pub exit_level: usize,
    /// Deferral-rule score observed at each visited level.
    pub scores: Vec<f32>,
}

/// Aggregate statistics of a cascade run over a labelled set.
#[derive(Debug, Clone)]
pub struct CascadeReport {
    pub n: usize,
    pub accuracy: f64,
    /// Fraction of samples exiting at each level (sums to 1).
    pub exit_fractions: Vec<f64>,
    /// Mean number of levels each sample visited.
    pub mean_levels_visited: f64,
}

/// Anything the serving pipeline can push a batch through: the real PJRT
/// cascade, or a synthetic stand-in for load testing
/// (`trafficgen::SyntheticClassifier`).  The `ReplicaPool` spawns one
/// `Pipeline` per replica over a shared `Arc<dyn BatchClassifier>`.
pub trait BatchClassifier: Send + Sync {
    /// Feature dimensionality every request must match.
    fn dim(&self) -> usize;
    /// Number of cascade levels (bounds `exit_level`).
    fn n_levels(&self) -> usize;
    /// Classify `n` row-major `n x dim` rows, results in input order.
    fn classify_batch(&self, features: &[f32], n: usize) -> Result<Vec<CascadeResult>>;
    /// Classify under an active gear (`planner::GearConfig`): backends
    /// that can retune per batch (threshold overrides, scaled synthetic
    /// service time) override this; the default ignores the gear so
    /// every backend stays usable behind a geared pipeline.
    fn classify_batch_geared(
        &self,
        features: &[f32],
        n: usize,
        gear: &crate::planner::gear::GearConfig,
    ) -> Result<Vec<CascadeResult>> {
        let _ = gear;
        self.classify_batch(features, n)
    }
}

/// Per-sample outcome of ONE tier's pass over a (sub-)batch.
#[derive(Debug, Clone, PartialEq)]
pub struct StageResult {
    /// Deferral-rule score observed at this tier.
    pub score: f32,
    /// `Some(prediction)`: the sample exits at this tier; `None`: defer
    /// to the next tier.  The final tier never returns `None`.
    pub decision: Option<Label>,
}

/// Stage-wise execution: run ONE cascade tier over a batch and report
/// per-row exit/defer decisions, without touching any other tier.
///
/// This is the unit both execution layouts are built from:
/// * **monolithic** -- [`classify_batch_staged`] drives every stage
///   in-process over the active subset (what `Cascade::classify_batch`
///   does on a single replica);
/// * **tiered** -- `coordinator::router::TieredFleet` puts each stage
///   behind its own `ReplicaPool` (its own GPU class, queue and
///   autoscaling) and routes deferrals between pools.
///
/// Both layouts MUST produce identical results on the same inputs and
/// thetas (property-tested in rust/tests/coordinator_props.rs and
/// rust/tests/tiered_integration.rs).
pub trait StageClassifier: BatchClassifier {
    /// Run tier `level0` (0-based) on `n` row-major rows.  `theta`
    /// overrides the tier's calibrated threshold when given (the active
    /// gear's theta); the final tier ignores it and always exits.
    fn classify_stage(
        &self,
        level0: usize,
        features: &[f32],
        n: usize,
        theta: Option<f32>,
    ) -> Result<Vec<StageResult>>;
}

/// Drive a [`StageClassifier`] through the full sieve: run stage 0 on
/// everything, gather the deferred subset (with its original indices),
/// run stage 1 on it, and so on.  This IS the monolithic cascade
/// execution -- `Cascade::classify_batch` delegates here -- and the
/// degenerate one-pool case of the tiered fleet's routed handoff.
pub fn classify_batch_staged(
    stage: &dyn StageClassifier,
    features: &[f32],
    n: usize,
    thetas: Option<&[f32]>,
) -> Result<Vec<CascadeResult>> {
    let dim = stage.dim();
    assert_eq!(features.len(), n * dim, "feature buffer size");
    let n_levels = stage.n_levels();
    let mut results: Vec<Option<CascadeResult>> = vec![None; n];
    // indices of samples still in flight
    let mut active: Vec<usize> = (0..n).collect();
    let mut active_scores: Vec<Vec<f32>> = vec![Vec::new(); n];
    for level0 in 0..n_levels {
        if active.is_empty() {
            break;
        }
        // gather the active subset
        let mut sub = Vec::with_capacity(active.len() * dim);
        for &i in &active {
            sub.extend_from_slice(&features[i * dim..(i + 1) * dim]);
        }
        let theta = thetas.and_then(|ts| ts.get(level0)).copied();
        let outs = stage.classify_stage(level0, &sub, active.len(), theta)?;
        let mut still_active = Vec::new();
        for (j, &i) in active.iter().enumerate() {
            active_scores[i].push(outs[j].score);
            match outs[j].decision {
                Some(prediction) => {
                    results[i] = Some(CascadeResult {
                        prediction,
                        exit_level: level0 + 1,
                        scores: std::mem::take(&mut active_scores[i]),
                    });
                }
                None => still_active.push(i),
            }
        }
        active = still_active;
    }
    debug_assert!(active.is_empty(), "final tier must accept everything");
    Ok(results
        .into_iter()
        .map(|r| r.expect("all samples resolved"))
        .collect())
}

/// A cascade of loaded tier executables + its deferral policy.
pub struct Cascade {
    tiers: Vec<Arc<TierExecutable>>,
    policy: DeferralPolicy,
}

impl BatchClassifier for Cascade {
    fn dim(&self) -> usize {
        self.tiers[0].dim
    }

    fn n_levels(&self) -> usize {
        self.tiers.len()
    }

    fn classify_batch(&self, features: &[f32], n: usize) -> Result<Vec<CascadeResult>> {
        Cascade::classify_batch(self, features, n)
    }

    fn classify_batch_geared(
        &self,
        features: &[f32],
        n: usize,
        gear: &crate::planner::gear::GearConfig,
    ) -> Result<Vec<CascadeResult>> {
        self.classify_batch_with(features, n, Some(&gear.thetas))
    }
}

impl StageClassifier for Cascade {
    fn classify_stage(
        &self,
        level0: usize,
        features: &[f32],
        n: usize,
        theta: Option<f32>,
    ) -> Result<Vec<StageResult>> {
        Cascade::classify_stage(self, level0, features, n, theta)
    }
}

impl Cascade {
    pub fn new(tiers: Vec<Arc<TierExecutable>>, policy: DeferralPolicy) -> Cascade {
        assert!(!tiers.is_empty(), "cascade needs at least one tier");
        assert_eq!(policy.n_tiers(), tiers.len(), "policy/tier count mismatch");
        Cascade { tiers, policy }
    }

    pub fn n_levels(&self) -> usize {
        self.tiers.len()
    }

    pub fn tiers(&self) -> &[Arc<TierExecutable>] {
        &self.tiers
    }

    pub fn policy(&self) -> &DeferralPolicy {
        &self.policy
    }

    /// Classify `n` rows (row-major `n x dim`).  Returns per-sample
    /// results in input order.
    pub fn classify_batch(&self, features: &[f32], n: usize) -> Result<Vec<CascadeResult>> {
        self.classify_batch_with(features, n, None)
    }

    /// Classify with optional per-tier threshold overrides (the active
    /// gear's thetas; see `planner`).  `thetas[i]` replaces the
    /// calibrated threshold of tier `i+1` when present; tiers past the
    /// override slice -- and always the final tier -- keep their policy
    /// behaviour.  Implemented as the stage-wise sieve driver over
    /// [`Cascade::classify_stage`], so monolithic execution and the
    /// tiered fleet's routed execution share one code path.
    pub fn classify_batch_with(
        &self,
        features: &[f32],
        n: usize,
        thetas: Option<&[f32]>,
    ) -> Result<Vec<CascadeResult>> {
        classify_batch_staged(self, features, n, thetas)
    }

    /// Run ONE tier over `n` rows (see [`StageClassifier`]).  The rule
    /// kind stays the policy's; only theta is overridden, and never for
    /// the final tier (it must accept everything).
    pub fn classify_stage(
        &self,
        level0: usize,
        features: &[f32],
        n: usize,
        theta: Option<f32>,
    ) -> Result<Vec<StageResult>> {
        let tier = &self.tiers[level0];
        assert_eq!(features.len(), n * tier.dim, "feature buffer size");
        let last = level0 + 1 == self.tiers.len();
        let over = match (theta, self.policy.rule(level0)) {
            (Some(theta), Some(r)) if !last => {
                Some(crate::coordinator::deferral::TierRule { rule: r.rule, theta })
            }
            _ => None,
        };
        let outs = tier.run(features, n)?;
        Ok(outs
            .iter()
            .map(|out| {
                let decision = match &over {
                    Some(rule) => rule.decide(out),
                    None => self.policy.decide(level0, out),
                };
                StageResult {
                    score: self.policy.score(level0, out),
                    decision: match decision {
                        Decision::Accept => Some(out.majority),
                        Decision::Defer => None,
                    },
                }
            })
            .collect())
    }

    /// Classify and score against labels.
    pub fn evaluate(&self, features: &[f32], labels: &[Label], n: usize) -> Result<(Vec<CascadeResult>, CascadeReport)> {
        let results = self.classify_batch(features, n)?;
        let report = report_from(&results, labels, self.tiers.len());
        Ok((results, report))
    }
}

/// Build a report from per-sample results + ground truth.
pub fn report_from(
    results: &[CascadeResult],
    labels: &[Label],
    n_levels: usize,
) -> CascadeReport {
    let n = results.len();
    assert_eq!(labels.len(), n);
    let mut hits = 0usize;
    let mut exits = vec![0usize; n_levels];
    let mut levels_visited = 0usize;
    for (r, &y) in results.iter().zip(labels) {
        if r.prediction == y {
            hits += 1;
        }
        exits[r.exit_level - 1] += 1;
        levels_visited += r.exit_level;
    }
    CascadeReport {
        n,
        accuracy: hits as f64 / n.max(1) as f64,
        exit_fractions: exits.iter().map(|&e| e as f64 / n.max(1) as f64).collect(),
        mean_levels_visited: levels_visited as f64 / n.max(1) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::RuleKind;

    // report_from unit coverage (Cascade itself needs PJRT artifacts and
    // is exercised by rust/tests/cascade_integration.rs).

    fn res(pred: Label, exit: usize) -> CascadeResult {
        CascadeResult { prediction: pred, exit_level: exit, scores: vec![] }
    }

    #[test]
    fn report_counts() {
        let results = vec![res(1, 1), res(0, 2), res(1, 1), res(2, 3)];
        let labels = vec![1, 1, 1, 2];
        let rep = report_from(&results, &labels, 3);
        assert_eq!(rep.n, 4);
        assert!((rep.accuracy - 0.75).abs() < 1e-12);
        assert_eq!(rep.exit_fractions, vec![0.5, 0.25, 0.25]);
        assert!((rep.mean_levels_visited - (1 + 2 + 1 + 3) as f64 / 4.0).abs() < 1e-12);
    }

    #[test]
    fn exit_fractions_sum_to_one() {
        let results: Vec<CascadeResult> =
            (0..100).map(|i| res(0, 1 + i % 4)).collect();
        let labels = vec![0; 100];
        let rep = report_from(&results, &labels, 4);
        let total: f64 = rep.exit_fractions.iter().sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "at least one tier")]
    fn empty_cascade_panics() {
        // No artifacts needed: constructor validates before any IO.
        let policy = DeferralPolicy::uniform(RuleKind::Vote, 0.5, 3);
        let _ = Cascade::new(Vec::new(), policy);
    }
}
