//! Agreement computation (paper §4.3, Eq. 3/4) as pure Rust.
//!
//! The PJRT artifacts already evaluate the deferral scores on-device (the
//! L1 `agreement` kernel); this host-side twin exists for (a) simulators
//! and baselines that produce logits without PJRT, (b) cross-checking the
//! kernel in integration tests, and (c) voting over *black-box* answer
//! sets in the API-cascade scenario where only answer strings exist.
//!
//! Semantics must match python/compile/kernels/agreement.py exactly:
//! plurality vote, ties toward the smaller class index.

use crate::types::TierOutput;

/// Agreement over stacked member logits for ONE sample.
/// `logits[m * classes + c]` = member m's logit for class c.
pub fn agree_logits(logits: &[f32], k: usize, classes: usize) -> TierOutput {
    assert_eq!(logits.len(), k * classes, "logits length");
    assert!(k > 0 && classes > 0);
    let mut counts = vec![0u32; classes];
    let mut preds = Vec::with_capacity(k);
    for m in 0..k {
        let row = &logits[m * classes..(m + 1) * classes];
        let p = argmax(row);
        preds.push(p);
        counts[p] += 1;
    }
    let majority = argmax_u32(&counts);
    let vote_frac = counts[majority] as f32 / k as f32;
    // mean softmax probability of the majority class across members
    let mut score_sum = 0.0f32;
    for m in 0..k {
        let row = &logits[m * classes..(m + 1) * classes];
        score_sum += softmax_prob(row, majority);
    }
    TierOutput {
        majority: majority as u32,
        vote_frac,
        mean_score: score_sum / k as f32,
    }
}

/// Agreement over a set of discrete answers (black-box API mode, §5.2.3):
/// returns (majority answer index into `answers`, vote fraction).
/// Ties break toward the answer that appeared FIRST in the list.
pub fn agree_votes(answers: &[u32]) -> (u32, f32) {
    assert!(!answers.is_empty());
    let mut counts: Vec<(u32, u32, usize)> = Vec::new(); // (answer, count, first_pos)
    for (pos, &a) in answers.iter().enumerate() {
        match counts.iter_mut().find(|(ans, _, _)| *ans == a) {
            Some((_, c, _)) => *c += 1,
            None => counts.push((a, 1, pos)),
        }
    }
    // max count; ties -> earliest first_pos
    let &(ans, c, _) = counts
        .iter()
        .max_by(|a, b| a.1.cmp(&b.1).then(b.2.cmp(&a.2)))
        .unwrap();
    (ans, c as f32 / answers.len() as f32)
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

fn argmax_u32(xs: &[u32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Numerically stable softmax probability of class `c`.
pub fn softmax_prob(logits: &[f32], c: usize) -> f32 {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let denom: f32 = logits.iter().map(|&x| (x - m).exp()).sum();
    (logits[c] - m).exp() / denom
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unanimous_vote() {
        // 3 members all prefer class 2 out of 4
        let logits = vec![
            0.0, 0.0, 5.0, 0.0, //
            -1.0, 0.0, 4.0, 0.0, //
            0.0, 1.0, 6.0, 0.0,
        ];
        let out = agree_logits(&logits, 3, 4);
        assert_eq!(out.majority, 2);
        assert!((out.vote_frac - 1.0).abs() < 1e-6);
        assert!(out.mean_score > 0.9);
    }

    #[test]
    fn split_vote_tie_breaks_low() {
        // 2 members -> class 3, 2 members -> class 1: majority = 1
        let mk = |c: usize| {
            let mut v = vec![0.0f32; 5];
            v[c] = 9.0;
            v
        };
        let mut logits = Vec::new();
        logits.extend(mk(3));
        logits.extend(mk(1));
        logits.extend(mk(3));
        logits.extend(mk(1));
        let out = agree_logits(&logits, 4, 5);
        assert_eq!(out.majority, 1);
        assert!((out.vote_frac - 0.5).abs() < 1e-6);
    }

    #[test]
    fn k1_is_argmax_with_softmax_conf() {
        let logits = vec![1.0f32, 3.0, 2.0];
        let out = agree_logits(&logits, 1, 3);
        assert_eq!(out.majority, 1);
        assert!((out.vote_frac - 1.0).abs() < 1e-6);
        let p = softmax_prob(&logits, 1);
        assert!((out.mean_score - p).abs() < 1e-6);
    }

    #[test]
    fn softmax_stable_for_large_logits() {
        let p = softmax_prob(&[1000.0, 999.0], 0);
        assert!(p.is_finite());
        assert!(p > 0.7 && p < 0.75); // sigmoid(1) ~ 0.731
    }

    #[test]
    fn vote_answers_majority_and_ties() {
        assert_eq!(agree_votes(&[7, 7, 3]), (7, 2.0 / 3.0));
        // tie 1-1: earliest answer wins
        assert_eq!(agree_votes(&[9, 4]), (9, 0.5));
        assert_eq!(agree_votes(&[4, 9]), (4, 0.5));
        assert_eq!(agree_votes(&[5]), (5, 1.0));
    }

    #[test]
    fn matches_kernel_semantics_on_random_data() {
        // Fuzz the host twin against a simple direct re-computation.
        let mut rng = crate::util::rng::Rng::new(9);
        for _ in 0..200 {
            let k = 1 + rng.below(5);
            let c = 2 + rng.below(8);
            let logits: Vec<f32> =
                (0..k * c).map(|_| (rng.f64() * 6.0 - 3.0) as f32).collect();
            let out = agree_logits(&logits, k, c);
            // majority must get the max count with low-index tiebreak
            let mut counts = vec![0u32; c];
            for m in 0..k {
                counts[argmax(&logits[m * c..(m + 1) * c])] += 1;
            }
            let want = counts
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(&a.0)))
                .unwrap()
                .0;
            assert_eq!(out.majority as usize, want);
            assert!(out.vote_frac <= 1.0 + 1e-6 && out.vote_frac > 0.0);
            assert!(out.mean_score <= 1.0 + 1e-6 && out.mean_score > 0.0);
        }
    }
}
