//! Deferral policies (paper §4.2-4.3).
//!
//! A `DeferralPolicy` holds one rule per non-final tier: the rule kind
//! (vote fraction, Eq. 3, or mean softmax score, Eq. 4) and the calibrated
//! threshold theta.  `decide` implements
//!
//! ```text
//! r(x) = 1  (defer)  iff  score(x) <= theta
//! ```
//!
//! The final tier always accepts (Algorithm 1 line 3-8).

use crate::types::{Decision, RuleKind, TierOutput};

/// Per-tier rule: defer when `rule.score_of(out) <= theta`.
#[derive(Debug, Clone, Copy)]
pub struct TierRule {
    pub rule: RuleKind,
    pub theta: f32,
}

impl TierRule {
    pub fn decide(&self, out: &TierOutput) -> Decision {
        if self.rule.score_of(out) <= self.theta {
            Decision::Defer
        } else {
            Decision::Accept
        }
    }
}

/// A cascade-wide deferral policy: rules for tiers 1..n-1.
#[derive(Debug, Clone)]
pub struct DeferralPolicy {
    rules: Vec<TierRule>,
    n_tiers: usize,
}

impl DeferralPolicy {
    /// `rules[i]` applies to tier i+1; the cascade has `n_tiers` tiers and
    /// the last one has no rule (it always accepts).
    pub fn new(rules: Vec<TierRule>, n_tiers: usize) -> DeferralPolicy {
        assert_eq!(
            rules.len(),
            n_tiers.saturating_sub(1),
            "need one rule per non-final tier"
        );
        DeferralPolicy { rules, n_tiers }
    }

    /// Uniform rule/threshold for every non-final tier.
    pub fn uniform(rule: RuleKind, theta: f32, n_tiers: usize) -> DeferralPolicy {
        DeferralPolicy::new(
            vec![TierRule { rule, theta }; n_tiers.saturating_sub(1)],
            n_tiers,
        )
    }

    pub fn n_tiers(&self) -> usize {
        self.n_tiers
    }

    pub fn rule(&self, tier_index0: usize) -> Option<&TierRule> {
        self.rules.get(tier_index0)
    }

    /// Decide for the tier with 0-based index `tier_index0`.
    /// The final tier always accepts.
    pub fn decide(&self, tier_index0: usize, out: &TierOutput) -> Decision {
        match self.rules.get(tier_index0) {
            Some(rule) if tier_index0 + 1 < self.n_tiers => rule.decide(out),
            _ => Decision::Accept,
        }
    }

    /// The score the rule at this tier extracts (for logging / analysis).
    pub fn score(&self, tier_index0: usize, out: &TierOutput) -> f32 {
        match self.rules.get(tier_index0) {
            Some(r) => r.rule.score_of(out),
            None => out.vote_frac,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn out(frac: f32, score: f32) -> TierOutput {
        TierOutput { majority: 0, vote_frac: frac, mean_score: score }
    }

    #[test]
    fn vote_rule_defers_at_or_below_theta() {
        let r = TierRule { rule: RuleKind::Vote, theta: 0.5 };
        assert_eq!(r.decide(&out(0.34, 0.9)), Decision::Defer);
        assert_eq!(r.decide(&out(0.5, 0.9)), Decision::Defer); // <= theta
        assert_eq!(r.decide(&out(0.67, 0.1)), Decision::Accept);
    }

    #[test]
    fn score_rule_uses_mean_score() {
        let r = TierRule { rule: RuleKind::MeanScore, theta: 0.8 };
        assert_eq!(r.decide(&out(1.0, 0.75)), Decision::Defer);
        assert_eq!(r.decide(&out(0.3, 0.95)), Decision::Accept);
    }

    #[test]
    fn final_tier_always_accepts() {
        let p = DeferralPolicy::uniform(RuleKind::Vote, 2.0, 3); // theta 2.0 defers everything
        assert_eq!(p.decide(0, &out(1.0, 1.0)), Decision::Defer);
        assert_eq!(p.decide(1, &out(1.0, 1.0)), Decision::Defer);
        assert_eq!(p.decide(2, &out(0.0, 0.0)), Decision::Accept);
        // out-of-range tier index also accepts (defensive)
        assert_eq!(p.decide(7, &out(0.0, 0.0)), Decision::Accept);
    }

    #[test]
    fn per_tier_thresholds() {
        let p = DeferralPolicy::new(
            vec![
                TierRule { rule: RuleKind::Vote, theta: 0.4 },
                TierRule { rule: RuleKind::MeanScore, theta: 0.9 },
            ],
            3,
        );
        assert_eq!(p.decide(0, &out(0.6, 0.0)), Decision::Accept);
        assert_eq!(p.decide(1, &out(0.6, 0.85)), Decision::Defer);
    }

    #[test]
    #[should_panic(expected = "one rule per non-final tier")]
    fn wrong_rule_count_panics() {
        DeferralPolicy::new(vec![], 3);
    }

    #[test]
    fn single_tier_cascade_needs_no_rules() {
        let p = DeferralPolicy::uniform(RuleKind::Vote, 0.5, 1);
        assert_eq!(p.decide(0, &out(0.0, 0.0)), Decision::Accept);
    }
}
