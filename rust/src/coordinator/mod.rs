//! The paper's L3 contribution: agreement-based deferral, the cascade
//! controller (Algorithm 1), dynamic batching and the serving pipeline.

pub mod agreement;
pub mod batcher;
pub mod cascade;
pub mod deferral;
pub mod pipeline;
