//! The paper's L3 contribution: agreement-based deferral, the cascade
//! controller (Algorithm 1), dynamic batching, the serving pipeline, and
//! the replicated serving pool with admission control.

pub mod agreement;
pub mod batcher;
pub mod cascade;
pub mod deferral;
pub mod pipeline;
pub mod replica;
