//! The paper's L3 contribution: agreement-based deferral, the cascade
//! controller (Algorithm 1), dynamic batching, the serving pipeline, the
//! replicated serving pool with admission control, and the tiered fleet
//! (pool-per-tier with routed deferral, `router`).

pub mod agreement;
pub mod batcher;
pub mod cascade;
pub mod deferral;
pub mod pipeline;
pub mod replica;
pub mod router;
