//! Dynamic batcher: accumulate requests until `max_batch` or `max_wait`,
//! then flush to a batch executor.
//!
//! This is the L3 serving piece that amortises PJRT dispatch + host->
//! device copies across requests (the "parallel inference execution"
//! leverage of §5.1.1).  Generic over the executor closure so the policy
//! is testable without PJRT.
//!
//! `max_batch` is runtime-adjustable (`set_max_batch`): the gear
//! controller retunes batch size on a gear shift without restarting the
//! collector.  The cap is re-read at every collector step, so a change
//! applies from the next flush decision on; items already queued are
//! never dropped by a cap change (a shrink just splits them across more
//! flushes).
//!
//! Invariants (property-tested in rust/tests/coordinator_props.rs):
//! * no request is dropped or duplicated;
//! * within a flush, requests keep arrival order;
//! * flushes are FIFO: a request never overtakes an earlier one.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Flush when this many requests are queued.
    pub max_batch: usize,
    /// Flush a non-empty queue after this long even if not full.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 32, max_wait: Duration::from_millis(2) }
    }
}

/// A batch item: opaque payload + the enqueue instant (for latency).
pub struct Item<T> {
    pub payload: T,
    pub enqueued: Instant,
}

enum Msg<T> {
    Push(Item<T>),
    Shutdown,
}

/// Sender side, gated so `push` and `shutdown` serialise: every `Ok`
/// push is sent strictly before the `Shutdown` message on the same
/// channel, so the collector is guaranteed to flush it (no silent drop
/// in a push/shutdown race).
struct Gate<T> {
    tx: Sender<Msg<T>>,
    closed: bool,
}

/// Handle for submitting items to a running batcher.
pub struct Batcher<T> {
    gate: Mutex<Gate<T>>,
    max_batch: Arc<AtomicUsize>,
    /// Behind a mutex so [`Batcher::join`] can take it from `&self`
    /// (replica drain joins the worker without owning the batcher).
    worker: Mutex<Option<JoinHandle<()>>>,
}

impl<T: Send + 'static> Batcher<T> {
    /// Spawn the collector thread.  `flush` receives each batch (size in
    /// `1..=max_batch`) in FIFO order, on the collector thread.
    pub fn spawn<F>(cfg: BatcherConfig, mut flush: F) -> Batcher<T>
    where
        F: FnMut(Vec<Item<T>>) + Send + 'static,
    {
        assert!(cfg.max_batch > 0);
        let max_batch = Arc::new(AtomicUsize::new(cfg.max_batch));
        let cap = Arc::clone(&max_batch);
        let (tx, rx) = channel::<Msg<T>>();
        let worker = std::thread::Builder::new()
            .name("abc-batcher".into())
            .spawn(move || collector_loop(rx, cfg, &cap, &mut flush))
            .expect("spawn batcher");
        Batcher {
            gate: Mutex::new(Gate { tx, closed: false }),
            max_batch,
            worker: Mutex::new(Some(worker)),
        }
    }

    /// Retune the flush size cap.  Takes effect at the collector's next
    /// step; queued items are never dropped (a shrink splits them across
    /// more flushes).  Zero is clamped to 1.
    pub fn set_max_batch(&self, max_batch: usize) {
        self.max_batch.store(max_batch.max(1), Ordering::Relaxed);
    }

    /// The currently configured flush size cap.
    pub fn max_batch(&self) -> usize {
        self.max_batch.load(Ordering::Relaxed)
    }

    /// Enqueue one item.  Returns Err if the batcher has shut down;
    /// an `Ok` return guarantees the item will be flushed.
    pub fn push(&self, payload: T) -> Result<(), &'static str> {
        let gate = self.gate.lock().unwrap();
        if gate.closed {
            return Err("batcher is shut down");
        }
        gate.tx
            .send(Msg::Push(Item { payload, enqueued: Instant::now() }))
            .map_err(|_| "batcher is shut down")
    }

    /// Ask the collector to stop.  All previously accepted items are
    /// still flushed; by the time this returns, further `push` calls
    /// error.  Idempotent; `Drop` still joins the worker.
    pub fn shutdown(&self) {
        let mut gate = self.gate.lock().unwrap();
        if !gate.closed {
            gate.closed = true;
            let _ = gate.tx.send(Msg::Shutdown);
        }
    }

    /// Drain-aware teardown: shut the gate, then *wait* for the
    /// collector to flush every previously accepted item and exit.
    /// This is what replica retirement calls -- by the time it returns,
    /// no flush callback will ever run again and the worker thread is
    /// gone.  Idempotent; must not be called from the flush callback
    /// itself (the collector cannot join itself).
    pub fn join(&self) {
        self.shutdown();
        let handle = self.worker.lock().unwrap().take();
        if let Some(w) = handle {
            let _ = w.join();
        }
    }
}

impl<T> Drop for Batcher<T> {
    fn drop(&mut self) {
        self.join();
    }
}

fn collector_loop<T, F>(
    rx: Receiver<Msg<T>>,
    cfg: BatcherConfig,
    max_batch: &AtomicUsize,
    flush: &mut F,
) where
    F: FnMut(Vec<Item<T>>),
{
    let mut pending: Vec<Item<T>> = Vec::with_capacity(cfg.max_batch);
    let mut deadline: Option<Instant> = None;
    loop {
        let msg = match deadline {
            None => match rx.recv() {
                Ok(m) => m,
                Err(_) => break,
            },
            Some(dl) => {
                let now = Instant::now();
                if now >= dl {
                    flush_batch(&mut pending, &mut deadline, max_batch, flush);
                    continue;
                }
                match rx.recv_timeout(dl - now) {
                    Ok(m) => m,
                    Err(RecvTimeoutError::Timeout) => {
                        flush_batch(&mut pending, &mut deadline, max_batch, flush);
                        continue;
                    }
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
        };
        match msg {
            Msg::Push(item) => {
                if pending.is_empty() {
                    deadline = Some(Instant::now() + cfg.max_wait);
                }
                pending.push(item);
                // re-read the cap each step: a gear shift may have retuned
                // it since the last flush
                if pending.len() >= max_batch.load(Ordering::Relaxed).max(1) {
                    flush_batch(&mut pending, &mut deadline, max_batch, flush);
                }
            }
            Msg::Shutdown => break,
        }
    }
    // drain whatever is left so nothing is dropped on shutdown (the
    // sender gate guarantees no Push can follow the Shutdown message,
    // so `pending` is everything outstanding; the try_recv sweep is
    // defense in depth for the handle-dropped-without-shutdown path)
    if !pending.is_empty() {
        let mut no_deadline = None;
        flush_batch(&mut pending, &mut no_deadline, max_batch, flush);
    }
    while let Ok(Msg::Push(item)) = rx.try_recv() {
        flush(vec![item]);
    }
}

/// Flush `pending` in FIFO chunks of at most the current cap, so the
/// `1..=max_batch` flush-size invariant survives a cap shrink that
/// happened while items were already queued.
fn flush_batch<T, F>(
    pending: &mut Vec<Item<T>>,
    deadline: &mut Option<Instant>,
    max_batch: &AtomicUsize,
    flush: &mut F,
) where
    F: FnMut(Vec<Item<T>>),
{
    *deadline = None;
    while !pending.is_empty() {
        let take = pending.len().min(max_batch.load(Ordering::Relaxed).max(1));
        flush(pending.drain(..take).collect());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    fn collect_flushes(
        cfg: BatcherConfig,
        n: usize,
        pace: Option<Duration>,
    ) -> Vec<Vec<usize>> {
        let flushes = Arc::new(Mutex::new(Vec::new()));
        {
            let fl = Arc::clone(&flushes);
            let b = Batcher::spawn(cfg, move |batch: Vec<Item<usize>>| {
                fl.lock().unwrap().push(batch.into_iter().map(|i| i.payload).collect());
            });
            for i in 0..n {
                b.push(i).unwrap();
                if let Some(p) = pace {
                    std::thread::sleep(p);
                }
            }
            // give the timeout flush a chance before drop
            std::thread::sleep(cfg.max_wait + Duration::from_millis(20));
        } // drop joins the worker
        Arc::try_unwrap(flushes).unwrap().into_inner().unwrap()
    }

    #[test]
    fn full_batches_flush_at_max_batch() {
        let cfg = BatcherConfig { max_batch: 4, max_wait: Duration::from_secs(10) };
        let flushes = collect_flushes(cfg, 12, None);
        let all: Vec<usize> = flushes.iter().flatten().copied().collect();
        assert_eq!(all, (0..12).collect::<Vec<_>>());
        assert!(flushes.iter().all(|f| f.len() <= 4));
    }

    #[test]
    fn timeout_flushes_partial_batch() {
        let cfg = BatcherConfig { max_batch: 100, max_wait: Duration::from_millis(5) };
        let flushes = collect_flushes(cfg, 3, None);
        let all: Vec<usize> = flushes.iter().flatten().copied().collect();
        assert_eq!(all, vec![0, 1, 2]);
    }

    #[test]
    fn no_drop_no_dup_under_pacing() {
        let cfg = BatcherConfig { max_batch: 3, max_wait: Duration::from_millis(2) };
        let flushes = collect_flushes(cfg, 25, Some(Duration::from_micros(700)));
        let all: Vec<usize> = flushes.iter().flatten().copied().collect();
        assert_eq!(all, (0..25).collect::<Vec<_>>(), "order/conservation");
    }

    #[test]
    fn shutdown_drains_pending() {
        let flushes = Arc::new(Mutex::new(Vec::new()));
        {
            let fl = Arc::clone(&flushes);
            let cfg =
                BatcherConfig { max_batch: 100, max_wait: Duration::from_secs(100) };
            let b = Batcher::spawn(cfg, move |batch: Vec<Item<u32>>| {
                fl.lock().unwrap().push(batch.len());
            });
            for _ in 0..5 {
                b.push(1).unwrap();
            }
            // drop immediately: worker must drain the 5 pending items
        }
        let sizes = flushes.lock().unwrap().clone();
        assert_eq!(sizes.iter().sum::<usize>(), 5);
    }

    #[test]
    fn push_after_shutdown_errors() {
        let flushed = Arc::new(Mutex::new(0usize));
        let fl = Arc::clone(&flushed);
        let cfg = BatcherConfig::default();
        let b: Batcher<u32> = Batcher::spawn(cfg, move |batch| {
            *fl.lock().unwrap() += batch.len();
        });
        assert!(b.push(1).is_ok());
        b.shutdown();
        // the gate closes synchronously: pushes fail immediately
        assert_eq!(b.push(2), Err("batcher is shut down"));
        b.shutdown(); // idempotent
        assert_eq!(b.push(3), Err("batcher is shut down"));
        drop(b); // joins the worker
        // the accepted push was still flushed, the rejected ones weren't
        assert_eq!(*flushed.lock().unwrap(), 1);
    }

    #[test]
    fn join_waits_for_accepted_items_then_is_idempotent() {
        let flushed = Arc::new(Mutex::new(0usize));
        let fl = Arc::clone(&flushed);
        let cfg = BatcherConfig { max_batch: 100, max_wait: Duration::from_secs(100) };
        let b: Batcher<u32> = Batcher::spawn(cfg, move |batch| {
            std::thread::sleep(Duration::from_millis(5));
            *fl.lock().unwrap() += batch.len();
        });
        for _ in 0..7 {
            b.push(1).unwrap();
        }
        // join returns only after the pending items were flushed
        b.join();
        assert_eq!(*flushed.lock().unwrap(), 7);
        assert_eq!(b.push(2), Err("batcher is shut down"));
        b.join(); // idempotent
        drop(b); // and Drop after join is a no-op
        assert_eq!(*flushed.lock().unwrap(), 7);
    }

    #[test]
    fn exact_max_batch_flushes_without_timeout() {
        // max_wait is effectively infinite: the only way these items can
        // flush is the size trigger firing exactly at the boundary.
        let flushes = Arc::new(Mutex::new(Vec::new()));
        {
            let fl = Arc::clone(&flushes);
            let cfg =
                BatcherConfig { max_batch: 4, max_wait: Duration::from_secs(3600) };
            let b = Batcher::spawn(cfg, move |batch: Vec<Item<usize>>| {
                fl.lock().unwrap().push(
                    batch.into_iter().map(|i| i.payload).collect::<Vec<_>>(),
                );
            });
            for i in 0..4 {
                b.push(i).unwrap();
            }
            // wait for the size-triggered flush (NOT the timeout)
            for _ in 0..500 {
                if !flushes.lock().unwrap().is_empty() {
                    break;
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            assert_eq!(*flushes.lock().unwrap(), vec![vec![0, 1, 2, 3]]);
        }
        // drop added nothing: the boundary batch was complete
        assert_eq!(flushes.lock().unwrap().len(), 1);
    }

    #[test]
    fn set_max_batch_applies_to_later_flushes() {
        let flushes = Arc::new(Mutex::new(Vec::new()));
        {
            let fl = Arc::clone(&flushes);
            let cfg =
                BatcherConfig { max_batch: 8, max_wait: Duration::from_secs(3600) };
            let b = Batcher::spawn(cfg, move |batch: Vec<Item<usize>>| {
                fl.lock().unwrap().push(
                    batch.into_iter().map(|i| i.payload).collect::<Vec<_>>(),
                );
            });
            assert_eq!(b.max_batch(), 8);
            b.set_max_batch(2);
            assert_eq!(b.max_batch(), 2);
            for i in 0..6 {
                b.push(i).unwrap();
            }
            for _ in 0..500 {
                if flushes.lock().unwrap().iter().flatten().count() == 6 {
                    break;
                }
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        let got = flushes.lock().unwrap().clone();
        let all: Vec<usize> = got.iter().flatten().copied().collect();
        assert_eq!(all, (0..6).collect::<Vec<_>>(), "order/conservation");
        // cap 2 bounds every flush; the 3600s max_wait means only the
        // size trigger can have fired
        assert!(got.iter().all(|f| f.len() <= 2), "cap ignored: {got:?}");
        // zero clamps to 1 instead of wedging the collector
        let b2: Batcher<usize> = Batcher::spawn(
            BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
            |_| {},
        );
        b2.set_max_batch(0);
        assert_eq!(b2.max_batch(), 1);
    }

    #[test]
    fn timeout_flushes_partial_then_next_batch() {
        let flushes = Arc::new(Mutex::new(Vec::new()));
        {
            let fl = Arc::clone(&flushes);
            let cfg =
                BatcherConfig { max_batch: 100, max_wait: Duration::from_millis(5) };
            let b = Batcher::spawn(cfg, move |batch: Vec<Item<usize>>| {
                fl.lock().unwrap().push(
                    batch.into_iter().map(|i| i.payload).collect::<Vec<_>>(),
                );
            });
            b.push(0).unwrap();
            b.push(1).unwrap();
            std::thread::sleep(Duration::from_millis(60));
            b.push(2).unwrap();
            b.push(3).unwrap();
            b.push(4).unwrap();
            std::thread::sleep(Duration::from_millis(60));
        }
        let got = flushes.lock().unwrap().clone();
        // Scheduling jitter may split either group into smaller flushes,
        // so only assert what the timeout genuinely guarantees: order,
        // conservation, and that the 60ms gap forced a flush boundary
        // between 1 and 2 (no flush holds both).
        assert!(got.len() >= 2, "timeout never flushed: {got:?}");
        let all: Vec<usize> = got.iter().flatten().copied().collect();
        assert_eq!(all, vec![0, 1, 2, 3, 4]);
        assert!(
            !got.iter().any(|f| f.contains(&1) && f.contains(&2)),
            "1 and 2 must be separated by the timeout flush: {got:?}"
        );
    }
}
