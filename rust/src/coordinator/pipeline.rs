//! The serving pipeline: request -> dynamic batcher -> cascade -> verdict.
//!
//! Ties the batcher to the cascade controller and the metrics registry.
//! Responses are delivered through per-request channels (a poor man's
//! oneshot); the whole pipeline is synchronous threads -- no async
//! runtime exists in the offline registry, and a thread per stage is
//! plenty for a CPU PJRT backend (DESIGN.md §3).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::batcher::{Batcher, BatcherConfig, Item};
use crate::coordinator::cascade::Cascade;
use crate::metrics::Metrics;
use crate::types::{Request, Verdict};

struct Job {
    request: Request,
    resp: Sender<Result<Verdict, String>>,
}

/// Client-side handle to a running pipeline.
pub struct Pipeline {
    batcher: Batcher<Job>,
    metrics: Arc<Metrics>,
    dim: usize,
}

impl Pipeline {
    /// Spawn the pipeline over a loaded cascade.
    pub fn spawn(cascade: Arc<Cascade>, cfg: BatcherConfig, metrics: Arc<Metrics>) -> Pipeline {
        let dim = cascade.tiers()[0].dim;
        let m = Arc::clone(&metrics);
        let batcher = Batcher::spawn(cfg, move |batch: Vec<Item<Job>>| {
            process_batch(&cascade, &m, batch);
        });
        Pipeline { batcher, metrics, dim }
    }

    /// Submit a request; returns a receiver for its verdict.
    pub fn submit(&self, request: Request) -> Result<Receiver<Result<Verdict, String>>> {
        anyhow::ensure!(
            request.features.len() == self.dim,
            "request {} has {} features, suite dim is {}",
            request.id,
            request.features.len(),
            self.dim
        );
        let (tx, rx) = channel();
        self.batcher
            .push(Job { request, resp: tx })
            .map_err(|e| anyhow::anyhow!(e))?;
        self.metrics.counter("requests_submitted").inc();
        Ok(rx)
    }

    /// Submit and block for the verdict (single-request convenience).
    pub fn infer(&self, request: Request) -> Result<Verdict> {
        let rx = self.submit(request)?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("pipeline dropped the request"))?
            .map_err(|e| anyhow::anyhow!(e))
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }
}

fn process_batch(cascade: &Cascade, metrics: &Metrics, batch: Vec<Item<Job>>) {
    let n = batch.len();
    let dim = cascade.tiers()[0].dim;
    let mut features = Vec::with_capacity(n * dim);
    for item in &batch {
        features.extend_from_slice(&item.payload.request.features);
    }
    let t0 = Instant::now();
    match cascade.classify_batch(&features, n) {
        Ok(results) => {
            metrics.counter("batches_ok").inc();
            metrics.histogram("batch_size").record(n as f64);
            metrics
                .histogram("batch_exec_s")
                .record(t0.elapsed().as_secs_f64());
            for (item, res) in batch.into_iter().zip(results) {
                let latency = item.enqueued.elapsed().as_secs_f64();
                metrics.histogram("request_latency_s").record(latency);
                metrics
                    .counter(&format!("exit_level_{}", res.exit_level))
                    .inc();
                let verdict = Verdict {
                    request_id: item.payload.request.id,
                    prediction: res.prediction,
                    exit_tier: res.exit_level,
                    tier_scores: res.scores,
                    latency_s: latency,
                };
                let _ = item.payload.resp.send(Ok(verdict));
            }
        }
        Err(e) => {
            metrics.counter("batches_err").inc();
            let msg = format!("cascade execution failed: {e:#}");
            for item in batch {
                let _ = item.payload.resp.send(Err(msg.clone()));
            }
        }
    }
}
