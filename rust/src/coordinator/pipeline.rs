//! The serving pipeline: request -> dynamic batcher -> classifier -> verdict.
//!
//! Ties the batcher to a [`BatchClassifier`] (the PJRT cascade in
//! production, a synthetic backend in load tests) and the metrics
//! registry.  Responses are delivered through per-request channels (a
//! poor man's oneshot); the whole pipeline is synchronous threads -- no
//! async runtime exists in the offline registry, and a thread per stage
//! is plenty for a CPU PJRT backend (DESIGN.md §3).
//!
//! The pipeline tracks its *outstanding* count (accepted but not yet
//! answered); `try_submit` turns that into admission control for the
//! `ReplicaPool`: the counter is bumped before the queue check, so the
//! per-pipeline outstanding count can never exceed the cap, even under
//! concurrent submitters.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::batcher::{Batcher, BatcherConfig, Item};
use crate::coordinator::cascade::BatchClassifier;
use crate::metrics::Metrics;
use crate::planner::gear::GearHandle;
use crate::types::{Request, Verdict};

struct Job {
    request: Request,
    resp: Sender<Result<Verdict, String>>,
}

/// Why `try_submit` refused a request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitRejection {
    /// The pipeline already holds `outstanding` >= the caller's cap.
    Full { outstanding: usize },
    /// The request failed validation (e.g. wrong feature dim).
    Invalid(String),
    /// The batcher has shut down.
    Closed,
}

impl std::fmt::Display for SubmitRejection {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitRejection::Full { outstanding } => {
                write!(f, "pipeline full ({outstanding} outstanding)")
            }
            SubmitRejection::Invalid(msg) => write!(f, "invalid request: {msg}"),
            SubmitRejection::Closed => write!(f, "pipeline is shut down"),
        }
    }
}

impl std::error::Error for SubmitRejection {}

/// Client-side handle to a running pipeline.
pub struct Pipeline {
    batcher: Batcher<Job>,
    metrics: Arc<Metrics>,
    outstanding: Arc<AtomicUsize>,
    /// Pre-resolved `requests_submitted` counter: the submit hot path
    /// must not pay a registry-lock lookup per request.
    submitted: Arc<crate::metrics::Counter>,
    dim: usize,
}

impl Pipeline {
    /// Spawn the pipeline over a batch classifier.
    pub fn spawn(
        classifier: Arc<dyn BatchClassifier>,
        cfg: BatcherConfig,
        metrics: Arc<Metrics>,
    ) -> Pipeline {
        Pipeline::spawn_with_gear(classifier, cfg, metrics, None)
    }

    /// Spawn with an optional shared gear handle: each flushed batch is
    /// classified under the gear config active *at flush time*
    /// (`BatchClassifier::classify_batch_geared`).  A gear swap touches
    /// only batches formed after it; responses for in-flight requests
    /// are unaffected, so shifts never drop or duplicate work.
    pub fn spawn_with_gear(
        classifier: Arc<dyn BatchClassifier>,
        cfg: BatcherConfig,
        metrics: Arc<Metrics>,
        gear: Option<Arc<GearHandle>>,
    ) -> Pipeline {
        let dim = classifier.dim();
        let m = Arc::clone(&metrics);
        let outstanding = Arc::new(AtomicUsize::new(0));
        let out = Arc::clone(&outstanding);
        let submitted = metrics.counter("requests_submitted");
        let batcher = Batcher::spawn(cfg, move |batch: Vec<Item<Job>>| {
            process_batch(classifier.as_ref(), &m, &out, gear.as_deref(), batch);
        });
        Pipeline { batcher, metrics, outstanding, submitted, dim }
    }

    /// Retune the dynamic batcher's flush cap (gear shifts; takes
    /// effect from the next flush decision on).
    pub fn set_max_batch(&self, max_batch: usize) {
        self.batcher.set_max_batch(max_batch);
    }

    /// Requests accepted but not yet answered (queued + in execution).
    pub fn outstanding(&self) -> usize {
        self.outstanding.load(Ordering::SeqCst)
    }

    /// Drain-aware teardown (replica retirement): close the batcher
    /// gate, flush everything already accepted, and join the collector
    /// thread.  Every request admitted before this call still gets its
    /// verdict; `try_submit`/`submit` afterwards return `Closed`.
    /// Idempotent.
    pub fn shutdown(&self) {
        self.batcher.join();
    }

    /// Submit a request; returns a receiver for its verdict.
    pub fn submit(&self, request: Request) -> Result<Receiver<Result<Verdict, String>>> {
        anyhow::ensure!(
            request.features.len() == self.dim,
            "request {} has {} features, suite dim is {}",
            request.id,
            request.features.len(),
            self.dim
        );
        let (tx, rx) = channel();
        self.outstanding.fetch_add(1, Ordering::SeqCst);
        if let Err(e) = self.batcher.push(Job { request, resp: tx }) {
            self.outstanding.fetch_sub(1, Ordering::SeqCst);
            return Err(anyhow::anyhow!(e));
        }
        self.submitted.inc();
        Ok(rx)
    }

    /// Bounded-queue submit: refuse (rather than queue) when this
    /// pipeline already holds `cap` outstanding requests.  The counter is
    /// reserved optimistically before the check, so outstanding never
    /// exceeds `cap` even with concurrent submitters.  Takes the request
    /// by reference so a refused probe costs no clone (the dispatcher may
    /// probe several replicas); the clone happens only on acceptance.
    pub fn try_submit(
        &self,
        request: &Request,
        cap: usize,
    ) -> Result<Receiver<Result<Verdict, String>>, SubmitRejection> {
        if request.features.len() != self.dim {
            return Err(SubmitRejection::Invalid(format!(
                "request {} has {} features, suite dim is {}",
                request.id,
                request.features.len(),
                self.dim
            )));
        }
        let prev = self.outstanding.fetch_add(1, Ordering::SeqCst);
        if prev >= cap {
            self.outstanding.fetch_sub(1, Ordering::SeqCst);
            return Err(SubmitRejection::Full { outstanding: prev });
        }
        let (tx, rx) = channel();
        if self.batcher.push(Job { request: request.clone(), resp: tx }).is_err() {
            self.outstanding.fetch_sub(1, Ordering::SeqCst);
            return Err(SubmitRejection::Closed);
        }
        self.submitted.inc();
        Ok(rx)
    }

    /// Submit and block for the verdict (single-request convenience).
    pub fn infer(&self, request: Request) -> Result<Verdict> {
        let rx = self.submit(request)?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("pipeline dropped the request"))?
            .map_err(|e| anyhow::anyhow!(e))
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }
}

fn process_batch(
    classifier: &dyn BatchClassifier,
    metrics: &Metrics,
    outstanding: &AtomicUsize,
    gear: Option<&GearHandle>,
    batch: Vec<Item<Job>>,
) {
    let n = batch.len();
    let dim = classifier.dim();
    let mut features = Vec::with_capacity(n * dim);
    for item in &batch {
        features.extend_from_slice(&item.payload.request.features);
    }
    // one gear snapshot per batch: every row in the batch runs under
    // the same config even if the controller swaps mid-execution
    let active = gear.map(|h| h.load());
    let t0 = Instant::now();
    let classified = match &active {
        Some(cfg) => classifier.classify_batch_geared(&features, n, cfg),
        None => classifier.classify_batch(&features, n),
    };
    match classified {
        Ok(results) => {
            metrics.counter("batches_ok").inc();
            metrics.histogram("batch_size").record(n as f64);
            metrics
                .histogram("batch_exec_s")
                .record(t0.elapsed().as_secs_f64());
            for (item, res) in batch.into_iter().zip(results) {
                let latency = item.enqueued.elapsed().as_secs_f64();
                metrics.histogram("request_latency_s").record(latency);
                metrics
                    .counter(&format!("exit_level_{}", res.exit_level))
                    .inc();
                let verdict = Verdict {
                    request_id: item.payload.request.id,
                    prediction: res.prediction,
                    exit_tier: res.exit_level,
                    tier_scores: res.scores,
                    latency_s: latency,
                };
                // free the admission slot BEFORE delivering, so a caller
                // unblocked by its verdict never observes a stale
                // nonzero outstanding count (and the slot is reusable
                // the moment the answer exists)
                outstanding.fetch_sub(1, Ordering::SeqCst);
                let _ = item.payload.resp.send(Ok(verdict));
            }
        }
        Err(e) => {
            metrics.counter("batches_err").inc();
            let msg = format!("classifier execution failed: {e:#}");
            for item in batch {
                outstanding.fetch_sub(1, Ordering::SeqCst);
                let _ = item.payload.resp.send(Err(msg.clone()));
            }
        }
    }
}
