//! The serving pipeline: request -> dynamic batcher -> classifier -> verdict.
//!
//! Ties the batcher to a [`BatchClassifier`] (the PJRT cascade in
//! production, a synthetic backend in load tests) and the metrics
//! registry.  Responses are delivered through per-request channels (a
//! poor man's oneshot); the whole pipeline is synchronous threads -- no
//! async runtime exists in the offline registry, and a thread per stage
//! is plenty for a CPU PJRT backend (DESIGN.md §3).
//!
//! The pipeline tracks its *outstanding* count (accepted but not yet
//! answered); `try_submit` turns that into admission control for the
//! `ReplicaPool`: the counter is bumped before the queue check, so the
//! per-pipeline outstanding count can never exceed the cap, even under
//! concurrent submitters.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::batcher::{Batcher, BatcherConfig, Item};
use crate::coordinator::cascade::BatchClassifier;
use crate::metrics::{Counter, Histogram, Metrics};
use crate::obs::{ObsHook, SpanKind};
use crate::planner::gear::GearHandle;
use crate::types::{Request, Verdict};

struct Job {
    request: Request,
    resp: Sender<Result<Verdict, String>>,
}

/// Every metric the batch-execution path touches, resolved ONCE at
/// spawn: the hot path increments through these `Arc` handles and never
/// takes the registry's map locks (those are for registration and
/// snapshots only -- DESIGN.md §12).
struct BatchMetrics {
    batches_ok: Arc<Counter>,
    batches_err: Arc<Counter>,
    batch_size: Arc<Histogram>,
    batch_exec_s: Arc<Histogram>,
    request_latency_s: Arc<Histogram>,
    /// Time a request sat in the batcher queue before its batch flushed.
    queue_wait_s: Arc<Histogram>,
    /// Classifier execution time attributed to each request of a batch.
    service_s: Arc<Histogram>,
    /// `exit_level_{i}` counters pre-registered for every level the
    /// classifier can exit at (plus one clamp slot for out-of-range).
    exit_levels: Vec<Arc<Counter>>,
}

impl BatchMetrics {
    fn resolve(metrics: &Metrics, n_levels: usize) -> BatchMetrics {
        BatchMetrics {
            batches_ok: metrics.counter("batches_ok"),
            batches_err: metrics.counter("batches_err"),
            batch_size: metrics.histogram("batch_size"),
            batch_exec_s: metrics.histogram("batch_exec_s"),
            request_latency_s: metrics.histogram("request_latency_s"),
            queue_wait_s: metrics.histogram("queue_wait_s"),
            service_s: metrics.histogram("service_s"),
            exit_levels: (0..=n_levels.max(1))
                .map(|i| metrics.counter(&format!("exit_level_{i}")))
                .collect(),
        }
    }

    fn exit_level(&self, level: usize) -> &Counter {
        &self.exit_levels[level.min(self.exit_levels.len() - 1)]
    }
}

/// Why `try_submit` refused a request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitRejection {
    /// The pipeline already holds `outstanding` >= the caller's cap.
    Full { outstanding: usize },
    /// The request failed validation (e.g. wrong feature dim).
    Invalid(String),
    /// The batcher has shut down.
    Closed,
}

impl std::fmt::Display for SubmitRejection {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitRejection::Full { outstanding } => {
                write!(f, "pipeline full ({outstanding} outstanding)")
            }
            SubmitRejection::Invalid(msg) => write!(f, "invalid request: {msg}"),
            SubmitRejection::Closed => write!(f, "pipeline is shut down"),
        }
    }
}

impl std::error::Error for SubmitRejection {}

/// Client-side handle to a running pipeline.
pub struct Pipeline {
    batcher: Batcher<Job>,
    metrics: Arc<Metrics>,
    outstanding: Arc<AtomicUsize>,
    /// Pre-resolved `requests_submitted` counter: the submit hot path
    /// must not pay a registry-lock lookup per request.
    submitted: Arc<crate::metrics::Counter>,
    dim: usize,
}

impl Pipeline {
    /// Spawn the pipeline over a batch classifier.
    pub fn spawn(
        classifier: Arc<dyn BatchClassifier>,
        cfg: BatcherConfig,
        metrics: Arc<Metrics>,
    ) -> Pipeline {
        Pipeline::spawn_with_gear(classifier, cfg, metrics, None)
    }

    /// Spawn with an optional shared gear handle: each flushed batch is
    /// classified under the gear config active *at flush time*
    /// (`BatchClassifier::classify_batch_geared`).  A gear swap touches
    /// only batches formed after it; responses for in-flight requests
    /// are unaffected, so shifts never drop or duplicate work.
    pub fn spawn_with_gear(
        classifier: Arc<dyn BatchClassifier>,
        cfg: BatcherConfig,
        metrics: Arc<Metrics>,
        gear: Option<Arc<GearHandle>>,
    ) -> Pipeline {
        Pipeline::spawn_with_obs(classifier, cfg, metrics, gear, ObsHook::default())
    }

    /// Spawn with an observability hook: sampled requests get
    /// queue-wait / batch-assembly / infer (and, for terminal hooks,
    /// complete) trace spans, tagged with the hook's tier index.
    pub fn spawn_with_obs(
        classifier: Arc<dyn BatchClassifier>,
        cfg: BatcherConfig,
        metrics: Arc<Metrics>,
        gear: Option<Arc<GearHandle>>,
        obs: ObsHook,
    ) -> Pipeline {
        let dim = classifier.dim();
        let bm = BatchMetrics::resolve(&metrics, classifier.n_levels());
        let outstanding = Arc::new(AtomicUsize::new(0));
        let out = Arc::clone(&outstanding);
        let submitted = metrics.counter("requests_submitted");
        // feature staging scratch, owned by the collector thread's
        // closure: cleared and refilled per batch, so steady-state
        // flushes stop allocating once it has grown to the largest
        // batch seen (`scripts/check_hotpath_allocs.sh` freezes this
        // file's allocation count)
        let mut features: Vec<f32> = Vec::new();
        let batcher = Batcher::spawn(cfg, move |batch: Vec<Item<Job>>| {
            process_batch(
                classifier.as_ref(),
                &bm,
                &out,
                gear.as_deref(),
                &obs,
                &mut features,
                batch,
            );
        });
        Pipeline { batcher, metrics, outstanding, submitted, dim }
    }

    /// Retune the dynamic batcher's flush cap (gear shifts; takes
    /// effect from the next flush decision on).
    pub fn set_max_batch(&self, max_batch: usize) {
        self.batcher.set_max_batch(max_batch);
    }

    /// Requests accepted but not yet answered (queued + in execution).
    pub fn outstanding(&self) -> usize {
        self.outstanding.load(Ordering::SeqCst)
    }

    /// Drain-aware teardown (replica retirement): close the batcher
    /// gate, flush everything already accepted, and join the collector
    /// thread.  Every request admitted before this call still gets its
    /// verdict; `try_submit`/`submit` afterwards return `Closed`.
    /// Idempotent.
    pub fn shutdown(&self) {
        self.batcher.join();
    }

    /// Submit a request; returns a receiver for its verdict.
    pub fn submit(&self, request: Request) -> Result<Receiver<Result<Verdict, String>>> {
        anyhow::ensure!(
            request.features.len() == self.dim,
            "request {} has {} features, suite dim is {}",
            request.id,
            request.features.len(),
            self.dim
        );
        let (tx, rx) = channel();
        self.outstanding.fetch_add(1, Ordering::SeqCst);
        if let Err(e) = self.batcher.push(Job { request, resp: tx }) {
            self.outstanding.fetch_sub(1, Ordering::SeqCst);
            return Err(anyhow::anyhow!(e));
        }
        self.submitted.inc();
        Ok(rx)
    }

    /// Bounded-queue submit: refuse (rather than queue) when this
    /// pipeline already holds `cap` outstanding requests.  The counter is
    /// reserved optimistically before the check, so outstanding never
    /// exceeds `cap` even with concurrent submitters.  Takes the request
    /// by reference so a refused probe costs no clone (the dispatcher may
    /// probe several replicas); the clone happens only on acceptance.
    pub fn try_submit(
        &self,
        request: &Request,
        cap: usize,
    ) -> Result<Receiver<Result<Verdict, String>>, SubmitRejection> {
        if request.features.len() != self.dim {
            return Err(SubmitRejection::Invalid(format!(
                "request {} has {} features, suite dim is {}",
                request.id,
                request.features.len(),
                self.dim
            )));
        }
        let prev = self.outstanding.fetch_add(1, Ordering::SeqCst);
        if prev >= cap {
            self.outstanding.fetch_sub(1, Ordering::SeqCst);
            return Err(SubmitRejection::Full { outstanding: prev });
        }
        let (tx, rx) = channel();
        if self.batcher.push(Job { request: request.clone(), resp: tx }).is_err() {
            self.outstanding.fetch_sub(1, Ordering::SeqCst);
            return Err(SubmitRejection::Closed);
        }
        self.submitted.inc();
        Ok(rx)
    }

    /// Submit and block for the verdict (single-request convenience).
    pub fn infer(&self, request: Request) -> Result<Verdict> {
        let rx = self.submit(request)?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("pipeline dropped the request"))?
            .map_err(|e| anyhow::anyhow!(e))
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }
}

fn process_batch(
    classifier: &dyn BatchClassifier,
    bm: &BatchMetrics,
    outstanding: &AtomicUsize,
    gear: Option<&GearHandle>,
    obs: &ObsHook,
    features: &mut Vec<f32>,
    batch: Vec<Item<Job>>,
) {
    let n = batch.len();
    let dim = classifier.dim();
    features.clear();
    features.reserve(n * dim);
    for item in &batch {
        features.extend_from_slice(&item.payload.request.features);
    }
    // one gear snapshot per batch: every row in the batch runs under
    // the same config even if the controller swaps mid-execution
    let active = gear.map(|h| h.load());
    let t0 = Instant::now();
    // queue wait ends when execution starts; `duration_since` saturates
    // to zero, so a clock hiccup can't panic the pipeline thread
    for item in &batch {
        bm.queue_wait_s
            .record(t0.duration_since(item.enqueued).as_secs_f64());
    }
    if let Some(tracer) = obs.tracer() {
        // the batch's assembly span (oldest member's wait) is emitted
        // once, attributed to its first sampled member
        let mut assembly_owner = None;
        let mut oldest_wait = 0.0f64;
        for item in &batch {
            let wait = t0.duration_since(item.enqueued).as_secs_f64();
            oldest_wait = oldest_wait.max(wait);
            let id = item.payload.request.id;
            if tracer.sampled(id) {
                tracer.record(id, SpanKind::QueueWait, obs.tier, wait);
                assembly_owner.get_or_insert(id);
            }
        }
        if let Some(id) = assembly_owner {
            tracer.record(id, SpanKind::BatchAssembly, obs.tier, oldest_wait);
        }
    }
    let classified = match &active {
        Some(cfg) => classifier.classify_batch_geared(&features, n, cfg),
        None => classifier.classify_batch(&features, n),
    };
    let exec_s = t0.elapsed().as_secs_f64();
    match classified {
        Ok(results) => {
            bm.batches_ok.inc();
            bm.batch_size.record(n as f64);
            bm.batch_exec_s.record(exec_s);
            for (item, res) in batch.into_iter().zip(results) {
                let latency = item.enqueued.elapsed().as_secs_f64();
                bm.request_latency_s.record(latency);
                bm.service_s.record(exec_s);
                bm.exit_level(res.exit_level).inc();
                if let Some(tracer) = obs.tracer() {
                    let id = item.payload.request.id;
                    if tracer.sampled(id) {
                        tracer.record(id, SpanKind::Infer, obs.tier, exec_s);
                        if obs.terminal {
                            tracer.record(id, SpanKind::Complete, obs.tier, latency);
                        }
                    }
                }
                let verdict = Verdict {
                    request_id: item.payload.request.id,
                    prediction: res.prediction,
                    exit_tier: res.exit_level,
                    tier_scores: res.scores,
                    latency_s: latency,
                };
                // free the admission slot BEFORE delivering, so a caller
                // unblocked by its verdict never observes a stale
                // nonzero outstanding count (and the slot is reusable
                // the moment the answer exists)
                outstanding.fetch_sub(1, Ordering::SeqCst);
                let _ = item.payload.resp.send(Ok(verdict));
            }
        }
        Err(e) => {
            bm.batches_err.inc();
            let msg = format!("classifier execution failed: {e:#}");
            for item in batch {
                outstanding.fetch_sub(1, Ordering::SeqCst);
                let _ = item.payload.resp.send(Err(msg.clone()));
            }
        }
    }
}
