//! Replicated serving: N independent `Pipeline` replicas behind a
//! least-outstanding-requests dispatcher with bounded queues and explicit
//! load shedding.
//!
//! Each replica owns its own dynamic batcher thread over a shared
//! `Arc<dyn BatchClassifier>` (the PJRT CPU client is thread-safe for
//! execution, so replicas genuinely run concurrently; the synthetic
//! backend sleeps, which parallelises trivially).  Admission control is
//! enforced *inside* each pipeline (`Pipeline::try_submit` reserves a
//! slot before checking the cap), so `outstanding <= max_queue` holds
//! per replica even under concurrent submitters -- the pool never grows
//! queues without bound.  When every replica is full the pool answers
//! with a typed [`PoolError::Overloaded`] instead of queueing, which the
//! TCP front end renders as the wire-protocol `overloaded` reply (see
//! `server`).

use std::sync::mpsc::Receiver;
use std::sync::Arc;

use crate::coordinator::batcher::BatcherConfig;
use crate::coordinator::cascade::BatchClassifier;
use crate::coordinator::pipeline::{Pipeline, SubmitRejection};
use crate::metrics::Metrics;
use crate::planner::gear::GearHandle;
use crate::types::{Request, Verdict};

/// Sizing knobs for a replica pool.
#[derive(Debug, Clone, Copy)]
pub struct PoolConfig {
    /// Number of independent pipeline replicas.
    pub replicas: usize,
    /// Max outstanding requests per replica before shedding.
    pub max_queue: usize,
    /// Batching policy for every replica.
    pub batcher: BatcherConfig,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig { replicas: 1, max_queue: 256, batcher: BatcherConfig::default() }
    }
}

/// Typed serving error surfaced to clients.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PoolError {
    /// Every replica's bounded queue is full; the request was shed.
    Overloaded { outstanding: usize, limit: usize },
    /// The request was refused before execution (validation / shutdown).
    Rejected(String),
    /// The request was admitted but execution failed.
    Failed(String),
}

impl std::fmt::Display for PoolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PoolError::Overloaded { outstanding, limit } => write!(
                f,
                "overloaded: {outstanding} outstanding across the pool (limit {limit})"
            ),
            PoolError::Rejected(msg) => write!(f, "rejected: {msg}"),
            PoolError::Failed(msg) => write!(f, "failed: {msg}"),
        }
    }
}

impl std::error::Error for PoolError {}

/// N pipeline replicas behind a least-outstanding-requests dispatcher.
pub struct ReplicaPool {
    replicas: Vec<Pipeline>,
    /// Pre-resolved `replica_{i}_requests` counters: the dispatch path
    /// must not pay a format!/registry-lock per request.
    replica_counters: Vec<Arc<crate::metrics::Counter>>,
    max_queue: usize,
    shed_counter: Arc<crate::metrics::Counter>,
    metrics: Arc<Metrics>,
    /// Shared gear handle when the pool serves under a gear plan
    /// (`spawn_geared`); the controller swaps it, pipelines read it.
    gear: Option<Arc<GearHandle>>,
}

impl ReplicaPool {
    /// Spawn `cfg.replicas` pipelines over a shared classifier.  All
    /// replicas share one metrics registry, so counters and histograms
    /// aggregate across the pool.
    pub fn spawn(
        classifier: Arc<dyn BatchClassifier>,
        cfg: PoolConfig,
        metrics: Arc<Metrics>,
    ) -> ReplicaPool {
        ReplicaPool::spawn_inner(classifier, cfg, metrics, None)
    }

    /// Spawn with a shared gear handle: every replica classifies each
    /// batch under the gear config active at flush time, and
    /// [`ReplicaPool::set_max_batch`] lets the controller retune the
    /// batchers on a shift.
    pub fn spawn_geared(
        classifier: Arc<dyn BatchClassifier>,
        cfg: PoolConfig,
        metrics: Arc<Metrics>,
        gear: Arc<GearHandle>,
    ) -> ReplicaPool {
        ReplicaPool::spawn_inner(classifier, cfg, metrics, Some(gear))
    }

    fn spawn_inner(
        classifier: Arc<dyn BatchClassifier>,
        cfg: PoolConfig,
        metrics: Arc<Metrics>,
        gear: Option<Arc<GearHandle>>,
    ) -> ReplicaPool {
        assert!(cfg.replicas > 0, "pool needs at least one replica");
        assert!(cfg.max_queue > 0, "max_queue must be > 0");
        let replicas: Vec<Pipeline> = (0..cfg.replicas)
            .map(|_| {
                Pipeline::spawn_with_gear(
                    Arc::clone(&classifier),
                    cfg.batcher,
                    Arc::clone(&metrics),
                    gear.clone(),
                )
            })
            .collect();
        let replica_counters = (0..cfg.replicas)
            .map(|i| metrics.counter(&format!("replica_{i}_requests")))
            .collect();
        let shed_counter = metrics.counter("requests_shed");
        ReplicaPool {
            replicas,
            replica_counters,
            max_queue: cfg.max_queue,
            shed_counter,
            metrics,
            gear,
        }
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    pub fn max_queue(&self) -> usize {
        self.max_queue
    }

    /// The shared gear handle, when serving under a plan.
    pub fn gear(&self) -> Option<&Arc<GearHandle>> {
        self.gear.as_ref()
    }

    /// Retune every replica's dynamic-batcher flush cap (gear shifts).
    pub fn set_max_batch(&self, max_batch: usize) {
        for p in &self.replicas {
            p.set_max_batch(max_batch);
        }
    }

    /// Total outstanding requests across all replicas.
    pub fn total_outstanding(&self) -> usize {
        self.replicas.iter().map(|p| p.outstanding()).sum()
    }

    /// Per-replica outstanding counts (diagnostics / tests).
    pub fn outstanding_per_replica(&self) -> Vec<usize> {
        self.replicas.iter().map(|p| p.outstanding()).collect()
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Submit to the least-loaded replica with room; sheds with
    /// [`PoolError::Overloaded`] when every replica is at `max_queue`.
    ///
    /// Fast path: one alloc-free argmin scan and a single `try_submit`
    /// probe.  Only if that replica filled up between the scan and the
    /// probe (or is genuinely full) do we fall back to probing the rest
    /// in ascending-outstanding order -- so a stale snapshot costs extra
    /// probes, never a false shed while any replica has room at probe
    /// time.
    pub fn submit(
        &self,
        request: Request,
    ) -> Result<Receiver<Result<Verdict, String>>, PoolError> {
        let mut least_i = 0usize;
        let mut least = usize::MAX;
        for (i, p) in self.replicas.iter().enumerate() {
            let o = p.outstanding();
            if o < least {
                least = o;
                least_i = i;
            }
        }
        match self.try_one(least_i, &request) {
            Ok(rx) => return Ok(rx),
            Err(Some(e)) => return Err(e),
            Err(None) => {} // full: fall through to the slow path
        }
        if self.replicas.len() > 1 {
            let mut order: Vec<usize> =
                (0..self.replicas.len()).filter(|&i| i != least_i).collect();
            order.sort_by_key(|&i| self.replicas[i].outstanding());
            for &i in &order {
                match self.try_one(i, &request) {
                    Ok(rx) => return Ok(rx),
                    Err(Some(e)) => return Err(e),
                    Err(None) => continue,
                }
            }
        }
        self.shed_counter.inc();
        Err(PoolError::Overloaded {
            outstanding: self.total_outstanding(),
            limit: self.replicas.len() * self.max_queue,
        })
    }

    /// Probe one replica: `Ok(rx)` accepted, `Err(None)` full (try the
    /// next), `Err(Some(e))` terminal.
    fn try_one(
        &self,
        i: usize,
        request: &Request,
    ) -> Result<Receiver<Result<Verdict, String>>, Option<PoolError>> {
        match self.replicas[i].try_submit(request, self.max_queue) {
            Ok(rx) => {
                self.replica_counters[i].inc();
                Ok(rx)
            }
            Err(SubmitRejection::Full { .. }) => Err(None),
            Err(SubmitRejection::Invalid(msg)) => Err(Some(PoolError::Rejected(msg))),
            Err(SubmitRejection::Closed) => {
                Err(Some(PoolError::Rejected("replica shut down".to_string())))
            }
        }
    }

    /// Submit and block for the verdict.
    pub fn infer(&self, request: Request) -> Result<Verdict, PoolError> {
        let rx = self.submit(request)?;
        match rx.recv() {
            Ok(Ok(v)) => Ok(v),
            Ok(Err(msg)) => Err(PoolError::Failed(msg)),
            Err(_) => Err(PoolError::Failed("pipeline dropped the request".to_string())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trafficgen::SyntheticClassifier;
    use std::time::Duration;

    fn synth(per_row_us: u64) -> Arc<dyn BatchClassifier> {
        Arc::new(SyntheticClassifier {
            dim: 4,
            levels: 3,
            base: Duration::from_micros(0),
            per_row: Duration::from_micros(per_row_us),
        })
    }

    fn req(id: u64) -> Request {
        Request { id, features: vec![0.5, -0.25, 0.125, 1.0], arrival_s: 0.0 }
    }

    #[test]
    fn pool_serves_basic_requests() {
        let pool = ReplicaPool::spawn(
            synth(10),
            PoolConfig { replicas: 2, max_queue: 16, batcher: BatcherConfig::default() },
            Metrics::new(),
        );
        for id in 0..20 {
            let v = pool.infer(req(id)).unwrap();
            assert_eq!(v.request_id, id);
            assert!(v.exit_tier >= 1 && v.exit_tier <= 3);
        }
        assert_eq!(pool.total_outstanding(), 0);
        assert!(pool.metrics().counter("requests_submitted").get() >= 20);
    }

    #[test]
    fn pool_rejects_bad_dim() {
        let pool =
            ReplicaPool::spawn(synth(10), PoolConfig::default(), Metrics::new());
        let err = pool
            .infer(Request { id: 1, features: vec![0.0; 3], arrival_s: 0.0 })
            .unwrap_err();
        assert!(matches!(err, PoolError::Rejected(_)), "got {err:?}");
        assert!(err.to_string().contains("features"));
    }

    #[test]
    fn pool_sheds_when_full_and_bounds_queue() {
        // slow classifier + tiny queue: the second wave must shed
        let pool = ReplicaPool::spawn(
            synth(20_000), // 20ms per row
            PoolConfig {
                replicas: 1,
                max_queue: 2,
                batcher: BatcherConfig {
                    max_batch: 1,
                    max_wait: Duration::from_micros(100),
                },
            },
            Metrics::new(),
        );
        let mut accepted = Vec::new();
        let mut shed = 0u64;
        for id in 0..8 {
            match pool.submit(req(id)) {
                Ok(rx) => accepted.push(rx),
                Err(PoolError::Overloaded { .. }) => shed += 1,
                Err(e) => panic!("unexpected {e:?}"),
            }
            assert!(pool.total_outstanding() <= 2, "queue bound violated");
        }
        assert!(shed > 0, "expected sheds");
        assert!(!accepted.is_empty(), "expected some accepts");
        assert_eq!(pool.metrics().counter("requests_shed").get(), shed);
        for rx in accepted {
            rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        }
        assert_eq!(pool.total_outstanding(), 0);
    }

    #[test]
    fn geared_pool_swaps_without_losing_requests() {
        use crate::planner::gear::{GearConfig, GearHandle};
        let handle = GearHandle::new(GearConfig {
            gear_id: 0,
            thetas: vec![0.6],
            work_factor: 1.0,
            max_batch: 4,
        });
        let pool = ReplicaPool::spawn_geared(
            synth(500),
            PoolConfig {
                replicas: 2,
                max_queue: 64,
                batcher: BatcherConfig {
                    max_batch: 4,
                    max_wait: Duration::from_micros(200),
                },
            },
            Metrics::new(),
            Arc::clone(&handle),
        );
        assert!(pool.gear().is_some());
        // submit a wave, swap gears mid-flight, submit another wave
        let mut rxs = Vec::new();
        for id in 0..30 {
            rxs.push(pool.submit(req(id)).unwrap());
        }
        handle.store(GearConfig {
            gear_id: 1,
            thetas: vec![0.3],
            work_factor: 0.25,
            max_batch: 8,
        });
        pool.set_max_batch(8);
        for id in 30..60 {
            rxs.push(pool.submit(req(id)).unwrap());
        }
        // every request is answered exactly once, none dropped
        for (i, rx) in rxs.into_iter().enumerate() {
            let v = rx
                .recv_timeout(Duration::from_secs(10))
                .expect("verdict arrives")
                .expect("no error");
            assert_eq!(v.request_id, i as u64);
        }
        assert_eq!(pool.total_outstanding(), 0);
        assert_eq!(handle.generation(), 1);
    }

    #[test]
    fn dispatch_spreads_across_replicas() {
        let pool = ReplicaPool::spawn(
            synth(2_000),
            PoolConfig {
                replicas: 3,
                max_queue: 4,
                batcher: BatcherConfig {
                    max_batch: 4,
                    max_wait: Duration::from_millis(1),
                },
            },
            Metrics::new(),
        );
        let rxs: Vec<_> = (0..9).filter_map(|id| pool.submit(req(id)).ok()).collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        }
        // least-outstanding routing must have touched every replica
        for i in 0..3 {
            assert!(
                pool.metrics().counter(&format!("replica_{i}_requests")).get() > 0,
                "replica {i} got no traffic"
            );
        }
    }
}
