//! Replicated serving: an **elastic** pool of independent `Pipeline`
//! replicas behind a least-outstanding-requests dispatcher with bounded
//! queues and explicit load shedding.
//!
//! Each replica owns its own dynamic batcher thread over a shared
//! `Arc<dyn BatchClassifier>` (the PJRT CPU client is thread-safe for
//! execution, so replicas genuinely run concurrently; the synthetic
//! backend sleeps, which parallelises trivially).  Admission control is
//! enforced *inside* each pipeline (`Pipeline::try_submit` reserves a
//! slot before checking the cap), so `outstanding <= max_queue` holds
//! per replica even under concurrent submitters -- the pool never grows
//! queues without bound.  When every admitting replica is full the pool
//! answers with a typed [`PoolError::Overloaded`] instead of queueing,
//! which the TCP front end renders as the wire-protocol `overloaded`
//! reply (see `server`).
//!
//! # Replica lifecycle (elastic scaling)
//!
//! ```text
//!   scale_up()            warmup elapses        drain()
//!  ------------> Warming ----------------> Live --------> Draining
//!                   |  (advance())                           |
//!                   | fallback admission                     | outstanding
//!                   | only when no Live                      | reaches 0
//!                   | replica admits                         v (advance())
//!                   +----------------------------------> Retired
//!                                             (batcher joined, slot removed)
//! ```
//!
//! * **Warming**: the replica's threads are up but it is still paying
//!   its simulated provisioning delay; the dispatcher skips it unless
//!   *no* live replica can admit (a stall is worse than a cold batch).
//!   The rental clock ([`ReplicaPool::replica_seconds`]) runs from
//!   `scale_up` -- you pay for a machine from the moment you rent it,
//!   not from the moment it is useful.
//! * **Live**: normal dispatch target.
//! * **Draining**: stops admitting (any `submit` that starts after
//!   `drain` returns will never route here) but keeps executing; once
//!   its outstanding count hits zero, [`ReplicaPool::advance`] retires
//!   it -- the batcher gate is closed, every accepted item was already
//!   flushed and answered, and the worker thread is joined.  No request
//!   is ever dropped or duplicated by scale-down (property-tested in
//!   rust/tests/autoscale_integration.rs).
//!
//! Retirement removes the slot under the pool's write lock while every
//! admission probe holds the read lock, so an "idle" check here cannot
//! race an in-flight admission: either the probe finished first (its
//! request is counted in `outstanding`, blocking retirement) or the
//! slot is already gone when the probe looks.

use std::sync::atomic::{AtomicBool, AtomicU8, AtomicUsize, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex, OnceLock, RwLock};
use std::time::{Duration, Instant};

use crate::coordinator::batcher::BatcherConfig;
use crate::coordinator::cascade::BatchClassifier;
use crate::coordinator::pipeline::{Pipeline, SubmitRejection};
use crate::cost::rental::Gpu;
use crate::metrics::{EventKind, EventRecord, Metrics};
use crate::obs::{ObsHook, SloObservatory, SpanKind, Tracer};
use crate::planner::gear::GearHandle;
use crate::types::{Class, Request, Verdict};

/// Sizing knobs for a replica pool.
#[derive(Debug, Clone, Copy)]
pub struct PoolConfig {
    /// Number of independent pipeline replicas at spawn (all Live).
    pub replicas: usize,
    /// Max outstanding requests per replica before shedding.
    pub max_queue: usize,
    /// Batching policy for every replica.
    pub batcher: BatcherConfig,
    /// GPU class every replica of this pool rents (prices the pool's
    /// `replica_seconds` in dollars; see [`ReplicaPool::dollars`]).  A
    /// monolithic pool runs the whole cascade, so it must be provisioned
    /// for the top model -- hence the expensive default.  Tiered fleets
    /// give each tier's pool its own class (`coordinator::router`).
    pub gpu: Gpu,
    /// Hard floor on Live replicas: `drain` never takes the fleet below
    /// it (1 preserves the pre-tiered "never drain the last Live
    /// replica" guarantee).
    pub min_replicas: usize,
    /// Hard ceiling on total slots: `scale_up` clamps provisioning so
    /// the pool never holds more (Warming + Live + Draining).
    pub max_replicas: usize,
    /// Weighted-fair admission quotas per SLO class, indexed by
    /// [`Class::index`] (premium, standard, batch).  `None` -- the
    /// default -- disables class-aware admission entirely: the
    /// admission path is byte-identical to the historical untagged
    /// FIFO one.  With `Some(w)`, each class is guaranteed a
    /// `w[c] / sum(w)` share of the pool's queue slots, and spare
    /// capacity other classes are not using is borrowable
    /// (work-conserving), so a bursty `batch` tenant cannot starve
    /// `premium` but an idle fleet still admits anyone.
    pub class_weights: Option<[f64; Class::COUNT]>,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            replicas: 1,
            max_queue: 256,
            batcher: BatcherConfig::default(),
            gpu: Gpu::H100,
            min_replicas: 1,
            max_replicas: usize::MAX,
            class_weights: None,
        }
    }
}

/// Typed serving error surfaced to clients.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PoolError {
    /// Every replica's bounded queue is full; the request was shed.
    Overloaded { outstanding: usize, limit: usize },
    /// The request was refused before execution (validation / shutdown).
    Rejected(String),
    /// The request was admitted but execution failed.
    Failed(String),
}

impl std::fmt::Display for PoolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PoolError::Overloaded { outstanding, limit } => write!(
                f,
                "overloaded: {outstanding} outstanding across the pool (limit {limit})"
            ),
            PoolError::Rejected(msg) => write!(f, "rejected: {msg}"),
            PoolError::Failed(msg) => write!(f, "failed: {msg}"),
        }
    }
}

impl std::error::Error for PoolError {}

/// Where a replica sits in its lifecycle.  `Retired` is not a state a
/// slot can be observed in -- retirement removes the slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum ReplicaState {
    Warming = 0,
    Live = 1,
    Draining = 2,
}

impl ReplicaState {
    fn from_u8(v: u8) -> ReplicaState {
        match v {
            0 => ReplicaState::Warming,
            1 => ReplicaState::Live,
            _ => ReplicaState::Draining,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ReplicaState::Warming => "warming",
            ReplicaState::Live => "live",
            ReplicaState::Draining => "draining",
        }
    }
}

/// One replica: a pipeline plus its lifecycle state and bookkeeping.
struct ReplicaSlot {
    /// Stable id (monotone across the pool's lifetime); names the
    /// `replica_{id}_requests` counter.
    id: usize,
    pipeline: Pipeline,
    state: AtomicU8,
    /// Pre-resolved per-replica request counter: the dispatch path must
    /// not pay a format!/registry-lock per request.
    requests: Arc<crate::metrics::Counter>,
    /// When the replica was provisioned (rental clock origin).
    started: Instant,
    /// When warm-up completes (== `started` for instant replicas).
    warm_at: Instant,
}

impl ReplicaSlot {
    fn state(&self) -> ReplicaState {
        ReplicaState::from_u8(self.state.load(Ordering::SeqCst))
    }

    fn transition(&self, from: ReplicaState, to: ReplicaState) -> bool {
        self.state
            .compare_exchange(from as u8, to as u8, Ordering::SeqCst, Ordering::SeqCst)
            .is_ok()
    }
}

/// Diagnostic snapshot of one replica (tests, `stats`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReplicaInfo {
    pub id: usize,
    pub state: ReplicaState,
    pub outstanding: usize,
    pub requests: u64,
}

/// Lifecycle transitions applied by one [`ReplicaPool::advance`] call.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Lifecycle {
    /// Warming replicas promoted to Live.
    pub warmed: usize,
    /// Draining replicas retired (batcher joined, slot removed).
    pub retired: usize,
}

/// Weighted-fair admission quota: per-class outstanding counts judged
/// against weighted shares of the pool's total queue slots, with
/// work-conserving borrowing.  All atomics -- the admission hot path
/// acquires no locks beyond the slots read lock it already holds.
///
/// Admission rule for class `c` over capacity `K` (live replicas x
/// `max_queue`): admit iff `out[c] < w[c]*K` (inside its own share) OR
/// `total_out < K - sum_{d != c} max(0, w[d]*K - out[d])` (spare
/// capacity no other class has reserved).  The check-then-increment is
/// intentionally racy across submitters: fairness is approximate under
/// contention, while the hard queue bound stays exact because every
/// pipeline still enforces `outstanding <= max_queue` on its own.
struct ClassQuota {
    /// Normalised class weights (sum to 1).
    weights: [f64; Class::COUNT],
    /// Quota-tracked in-flight requests per class (acquired at
    /// admission, released when the verdict is delivered).
    out: [AtomicUsize; Class::COUNT],
    /// Per-class pressure-episode latch: one `EventKind::Shed` record
    /// per episode (set on the first quota shed, cleared by the next
    /// successful admit), not one per shed request.
    latched: [AtomicBool; Class::COUNT],
}

impl ClassQuota {
    fn new(weights: [f64; Class::COUNT]) -> ClassQuota {
        let sum: f64 = weights.iter().sum();
        assert!(
            sum > 0.0 && weights.iter().all(|w| *w >= 0.0),
            "class weights must be non-negative and sum > 0, got {weights:?}"
        );
        ClassQuota {
            weights: weights.map(|w| w / sum),
            out: std::array::from_fn(|_| AtomicUsize::new(0)),
            latched: std::array::from_fn(|_| AtomicBool::new(false)),
        }
    }

    /// Try to take one queue slot for `class` under total capacity
    /// `capacity`; increments the class's outstanding count on success.
    fn try_acquire(&self, class: Class, capacity: usize) -> bool {
        let c = class.index();
        let share_c = self.weights[c] * capacity as f64;
        if (self.out[c].load(Ordering::Relaxed) as f64) < share_c {
            self.out[c].fetch_add(1, Ordering::Relaxed);
            return true;
        }
        // work-conserving borrow: spare slots not reserved by the
        // other classes' unused shares
        let mut total = 0usize;
        let mut reserved_other = 0.0f64;
        for d in 0..Class::COUNT {
            let o = self.out[d].load(Ordering::Relaxed);
            total += o;
            if d != c {
                let share_d = self.weights[d] * capacity as f64;
                reserved_other += (share_d - o as f64).max(0.0);
            }
        }
        if (total as f64) < capacity as f64 - reserved_other {
            self.out[c].fetch_add(1, Ordering::Relaxed);
            return true;
        }
        false
    }

    /// Give back one slot (saturating: never underflows even on a
    /// spurious release).
    fn release(&self, class: Class) {
        let _ = self.out[class.index()].fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |v| v.checked_sub(1),
        );
    }

    /// Outstanding quota units for one class (diagnostics/tests).
    fn outstanding(&self, class: Class) -> usize {
        self.out[class.index()].load(Ordering::Relaxed)
    }
}

/// An elastic pool of pipeline replicas behind a least-outstanding
/// dispatcher.
pub struct ReplicaPool {
    classifier: Arc<dyn BatchClassifier>,
    /// Batcher template for new replicas; `max_batch` is shadowed by
    /// `cur_max_batch` so replicas spawned after a gear shift inherit
    /// the *current* cap, not the spawn-time one.
    batcher: BatcherConfig,
    cur_max_batch: AtomicUsize,
    slots: RwLock<Vec<Arc<ReplicaSlot>>>,
    next_id: AtomicUsize,
    max_queue: usize,
    gpu: Gpu,
    min_replicas: usize,
    max_replicas: usize,
    shed_counter: Arc<crate::metrics::Counter>,
    retired_counter: Arc<crate::metrics::Counter>,
    /// Accumulated replica-seconds of retired replicas; active replicas
    /// contribute `started.elapsed()` on top (see `replica_seconds`).
    retired_seconds: Mutex<f64>,
    metrics: Arc<Metrics>,
    /// Shared gear handle when the pool serves under a gear plan
    /// (`spawn_geared`); the controller swaps it, pipelines read it.
    gear: Option<Arc<GearHandle>>,
    /// Observability hook cloned into every replica pipeline; also
    /// drives the pool's own enqueue/shed spans when it is terminal.
    obs: ObsHook,
    /// Weighted-fair class quotas (None = untagged FIFO admission).
    quota: Option<ClassQuota>,
    /// Attached SLO observatory (monolithic deployments; a fleet's tier
    /// pools leave this empty and the fleet keeps the books instead --
    /// exactly one bookkeeper per request).  `OnceLock` so the
    /// per-request `get()` is a lock-free load.
    slo: OnceLock<Arc<SloObservatory>>,
}

impl ReplicaPool {
    /// Spawn `cfg.replicas` pipelines over a shared classifier.  All
    /// replicas share one metrics registry, so counters and histograms
    /// aggregate across the pool.
    pub fn spawn(
        classifier: Arc<dyn BatchClassifier>,
        cfg: PoolConfig,
        metrics: Arc<Metrics>,
    ) -> ReplicaPool {
        ReplicaPool::spawn_with_obs(classifier, cfg, metrics, None, ObsHook::default())
    }

    /// Spawn with a shared gear handle: every replica classifies each
    /// batch under the gear config active at flush time, and
    /// [`ReplicaPool::set_max_batch`] lets the controller retune the
    /// batchers on a shift.
    pub fn spawn_geared(
        classifier: Arc<dyn BatchClassifier>,
        cfg: PoolConfig,
        metrics: Arc<Metrics>,
        gear: Arc<GearHandle>,
    ) -> ReplicaPool {
        ReplicaPool::spawn_with_obs(classifier, cfg, metrics, Some(gear), ObsHook::default())
    }

    /// Spawn with an observability hook: sampled requests get trace
    /// spans (enqueue/shed here; queue-wait/infer inside the replica
    /// pipelines), tagged with the hook's tier index.  A fleet passes
    /// [`ObsHook::for_tier`] so the router owns the terminal spans; a
    /// monolithic deployment passes [`ObsHook::monolithic`].
    pub fn spawn_with_obs(
        classifier: Arc<dyn BatchClassifier>,
        cfg: PoolConfig,
        metrics: Arc<Metrics>,
        gear: Option<Arc<GearHandle>>,
        obs: ObsHook,
    ) -> ReplicaPool {
        assert!(cfg.replicas > 0, "pool needs at least one replica");
        assert!(cfg.max_queue > 0, "max_queue must be > 0");
        assert!(cfg.min_replicas >= 1, "min_replicas must be >= 1");
        assert!(
            cfg.min_replicas <= cfg.max_replicas,
            "min_replicas {} > max_replicas {}",
            cfg.min_replicas,
            cfg.max_replicas
        );
        assert!(
            cfg.replicas <= cfg.max_replicas,
            "replicas {} > max_replicas {}",
            cfg.replicas,
            cfg.max_replicas
        );
        let pool = ReplicaPool {
            classifier,
            batcher: cfg.batcher,
            cur_max_batch: AtomicUsize::new(cfg.batcher.max_batch),
            slots: RwLock::new(Vec::new()),
            next_id: AtomicUsize::new(0),
            max_queue: cfg.max_queue,
            gpu: cfg.gpu,
            min_replicas: cfg.min_replicas,
            max_replicas: cfg.max_replicas,
            shed_counter: metrics.counter("requests_shed"),
            retired_counter: metrics.counter("replicas_retired"),
            retired_seconds: Mutex::new(0.0),
            metrics,
            gear,
            obs,
            quota: cfg.class_weights.map(ClassQuota::new),
            slo: OnceLock::new(),
        };
        pool.scale_up(cfg.replicas, Duration::ZERO);
        pool
    }

    fn spawn_slot(&self, warmup: Duration) -> Arc<ReplicaSlot> {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let pipeline = Pipeline::spawn_with_obs(
            Arc::clone(&self.classifier),
            BatcherConfig {
                max_batch: self.cur_max_batch.load(Ordering::Relaxed),
                max_wait: self.batcher.max_wait,
            },
            Arc::clone(&self.metrics),
            self.gear.clone(),
            self.obs.clone(),
        );
        let state = if warmup.is_zero() {
            ReplicaState::Live
        } else {
            ReplicaState::Warming
        };
        let now = Instant::now();
        Arc::new(ReplicaSlot {
            id,
            pipeline,
            state: AtomicU8::new(state as u8),
            requests: self.metrics.counter(&format!("replica_{id}_requests")),
            started: now,
            warm_at: now + warmup,
        })
    }

    /// Provision `n` new replicas (clamped so total slots never exceed
    /// the pool's `max_replicas`).  With a zero `warmup` they are Live
    /// immediately; otherwise they start Warming and [`advance`]
    /// promotes them once the warm-up elapses.  Returns the new ids.
    /// The rental clock starts now either way.
    ///
    /// [`advance`]: ReplicaPool::advance
    pub fn scale_up(&self, n: usize, warmup: Duration) -> Vec<usize> {
        let mut slots = self.slots.write().unwrap();
        let room = self.max_replicas.saturating_sub(slots.len());
        let n = n.min(room);
        let mut created = Vec::with_capacity(n);
        for _ in 0..n {
            let slot = self.spawn_slot(warmup);
            created.push(slot.id);
            slots.push(slot);
        }
        created
    }

    /// Begin graceful scale-down: mark up to `n` Live replicas as
    /// Draining, least-outstanding first (they finish soonest).  A
    /// draining replica stops admitting -- any `submit` that starts
    /// after this returns will never route to it -- but keeps executing
    /// until its queue empties, at which point [`advance`] retires it.
    /// Never drains below the pool's `min_replicas` Live floor (1 by
    /// default: the last Live replica is protected).  Returns the
    /// drained ids.
    ///
    /// [`advance`]: ReplicaPool::advance
    pub fn drain(&self, n: usize) -> Vec<usize> {
        // WRITE lock: concurrent drain() calls must serialise, or two
        // callers could each see 2 Live replicas and between them drain
        // both -- violating the Live-floor guarantee.  (scale_up and
        // retirement also hold the write lock, so the Live set cannot
        // shift under us.)
        let slots = self.slots.write().unwrap();
        let mut live: Vec<&Arc<ReplicaSlot>> = slots
            .iter()
            .filter(|s| s.state() == ReplicaState::Live)
            .collect();
        let allowed = n.min(live.len().saturating_sub(self.min_replicas.max(1)));
        live.sort_by_key(|s| s.pipeline.outstanding());
        let mut drained = Vec::new();
        for slot in live.into_iter().take(allowed) {
            if slot.transition(ReplicaState::Live, ReplicaState::Draining) {
                drained.push(slot.id);
            }
        }
        drained
    }

    /// Advance the lifecycle: promote Warming replicas whose warm-up
    /// has elapsed, and retire Draining replicas whose queues are
    /// empty (close + join their batcher, remove the slot, bank their
    /// replica-seconds).  The autoscaler calls this every sample tick;
    /// tests call it directly.
    pub fn advance(&self, now: Instant) -> Lifecycle {
        let mut changes = Lifecycle::default();
        {
            let slots = self.slots.read().unwrap();
            for slot in slots.iter() {
                if slot.state() == ReplicaState::Warming
                    && now >= slot.warm_at
                    && slot.transition(ReplicaState::Warming, ReplicaState::Live)
                {
                    changes.warmed += 1;
                }
            }
        }
        // Retirement must re-check idleness under the WRITE lock: every
        // admission probe runs under the read lock, so a probe either
        // completed before we got here (outstanding > 0 blocks retire)
        // or starts after and no longer sees the slot.
        let mut retired = Vec::new();
        {
            let mut slots = self.slots.write().unwrap();
            let mut i = 0;
            while i < slots.len() {
                if slots[i].state() == ReplicaState::Draining
                    && slots[i].pipeline.outstanding() == 0
                {
                    retired.push(slots.remove(i));
                } else {
                    i += 1;
                }
            }
        }
        for slot in retired {
            // queue already empty: shutdown flushes nothing, joins the
            // collector thread deterministically
            slot.pipeline.shutdown();
            *self.retired_seconds.lock().unwrap() +=
                slot.started.elapsed().as_secs_f64();
            self.retired_counter.inc();
            changes.retired += 1;
        }
        changes
    }

    /// Replicas currently admitting traffic (Live).  This is what the
    /// controller's admission-capacity math and the wire `overloaded`
    /// limit use.
    pub fn n_replicas(&self) -> usize {
        self.count_state(ReplicaState::Live)
    }

    /// All slots regardless of state (Warming + Live + Draining).
    pub fn n_slots(&self) -> usize {
        self.slots.read().unwrap().len()
    }

    /// (warming, live, draining) slot counts.
    pub fn counts(&self) -> (usize, usize, usize) {
        let slots = self.slots.read().unwrap();
        let mut c = (0, 0, 0);
        for s in slots.iter() {
            match s.state() {
                ReplicaState::Warming => c.0 += 1,
                ReplicaState::Live => c.1 += 1,
                ReplicaState::Draining => c.2 += 1,
            }
        }
        c
    }

    fn count_state(&self, state: ReplicaState) -> usize {
        self.slots
            .read()
            .unwrap()
            .iter()
            .filter(|s| s.state() == state)
            .count()
    }

    /// Total replica-seconds provisioned so far: retired replicas'
    /// lifetimes plus every active slot's age.  This is the simulated
    /// rental bill -- multiply by $/replica-hour for dollars (see
    /// `cost::rental` for the paper's Table 4 prices).
    pub fn replica_seconds(&self) -> f64 {
        let active: f64 = self
            .slots
            .read()
            .unwrap()
            .iter()
            .map(|s| s.started.elapsed().as_secs_f64())
            .sum();
        active + *self.retired_seconds.lock().unwrap()
    }

    /// The GPU class this pool's replicas rent.
    pub fn gpu(&self) -> Gpu {
        self.gpu
    }

    /// Rental dollars this pool has accrued: [`replica_seconds`] priced
    /// at the pool's GPU class (paper Table 4 $/hour).
    ///
    /// [`replica_seconds`]: ReplicaPool::replica_seconds
    pub fn dollars(&self) -> f64 {
        self.gpu.dollars_for(self.replica_seconds())
    }

    /// Current burn rate in $/hour: every provisioned slot (Warming +
    /// Live + Draining) bills at the pool's GPU class.
    pub fn dollars_per_hour(&self) -> f64 {
        self.n_slots() as f64 * self.gpu.dollars_per_hour()
    }

    /// Per-replica diagnostic snapshot (id, state, outstanding,
    /// request count), in slot order.
    pub fn snapshot_replicas(&self) -> Vec<ReplicaInfo> {
        self.slots
            .read()
            .unwrap()
            .iter()
            .map(|s| ReplicaInfo {
                id: s.id,
                state: s.state(),
                outstanding: s.pipeline.outstanding(),
                requests: s.requests.get(),
            })
            .collect()
    }

    pub fn max_queue(&self) -> usize {
        self.max_queue
    }

    /// The shared gear handle, when serving under a plan.
    pub fn gear(&self) -> Option<&Arc<GearHandle>> {
        self.gear.as_ref()
    }

    /// Retune every replica's dynamic-batcher flush cap (gear shifts).
    /// Replicas provisioned later inherit the new cap too.
    pub fn set_max_batch(&self, max_batch: usize) {
        self.cur_max_batch.store(max_batch.max(1), Ordering::Relaxed);
        for s in self.slots.read().unwrap().iter() {
            s.pipeline.set_max_batch(max_batch);
        }
    }

    /// Total outstanding requests across all replicas (any state).
    pub fn total_outstanding(&self) -> usize {
        self.slots
            .read()
            .unwrap()
            .iter()
            .map(|s| s.pipeline.outstanding())
            .sum()
    }

    /// Per-replica outstanding counts (diagnostics / tests).
    pub fn outstanding_per_replica(&self) -> Vec<usize> {
        self.slots
            .read()
            .unwrap()
            .iter()
            .map(|s| s.pipeline.outstanding())
            .collect()
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// The attached tracer, when sampling is enabled.
    pub fn tracer(&self) -> Option<&Arc<Tracer>> {
        self.obs.tracer()
    }

    /// Attach an SLO observatory: [`ReplicaPool::infer`] records
    /// per-class submitted/completed/shed books and latencies into it.
    /// One-shot (later attaches are ignored); monolithic deployments
    /// only -- a fleet keeps its own books at the fleet boundary.
    pub fn attach_slo(&self, slo: Arc<SloObservatory>) {
        let _ = self.slo.set(slo);
    }

    /// The attached SLO observatory, if any.
    pub fn slo(&self) -> Option<&Arc<SloObservatory>> {
        self.slo.get()
    }

    /// Quota-tracked in-flight requests for one class (0 when
    /// class-aware admission is disabled).  Diagnostics/tests.
    pub fn class_outstanding(&self, class: Class) -> usize {
        self.quota.as_ref().map(|q| q.outstanding(class)).unwrap_or(0)
    }

    /// Record one quota-pressure shed episode into the event log: one
    /// `EventKind::Shed` per pressure episode per class (latched; the
    /// next successful admit of the class re-arms it), tagged with the
    /// class and `trigger="quota"`.
    fn note_quota_shed(&self, q: &ClassQuota, class: Class, live: usize) {
        if !q.latched[class.index()].swap(true, Ordering::Relaxed) {
            self.metrics.events().record(EventRecord {
                kind: EventKind::Shed,
                decider: "admission",
                trigger: "quota",
                tier: self.obs.tier,
                old_gear: 0,
                new_gear: 0,
                old_replicas: live,
                new_replicas: live,
                class: Some(class.name()),
            });
        }
    }

    /// Submit to the least-loaded admitting replica; sheds with
    /// [`PoolError::Overloaded`] when every one is at `max_queue`.
    ///
    /// Fast path: one alloc-free argmin scan over Live replicas and a
    /// single `try_submit` probe.  Only if that replica filled up (or
    /// retired) between the scan and the probe do we fall back to
    /// probing the rest in ascending-outstanding order -- a stale
    /// snapshot costs extra probes, never a false shed while any
    /// admitting replica has room at probe time.  When *no* Live
    /// replica admits, Warming replicas are probed as a fallback (a
    /// cold batch beats a stall); Draining replicas are never probed.
    pub fn submit(
        &self,
        request: Request,
    ) -> Result<Receiver<Result<Verdict, String>>, PoolError> {
        // resolve the span decision once: terminal pools own the
        // enqueue/shed markers, a fleet's tier pools leave them to the
        // router (which sees the whole request, deferrals included)
        let span_tracer = if self.obs.terminal {
            self.obs.tracer().filter(|t| t.sampled(request.id))
        } else {
            None
        };
        let slots = self.slots.read().unwrap();
        // Class-aware admission gate (quota enabled only): the class
        // must fit its weighted-fair share -- or borrow genuinely spare
        // capacity -- BEFORE any replica probe.  The quota unit is held
        // until the verdict is delivered; [`ReplicaPool::infer`]
        // releases it, so callers that pair `submit` with their own
        // `recv` under quotas must route through `infer` (the serving
        // front ends and the fleet router all do).
        if let Some(q) = &self.quota {
            let live = slots
                .iter()
                .filter(|s| s.state() == ReplicaState::Live)
                .count();
            let capacity = live.max(1) * self.max_queue;
            if !q.try_acquire(request.class, capacity) {
                let outstanding: usize =
                    slots.iter().map(|s| s.pipeline.outstanding()).sum();
                self.shed_counter.inc();
                self.note_quota_shed(q, request.class, live);
                if let Some(t) = span_tracer {
                    t.record_with_class(
                        request.id,
                        SpanKind::Shed,
                        self.obs.tier,
                        0.0,
                        Some(request.class.name()),
                    );
                }
                return Err(PoolError::Overloaded {
                    outstanding,
                    limit: capacity,
                });
            }
            // admitted: the class's pressure episode (if any) is over
            q.latched[request.class.index()].store(false, Ordering::Relaxed);
        }
        match self.dispatch(&slots, ReplicaState::Live, &request) {
            Ok(rx) => {
                if let Some(t) = span_tracer {
                    t.record(request.id, SpanKind::Enqueue, self.obs.tier, 0.0);
                }
                return Ok(rx);
            }
            Err(Some(e)) => {
                if let Some(q) = &self.quota {
                    q.release(request.class);
                }
                return Err(e);
            }
            Err(None) => {}
        }
        match self.dispatch(&slots, ReplicaState::Warming, &request) {
            Ok(rx) => {
                if let Some(t) = span_tracer {
                    t.record(request.id, SpanKind::Enqueue, self.obs.tier, 0.0);
                }
                return Ok(rx);
            }
            Err(Some(e)) => {
                if let Some(q) = &self.quota {
                    q.release(request.class);
                }
                return Err(e);
            }
            Err(None) => {}
        }
        if let Some(q) = &self.quota {
            q.release(request.class);
        }
        let live = slots
            .iter()
            .filter(|s| s.state() == ReplicaState::Live)
            .count();
        if slots.is_empty() {
            return Err(PoolError::Rejected("pool has no replicas".to_string()));
        }
        let outstanding: usize =
            slots.iter().map(|s| s.pipeline.outstanding()).sum();
        self.shed_counter.inc();
        if let Some(t) = span_tracer {
            t.record(request.id, SpanKind::Shed, self.obs.tier, 0.0);
        }
        Err(PoolError::Overloaded {
            outstanding,
            limit: live.max(1) * self.max_queue,
        })
    }

    /// Probe every `state` replica, least-outstanding first: `Ok(rx)`
    /// accepted, `Err(None)` all full/unavailable, `Err(Some(e))`
    /// terminal.
    fn dispatch(
        &self,
        slots: &[Arc<ReplicaSlot>],
        state: ReplicaState,
        request: &Request,
    ) -> Result<Receiver<Result<Verdict, String>>, Option<PoolError>> {
        let mut least: Option<(usize, usize)> = None; // (outstanding, index)
        for (i, s) in slots.iter().enumerate() {
            if s.state() != state {
                continue;
            }
            let o = s.pipeline.outstanding();
            if least.map(|(lo, _)| o < lo).unwrap_or(true) {
                least = Some((o, i));
            }
        }
        let Some((_, least_i)) = least else {
            return Err(None); // no replica in this state
        };
        match self.try_slot(&slots[least_i], request) {
            Ok(rx) => return Ok(rx),
            Err(Some(e)) => return Err(Some(e)),
            Err(None) => {} // full: fall through to the slow path
        }
        let mut order: Vec<usize> = (0..slots.len())
            .filter(|&i| i != least_i && slots[i].state() == state)
            .collect();
        order.sort_by_key(|&i| slots[i].pipeline.outstanding());
        for &i in &order {
            match self.try_slot(&slots[i], request) {
                Ok(rx) => return Ok(rx),
                Err(Some(e)) => return Err(Some(e)),
                Err(None) => continue,
            }
        }
        Err(None)
    }

    /// Probe one replica: `Ok(rx)` accepted, `Err(None)` full or gone
    /// (try the next), `Err(Some(e))` terminal.  A `Closed` pipeline is
    /// a replica that retired between our state load and the probe --
    /// with other replicas available that is a retry, not an error.
    fn try_slot(
        &self,
        slot: &ReplicaSlot,
        request: &Request,
    ) -> Result<Receiver<Result<Verdict, String>>, Option<PoolError>> {
        match slot.pipeline.try_submit(request, self.max_queue) {
            Ok(rx) => {
                slot.requests.inc();
                Ok(rx)
            }
            Err(SubmitRejection::Full { .. }) => Err(None),
            Err(SubmitRejection::Closed) => Err(None),
            Err(SubmitRejection::Invalid(msg)) => Err(Some(PoolError::Rejected(msg))),
        }
    }

    /// Submit and block for the verdict.  This is where the class
    /// books balance: an attached SLO observatory sees exactly one
    /// submitted and exactly one terminal (completed or shed) record
    /// per call, and the admission quota unit taken in `submit` is
    /// given back once the verdict (or failure) is delivered.
    pub fn infer(&self, request: Request) -> Result<Verdict, PoolError> {
        let class = request.class;
        if let Some(slo) = self.slo.get() {
            slo.record_submitted(class);
        }
        let rx = match self.submit(request) {
            Ok(rx) => rx,
            Err(e) => {
                if let Some(slo) = self.slo.get() {
                    slo.record_shed(class);
                }
                return Err(e);
            }
        };
        let out = match rx.recv() {
            Ok(Ok(v)) => Ok(v),
            Ok(Err(msg)) => Err(PoolError::Failed(msg)),
            Err(_) => {
                Err(PoolError::Failed("pipeline dropped the request".to_string()))
            }
        };
        if let Some(q) = &self.quota {
            q.release(class);
        }
        if let Some(slo) = self.slo.get() {
            match &out {
                Ok(v) => slo.record_completed(class, v.latency_s),
                // an admitted-then-failed request still terminates the
                // books exactly once: count it with the sheds
                Err(_) => slo.record_shed(class),
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trafficgen::SyntheticClassifier;
    use std::time::Duration;

    fn synth(per_row_us: u64) -> Arc<dyn BatchClassifier> {
        Arc::new(SyntheticClassifier {
            dim: 4,
            levels: 3,
            base: Duration::from_micros(0),
            per_row: Duration::from_micros(per_row_us),
        })
    }

    fn req(id: u64) -> Request {
        Request {
            id,
            features: vec![0.5, -0.25, 0.125, 1.0],
            arrival_s: 0.0,
            class: Class::Standard,
        }
    }

    fn creq(id: u64, class: Class) -> Request {
        Request { class, ..req(id) }
    }

    #[test]
    fn pool_serves_basic_requests() {
        let pool = ReplicaPool::spawn(
            synth(10),
            PoolConfig {
                replicas: 2,
                max_queue: 16,
                ..PoolConfig::default()
            },
            Metrics::new(),
        );
        for id in 0..20 {
            let v = pool.infer(req(id)).unwrap();
            assert_eq!(v.request_id, id);
            assert!(v.exit_tier >= 1 && v.exit_tier <= 3);
        }
        assert_eq!(pool.total_outstanding(), 0);
        assert!(pool.metrics().counter("requests_submitted").get() >= 20);
        assert_eq!(pool.counts(), (0, 2, 0));
    }

    #[test]
    fn pool_rejects_bad_dim() {
        let pool =
            ReplicaPool::spawn(synth(10), PoolConfig::default(), Metrics::new());
        let err = pool
            .infer(Request {
                id: 1,
                features: vec![0.0; 3],
                arrival_s: 0.0,
                class: Class::Standard,
            })
            .unwrap_err();
        assert!(matches!(err, PoolError::Rejected(_)), "got {err:?}");
        assert!(err.to_string().contains("features"));
    }

    #[test]
    fn pool_sheds_when_full_and_bounds_queue() {
        // slow classifier + tiny queue: the second wave must shed
        let pool = ReplicaPool::spawn(
            synth(20_000), // 20ms per row
            PoolConfig {
                replicas: 1,
                max_queue: 2,
                batcher: BatcherConfig {
                    max_batch: 1,
                    max_wait: Duration::from_micros(100),
                },
                ..PoolConfig::default()
            },
            Metrics::new(),
        );
        let mut accepted = Vec::new();
        let mut shed = 0u64;
        for id in 0..8 {
            match pool.submit(req(id)) {
                Ok(rx) => accepted.push(rx),
                Err(PoolError::Overloaded { .. }) => shed += 1,
                Err(e) => panic!("unexpected {e:?}"),
            }
            assert!(pool.total_outstanding() <= 2, "queue bound violated");
        }
        assert!(shed > 0, "expected sheds");
        assert!(!accepted.is_empty(), "expected some accepts");
        assert_eq!(pool.metrics().counter("requests_shed").get(), shed);
        for rx in accepted {
            rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        }
        assert_eq!(pool.total_outstanding(), 0);
    }

    #[test]
    fn geared_pool_swaps_without_losing_requests() {
        use crate::planner::gear::{GearConfig, GearHandle};
        let handle = GearHandle::new(GearConfig {
            gear_id: 0,
            thetas: vec![0.6],
            work_factor: 1.0,
            max_batch: 4,
        });
        let pool = ReplicaPool::spawn_geared(
            synth(500),
            PoolConfig {
                replicas: 2,
                max_queue: 64,
                batcher: BatcherConfig {
                    max_batch: 4,
                    max_wait: Duration::from_micros(200),
                },
                ..PoolConfig::default()
            },
            Metrics::new(),
            Arc::clone(&handle),
        );
        assert!(pool.gear().is_some());
        // submit a wave, swap gears mid-flight, submit another wave
        let mut rxs = Vec::new();
        for id in 0..30 {
            rxs.push(pool.submit(req(id)).unwrap());
        }
        handle.store(GearConfig {
            gear_id: 1,
            thetas: vec![0.3],
            work_factor: 0.25,
            max_batch: 8,
        });
        pool.set_max_batch(8);
        for id in 30..60 {
            rxs.push(pool.submit(req(id)).unwrap());
        }
        // every request is answered exactly once, none dropped
        for (i, rx) in rxs.into_iter().enumerate() {
            let v = rx
                .recv_timeout(Duration::from_secs(10))
                .expect("verdict arrives")
                .expect("no error");
            assert_eq!(v.request_id, i as u64);
        }
        assert_eq!(pool.total_outstanding(), 0);
        assert_eq!(handle.generation(), 1);
    }

    #[test]
    fn dispatch_spreads_across_replicas() {
        let pool = ReplicaPool::spawn(
            synth(2_000),
            PoolConfig {
                replicas: 3,
                max_queue: 4,
                batcher: BatcherConfig {
                    max_batch: 4,
                    max_wait: Duration::from_millis(1),
                },
                ..PoolConfig::default()
            },
            Metrics::new(),
        );
        let rxs: Vec<_> = (0..9).filter_map(|id| pool.submit(req(id)).ok()).collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        }
        // least-outstanding routing must have touched every replica
        for i in 0..3 {
            assert!(
                pool.metrics().counter(&format!("replica_{i}_requests")).get() > 0,
                "replica {i} got no traffic"
            );
        }
    }

    #[test]
    fn scale_up_warms_then_goes_live() {
        let pool = ReplicaPool::spawn(
            synth(10),
            PoolConfig {
                replicas: 1,
                max_queue: 16,
                ..PoolConfig::default()
            },
            Metrics::new(),
        );
        assert_eq!(pool.counts(), (0, 1, 0));
        let ids = pool.scale_up(2, Duration::from_millis(30));
        assert_eq!(ids, vec![1, 2]);
        assert_eq!(pool.counts(), (2, 1, 0));
        // not warm yet: advance promotes nothing
        assert_eq!(pool.advance(Instant::now()).warmed, 0);
        std::thread::sleep(Duration::from_millis(40));
        let changes = pool.advance(Instant::now());
        assert_eq!(changes.warmed, 2);
        assert_eq!(pool.counts(), (0, 3, 0));
        assert_eq!(pool.n_replicas(), 3);
        assert_eq!(pool.n_slots(), 3);
    }

    #[test]
    fn warming_replica_admits_only_as_a_last_resort() {
        // one live replica with a tiny queue + one warming replica
        let pool = ReplicaPool::spawn(
            synth(20_000),
            PoolConfig {
                replicas: 1,
                max_queue: 1,
                batcher: BatcherConfig {
                    max_batch: 1,
                    max_wait: Duration::from_micros(100),
                },
                ..PoolConfig::default()
            },
            Metrics::new(),
        );
        pool.scale_up(1, Duration::from_secs(3600));
        assert_eq!(pool.counts(), (1, 1, 0));
        // first request lands on the live replica, second overflows to
        // the warming one instead of shedding
        let rx0 = pool.submit(req(0)).unwrap();
        let rx1 = pool.submit(req(1)).unwrap();
        let snap = pool.snapshot_replicas();
        assert_eq!(snap[0].requests + snap[1].requests, 2);
        assert_eq!(snap[0].requests, 1, "live replica skipped: {snap:?}");
        rx0.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        rx1.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
    }

    #[test]
    fn drain_blocks_admission_and_retires_when_idle() {
        let pool = ReplicaPool::spawn(
            synth(5_000), // 5ms/row: queued work takes a beat to finish
            PoolConfig {
                replicas: 2,
                max_queue: 8,
                batcher: BatcherConfig {
                    max_batch: 2,
                    max_wait: Duration::from_micros(200),
                },
                ..PoolConfig::default()
            },
            Metrics::new(),
        );
        // park some work on both replicas
        let rxs: Vec<_> = (0..6).map(|id| pool.submit(req(id)).unwrap()).collect();
        let drained = pool.drain(1);
        assert_eq!(drained.len(), 1);
        let victim = drained[0];
        let before = pool
            .snapshot_replicas()
            .iter()
            .find(|r| r.id == victim)
            .unwrap()
            .requests;
        // a draining replica never admits new work: traffic keeps
        // flowing, its counter stays frozen
        let mut more = Vec::new();
        for id in 6..18 {
            if let Ok(rx) = pool.submit(req(id)) {
                more.push(rx);
            }
        }
        let after = pool
            .snapshot_replicas()
            .iter()
            .find(|r| r.id == victim)
            .unwrap()
            .requests;
        assert_eq!(before, after, "draining replica admitted new work");
        let victim_alive = |pool: &ReplicaPool| {
            pool.snapshot_replicas().iter().any(|r| r.id == victim)
        };
        // an early advance is harmless: it may only retire the victim
        // once its queue is empty (write-lock re-check)
        let _ = pool.advance(Instant::now());
        // every admitted request -- including the victim's queue -- is
        // still answered: drain never drops work
        for rx in rxs.into_iter().chain(more) {
            rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        }
        // now idle: retires, slot disappears, rental seconds are banked
        let _ = pool.advance(Instant::now());
        assert!(!victim_alive(&pool), "drained replica still present");
        assert_eq!(pool.n_slots(), 1);
        assert_eq!(pool.counts(), (0, 1, 0));
        assert_eq!(pool.metrics().counter("replicas_retired").get(), 1);
        assert!(pool.replica_seconds() > 0.0);
        // pool still serves
        pool.infer(req(99)).unwrap();
    }

    #[test]
    fn drain_never_takes_the_last_live_replica() {
        let pool = ReplicaPool::spawn(
            synth(10),
            PoolConfig {
                replicas: 2,
                max_queue: 8,
                ..PoolConfig::default()
            },
            Metrics::new(),
        );
        assert_eq!(pool.drain(5).len(), 1, "only one of two may drain");
        assert_eq!(pool.drain(5).len(), 0, "last live replica is protected");
        assert_eq!(pool.counts().1, 1);
        pool.infer(req(1)).unwrap();
    }

    #[test]
    fn pool_bounds_clamp_scale_up_and_drain() {
        let pool = ReplicaPool::spawn(
            synth(10),
            PoolConfig {
                replicas: 2,
                max_queue: 8,
                min_replicas: 2,
                max_replicas: 3,
                ..PoolConfig::default()
            },
            Metrics::new(),
        );
        // scale_up clamps at max_replicas slots
        let ids = pool.scale_up(5, Duration::ZERO);
        assert_eq!(ids.len(), 1, "only one slot of headroom");
        assert_eq!(pool.n_slots(), 3);
        assert!(pool.scale_up(1, Duration::ZERO).is_empty());
        // drain respects the min_replicas Live floor (not just last-Live)
        assert_eq!(pool.drain(5).len(), 1);
        assert_eq!(pool.drain(5).len(), 0, "floor of 2 Live replicas holds");
        assert_eq!(pool.counts().1, 2);
        pool.infer(req(1)).unwrap();
    }

    #[test]
    fn dollars_price_replica_seconds_at_the_pool_gpu() {
        use crate::cost::rental::Gpu;
        let pool = ReplicaPool::spawn(
            synth(10),
            PoolConfig {
                replicas: 2,
                max_queue: 8,
                gpu: Gpu::V100,
                ..PoolConfig::default()
            },
            Metrics::new(),
        );
        assert_eq!(pool.gpu(), Gpu::V100);
        std::thread::sleep(Duration::from_millis(20));
        let rs = pool.replica_seconds();
        let d = pool.dollars();
        assert!(d > 0.0);
        // the clock keeps running between the two reads: allow a loose
        // margin, the price factor is what matters
        assert!((d - rs / 3600.0 * 0.50).abs() < 1e-5, "{d} vs {rs}");
        // burn rate counts every provisioned slot at the class price
        assert!((pool.dollars_per_hour() - 2.0 * 0.50).abs() < 1e-12);
    }

    #[test]
    fn weighted_fair_quota_protects_premium_share() {
        // 1 replica x max_queue 10 => capacity 10; weights 0.6/0.3/0.1
        // give batch exactly 1 guaranteed slot and no borrowable spare
        // while the other shares are unclaimed.
        let metrics = Metrics::new();
        let pool = ReplicaPool::spawn(
            synth(20_000), // 20ms/row: nothing completes mid-test
            PoolConfig {
                replicas: 1,
                max_queue: 10,
                batcher: BatcherConfig {
                    max_batch: 1,
                    max_wait: Duration::from_micros(100),
                },
                class_weights: Some([0.6, 0.3, 0.1]),
                ..PoolConfig::default()
            },
            Arc::clone(&metrics),
        );
        let mut batch_ok = 0;
        let mut batch_shed = 0;
        let mut rxs = Vec::new();
        for id in 0..3 {
            match pool.submit(creq(id, Class::Batch)) {
                Ok(rx) => {
                    batch_ok += 1;
                    rxs.push(rx);
                }
                Err(PoolError::Overloaded { .. }) => batch_shed += 1,
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        assert_eq!(batch_ok, 1, "batch share is 0.1 * 10 = 1 slot");
        assert_eq!(batch_shed, 2);
        assert_eq!(pool.class_outstanding(Class::Batch), 1);
        // premium's 6-slot share is untouched by the batch burst
        let mut prem_ok = 0;
        for id in 10..17 {
            match pool.submit(creq(id, Class::Premium)) {
                Ok(rx) => {
                    prem_ok += 1;
                    rxs.push(rx);
                }
                Err(PoolError::Overloaded { .. }) => {}
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        assert_eq!(prem_ok, 6, "premium admits its full weighted share");
        // quota sheds logged once per pressure episode, class-tagged
        let sheds: Vec<_> = metrics
            .events()
            .snapshot()
            .into_iter()
            .filter(|e| e.kind == EventKind::Shed)
            .collect();
        assert_eq!(sheds.len(), 2, "one episode per class: {sheds:?}");
        assert_eq!(sheds[0].class, Some("batch"));
        assert_eq!(sheds[0].trigger, "quota");
        assert_eq!(sheds[0].decider, "admission");
        assert_eq!(sheds[1].class, Some("premium"));
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        }
    }

    #[test]
    fn untagged_single_class_admission_matches_fifo() {
        // With every request Standard, a weights vector that gives
        // Standard the whole capacity must shed exactly like the
        // quota-disabled pool: the degenerate single-class case is the
        // historical untagged path.
        let mk = |weights: Option<[f64; Class::COUNT]>| {
            ReplicaPool::spawn(
                synth(20_000),
                PoolConfig {
                    replicas: 1,
                    max_queue: 2,
                    batcher: BatcherConfig {
                        max_batch: 1,
                        max_wait: Duration::from_micros(100),
                    },
                    class_weights: weights,
                    ..PoolConfig::default()
                },
                Metrics::new(),
            )
        };
        let fifo = mk(None);
        let single = mk(Some([0.0, 1.0, 0.0]));
        let run = |pool: &ReplicaPool| {
            let mut outcomes = Vec::new();
            let mut rxs = Vec::new();
            for id in 0..8 {
                match pool.submit(req(id)) {
                    Ok(rx) => {
                        outcomes.push("ok");
                        rxs.push(rx);
                    }
                    Err(PoolError::Overloaded { limit, .. }) => {
                        outcomes.push("shed");
                        assert_eq!(limit, 2);
                    }
                    Err(e) => panic!("unexpected {e:?}"),
                }
            }
            for rx in rxs {
                rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
            }
            outcomes
        };
        assert_eq!(run(&fifo), run(&single));
    }

    #[test]
    fn quota_slots_release_on_verdict_delivery() {
        let pool = ReplicaPool::spawn(
            synth(10),
            PoolConfig {
                replicas: 1,
                max_queue: 4,
                class_weights: Some([0.5, 0.3, 0.2]),
                ..PoolConfig::default()
            },
            Metrics::new(),
        );
        for id in 0..12 {
            let class = Class::ALL[(id % 3) as usize];
            pool.infer(creq(id, class)).unwrap();
            for c in Class::ALL {
                assert_eq!(pool.class_outstanding(c), 0, "leaked unit for {c:?}");
            }
        }
    }

    #[test]
    fn attached_slo_books_balance_through_infer() {
        use crate::obs::slo::SloConfig;
        let metrics = Metrics::new();
        let pool = ReplicaPool::spawn(
            synth(10),
            PoolConfig {
                replicas: 1,
                max_queue: 8,
                class_weights: Some([0.5, 0.3, 0.2]),
                ..PoolConfig::default()
            },
            Arc::clone(&metrics),
        );
        let slo = SloObservatory::new(SloConfig::default(), &metrics);
        pool.attach_slo(Arc::clone(&slo));
        assert!(pool.slo().is_some());
        for id in 0..15 {
            let class = Class::ALL[(id % 3) as usize];
            pool.infer(creq(id, class)).unwrap();
        }
        for c in Class::ALL {
            let s = slo.status(c);
            assert_eq!(s.submitted, 5, "{c:?}");
            assert_eq!(s.completed, 5, "{c:?}");
            assert_eq!(s.shed, 0, "{c:?}");
        }
    }

    #[test]
    fn replica_seconds_accumulate_across_retirement() {
        let pool = ReplicaPool::spawn(
            synth(10),
            PoolConfig {
                replicas: 2,
                max_queue: 8,
                ..PoolConfig::default()
            },
            Metrics::new(),
        );
        std::thread::sleep(Duration::from_millis(20));
        let before = pool.replica_seconds();
        assert!(before >= 2.0 * 0.020 * 0.5, "clock barely ran: {before}");
        pool.drain(1);
        pool.advance(Instant::now());
        assert_eq!(pool.n_slots(), 1);
        std::thread::sleep(Duration::from_millis(20));
        let after = pool.replica_seconds();
        // monotone: the retired replica's time is banked, the survivor
        // keeps accruing
        assert!(after > before, "{after} <= {before}");
    }
}
