//! ASCII table rendering for experiment output (paper-row format).

/// A simple column-aligned ASCII table with a header row.
#[derive(Debug, Clone, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Table {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push(' ');
                s.push_str(c);
                s.push_str(&" ".repeat(widths[i] - c.len() + 1));
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// CSV rendering of the same table (header + rows).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with fixed decimals, trimming "-0.000".
pub fn fnum(x: f64, decimals: usize) -> String {
    let s = format!("{:.*}", decimals, x);
    if s.starts_with("-0.") && s[1..].parse::<f64>() == Ok(0.0) {
        s[1..].to_string()
    } else {
        s
    }
}

/// Human-readable FLOPs/bytes style formatting (e.g. 3.97e7 -> "39.7M").
pub fn human(x: f64) -> String {
    let ax = x.abs();
    if ax >= 1e12 {
        format!("{:.2}T", x / 1e12)
    } else if ax >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if ax >= 1e6 {
        format!("{:.1}M", x / 1e6)
    } else if ax >= 1e3 {
        format!("{:.1}k", x / 1e3)
    } else {
        format!("{:.1}", x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "val"]);
        t.row(vec!["a", "1"]);
        t.row(vec!["longer", "22"]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("| name   | val |"));
        assert!(r.contains("| longer | 22  |"));
        // all separator lines have equal length
        let lens: Vec<usize> =
            r.lines().skip(1).map(|l| l.chars().count()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y", "has \"quote\""]);
        let csv = t.to_csv();
        assert_eq!(csv, "a,b\n\"x,y\",\"has \"\"quote\"\"\"\n");
    }

    #[test]
    fn fnum_no_negative_zero() {
        assert_eq!(fnum(-0.00001, 3), "0.000");
        assert_eq!(fnum(1.23456, 2), "1.23");
    }

    #[test]
    fn human_scales() {
        assert_eq!(human(3.97e7), "39.7M");
        assert_eq!(human(2.48e8), "248.0M");
        assert_eq!(human(4.3e9), "4.30G");
        assert_eq!(human(12.0), "12.0");
    }
}
