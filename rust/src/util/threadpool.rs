//! Fixed-size thread pool + scoped parallel map.
//!
//! The offline registry has no tokio/rayon; the coordinator's ensemble
//! fan-out (the paper's rho ~ 1 parallel execution, §4.1) and the tier
//! worker pools run on this instead.  Work items are boxed closures on an
//! mpsc channel guarded by a mutex; `scope_map` provides the structured
//! "run f over these items in parallel, collect results in order" shape
//! the experiments use.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// A fixed pool of worker threads executing boxed jobs.
pub struct ThreadPool {
    tx: Sender<Msg>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize) -> ThreadPool {
        assert!(size > 0, "pool size must be > 0");
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("abc-worker-{i}"))
                    .spawn(move || worker_loop(rx))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx, workers, size }
    }

    /// Pool sized to the machine (leaving one core for the coordinator).
    pub fn default_size() -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get().saturating_sub(1).max(1))
            .unwrap_or(4)
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.send(Msg::Run(Box::new(f))).expect("pool alive");
    }

    /// Run `f(item)` for every item, in parallel, returning results in
    /// input order.  Panics in `f` are propagated as a panic here.
    pub fn scope_map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx) = channel::<(usize, std::thread::Result<R>)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.execute(move || {
                let out = catch_unwind(AssertUnwindSafe(|| f(item)));
                let _ = rtx.send((i, out));
            });
        }
        drop(rtx);
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, res) = rrx.recv().expect("worker result");
            match res {
                Ok(r) => slots[i] = Some(r),
                Err(p) => std::panic::resume_unwind(p),
            }
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<Msg>>>) {
    loop {
        let msg = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        match msg {
            Ok(Msg::Run(job)) => {
                // A panicking job must not kill the worker: swallow it;
                // scope_map re-raises on the caller side.
                let _ = catch_unwind(AssertUnwindSafe(job));
            }
            Ok(Msg::Shutdown) | Err(_) => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in 0..self.workers.len() {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scope_map_preserves_order() {
        let pool = ThreadPool::new(8);
        let items: Vec<usize> = (0..500).collect();
        let out = pool.scope_map(items, |x| x * 2);
        assert_eq!(out, (0..500).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn scope_map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<u8> = pool.scope_map(Vec::<u8>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn scope_map_parallelism_actually_happens() {
        // 4 jobs of 50ms on 4 threads should finish well under 200ms.
        let pool = ThreadPool::new(4);
        let t0 = std::time::Instant::now();
        pool.scope_map(vec![(); 4], |_| {
            std::thread::sleep(std::time::Duration::from_millis(50))
        });
        assert!(t0.elapsed() < std::time::Duration::from_millis(170));
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn scope_map_propagates_panic() {
        let pool = ThreadPool::new(2);
        pool.scope_map(vec![1, 2, 3], |x| {
            if x == 2 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn pool_survives_job_panic() {
        let pool = ThreadPool::new(1);
        pool.execute(|| panic!("ignored"));
        let out = pool.scope_map(vec![5], |x| x + 1);
        assert_eq!(out, vec![6]);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        drop(pool); // must not hang
    }
}
