//! Pooled byte buffers for the zero-allocation reply path
//! (DESIGN.md §16).
//!
//! The event-driven frontend renders every reply -- and stages every
//! framed request line -- in a [`PooledBuf`] checked out of a shared
//! [`BufPool`] free list instead of a freshly heap-allocated `String`.
//! Dropping the buffer returns it to the pool, so the steady-state
//! serving hot path recycles a small working set of buffers and
//! performs no per-request byte-buffer allocations at all
//! (`scripts/check_hotpath_allocs.sh` freezes the `format!` /
//! `to_string` / `String::` counts of the frontend files).
//!
//! Ownership invariant (pinned in DESIGN.md §16): a checked-out buffer
//! is owned by exactly ONE of {worker, sequencer stash, connection
//! write queue} at all times; ownership moves by `move`, never by
//! clone, and the pool sees the buffer again only through `Drop`.
//!
//! The free-list `Mutex` is the one justified lock on the frontend
//! path (`scripts/hotpath_lock_baseline.txt` covers this file): the
//! critical section is a `Vec` push/pop -- tens of nanoseconds --
//! and both acquisitions happen once per request, not per byte.
//! Oversized buffers (a client that sent a near-`MAX_LINE` request)
//! are dropped on return instead of pinning megabytes in the pool,
//! and the free list itself is capacity-bounded.

use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Buffers whose capacity grew beyond this are not recycled: returning
/// a 1 MiB line buffer to the pool would pin worst-case memory forever
/// in exchange for saving one allocation on a path that is, by
/// definition, anomalous.
pub const MAX_RECYCLED_CAPACITY: usize = 64 << 10;

/// Upper bound on pooled buffers; beyond it, returned buffers are
/// simply freed.  256 covers every in-flight line + reply of a fully
/// loaded reactor (per-connection in-flight is capped far lower).
pub const MAX_POOLED: usize = 256;

/// A bounded free list of byte buffers.
pub struct BufPool {
    free: Mutex<Vec<Vec<u8>>>,
    max_pooled: usize,
    /// Checkouts served from the free list.
    hits: AtomicU64,
    /// Checkouts that had to allocate a fresh buffer.
    misses: AtomicU64,
    /// Returns accepted back into the free list.
    recycled: AtomicU64,
    /// Returns dropped (oversized buffer or full free list).
    discarded: AtomicU64,
}

/// Point-in-time pool accounting, for benches and tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolStats {
    pub hits: u64,
    pub misses: u64,
    pub recycled: u64,
    pub discarded: u64,
    pub free: usize,
}

impl BufPool {
    pub fn new() -> Arc<BufPool> {
        BufPool::with_capacity(MAX_POOLED)
    }

    pub fn with_capacity(max_pooled: usize) -> Arc<BufPool> {
        Arc::new(BufPool {
            free: Mutex::new(Vec::new()),
            max_pooled,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            recycled: AtomicU64::new(0),
            discarded: AtomicU64::new(0),
        })
    }

    /// Check out an empty buffer: recycled when one is free, freshly
    /// allocated otherwise.
    pub fn get(self: &Arc<Self>) -> PooledBuf {
        let recycled = self.free.lock().expect("bufpool poisoned").pop();
        let buf = match recycled {
            Some(b) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                b
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Vec::new()
            }
        };
        PooledBuf { buf, pool: Arc::clone(self) }
    }

    fn put(&self, mut buf: Vec<u8>) {
        if buf.capacity() > MAX_RECYCLED_CAPACITY {
            self.discarded.fetch_add(1, Ordering::Relaxed);
            return;
        }
        buf.clear();
        let mut free = self.free.lock().expect("bufpool poisoned");
        if free.len() >= self.max_pooled {
            drop(free);
            self.discarded.fetch_add(1, Ordering::Relaxed);
            return;
        }
        free.push(buf);
        drop(free);
        self.recycled.fetch_add(1, Ordering::Relaxed);
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            recycled: self.recycled.load(Ordering::Relaxed),
            discarded: self.discarded.load(Ordering::Relaxed),
            free: self.free.lock().expect("bufpool poisoned").len(),
        }
    }
}

/// An owned byte buffer on loan from a [`BufPool`]; derefs to
/// `Vec<u8>` and returns to the pool on drop.
pub struct PooledBuf {
    buf: Vec<u8>,
    pool: Arc<BufPool>,
}

impl PooledBuf {
    /// Detach the bytes from the pool (the buffer will NOT recycle).
    /// For cold paths that need an owned `Vec<u8>` outliving the pool.
    pub fn into_vec(mut self) -> Vec<u8> {
        std::mem::take(&mut self.buf)
    }
}

impl Deref for PooledBuf {
    type Target = Vec<u8>;

    fn deref(&self) -> &Vec<u8> {
        &self.buf
    }
}

impl DerefMut for PooledBuf {
    fn deref_mut(&mut self) -> &mut Vec<u8> {
        &mut self.buf
    }
}

impl Drop for PooledBuf {
    fn drop(&mut self) {
        // an into_vec'd (empty, zero-capacity) buffer recycles as a
        // plain empty Vec: put() is cheap either way
        self.pool.put(std::mem::take(&mut self.buf));
    }
}

impl std::fmt::Debug for PooledBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PooledBuf").field("len", &self.buf.len()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_put_recycles_capacity() {
        let pool = BufPool::new();
        let mut b = pool.get();
        b.extend_from_slice(b"hello");
        let cap = b.capacity();
        assert!(cap >= 5);
        drop(b);
        let s = pool.stats();
        assert_eq!((s.hits, s.misses, s.recycled, s.free), (0, 1, 1, 1));
        // the second checkout reuses the same allocation, cleared
        let b2 = pool.get();
        assert_eq!(b2.len(), 0);
        assert_eq!(b2.capacity(), cap);
        assert_eq!(pool.stats().hits, 1);
    }

    #[test]
    fn oversized_buffers_are_not_pooled() {
        let pool = BufPool::new();
        let mut b = pool.get();
        b.reserve(MAX_RECYCLED_CAPACITY + 1);
        drop(b);
        let s = pool.stats();
        assert_eq!(s.discarded, 1);
        assert_eq!(s.free, 0, "oversized buffer must not be retained");
    }

    #[test]
    fn free_list_is_bounded() {
        let pool = BufPool::with_capacity(2);
        let bufs: Vec<_> = (0..4).map(|_| pool.get()).collect();
        drop(bufs);
        let s = pool.stats();
        assert_eq!(s.free, 2);
        assert_eq!(s.recycled, 2);
        assert_eq!(s.discarded, 2);
    }

    #[test]
    fn into_vec_detaches_without_recycling_bytes() {
        let pool = BufPool::new();
        let mut b = pool.get();
        b.extend_from_slice(b"keep me");
        let v = b.into_vec();
        assert_eq!(v, b"keep me");
        // the detached buffer's pool slot returned as an empty Vec
        assert_eq!(pool.stats().recycled, 1);
        let b2 = pool.get();
        assert_eq!(b2.capacity(), 0, "detached capacity must not come back");
    }

    #[test]
    fn concurrent_checkouts_are_exact() {
        let pool = BufPool::new();
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let pool = Arc::clone(&pool);
                std::thread::spawn(move || {
                    for i in 0..500 {
                        let mut b = pool.get();
                        b.extend_from_slice(&[i as u8; 16]);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let s = pool.stats();
        assert_eq!(s.hits + s.misses, 8 * 500);
        assert_eq!(s.recycled + s.discarded, 8 * 500);
        assert!(s.free <= MAX_POOLED);
    }
}
