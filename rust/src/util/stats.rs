//! Streaming statistics and quantile summaries for metrics and benches.

/// Welford-style streaming mean/variance plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Streaming {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Streaming {
    pub fn new() -> Self {
        Streaming { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &Streaming) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let d = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += d * n2 / n;
        self.m2 += other.m2 + d * d * n1 * n2 / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Exact quantiles over a retained sample vector.  Fine for bench-scale
/// data (hundreds of thousands of points); the serving path uses
/// `metrics::Histogram` instead.
#[derive(Debug, Clone, Default)]
pub struct Samples {
    data: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.data.push(x);
        self.sorted = false;
    }

    pub fn extend(&mut self, xs: &[f64]) {
        self.data.extend_from_slice(xs);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            return f64::NAN;
        }
        self.data.iter().sum::<f64>() / self.data.len() as f64
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.data.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Linear-interpolated quantile, q in [0, 1].
    pub fn quantile(&mut self, q: f64) -> f64 {
        if self.data.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let q = q.clamp(0.0, 1.0);
        let pos = q * (self.data.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            self.data[lo]
        } else {
            let frac = pos - lo as f64;
            self.data[lo] * (1.0 - frac) + self.data[hi] * frac
        }
    }

    pub fn p50(&mut self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p90(&mut self) -> f64 {
        self.quantile(0.90)
    }

    pub fn p99(&mut self) -> f64 {
        self.quantile(0.99)
    }
}

/// Two-proportion comparison helper: standard error of an accuracy
/// estimate from n Bernoulli trials (used by tests asserting "accuracy
/// within noise of the manifest value").
pub fn binomial_se(acc: f64, n: usize) -> f64 {
    if n == 0 {
        return f64::INFINITY;
    }
    (acc * (1.0 - acc) / n as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streaming_mean_var() {
        let mut s = Streaming::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // sample variance of the classic dataset = 32/7
        assert!((s.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn streaming_merge_equals_single_pass() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = Streaming::new();
        for &x in &xs {
            all.push(x);
        }
        let mut a = Streaming::new();
        let mut b = Streaming::new();
        for &x in &xs[..300] {
            a.push(x);
        }
        for &x in &xs[300..] {
            b.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.var() - all.var()).abs() < 1e-9);
    }

    #[test]
    fn empty_streaming_is_nan() {
        let s = Streaming::new();
        assert!(s.mean().is_nan());
        assert_eq!(s.var(), 0.0);
    }

    #[test]
    fn quantiles_interpolate() {
        let mut s = Samples::new();
        s.extend(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.quantile(0.0), 1.0);
        assert_eq!(s.quantile(1.0), 4.0);
        assert!((s.p50() - 2.5).abs() < 1e-12);
        assert!((s.quantile(1.0 / 3.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn quantile_unsorted_input() {
        let mut s = Samples::new();
        s.extend(&[9.0, 1.0, 5.0, 3.0, 7.0]);
        assert_eq!(s.p50(), 5.0);
        assert_eq!(s.quantile(0.0), 1.0);
    }

    #[test]
    fn quantile_empty_is_nan() {
        let mut s = Samples::new();
        assert!(s.p99().is_nan());
    }

    #[test]
    fn binomial_se_sane() {
        assert!((binomial_se(0.5, 100) - 0.05).abs() < 1e-12);
        assert!(binomial_se(0.5, 0).is_infinite());
    }
}
