//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** core.
//!
//! Built from scratch (no `rand` in the offline registry).  Everything in
//! the simulators and workload generators that needs randomness takes an
//! explicit `Rng` so experiment runs are exactly reproducible from a seed.

/// xoshiro256** generator (public domain reference algorithm by
/// Blackman & Vigna), seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (e.g. one per simulated worker).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform usize in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Lemire's method with rejection for unbiased results.
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_u64(x, n);
            if lo >= n || lo >= x.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (std::f64::consts::TAU * u2).cos();
            }
        }
    }

    /// Exponential with rate lambda (mean 1/lambda).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        loop {
            let u = self.f64();
            if u > 1e-300 {
                return -u.ln() / lambda;
            }
        }
    }

    /// Beta(a, b) via Johnk/gamma-ratio (small-alpha safe: uses
    /// Marsaglia-Tsang on shifted alpha).
    pub fn beta(&mut self, a: f64, b: f64) -> f64 {
        let x = self.gamma(a);
        let y = self.gamma(b);
        if x + y == 0.0 {
            0.5
        } else {
            x / (x + y)
        }
    }

    /// Gamma(shape, 1) via Marsaglia-Tsang, boosting shape < 1.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            let u = self.f64().max(1e-300);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted() needs positive total weight");
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[inline]
fn mul_u64(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_coverage() {
        let mut r = Rng::new(2);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "counts {counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn beta_in_range_and_mean() {
        let mut r = Rng::new(5);
        let n = 30_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.beta(1.2, 3.0);
            assert!((0.0..=1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        let expect = 1.2 / (1.2 + 3.0);
        assert!((mean - expect).abs() < 0.02, "mean {mean} expect {expect}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(7);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > 8 * counts[0] / 2, "{counts:?}");
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(8);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
