//! Small CLI argument parser (the offline registry has no clap).
//!
//! Supports: positional args, `--flag`, `--key value`, `--key=value`,
//! subcommand extraction, typed getters with defaults, and usage
//! generation from registered options.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    positional: Vec<String>,
    options: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    Missing(String),
    Invalid(String, String),
    Unexpected(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Missing(name) => write!(f, "missing required option --{name}"),
            CliError::Invalid(name, v) => write!(f, "invalid value for --{name}: {v:?}"),
            CliError::Unexpected(arg) => write!(f, "unexpected argument {arg:?}"),
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse a raw arg list (without argv[0]).
    ///
    /// Any `--name` followed by a token not starting with `--` is an
    /// option with that value; `--name=value` works too; a `--name`
    /// followed by another option (or end) is a boolean flag.
    pub fn parse<I, S>(raw: I) -> Args
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let raw: Vec<String> = raw.into_iter().map(Into::into).collect();
        let mut positional = Vec::new();
        let mut options: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < raw.len() {
            let tok = &raw[i];
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    options.entry(k.to_string()).or_default().push(v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    options
                        .entry(name.to_string())
                        .or_default()
                        .push(raw[i + 1].clone());
                    i += 1;
                } else {
                    flags.push(name.to_string());
                }
            } else {
                positional.push(tok.clone());
            }
            i += 1;
        }
        Args { positional, options, flags }
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// First positional argument (the subcommand), plus the rest.
    pub fn subcommand(&self) -> Option<(&str, Args)> {
        let (first, rest) = self.positional.split_first()?;
        Some((
            first.as_str(),
            Args {
                positional: rest.to_vec(),
                options: self.options.clone(),
                flags: self.flags.clone(),
            },
        ))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || self
                .options
                .get(name)
                .map(|v| v.last().map(|s| s == "true").unwrap_or(false))
                .unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.options
            .get(name)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn req_str(&self, name: &str) -> Result<&str, CliError> {
        self.get(name).ok_or_else(|| CliError::Missing(name.to_string()))
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::Invalid(name.to_string(), v.to_string())),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::Invalid(name.to_string(), v.to_string())),
        }
    }

    /// Port-sized option: rejects values outside `u16` instead of
    /// silently truncating (the old `u64_or(..) as u16` wrapped 70000
    /// to 4464).
    pub fn u16_or(&self, name: &str, default: u16) -> Result<u16, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::Invalid(name.to_string(), v.to_string())),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::Invalid(name.to_string(), v.to_string())),
        }
    }

    /// Comma-separated list option: `--suites a,b,c`.
    pub fn list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect(),
        }
    }

    /// Comma-separated list of any parseable type (backs the typed
    /// `*_list_or` getters).
    fn parsed_list_or<T: std::str::FromStr + Clone>(
        &self,
        name: &str,
        default: &[T],
    ) -> Result<Vec<T>, CliError> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.parse()
                        .map_err(|_| CliError::Invalid(name.to_string(), s.to_string()))
                })
                .collect(),
        }
    }

    /// Comma-separated numeric list: `--ks 1,3,5`.
    pub fn usize_list_or(
        &self,
        name: &str,
        default: &[usize],
    ) -> Result<Vec<usize>, CliError> {
        self.parsed_list_or(name, default)
    }

    /// Comma-separated float list: `--epsilons 0.01,0.05`.
    pub fn f64_list_or(
        &self,
        name: &str,
        default: &[f64],
    ) -> Result<Vec<f64>, CliError> {
        self.parsed_list_or(name, default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace())
    }

    #[test]
    fn positional_and_subcommand() {
        let a = args("exp fig2 --out /tmp/x");
        let (cmd, rest) = a.subcommand().unwrap();
        assert_eq!(cmd, "exp");
        assert_eq!(rest.positional(), &["fig2".to_string()]);
        assert_eq!(rest.get("out"), Some("/tmp/x"));
    }

    #[test]
    fn key_value_forms() {
        let a = args("--a 1 --b=2 --c --d 3");
        assert_eq!(a.get("a"), Some("1"));
        assert_eq!(a.get("b"), Some("2"));
        assert!(a.flag("c"));
        assert_eq!(a.get("d"), Some("3"));
        assert!(!a.flag("d"));
    }

    #[test]
    fn typed_getters() {
        let a = args("--n 42 --x 2.5 --bad zz");
        assert_eq!(a.usize_or("n", 0).unwrap(), 42);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
        assert!((a.f64_or("x", 0.0).unwrap() - 2.5).abs() < 1e-12);
        assert!(a.usize_or("bad", 0).is_err());
        assert!(a.req_str("nope").is_err());
    }

    #[test]
    fn u16_rejects_out_of_range_ports() {
        let a = args("--port 7878 --big 70000");
        assert_eq!(a.u16_or("port", 1).unwrap(), 7878);
        assert_eq!(a.u16_or("missing", 9).unwrap(), 9);
        assert!(a.u16_or("big", 1).is_err(), "70000 must not wrap");
    }

    #[test]
    fn repeated_options_last_wins_get() {
        let a = args("--k 1 --k 2");
        assert_eq!(a.get("k"), Some("2"));
        assert_eq!(a.get_all("k"), vec!["1", "2"]);
    }

    #[test]
    fn list_option() {
        let a = args("--suites a,b , c");
        assert_eq!(a.list_or("suites", &[]), vec!["a", "b"]);
        let b = args("");
        assert_eq!(b.list_or("suites", &["all"]), vec!["all"]);
    }

    #[test]
    fn numeric_list_options() {
        let a = args("--ks 1,3,5 --epsilons 0.01,0.05 --bad 1,x");
        assert_eq!(a.usize_list_or("ks", &[]).unwrap(), vec![1, 3, 5]);
        assert_eq!(a.usize_list_or("missing", &[2, 4]).unwrap(), vec![2, 4]);
        assert_eq!(a.f64_list_or("epsilons", &[]).unwrap(), vec![0.01, 0.05]);
        assert_eq!(a.f64_list_or("missing", &[0.5]).unwrap(), vec![0.5]);
        assert!(a.usize_list_or("bad", &[]).is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = args("run --verbose");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }
}
