//! Minimal JSON value, parser and writer.
//!
//! Built from scratch because the offline registry has no serde/serde_json
//! (DESIGN.md §3).  Covers the full JSON grammar (RFC 8259): objects,
//! arrays, strings with escapes (incl. `\uXXXX` + surrogate pairs),
//! numbers, booleans, null.  Object key order is preserved (insertion
//! order) so manifests round-trip stably.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.  Objects keep a parallel key-ordering vector so that
/// serialisation is deterministic and matches the input document.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(JsonObj),
}

/// An order-preserving JSON object.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct JsonObj {
    map: BTreeMap<String, Json>,
    order: Vec<String>,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, key: impl Into<String>, value: Json) {
        let key = key.into();
        if !self.map.contains_key(&key) {
            self.order.push(key.clone());
        }
        self.map.insert(key, value);
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.map.get(key)
    }

    pub fn contains_key(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.order.iter()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Json)> {
        self.order.iter().map(move |k| (k, &self.map[k]))
    }
}

impl<S: Into<String>> FromIterator<(S, Json)> for JsonObj {
    fn from_iter<T: IntoIterator<Item = (S, Json)>>(iter: T) -> Self {
        let mut o = JsonObj::new();
        for (k, v) in iter {
            o.insert(k, v);
        }
        o
    }
}

/// Parse or typed-access error.
#[derive(Debug)]
pub enum JsonError {
    Parse { pos: usize, msg: String },
    Type { expected: &'static str, path: String },
    Missing(String),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Parse { pos, msg } => {
                write!(f, "json parse error at byte {pos}: {msg}")
            }
            JsonError::Type { expected, path } => {
                write!(f, "json type error: expected {expected} at {path}")
            }
            JsonError::Missing(key) => write!(f, "json missing key {key:?}"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ----- constructors ---------------------------------------------------

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn arr_of_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_of_str<S: AsRef<str>>(v: &[S]) -> Json {
        Json::Arr(v.iter().map(|s| Json::str(s.as_ref())).collect())
    }

    // ----- typed accessors ------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64 {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["k"]`-style access; returns Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    /// Typed access that produces a useful error for manifests/configs.
    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.req(key)?
            .as_str()
            .ok_or(JsonError::Type { expected: "string", path: key.to_string() })
    }

    pub fn req_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.req(key)?
            .as_f64()
            .ok_or(JsonError::Type { expected: "number", path: key.to_string() })
    }

    pub fn req_usize(&self, key: &str) -> Result<usize, JsonError> {
        self.req(key)?
            .as_usize()
            .ok_or(JsonError::Type { expected: "usize", path: key.to_string() })
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json], JsonError> {
        self.req(key)?
            .as_arr()
            .ok_or(JsonError::Type { expected: "array", path: key.to_string() })
    }

    pub fn req_obj(&self, key: &str) -> Result<&JsonObj, JsonError> {
        self.req(key)?
            .as_obj()
            .ok_or(JsonError::Type { expected: "object", path: key.to_string() })
    }

    fn req(&self, key: &str) -> Result<&Json, JsonError> {
        match self.as_obj().and_then(|o| o.get(key)) {
            Some(v) => Ok(v),
            None => Err(JsonError::Missing(key.to_string())),
        }
    }

    // ----- parsing --------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ----- serialisation ---------------------------------------------------

    /// Compact serialisation.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialisation with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no Inf/NaN; emit null like most tolerant writers.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{}", n));
    }
}

/// Allocation-free twin of the tree writer's number rule, for the
/// frontend's pooled reply buffers (DESIGN.md §16).  `write!` into a
/// `Vec<u8>` formats in place -- no intermediate `String` -- and the
/// branch structure is kept identical to [`write_num`] so the rendered
/// bytes are too (pinned by a unit test below).
pub fn write_num_bytes(out: &mut Vec<u8>, n: f64) {
    use std::io::Write;
    if !n.is_finite() {
        out.extend_from_slice(b"null");
    } else if n.fract() == 0.0 && n.abs() < 9e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{}", n);
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Allocation-free twin of [`write_str`]: identical escaping, straight
/// into a byte buffer (UTF-8 passes through verbatim, exactly as
/// `String::push` would append it).
pub fn write_str_bytes(out: &mut Vec<u8>, s: &str) {
    use std::io::Write;
    out.push(b'"');
    for c in s.chars() {
        match c {
            '"' => out.extend_from_slice(b"\\\""),
            '\\' => out.extend_from_slice(b"\\\\"),
            '\n' => out.extend_from_slice(b"\\n"),
            '\r' => out.extend_from_slice(b"\\r"),
            '\t' => out.extend_from_slice(b"\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => {
                let mut utf8 = [0u8; 4];
                out.extend_from_slice(c.encode_utf8(&mut utf8).as_bytes());
            }
        }
    }
    out.push(b'"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError::Parse { pos: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(format!("expected {:?}", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected {:?}", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected {word}")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut obj = JsonObj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            obj.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(obj)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        match char::from_u32(cp) {
                            Some(c) => s.push(c),
                            None => return Err(self.err("invalid codepoint")),
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                // JSON strings are UTF-8 here; copy raw bytes of multibyte
                // chars straight through.
                Some(b) if b >= 0x20 => {
                    if b < 0x80 {
                        s.push(b as char);
                    } else {
                        // re-decode the multibyte sequence
                        let start = self.pos - 1;
                        let len = utf8_len(b);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        match std::str::from_utf8(&self.bytes[start..end]) {
                            Ok(chunk) => {
                                s.push_str(chunk);
                                self.pos = end;
                            }
                            Err(_) => return Err(self.err("invalid utf-8")),
                        }
                    }
                }
                Some(_) => return Err(self.err("control char in string")),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(format!("bad number {text:?}")))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

// ----- lazy scanning ---------------------------------------------------

/// Zero-allocation lazy scanner over one JSON document (DESIGN.md §15).
///
/// Finds top-level object fields by *skipping* tokens instead of building
/// a tree -- the serving hot path only needs `id`/`features`/`class` out
/// of each infer line, and tree construction (String keys, BTreeMap,
/// boxed values) dominates its parse cost.  mik-sdk's ADR-002 measured
/// ~33x for exactly this partial-extraction pattern.
///
/// The scanner validates everything it walks with the same grammar as
/// [`Json::parse`] (string escapes incl. surrogate pairs, the number
/// token shape, nesting, no trailing garbage) and returns `None` for
/// anything it is not *sure* about -- malformed input, escaped object
/// keys (which would need unescaping to compare), non-object documents.
/// Callers treat `None` as "fall back to the full parser", so lazy and
/// eager paths accept exactly the same documents and every error message
/// comes from one place.
///
/// Duplicate keys follow [`JsonObj::insert`] semantics: the last
/// occurrence wins.
pub struct JsonScan<'a> {
    bytes: &'a [u8],
}

impl<'a> JsonScan<'a> {
    pub fn new(text: &'a str) -> JsonScan<'a> {
        JsonScan { bytes: text.as_bytes() }
    }

    /// Walk the top-level object, calling `visit(raw_key, value_span)`
    /// for every member.  `None`: not an object, malformed anywhere, a
    /// key containing escapes, or trailing characters after the close.
    fn walk<F: FnMut(&'a [u8], (usize, usize))>(&self, mut visit: F) -> Option<()> {
        let mut s = Skipper { bytes: self.bytes, pos: 0 };
        s.ws();
        if s.bump()? != b'{' {
            return None;
        }
        s.ws();
        if s.peek() == Some(b'}') {
            s.pos += 1;
        } else {
            loop {
                s.ws();
                let (ks, ke, escaped) = s.skip_string()?;
                if escaped {
                    // the raw key bytes would not compare against the
                    // unescaped name; let the tree parser handle it
                    return None;
                }
                s.ws();
                if s.bump()? != b':' {
                    return None;
                }
                s.ws();
                let vs = s.pos;
                s.skip_value()?;
                visit(&self.bytes[ks..ke], (vs, s.pos));
                s.ws();
                match s.bump()? {
                    b',' => continue,
                    b'}' => break,
                    _ => return None,
                }
            }
        }
        s.ws();
        if s.pos != self.bytes.len() {
            return None; // Json::parse rejects trailing characters too
        }
        Some(())
    }

    /// Raw text of the value of top-level field `name` -- the last
    /// occurrence, matching the tree parser's duplicate-key overwrite.
    /// `None`: absent field, or any condition [`JsonScan::walk`] rejects.
    pub fn field(&self, name: &str) -> Option<&'a str> {
        let mut found = None;
        self.walk(|key, span| {
            if key == name.as_bytes() {
                found = Some(span);
            }
        })?;
        let (s, e) = found?;
        std::str::from_utf8(&self.bytes[s..e]).ok()
    }

    /// `Some(true/false)` iff the document is a well-formed object the
    /// scanner fully understands; `None` falls back like [`Self::field`].
    pub fn has_field(&self, name: &str) -> Option<bool> {
        let mut found = false;
        self.walk(|key, _| {
            if key == name.as_bytes() {
                found = true;
            }
        })?;
        Some(found)
    }

    /// Field as a number, mirroring [`Json::as_f64`] (the value must be
    /// a number token, not a stringified number).
    pub fn field_f64(&self, name: &str) -> Option<f64> {
        let raw = self.field(name)?;
        let first = *raw.as_bytes().first()?;
        if first != b'-' && !first.is_ascii_digit() {
            return None;
        }
        raw.parse::<f64>().ok()
    }

    /// Field as a u64 through the f64 path, mirroring [`Json::as_u64`]
    /// exactly (so `7.0` and `1e3` are valid ids on both paths).
    pub fn field_u64(&self, name: &str) -> Option<u64> {
        let n = self.field_f64(name)?;
        if n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64 {
            Some(n as u64)
        } else {
            None
        }
    }

    /// Field as an escape-free string literal's content.  Strings that
    /// need unescaping return `None` (fall back to the tree parser).
    pub fn field_str(&self, name: &str) -> Option<&'a str> {
        let raw = self.field(name)?.as_bytes();
        if raw.len() < 2 || raw[0] != b'"' {
            return None;
        }
        let inner = &raw[1..raw.len() - 1];
        if inner.contains(&b'\\') {
            return None;
        }
        std::str::from_utf8(inner).ok()
    }

    /// Parse field `name` as a flat array of numbers, appending to
    /// `out`; returns how many were appended.  `None`: absent, not an
    /// array, any non-number element, or a malformed document -- the
    /// cases where the tree parser's error decides.
    pub fn field_nums(&self, name: &str, out: &mut Vec<f64>) -> Option<usize> {
        let raw = self.field(name)?.as_bytes();
        let before = out.len();
        match Self::nums_into(raw, out) {
            Some(()) => Some(out.len() - before),
            None => {
                out.truncate(before); // failed scans leave no partial output
                None
            }
        }
    }

    fn nums_into(raw: &[u8], out: &mut Vec<f64>) -> Option<()> {
        let mut s = Skipper { bytes: raw, pos: 0 };
        if s.bump()? != b'[' {
            return None;
        }
        s.ws();
        if s.peek() == Some(b']') {
            s.pos += 1;
        } else {
            loop {
                s.ws();
                out.push(s.skip_number()?);
                s.ws();
                match s.bump()? {
                    b',' => continue,
                    b']' => break,
                    _ => return None,
                }
            }
        }
        if s.pos != raw.len() {
            return None;
        }
        Some(())
    }
}

/// Token-skipping cursor behind [`JsonScan`]: same grammar as
/// [`Parser`], but no value construction.  Every method returns `None`
/// on input `Parser` would reject, which the scan surfaces as
/// "fall back to the full parse".
struct Skipper<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Skipper<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn skip_value(&mut self) -> Option<()> {
        self.ws();
        match self.peek()? {
            b'{' => self.skip_object(),
            b'[' => self.skip_array(),
            b'"' => self.skip_string().map(|_| ()),
            b't' => self.literal(b"true"),
            b'f' => self.literal(b"false"),
            b'n' => self.literal(b"null"),
            c if c == b'-' || c.is_ascii_digit() => self.skip_number().map(|_| ()),
            _ => None,
        }
    }

    fn literal(&mut self, word: &[u8]) -> Option<()> {
        if self.bytes[self.pos..].starts_with(word) {
            self.pos += word.len();
            Some(())
        } else {
            None
        }
    }

    fn skip_object(&mut self) -> Option<()> {
        if self.bump()? != b'{' {
            return None;
        }
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Some(());
        }
        loop {
            self.ws();
            self.skip_string()?;
            self.ws();
            if self.bump()? != b':' {
                return None;
            }
            self.skip_value()?;
            self.ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Some(()),
                _ => return None,
            }
        }
    }

    fn skip_array(&mut self) -> Option<()> {
        if self.bump()? != b'[' {
            return None;
        }
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Some(());
        }
        loop {
            self.skip_value()?;
            self.ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Some(()),
                _ => return None,
            }
        }
    }

    /// Skip a string, returning the content span (between the quotes)
    /// and whether it contained any escape.  Escape validation matches
    /// [`Parser::string`] including surrogate pairing, so the scanner
    /// never accepts a string the parser rejects.
    fn skip_string(&mut self) -> Option<(usize, usize, bool)> {
        if self.bump()? != b'"' {
            return None;
        }
        let start = self.pos;
        let mut escaped = false;
        loop {
            match self.bump()? {
                b'"' => return Some((start, self.pos - 1, escaped)),
                b'\\' => {
                    escaped = true;
                    match self.bump()? {
                        b'"' | b'\\' | b'/' | b'b' | b'f' | b'n' | b'r' | b't' => {}
                        b'u' => {
                            let hi = self.hex4()?;
                            if (0xD800..0xDC00).contains(&hi) {
                                // high surrogate: a low one must follow
                                if self.bump()? != b'\\' || self.bump()? != b'u' {
                                    return None;
                                }
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return None;
                                }
                            } else if (0xDC00..0xE000).contains(&hi) {
                                return None; // lone low surrogate
                            }
                        }
                        _ => return None,
                    }
                }
                b if b < 0x20 => return None, // raw control char
                // multibyte UTF-8 payload: the input came from a &str,
                // so the bytes are already valid; pass through
                _ => {}
            }
        }
    }

    fn hex4(&mut self) -> Option<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let d = (self.bump()? as char).to_digit(16)?;
            v = v * 16 + d;
        }
        Some(v)
    }

    /// Skip one number token ([`Parser::number`]'s walk) and validate it
    /// parses, returning the value.
    fn skip_number(&mut self) -> Option<f64> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).ok()?;
        text.parse::<f64>().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::str("hi"));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\Aé"));
    }

    #[test]
    fn parse_surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = Json::parse("\"héllo → wörld\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → wörld"));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"n":1.5,"s":"a\"b","arr":[true,false,null],"o":{"k":2}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn object_preserves_key_order() {
        let v = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().keys().cloned().collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
        assert!(v.to_string().starts_with(r#"{"z":"#));
    }

    #[test]
    fn reject_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,").is_err());
        assert!(Json::parse("42 junk").is_err());
        assert!(Json::parse("'single'").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 7, "s": "x", "a": [1], "o": {}}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 7);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert_eq!(v.req_arr("a").unwrap().len(), 1);
        assert!(v.req_obj("o").is_ok());
        assert!(matches!(v.req_str("n"), Err(JsonError::Type { .. })));
        assert!(matches!(v.req_str("zz"), Err(JsonError::Missing(_))));
    }

    #[test]
    fn num_integer_formatting() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn as_u64_bounds() {
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(1.5).as_u64(), None);
        assert_eq!(Json::Num(12.0).as_u64(), Some(12));
    }

    // ----- lazy scanner ----------------------------------------------

    #[test]
    fn scan_extracts_fields_without_a_tree() {
        let line = r#"{"id": 42, "features": [0.5, -1.25e2, 3], "class": "premium"}"#;
        let s = JsonScan::new(line);
        assert_eq!(s.field_u64("id"), Some(42));
        assert_eq!(s.field("features"), Some("[0.5, -1.25e2, 3]"));
        assert_eq!(s.field_str("class"), Some("premium"));
        assert_eq!(s.has_field("cmd"), Some(false));
        let mut nums = Vec::new();
        assert_eq!(s.field_nums("features", &mut nums), Some(3));
        assert_eq!(nums, vec![0.5, -125.0, 3.0]);
    }

    #[test]
    fn scan_id_follows_the_f64_path_like_as_u64() {
        // 7.0 and 1e3 are valid u64s through Json::as_u64; 1.5 and -1
        // are not -- the scanner must agree exactly
        assert_eq!(JsonScan::new(r#"{"id":7.0}"#).field_u64("id"), Some(7));
        assert_eq!(JsonScan::new(r#"{"id":1e3}"#).field_u64("id"), Some(1000));
        assert_eq!(JsonScan::new(r#"{"id":1.5}"#).field_u64("id"), None);
        assert_eq!(JsonScan::new(r#"{"id":-1}"#).field_u64("id"), None);
        assert_eq!(JsonScan::new(r#"{"id":"7"}"#).field_u64("id"), None);
    }

    #[test]
    fn scan_skips_strings_with_escapes_and_surrogate_pairs() {
        // escapes live in a *skipped* field; the target field still lands
        let line = r#"{"note":"a\n\"b\"\\ A \ud83d\ude00 😀","id":9}"#;
        let s = JsonScan::new(line);
        assert_eq!(s.field_u64("id"), Some(9));
        assert_eq!(s.has_field("note"), Some(true));
        // a lone high surrogate is malformed on both paths
        let bad = r#"{"note":"\ud83d","id":9}"#;
        assert!(Json::parse(bad).is_err());
        assert_eq!(JsonScan::new(bad).field_u64("id"), None);
        // ... as is a lone low surrogate
        let bad = r#"{"note":"\ude00x","id":9}"#;
        assert!(Json::parse(bad).is_err());
        assert_eq!(JsonScan::new(bad).field_u64("id"), None);
        // and a bad escape letter
        let bad = r#"{"note":"\q","id":9}"#;
        assert!(Json::parse(bad).is_err());
        assert_eq!(JsonScan::new(bad).field_u64("id"), None);
    }

    #[test]
    fn scan_skips_nested_objects_and_arrays() {
        let line = concat!(
            r#"{"meta":{"a":[1,{"b":[[],{}]},"x"],"c":{"d":null}},"#,
            r#""id":3,"tail":[true,false,[1,[2,[3]]]]}"#
        );
        let s = JsonScan::new(line);
        assert_eq!(s.field_u64("id"), Some(3));
        assert_eq!(s.field("meta"), Some(r#"{"a":[1,{"b":[[],{}]},"x"],"c":{"d":null}}"#));
        // unbalanced nesting is malformed
        assert_eq!(JsonScan::new(r#"{"a":[1,{"b":2},"id":3}"#).field_u64("id"), None);
    }

    #[test]
    fn scan_rejects_truncated_lines() {
        let full = r#"{"id":1,"features":[0.5,0.25],"class":"batch"}"#;
        assert_eq!(JsonScan::new(full).field_u64("id"), Some(1));
        for cut in 1..full.len() {
            if !full.is_char_boundary(cut) {
                continue;
            }
            let prefix = &full[..cut];
            assert_eq!(
                JsonScan::new(prefix).field_u64("id"),
                None,
                "truncation at {cut} ({prefix:?}) must not scan"
            );
        }
    }

    #[test]
    fn scan_rejects_what_the_parser_rejects() {
        for doc in [
            "",
            "not json",
            r#"{"id":1,} "#,
            r#"{"id":1} trailing"#,
            r#"{"id" 1}"#,
            r#"{"id":01x}"#,
            r#"{"id":nulll}"#,
            r#"{"id":1e}"#,
            r#"{"id":-}"#,
            r#"{"id":tru}"#,
            "{\"id\":\"\u{1}\"}",
        ] {
            assert!(Json::parse(doc).is_err(), "parser accepts {doc:?}");
            assert_eq!(JsonScan::new(doc).has_field("id"), None, "scan accepts {doc:?}");
        }
        // valid JSON that is not an object also defers to the parser
        // (whose typed accessors then produce the canonical error)
        assert_eq!(JsonScan::new("[1,2]").has_field("id"), None);
        assert_eq!(JsonScan::new("42").has_field("id"), None);
    }

    #[test]
    fn scan_duplicate_keys_last_wins_like_insert() {
        let line = r#"{"id":1,"id":2}"#;
        assert_eq!(JsonScan::new(line).field_u64("id"), Some(2));
        assert_eq!(Json::parse(line).unwrap().get("id").as_u64(), Some(2));
    }

    #[test]
    fn scan_escaped_keys_defer_to_the_parser() {
        // "id" unescapes to "id"; raw-byte comparison cannot see
        // that, so the scan must bail (None) instead of missing it
        let line = "{\"i\\u0064\":5}";
        assert_eq!(JsonScan::new(line).has_field("id"), None);
        assert_eq!(Json::parse(line).unwrap().get("id").as_u64(), Some(5));
    }

    #[test]
    fn scan_field_nums_rejects_non_numeric_elements() {
        let mut out = Vec::new();
        assert_eq!(
            JsonScan::new(r#"{"features":["x"]}"#).field_nums("features", &mut out),
            None
        );
        assert_eq!(
            JsonScan::new(r#"{"features":[1,[2]]}"#).field_nums("features", &mut out),
            None
        );
        assert_eq!(
            JsonScan::new(r#"{"features":[1,null]}"#).field_nums("features", &mut out),
            None
        );
        assert!(out.is_empty(), "failed scans must not leave partial output");
        assert_eq!(
            JsonScan::new(r#"{"features":[]}"#).field_nums("features", &mut out),
            Some(0)
        );
    }

    #[test]
    fn byte_writers_match_the_tree_writers() {
        // the zero-alloc frontend renders through these; any divergence
        // from the tree writer breaks the byte-identity differential
        for n in [
            0.0,
            -0.0,
            1.0,
            -17.0,
            3.5,
            -0.004,
            0.0021,
            1e-7,
            8.9e15,
            9.1e15,
            1.0e16,
            f64::NAN,
            f64::INFINITY,
            f64::NEG_INFINITY,
            0.1 + 0.2,
            123456789.125,
        ] {
            let mut s = String::new();
            write_num(&mut s, n);
            let mut b = Vec::new();
            write_num_bytes(&mut b, n);
            assert_eq!(s.as_bytes(), &b[..], "num divergence on {n}");
        }
        for text in [
            "",
            "plain",
            "quote \" backslash \\",
            "newline \n tab \t cr \r",
            "control \u{1} \u{1f}",
            "unicode 😀 é \u{2028}",
        ] {
            let mut s = String::new();
            write_str(&mut s, text);
            let mut b = Vec::new();
            write_str_bytes(&mut b, text);
            assert_eq!(s.as_bytes(), &b[..], "str divergence on {text:?}");
        }
    }
}
