//! Minimal JSON value, parser and writer.
//!
//! Built from scratch because the offline registry has no serde/serde_json
//! (DESIGN.md §3).  Covers the full JSON grammar (RFC 8259): objects,
//! arrays, strings with escapes (incl. `\uXXXX` + surrogate pairs),
//! numbers, booleans, null.  Object key order is preserved (insertion
//! order) so manifests round-trip stably.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.  Objects keep a parallel key-ordering vector so that
/// serialisation is deterministic and matches the input document.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(JsonObj),
}

/// An order-preserving JSON object.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct JsonObj {
    map: BTreeMap<String, Json>,
    order: Vec<String>,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, key: impl Into<String>, value: Json) {
        let key = key.into();
        if !self.map.contains_key(&key) {
            self.order.push(key.clone());
        }
        self.map.insert(key, value);
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.map.get(key)
    }

    pub fn contains_key(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.order.iter()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Json)> {
        self.order.iter().map(move |k| (k, &self.map[k]))
    }
}

impl<S: Into<String>> FromIterator<(S, Json)> for JsonObj {
    fn from_iter<T: IntoIterator<Item = (S, Json)>>(iter: T) -> Self {
        let mut o = JsonObj::new();
        for (k, v) in iter {
            o.insert(k, v);
        }
        o
    }
}

/// Parse or typed-access error.
#[derive(Debug)]
pub enum JsonError {
    Parse { pos: usize, msg: String },
    Type { expected: &'static str, path: String },
    Missing(String),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Parse { pos, msg } => {
                write!(f, "json parse error at byte {pos}: {msg}")
            }
            JsonError::Type { expected, path } => {
                write!(f, "json type error: expected {expected} at {path}")
            }
            JsonError::Missing(key) => write!(f, "json missing key {key:?}"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ----- constructors ---------------------------------------------------

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn arr_of_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_of_str<S: AsRef<str>>(v: &[S]) -> Json {
        Json::Arr(v.iter().map(|s| Json::str(s.as_ref())).collect())
    }

    // ----- typed accessors ------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64 {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["k"]`-style access; returns Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    /// Typed access that produces a useful error for manifests/configs.
    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.req(key)?
            .as_str()
            .ok_or(JsonError::Type { expected: "string", path: key.to_string() })
    }

    pub fn req_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.req(key)?
            .as_f64()
            .ok_or(JsonError::Type { expected: "number", path: key.to_string() })
    }

    pub fn req_usize(&self, key: &str) -> Result<usize, JsonError> {
        self.req(key)?
            .as_usize()
            .ok_or(JsonError::Type { expected: "usize", path: key.to_string() })
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json], JsonError> {
        self.req(key)?
            .as_arr()
            .ok_or(JsonError::Type { expected: "array", path: key.to_string() })
    }

    pub fn req_obj(&self, key: &str) -> Result<&JsonObj, JsonError> {
        self.req(key)?
            .as_obj()
            .ok_or(JsonError::Type { expected: "object", path: key.to_string() })
    }

    fn req(&self, key: &str) -> Result<&Json, JsonError> {
        match self.as_obj().and_then(|o| o.get(key)) {
            Some(v) => Ok(v),
            None => Err(JsonError::Missing(key.to_string())),
        }
    }

    // ----- parsing --------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ----- serialisation ---------------------------------------------------

    /// Compact serialisation.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialisation with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no Inf/NaN; emit null like most tolerant writers.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{}", n));
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError::Parse { pos: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(format!("expected {:?}", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected {:?}", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected {word}")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut obj = JsonObj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            obj.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(obj)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        match char::from_u32(cp) {
                            Some(c) => s.push(c),
                            None => return Err(self.err("invalid codepoint")),
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                // JSON strings are UTF-8 here; copy raw bytes of multibyte
                // chars straight through.
                Some(b) if b >= 0x20 => {
                    if b < 0x80 {
                        s.push(b as char);
                    } else {
                        // re-decode the multibyte sequence
                        let start = self.pos - 1;
                        let len = utf8_len(b);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        match std::str::from_utf8(&self.bytes[start..end]) {
                            Ok(chunk) => {
                                s.push_str(chunk);
                                self.pos = end;
                            }
                            Err(_) => return Err(self.err("invalid utf-8")),
                        }
                    }
                }
                Some(_) => return Err(self.err("control char in string")),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(format!("bad number {text:?}")))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::str("hi"));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\Aé"));
    }

    #[test]
    fn parse_surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = Json::parse("\"héllo → wörld\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → wörld"));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"n":1.5,"s":"a\"b","arr":[true,false,null],"o":{"k":2}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn object_preserves_key_order() {
        let v = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().keys().cloned().collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
        assert!(v.to_string().starts_with(r#"{"z":"#));
    }

    #[test]
    fn reject_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,").is_err());
        assert!(Json::parse("42 junk").is_err());
        assert!(Json::parse("'single'").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 7, "s": "x", "a": [1], "o": {}}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 7);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert_eq!(v.req_arr("a").unwrap().len(), 1);
        assert!(v.req_obj("o").is_ok());
        assert!(matches!(v.req_str("n"), Err(JsonError::Type { .. })));
        assert!(matches!(v.req_str("zz"), Err(JsonError::Missing(_))));
    }

    #[test]
    fn num_integer_formatting() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn as_u64_bounds() {
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(1.5).as_u64(), None);
        assert_eq!(Json::Num(12.0).as_u64(), Some(12));
    }
}
