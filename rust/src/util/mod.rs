//! From-scratch substrates: JSON, CLI parsing, PRNG, thread pool,
//! statistics, tables, property testing (DESIGN.md §3).

pub mod bufpool;
pub mod cli;
pub mod json;
pub mod minicheck;
pub mod rng;
pub mod stats;
pub mod table;
pub mod threadpool;
