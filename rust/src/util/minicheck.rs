//! Mini property-testing engine (the offline registry has no proptest).
//!
//! `check(seed, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and asserts `prop` on each; on failure it performs greedy shrinking via
//! the input's `Shrink` implementation and reports the minimal failing
//! case.  Coordinator invariants (routing conservation, batcher ordering,
//! cascade exit distribution) are tested with this.

use crate::util::rng::Rng;

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized + Clone + std::fmt::Debug {
    /// Candidate shrinks, in decreasing preference. Default: none.
    fn shrinks(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for usize {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for u64 {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for f64 {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
        }
        out
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // drop halves
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        // drop one element
        if self.len() <= 16 {
            for i in 0..self.len() {
                let mut v = self.clone();
                v.remove(i);
                out.push(v);
            }
        } else {
            let mut v = self.clone();
            v.pop();
            out.push(v);
        }
        // shrink one element
        for i in 0..self.len().min(8) {
            for s in self[i].shrinks() {
                let mut v = self.clone();
                v[i] = s;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrinks(&self) -> Vec<Self> {
        let mut out: Vec<Self> =
            self.0.shrinks().into_iter().map(|a| (a, self.1.clone())).collect();
        out.extend(self.1.shrinks().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Result of a property evaluation.
pub type PropResult = Result<(), String>;

/// Helper to build a failing PropResult.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

/// Run a property over `cases` generated inputs; panic with the minimal
/// shrunk counterexample on failure.
pub fn check<T, G, P>(seed: u64, cases: usize, gen: G, prop: P)
where
    T: Shrink,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> PropResult,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            let (min_input, min_msg) = shrink_loop(input, msg, &prop);
            panic!(
                "minicheck: property failed (case {case}/{cases}, seed {seed})\n\
                 message: {min_msg}\n\
                 minimal input: {min_input:?}"
            );
        }
    }
}

fn shrink_loop<T: Shrink, P: Fn(&T) -> PropResult>(
    mut input: T,
    mut msg: String,
    prop: &P,
) -> (T, String) {
    // Greedy descent, capped to keep failures fast.
    for _ in 0..1000 {
        let mut advanced = false;
        for cand in input.shrinks() {
            if let Err(m) = prop(&cand) {
                input = cand;
                msg = m;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    (input, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_good_property() {
        check(1, 200, |r| r.below(100), |&x| {
            prop_assert!(x < 100, "x={x} out of range");
            Ok(())
        });
    }

    #[test]
    fn shrinks_to_minimal() {
        // Property "x < 37" fails for x >= 37; minimal counterexample
        // reachable by our shrinker from any failing x is exactly 37.
        let result = std::panic::catch_unwind(|| {
            check(2, 500, |r| r.below(1000), |&x| {
                prop_assert!(x < 37, "too big");
                Ok(())
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("minimal input: 37"), "got: {msg}");
    }

    #[test]
    fn vec_shrink_reduces_length() {
        // "no vector contains 7" fails; minimal failing vec is [7].
        let result = std::panic::catch_unwind(|| {
            check(
                3,
                500,
                |r| (0..r.below(20)).map(|_| r.below(10)).collect::<Vec<usize>>(),
                |v| {
                    prop_assert!(!v.contains(&7), "contains 7");
                    Ok(())
                },
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("minimal input: [7]"), "got: {msg}");
    }

    #[test]
    fn tuple_shrink_works() {
        let result = std::panic::catch_unwind(|| {
            check(
                4,
                300,
                |r| (r.below(50), r.below(50)),
                |&(a, b)| {
                    prop_assert!(a + b < 30, "sum too big");
                    Ok(())
                },
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // minimal failing sum is 30 with one coordinate 0
        assert!(msg.contains("(0, 30)") || msg.contains("(30, 0)"), "got: {msg}");
    }
}
