//! Model registry: loads and owns a suite's full tier ladder as live
//! PJRT executables (the Rust-side "model zoo").

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::data::format::{self, Dataset};
use crate::runtime::engine::Engine;
use crate::runtime::executable::{TierExecutable, Variant};
use crate::zoo::manifest::{Manifest, SuiteEntry};

/// A fully loaded suite: datasets on the host, tier executables on the
/// PJRT device, ready for the coordinator.
pub struct SuiteRuntime {
    pub suite: SuiteEntry,
    /// Ensemble (ABC) executables, ascending tier order.
    pub tiers: Vec<Arc<TierExecutable>>,
    /// Single-model (baseline) executables, ascending tier order.
    pub singles: Vec<Arc<TierExecutable>>,
}

impl SuiteRuntime {
    /// Load every tier of `suite_name`.  `with_singles` also loads the
    /// member-0 baseline artifacts (needed by WoC / single-model runs).
    pub fn load(
        engine: Arc<Engine>,
        manifest: &Manifest,
        suite_name: &str,
        with_singles: bool,
    ) -> Result<SuiteRuntime> {
        let suite = manifest
            .suite(suite_name)
            .with_context(|| format!("suite {suite_name} not in manifest"))?
            .clone();
        let mut tiers = Vec::new();
        let mut singles = Vec::new();
        for t in &suite.tiers {
            tiers.push(Arc::new(TierExecutable::load(
                Arc::clone(&engine),
                manifest,
                suite.dim,
                suite.classes,
                t,
                Variant::Ensemble,
            )?));
            if with_singles {
                singles.push(Arc::new(TierExecutable::load(
                    Arc::clone(&engine),
                    manifest,
                    suite.dim,
                    suite.classes,
                    t,
                    Variant::Single,
                )?));
            }
        }
        Ok(SuiteRuntime { suite, tiers, singles })
    }

    /// Load a dataset split of this suite from the artifacts directory.
    pub fn dataset(&self, manifest: &Manifest, split: &str) -> Result<Dataset> {
        let rel = self
            .suite
            .data
            .get(split)
            .with_context(|| format!("split {split} not in manifest"))?;
        let ds = format::read_file(manifest.path(rel))
            .with_context(|| format!("reading {split} split"))?;
        if ds.dim != self.suite.dim {
            bail!(
                "dataset dim {} != suite dim {} for {split}",
                ds.dim,
                self.suite.dim
            );
        }
        Ok(ds)
    }

    pub fn n_tiers(&self) -> usize {
        self.tiers.len()
    }

    pub fn tier(&self, tier_id: usize) -> Option<&Arc<TierExecutable>> {
        self.tiers.iter().find(|t| t.tier == tier_id)
    }

    pub fn single(&self, tier_id: usize) -> Option<&Arc<TierExecutable>> {
        self.singles.iter().find(|t| t.tier == tier_id)
    }
}
