//! Model zoo: manifest parsing + live registry of loaded tier executables.

pub mod manifest;
pub mod registry;
