//! Typed view of `artifacts/manifest.json` (written by python/compile/aot.py).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

#[derive(Debug)]
pub enum ManifestError {
    Io(std::io::Error),
    Json(crate::util::json::JsonError),
    Version(u64),
    Inconsistent(String),
}

impl std::fmt::Display for ManifestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ManifestError::Io(e) => write!(f, "io error reading manifest: {e}"),
            ManifestError::Json(e) => write!(f, "manifest json error: {e}"),
            ManifestError::Version(v) => {
                write!(f, "unsupported manifest format_version {v}")
            }
            ManifestError::Inconsistent(msg) => write!(f, "manifest inconsistency: {msg}"),
        }
    }
}

impl std::error::Error for ManifestError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ManifestError::Io(e) => Some(e),
            ManifestError::Json(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ManifestError {
    fn from(e: std::io::Error) -> ManifestError {
        ManifestError::Io(e)
    }
}

impl From<crate::util::json::JsonError> for ManifestError {
    fn from(e: crate::util::json::JsonError) -> ManifestError {
        ManifestError::Json(e)
    }
}

/// One cascade tier's metadata (ensemble of k models).
#[derive(Debug, Clone)]
pub struct TierEntry {
    pub tier: usize,
    pub k: usize,
    pub hidden: Vec<usize>,
    pub input_slice: usize,
    /// Forward FLOPs of ONE member on one sample.
    pub flops_per_sample_member: u64,
    pub params_member: u64,
    pub val_acc_members: Vec<f64>,
    pub val_acc_ensemble: f64,
    pub test_acc_members: Vec<f64>,
    pub test_acc_ensemble: f64,
    /// npz sidecar with w0, b0, w1, b1, ... arrays.
    pub weights: PathBuf,
    pub param_names: Vec<String>,
    /// batch bucket -> HLO text path (ensemble + agreement artifact).
    pub ensemble_hlo: BTreeMap<usize, PathBuf>,
    /// batch bucket -> HLO text path (member-0 single-model artifact).
    pub single_hlo: BTreeMap<usize, PathBuf>,
}

impl TierEntry {
    /// Ensemble FLOPs per sample (k members).
    pub fn flops_ensemble(&self) -> u64 {
        self.flops_per_sample_member * self.k as u64
    }
}

/// One benchmark suite with its data splits and tier ladder.
#[derive(Debug, Clone)]
pub struct SuiteEntry {
    pub name: String,
    pub paper_dataset: String,
    pub classes: usize,
    pub dim: usize,
    pub n_train: usize,
    pub n_val: usize,
    pub n_test: usize,
    /// split name -> dataset path (relative to artifacts root).
    pub data: BTreeMap<String, PathBuf>,
    pub tiers: Vec<TierEntry>,
}

impl SuiteEntry {
    pub fn tier(&self, tier_id: usize) -> Option<&TierEntry> {
        self.tiers.iter().find(|t| t.tier == tier_id)
    }

    /// The most expensive tier (the paper's h2 / best single model host).
    pub fn top_tier(&self) -> &TierEntry {
        self.tiers.last().expect("suite has tiers")
    }
}

/// The whole artifacts manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub ensemble_buckets: Vec<usize>,
    pub single_buckets: Vec<usize>,
    pub suites: Vec<SuiteEntry>,
}

impl Manifest {
    /// Load `<root>/manifest.json` and resolve all paths against root.
    pub fn load(root: impl AsRef<Path>) -> Result<Manifest, ManifestError> {
        let root = root.as_ref().to_path_buf();
        let text = std::fs::read_to_string(root.join("manifest.json"))?;
        let v = Json::parse(&text)?;
        let version = v.req_f64("format_version")? as u64;
        if version != 1 {
            return Err(ManifestError::Version(version));
        }
        let buckets = |key: &str| -> Result<Vec<usize>, ManifestError> {
            Ok(v.req_arr(key)?
                .iter()
                .filter_map(|b| b.as_usize())
                .collect())
        };
        let mut suites = Vec::new();
        for s in v.req_arr("suites")? {
            suites.push(parse_suite(s)?);
        }
        let m = Manifest {
            root,
            ensemble_buckets: buckets("ensemble_buckets")?,
            single_buckets: buckets("single_buckets")?,
            suites,
        };
        m.validate()?;
        Ok(m)
    }

    pub fn suite(&self, name: &str) -> Option<&SuiteEntry> {
        self.suites.iter().find(|s| s.name == name)
    }

    pub fn suite_names(&self) -> Vec<&str> {
        self.suites.iter().map(|s| s.name.as_str()).collect()
    }

    /// Absolute path of a manifest-relative path.
    pub fn path(&self, rel: &Path) -> PathBuf {
        self.root.join(rel)
    }

    fn validate(&self) -> Result<(), ManifestError> {
        for s in &self.suites {
            if s.tiers.is_empty() {
                return Err(ManifestError::Inconsistent(format!(
                    "suite {} has no tiers",
                    s.name
                )));
            }
            let mut prev = 0usize;
            for t in &s.tiers {
                if t.tier <= prev {
                    return Err(ManifestError::Inconsistent(format!(
                        "suite {}: tiers not strictly increasing",
                        s.name
                    )));
                }
                prev = t.tier;
                if t.val_acc_members.len() != t.k {
                    return Err(ManifestError::Inconsistent(format!(
                        "suite {} tier {}: {} member accs for k={}",
                        s.name,
                        t.tier,
                        t.val_acc_members.len(),
                        t.k
                    )));
                }
                for bucket in &self.ensemble_buckets {
                    if !t.ensemble_hlo.contains_key(bucket) {
                        return Err(ManifestError::Inconsistent(format!(
                            "suite {} tier {}: missing ensemble bucket {}",
                            s.name, t.tier, bucket
                        )));
                    }
                }
            }
        }
        Ok(())
    }
}

fn parse_suite(s: &Json) -> Result<SuiteEntry, ManifestError> {
    let mut data = BTreeMap::new();
    for (split, p) in s.req_obj("data")?.iter() {
        let path = p.as_str().ok_or(crate::util::json::JsonError::Type {
            expected: "string",
            path: format!("data.{split}"),
        })?;
        data.insert(split.clone(), PathBuf::from(path));
    }
    let mut tiers = Vec::new();
    for t in s.req_arr("tiers")? {
        tiers.push(parse_tier(t)?);
    }
    Ok(SuiteEntry {
        name: s.req_str("name")?.to_string(),
        paper_dataset: s.req_str("paper_dataset")?.to_string(),
        classes: s.req_usize("classes")?,
        dim: s.req_usize("dim")?,
        n_train: s.req_usize("n_train")?,
        n_val: s.req_usize("n_val")?,
        n_test: s.req_usize("n_test")?,
        data,
        tiers,
    })
}

fn parse_tier(t: &Json) -> Result<TierEntry, ManifestError> {
    let f64s = |key: &str| -> Result<Vec<f64>, ManifestError> {
        Ok(t.req_arr(key)?.iter().filter_map(|x| x.as_f64()).collect())
    };
    let hlo_map = |key: &str| -> Result<BTreeMap<usize, PathBuf>, ManifestError> {
        let mut out = BTreeMap::new();
        for (bucket, p) in t.req_obj(key)?.iter() {
            let b: usize = bucket.parse().map_err(|_| {
                ManifestError::Inconsistent(format!("bad bucket key {bucket:?}"))
            })?;
            out.insert(
                b,
                PathBuf::from(p.as_str().ok_or(
                    crate::util::json::JsonError::Type {
                        expected: "string",
                        path: format!("{key}.{bucket}"),
                    },
                )?),
            );
        }
        Ok(out)
    };
    Ok(TierEntry {
        tier: t.req_usize("tier")?,
        k: t.req_usize("k")?,
        hidden: t
            .req_arr("hidden")?
            .iter()
            .filter_map(|x| x.as_usize())
            .collect(),
        input_slice: t.req_usize("input_slice")?,
        flops_per_sample_member: t.req_f64("flops_per_sample_member")? as u64,
        params_member: t.req_f64("params_member")? as u64,
        val_acc_members: f64s("val_acc_members")?,
        val_acc_ensemble: t.req_f64("val_acc_ensemble")?,
        test_acc_members: f64s("test_acc_members")?,
        test_acc_ensemble: t.req_f64("test_acc_ensemble")?,
        weights: PathBuf::from(t.req_str("weights")?),
        param_names: t
            .req_arr("param_names")?
            .iter()
            .filter_map(|x| x.as_str().map(String::from))
            .collect(),
        ensemble_hlo: hlo_map("ensemble_hlo")?,
        single_hlo: hlo_map("single_hlo")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format_version": 1,
      "created_unix": 1,
      "jax_version": "0.8.2",
      "ensemble_buckets": [1, 8],
      "single_buckets": [8],
      "suites": [{
        "name": "s1", "paper_dataset": "P", "classes": 3, "dim": 4,
        "n_train": 10, "n_val": 5, "n_test": 5,
        "data": {"train": "data/a.abds", "val": "data/b.abds", "test": "data/c.abds"},
        "tiers": [{
          "tier": 1, "k": 2, "hidden": [8], "input_slice": 2,
          "flops_per_sample_member": 100, "params_member": 50,
          "val_acc_members": [0.5, 0.6], "val_acc_ensemble": 0.62,
          "test_acc_members": [0.5, 0.55], "test_acc_ensemble": 0.6,
          "weights": "weights/s1_t1.npz",
          "param_names": ["w0", "b0", "w1", "b1"],
          "ensemble_hlo": {"1": "hlo/e1.txt", "8": "hlo/e8.txt"},
          "single_hlo": {"8": "hlo/s8.txt"}
        }]
      }]
    }"#;

    fn write_sample(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
    }

    #[test]
    fn parses_sample() {
        let dir = std::env::temp_dir().join(format!("mani-{}", std::process::id()));
        write_sample(&dir);
        let m = Manifest::load(&dir).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        assert_eq!(m.ensemble_buckets, vec![1, 8]);
        assert_eq!(m.suite_names(), vec!["s1"]);
        let s = m.suite("s1").unwrap();
        assert_eq!(s.classes, 3);
        let t = s.tier(1).unwrap();
        assert_eq!(t.k, 2);
        assert_eq!(t.flops_ensemble(), 200);
        assert_eq!(t.ensemble_hlo[&8], PathBuf::from("hlo/e8.txt"));
        assert_eq!(s.top_tier().tier, 1);
        assert!(m.suite("nope").is_none());
    }

    #[test]
    fn rejects_bad_version() {
        let dir = std::env::temp_dir().join(format!("mani2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            SAMPLE.replace("\"format_version\": 1", "\"format_version\": 9"),
        )
        .unwrap();
        let err = Manifest::load(&dir).unwrap_err();
        std::fs::remove_dir_all(&dir).ok();
        assert!(matches!(err, ManifestError::Version(9)));
    }

    #[test]
    fn rejects_member_acc_mismatch() {
        let dir = std::env::temp_dir().join(format!("mani3-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            SAMPLE.replace("[0.5, 0.6]", "[0.5]"),
        )
        .unwrap();
        let err = Manifest::load(&dir).unwrap_err();
        std::fs::remove_dir_all(&dir).ok();
        assert!(matches!(err, ManifestError::Inconsistent(_)));
    }

    #[test]
    fn rejects_missing_bucket() {
        let dir = std::env::temp_dir().join(format!("mani4-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            SAMPLE.replace("\"1\": \"hlo/e1.txt\", ", ""),
        )
        .unwrap();
        let err = Manifest::load(&dir).unwrap_err();
        std::fs::remove_dir_all(&dir).ok();
        assert!(matches!(err, ManifestError::Inconsistent(_)));
    }
}
