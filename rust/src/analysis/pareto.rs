//! Pareto-frontier analysis for (cost, accuracy) points (Fig. 1/2).

/// A labelled operating point: lower cost is better, higher value better.
#[derive(Debug, Clone, PartialEq)]
pub struct Point {
    pub label: String,
    pub cost: f64,
    pub value: f64,
}

impl Point {
    pub fn new(label: impl Into<String>, cost: f64, value: f64) -> Point {
        Point { label: label.into(), cost, value }
    }

    /// True iff `self` weakly dominates `other` (<= cost, >= value, and
    /// strictly better in at least one).
    pub fn dominates(&self, other: &Point) -> bool {
        self.cost <= other.cost
            && self.value >= other.value
            && (self.cost < other.cost || self.value > other.value)
    }
}

/// The Pareto-efficient subset, sorted by ascending cost.
pub fn frontier(points: &[Point]) -> Vec<Point> {
    let mut front: Vec<Point> = points
        .iter()
        .filter(|p| !points.iter().any(|q| q.dominates(p)))
        .cloned()
        .collect();
    front.sort_by(|a, b| a.cost.partial_cmp(&b.cost).unwrap());
    front.dedup_by(|a, b| a.cost == b.cost && a.value == b.value);
    front
}

/// Labels of points NOT on the frontier (dominated by someone).
pub fn dominated<'a>(points: &'a [Point]) -> Vec<&'a str> {
    points
        .iter()
        .filter(|p| points.iter().any(|q| q.dominates(p)))
        .map(|p| p.label.as_str())
        .collect()
}

/// Hypervolume-style scalar: area under the frontier's step function up
/// to `max_cost` (useful to compare frontiers of two methods; larger is
/// better).
pub fn frontier_area(points: &[Point], max_cost: f64) -> f64 {
    let front = frontier(points);
    let mut area = 0.0;
    let mut best_value = 0.0f64;
    let mut last_cost = 0.0f64;
    for p in front.iter().filter(|p| p.cost <= max_cost) {
        area += best_value * (p.cost - last_cost);
        best_value = best_value.max(p.value);
        last_cost = p.cost;
    }
    area += best_value * (max_cost - last_cost).max(0.0);
    area
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pts() -> Vec<Point> {
        vec![
            Point::new("cheap-weak", 1.0, 0.6),
            Point::new("mid", 5.0, 0.8),
            Point::new("dominated", 6.0, 0.75),
            Point::new("big", 20.0, 0.9),
            Point::new("worse-big", 25.0, 0.9),
        ]
    }

    #[test]
    fn frontier_excludes_dominated() {
        let f = frontier(&pts());
        let labels: Vec<&str> = f.iter().map(|p| p.label.as_str()).collect();
        assert_eq!(labels, vec!["cheap-weak", "mid", "big"]);
    }

    #[test]
    fn dominated_lists_the_rest() {
        let points = pts();
        let d = dominated(&points);
        assert_eq!(d, vec!["dominated", "worse-big"]);
    }

    #[test]
    fn dominates_requires_strict_improvement() {
        let a = Point::new("a", 1.0, 0.5);
        let b = Point::new("b", 1.0, 0.5);
        assert!(!a.dominates(&b));
        assert!(!b.dominates(&a));
        let c = Point::new("c", 1.0, 0.6);
        assert!(c.dominates(&a));
        assert!(!a.dominates(&c));
    }

    #[test]
    fn frontier_area_monotone_in_improvements() {
        let base = pts();
        let mut improved = pts();
        improved.push(Point::new("abc", 3.0, 0.85)); // new efficient point
        let a0 = frontier_area(&base, 30.0);
        let a1 = frontier_area(&improved, 30.0);
        assert!(a1 > a0, "{a1} vs {a0}");
    }

    #[test]
    fn frontier_area_empty_is_zero() {
        assert_eq!(frontier_area(&[], 10.0), 0.0);
    }
}
