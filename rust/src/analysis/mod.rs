//! Result analysis: Pareto frontiers over (cost, accuracy) points.

pub mod pareto;
