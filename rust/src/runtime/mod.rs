//! PJRT runtime: engine (client + HLO loading) and bucketed tier
//! executables bound to their uploaded weights.

pub mod engine;
pub mod executable;
