//! PJRT engine: loads AOT HLO-text artifacts and executes them on the CPU
//! PJRT client (`xla` crate / xla_extension 0.5.1).
//!
//! Interchange is HLO *text*: jax >= 0.5 emits HloModuleProto with 64-bit
//! instruction ids that this XLA rejects; the text parser reassigns ids
//! (see /opt/xla-example/README.md and DESIGN.md §2).

use std::path::Path;

use anyhow::{Context, Result};
use xla::FromRawBytes;

/// Wrapper around the PJRT CPU client.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    /// Create a CPU engine.  One engine per process is typical; executables
    /// created from it keep a handle to the client.
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Direct access to the underlying PJRT client (advanced callers).
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load an HLO text file and compile it into a loaded executable.
    pub fn load_hlo(&self, path: impl AsRef<Path>) -> Result<xla::PjRtLoadedExecutable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(exe)
    }

    /// Upload host f32 data as a device buffer (used for inputs and for
    /// the one-time weight upload).
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .context("uploading f32 buffer")
    }

    /// Load every named array of an .npz weight sidecar, in the given
    /// order, as device buffers.
    ///
    /// SOUNDNESS: `buffer_from_host_literal` enqueues an *asynchronous*
    /// host->device copy on the client's thread pool
    /// (`AbstractTfrtCpuBuffer::CopyFromLiteral`); the source literal must
    /// stay alive until the copy completes or the copier reads freed
    /// memory (observed as flaky SIGSEGV/heap corruption).  We therefore
    /// return the literals together with the buffers and the caller keeps
    /// both for the executable's lifetime.
    pub fn upload_npz_weights(
        &self,
        path: impl AsRef<Path>,
        names: &[String],
    ) -> Result<(Vec<xla::PjRtBuffer>, Vec<xla::Literal>)> {
        let path = path.as_ref();
        let name_refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let literals = xla::Literal::read_npz_by_name(path, &(), &name_refs)
            .with_context(|| format!("reading npz {}", path.display()))?;
        let mut buffers = Vec::with_capacity(literals.len());
        for lit in &literals {
            buffers.push(
                self.client
                    .buffer_from_host_literal(None, lit)
                    .context("uploading weight literal")?,
            );
        }
        // Force the async copies to complete while the sources are
        // guaranteed alive (a host read-back synchronises the chain).
        for buf in &buffers {
            let _ = buf
                .to_literal_sync()
                .context("synchronising weight upload")?;
        }
        Ok((buffers, literals))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_engine_boots() {
        let e = Engine::cpu().unwrap();
        assert!(e.device_count() >= 1);
        assert!(!e.platform().is_empty());
    }

    #[test]
    fn upload_roundtrip() {
        let e = Engine::cpu().unwrap();
        let buf = e.upload_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        let lit = buf.to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn upload_dim_mismatch_errors() {
        let e = Engine::cpu().unwrap();
        assert!(e.upload_f32(&[1.0, 2.0, 3.0], &[2, 2]).is_err());
    }
}
