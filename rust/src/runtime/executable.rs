//! Bucketed tier executables: the runtime objects the coordinator calls.
//!
//! A `TierExecutable` owns one compiled PJRT executable per batch bucket
//! plus the tier's weights, uploaded to the device ONCE at load time
//! (the artifacts keep weights as runtime parameters -- HLO text elides
//! large constants).  `run` picks the smallest bucket that fits, pads the
//! batch, executes, and truncates the outputs; batches larger than the
//! biggest bucket are chunked.
//!
//! Thread-safety: the raw `xla` wrapper types hold C pointers and are not
//! `Send`/`Sync`, but the PJRT CPU client is thread-safe for compilation
//! and execution, and our weight buffers are immutable after upload.  We
//! therefore wrap the executable set in a struct with an explicit
//! `unsafe impl Send + Sync` (see `SAFETY` note below).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::runtime::engine::Engine;
use crate::types::TierOutput;
use crate::zoo::manifest::{Manifest, TierEntry};

/// Which artifact variant of the tier to load.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// Ensemble of k members + agreement reduce: returns TierOutput.
    Ensemble,
    /// Member-0 single model: prediction + max-softmax confidence.
    Single,
}

/// Output of the single-model artifact (baselines).
#[derive(Debug, Clone, Copy)]
pub struct SingleOutput {
    pub pred: u32,
    pub confidence: f32,
}

struct Inner {
    buckets: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    weights: Vec<xla::PjRtBuffer>,
    /// Host copies backing the async weight upload; MUST outlive the
    /// buffers (see Engine::upload_npz_weights soundness note).
    _weight_literals: Vec<xla::Literal>,
}

// SAFETY: PJRT's C API guarantees thread-safe `Compile`/`Execute` on the
// CPU client; `PjRtLoadedExecutable::execute_b` takes `&self` and the
// weight buffers are never mutated after upload.  The wrapper types are
// only `!Send`/`!Sync` because they contain raw pointers.
unsafe impl Send for Inner {}
unsafe impl Sync for Inner {}

/// A loaded, bucketed tier artifact bound to its weights.
pub struct TierExecutable {
    inner: Inner,
    /// Engine handle used for per-call input uploads.
    engine: std::sync::Arc<Engine>,
    pub tier: usize,
    pub k: usize,
    pub dim: usize,
    pub classes: usize,
    pub variant: Variant,
    /// Available batch buckets, ascending.
    pub bucket_sizes: Vec<usize>,
}

// SAFETY: see Inner. Engine's client is likewise thread-safe.
unsafe impl Send for TierExecutable {}
unsafe impl Sync for TierExecutable {}

impl TierExecutable {
    /// Load all buckets of a tier's artifact + upload its weights.
    pub fn load(
        engine: std::sync::Arc<Engine>,
        manifest: &Manifest,
        suite_dim: usize,
        suite_classes: usize,
        tier: &TierEntry,
        variant: Variant,
    ) -> Result<TierExecutable> {
        let hlo_map = match variant {
            Variant::Ensemble => &tier.ensemble_hlo,
            Variant::Single => &tier.single_hlo,
        };
        if hlo_map.is_empty() {
            bail!("tier {} has no {:?} artifacts", tier.tier, variant);
        }
        let mut buckets = BTreeMap::new();
        for (&bucket, rel) in hlo_map {
            let exe = engine
                .load_hlo(manifest.path(rel))
                .with_context(|| format!("tier {} bucket {}", tier.tier, bucket))?;
            buckets.insert(bucket, exe);
        }
        let (weights, weight_literals) = engine
            .upload_npz_weights(manifest.path(&tier.weights), &tier.param_names)
            .with_context(|| format!("weights for tier {}", tier.tier))?;
        let bucket_sizes: Vec<usize> = buckets.keys().copied().collect();
        Ok(TierExecutable {
            inner: Inner { buckets, weights, _weight_literals: weight_literals },
            engine,
            tier: tier.tier,
            k: tier.k,
            dim: suite_dim,
            classes: suite_classes,
            variant,
            bucket_sizes,
        })
    }

    /// Smallest bucket that fits `n`, or the largest bucket if none do.
    pub fn pick_bucket(&self, n: usize) -> usize {
        for &b in &self.bucket_sizes {
            if b >= n {
                return b;
            }
        }
        *self.bucket_sizes.last().unwrap()
    }

    /// Next chunk size for a remaining batch of `n` rows.
    ///
    /// Padding straight up to `pick_bucket(n)` can waste up to 4x compute
    /// at the most expensive tier (e.g. 33 deferred rows padding to the
    /// 128 bucket); splitting into exact buckets costs extra dispatches
    /// whose fixed overhead dominates for the small tiers.  Heuristic
    /// (perf pass, EXPERIMENTS.md SS Perf): pad up when the padded bucket
    /// is within 2x of the remaining rows (single dispatch), otherwise
    /// issue the largest bucket that fits and continue.
    pub fn next_chunk(&self, n: usize) -> usize {
        debug_assert!(n > 0);
        let up = self.bucket_sizes.iter().copied().find(|&b| b >= n);
        let down = self.bucket_sizes.iter().rev().copied().find(|&b| b <= n);
        match (up, down) {
            (Some(up), Some(down)) => {
                if up <= 2 * n {
                    n // pad up to `up`: waste < 2x, single dispatch
                } else {
                    down
                }
            }
            (Some(_), None) => n,    // below the smallest bucket: pad up
            (None, Some(down)) => down, // above the largest bucket: chunk
            (None, None) => unreachable!("no buckets"),
        }
    }

    /// Padded-sample waste for a batch of `n` (used by the batcher).
    pub fn padding_waste(&self, n: usize) -> usize {
        let mut remaining = n;
        let mut padded = 0;
        while remaining > 0 {
            let chunk = self.next_chunk(remaining);
            padded += self.pick_bucket(chunk);
            remaining -= chunk;
        }
        padded - n
    }

    /// Run the ensemble artifact over `n` rows of `features`
    /// (row-major `n x dim`).  Returns one TierOutput per row.
    pub fn run(&self, features: &[f32], n: usize) -> Result<Vec<TierOutput>> {
        let (outputs, _) = self.run_impl(features, n, false)?;
        Ok(outputs)
    }

    /// As `run`, but also returns the stacked member logits
    /// (`k * n * classes`, member-major) for analysis paths.
    pub fn run_with_logits(
        &self,
        features: &[f32],
        n: usize,
    ) -> Result<(Vec<TierOutput>, Vec<f32>)> {
        let (outputs, logits) = self.run_impl(features, n, true)?;
        Ok((outputs, logits))
    }

    fn run_impl(
        &self,
        features: &[f32],
        n: usize,
        want_logits: bool,
    ) -> Result<(Vec<TierOutput>, Vec<f32>)> {
        if self.variant != Variant::Ensemble {
            bail!("run() on a Single-variant executable");
        }
        check_features(features, n, self.dim)?;
        let mut out = Vec::with_capacity(n);
        // Globally member-major layout: logits_all[(m * n + i) * classes..]
        // is member m's logits for sample i, regardless of chunking.
        let mut logits_all = if want_logits {
            vec![0.0f32; self.k * n * self.classes]
        } else {
            Vec::new()
        };
        let mut start = 0;
        while start < n {
            let chunk = self.next_chunk(n - start);
            let bucket = self.pick_bucket(chunk);
            let rows = &features[start * self.dim..(start + chunk) * self.dim];
            let padded = pad_rows(rows, chunk, bucket, self.dim);
            let input = self
                .engine
                .upload_f32(&padded, &[bucket, self.dim])
                .context("uploading input batch")?;
            let mut args: Vec<&xla::PjRtBuffer> = vec![&input];
            args.extend(self.inner.weights.iter());
            let exe = &self.inner.buckets[&bucket];
            let result = exe.execute_b(&args).context("tier execute")?;
            // SOUNDNESS: decomposed tuple literals alias the parent
            // literal's storage in xla_extension 0.5.1, so the parent MUST
            // outlive every read of the parts (`Literal::to_tuple`, which
            // drops the parent, segfaults after ~hundreds of calls).  Keep
            // `tuple` alive until all `to_vec` copies are done.
            let mut tuple = result[0][0]
                .to_literal_sync()
                .context("fetching result literal")?;
            let parts = tuple.decompose_tuple().context("untupling result")?;
            if parts.len() != 4 {
                bail!("ensemble artifact returned {} outputs, expected 4", parts.len());
            }
            let maj = parts[0].to_vec::<i32>().context("majority output")?;
            let frac = parts[1].to_vec::<f32>().context("vote_frac output")?;
            let score = parts[2].to_vec::<f32>().context("mean_score output")?;
            for i in 0..chunk {
                out.push(TierOutput {
                    majority: maj[i] as u32,
                    vote_frac: frac[i],
                    mean_score: score[i],
                });
            }
            if want_logits {
                let lg = parts[3].to_vec::<f32>().context("logits output")?;
                // chunk logits are (k, bucket, classes); scatter the real
                // rows into the global (k, n, classes) buffer.
                for m in 0..self.k {
                    for i in 0..chunk {
                        let src = (m * bucket + i) * self.classes;
                        let dst = (m * n + start + i) * self.classes;
                        logits_all[dst..dst + self.classes]
                            .copy_from_slice(&lg[src..src + self.classes]);
                    }
                }
            }
            drop(parts);
            drop(tuple);
            start += chunk;
        }
        Ok((out, logits_all))
    }

    /// Run the single-model artifact (member 0).
    pub fn run_single(&self, features: &[f32], n: usize) -> Result<Vec<SingleOutput>> {
        if self.variant != Variant::Single {
            bail!("run_single() on an Ensemble-variant executable");
        }
        check_features(features, n, self.dim)?;
        let mut out = Vec::with_capacity(n);
        let mut start = 0;
        while start < n {
            let chunk = self.next_chunk(n - start);
            let bucket = self.pick_bucket(chunk);
            let rows = &features[start * self.dim..(start + chunk) * self.dim];
            let padded = pad_rows(rows, chunk, bucket, self.dim);
            let input = self.engine.upload_f32(&padded, &[bucket, self.dim])?;
            let mut args: Vec<&xla::PjRtBuffer> = vec![&input];
            args.extend(self.inner.weights.iter());
            let exe = &self.inner.buckets[&bucket];
            let result = exe.execute_b(&args)?;
            // SOUNDNESS: parent literal must outlive the decomposed parts
            // (see run_impl).
            let mut tuple = result[0][0].to_literal_sync()?;
            let parts = tuple.decompose_tuple()?;
            if parts.len() != 3 {
                bail!("single artifact returned {} outputs, expected 3", parts.len());
            }
            let pred = parts[0].to_vec::<i32>()?;
            let conf = parts[1].to_vec::<f32>()?;
            for i in 0..chunk {
                out.push(SingleOutput { pred: pred[i] as u32, confidence: conf[i] });
            }
            drop(parts);
            drop(tuple);
            start += chunk;
        }
        Ok(out)
    }
}

fn check_features(features: &[f32], n: usize, dim: usize) -> Result<()> {
    if features.len() != n * dim {
        bail!(
            "feature buffer has {} floats, expected {} ({} rows x {} dim)",
            features.len(),
            n * dim,
            n,
            dim
        );
    }
    if n == 0 {
        bail!("empty batch");
    }
    Ok(())
}

/// Pad `rows` (chunk x dim) up to `bucket` rows by repeating the last row
/// (repeats keep the agreement kernel's numerics in-distribution, unlike
/// zero rows).
fn pad_rows(rows: &[f32], chunk: usize, bucket: usize, dim: usize) -> Vec<f32> {
    let mut padded = Vec::with_capacity(bucket * dim);
    padded.extend_from_slice(rows);
    let last = &rows[(chunk - 1) * dim..chunk * dim];
    for _ in chunk..bucket {
        padded.extend_from_slice(last);
    }
    padded
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_rows_repeats_last() {
        let rows = vec![1.0, 2.0, 3.0, 4.0]; // 2 rows x 2 dim
        let p = pad_rows(&rows, 2, 4, 2);
        assert_eq!(p, vec![1.0, 2.0, 3.0, 4.0, 3.0, 4.0, 3.0, 4.0]);
    }

    #[test]
    fn check_features_validates() {
        assert!(check_features(&[0.0; 6], 3, 2).is_ok());
        assert!(check_features(&[0.0; 5], 3, 2).is_err());
        assert!(check_features(&[], 0, 2).is_err());
    }
}
