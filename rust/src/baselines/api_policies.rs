//! Routing policies for the black-box API scenario (paper §5.2.3, Fig. 5).
//!
//! All policies run over the same simulated agent fleet (sim/api_llm):
//!
//! * `AbcVoting`       -- the paper's contribution: each tier's agents
//!                        answer once (temp 0), vote; defer below theta_v.
//! * `SingleModel`     -- one fixed model answers everything.
//! * `FrugalGpt`       -- single best model per tier + a learned scorer
//!                        g(prompt, answer) with per-tier thresholds
//!                        (Chen et al. 2023).  The scorer is simulated as
//!                        a noisy correctness signal whose quality degrades
//!                        with sample difficulty ("the trained scorer
//!                        struggles as the tasks get harder").
//! * `AutoMix{T,P}`    -- single best model per tier + k=8 few-shot
//!                        self-verification calls at temp 1.0, averaged,
//!                        then a threshold (T) or POMDP-lite (P)
//!                        meta-verifier (Madaan et al. 2023).
//! * `MotCascade`      -- weaker LLM samples its own answer several times
//!                        at temp>0; consistency-based deferral
//!                        (Yue et al. 2024).
//!
//! Setup costs (router training, labelled data) are NOT billed, matching
//! the paper's "costs not reflected in our plots" framing -- ABC wins
//! before counting them.

use crate::coordinator::agreement::agree_votes;
use crate::sim::api_llm::{best_of_tier, tier_agents, LlmAgent, LlmSample, LlmTask};
use crate::util::rng::Rng;

/// Outcome of running a policy over a task's sample set.
#[derive(Debug, Clone)]
pub struct PolicyRun {
    pub policy: String,
    pub accuracy: f64,
    /// Mean dollars per sample (the paper's "average price per query").
    pub usd_per_query: f64,
    pub total_usd: f64,
    /// Fraction of samples answered at each tier position used.
    pub exit_fractions: Vec<f64>,
    /// Mean billed tokens per sample.
    pub tokens_per_query: f64,
}

fn finish(
    policy: String,
    n: usize,
    hits: usize,
    usd: f64,
    tokens: u64,
    exits: Vec<usize>,
) -> PolicyRun {
    PolicyRun {
        policy,
        accuracy: hits as f64 / n.max(1) as f64,
        usd_per_query: usd / n.max(1) as f64,
        total_usd: usd,
        exit_fractions: exits.iter().map(|&e| e as f64 / n.max(1) as f64).collect(),
        tokens_per_query: tokens as f64 / n.max(1) as f64,
    }
}

/// ABC with the voting rule (Eq. 3) over tier ensembles.
/// `tiers` lists which Table 1 tiers participate (e.g. [1,2,3] or [1,2]).
/// `theta_v`: defer when vote fraction <= theta_v.
pub fn run_abc_voting(
    task: &LlmTask,
    samples: &[LlmSample],
    agents: &[LlmAgent],
    tiers: &[usize],
    theta_v: f64,
    rng: &mut Rng,
) -> PolicyRun {
    let mut usd = 0.0;
    let mut tokens_total = 0u64;
    let mut hits = 0;
    let mut exits = vec![0usize; tiers.len()];
    for s in samples {
        let mut answered = false;
        for (pos, &tier) in tiers.iter().enumerate() {
            let members = tier_agents(agents, tier);
            let mut answers = Vec::with_capacity(members.len());
            for a in &members {
                let (ans, tok) = a.answer(s, 0.0, task, rng);
                usd += a.cost(tok);
                tokens_total += tok;
                answers.push(ans);
            }
            let (majority, frac) = agree_votes(&answers);
            let last = pos + 1 == tiers.len();
            if last || frac as f64 > theta_v {
                if majority == s.truth {
                    hits += 1;
                }
                exits[pos] += 1;
                answered = true;
                break;
            }
        }
        debug_assert!(answered);
    }
    finish(
        format!("ABC(vote>{theta_v:.2})"),
        samples.len(),
        hits,
        usd,
        tokens_total,
        exits,
    )
}

/// Always call one fixed model.
pub fn run_single_model(
    task: &LlmTask,
    samples: &[LlmSample],
    agent: &LlmAgent,
    rng: &mut Rng,
) -> PolicyRun {
    let mut usd = 0.0;
    let mut tokens_total = 0u64;
    let mut hits = 0;
    for s in samples {
        let (ans, tok) = agent.answer(s, 0.0, task, rng);
        usd += agent.cost(tok);
        tokens_total += tok;
        if ans == s.truth {
            hits += 1;
        }
    }
    finish(
        format!("Single({})", agent.model.name),
        samples.len(),
        hits,
        usd,
        tokens_total,
        vec![samples.len()],
    )
}

/// FrugalGPT-style scorer: a learned g(query, answer) in [0, 1].
/// Simulated as a correctness signal observed through noise that grows
/// with difficulty -- the scorer was trained on ~500 samples and
/// generalises worse on hard inputs.
fn frugal_scorer(correct: bool, difficulty: f64, rng: &mut Rng) -> f64 {
    // The paper's observation (§5.2.3): "the trained scorer struggles as
    // the tasks get harder; hence, it is more likely to take the safer
    // route to cascade as test sample difficulty increases."  The
    // correct-answer signal decays with difficulty (pushing scores below
    // the threshold => more deferrals => more cost), and the noise grows.
    let signal = if correct { 0.74 - 0.36 * difficulty } else { 0.42 + 0.08 * difficulty };
    let noise = 0.16 + 0.22 * difficulty;
    (signal + noise * rng.normal()).clamp(0.0, 1.0)
}

/// FrugalGPT: best single model per tier + scorer thresholds.
pub fn run_frugal_gpt(
    task: &LlmTask,
    samples: &[LlmSample],
    agents: &[LlmAgent],
    tiers: &[usize],
    threshold: f64,
    rng: &mut Rng,
) -> PolicyRun {
    let mut usd = 0.0;
    let mut tokens_total = 0u64;
    let mut hits = 0;
    let mut exits = vec![0usize; tiers.len()];
    for s in samples {
        for (pos, &tier) in tiers.iter().enumerate() {
            let agent = best_of_tier(agents, tier);
            let (ans, tok) = agent.answer(s, 0.0, task, rng);
            usd += agent.cost(tok);
            tokens_total += tok;
            let last = pos + 1 == tiers.len();
            let score = frugal_scorer(ans == s.truth, s.difficulty, rng);
            if last || score >= threshold {
                if ans == s.truth {
                    hits += 1;
                }
                exits[pos] += 1;
                break;
            }
        }
    }
    finish(
        format!("FrugalGPT(t={threshold:.2})"),
        samples.len(),
        hits,
        usd,
        tokens_total,
        exits,
    )
}

/// AutoMix self-verification: k samples of a noisy verifier at temp 1.0.
/// The verifier is the SAME model re-prompted, so each check is billed.
fn automix_verify(
    agent: &LlmAgent,
    _s: &LlmSample,
    correct: bool,
    task: &LlmTask,
    k: usize,
    usd: &mut f64,
    tokens_total: &mut u64,
    rng: &mut Rng,
) -> f64 {
    // each verification re-sends the question + candidate answer with a
    // few-shot verification prompt (~60% of the task prompt)
    let mut yes = 0usize;
    for _ in 0..k {
        let tok = (task.tokens_mean * 0.6 + task.tokens_std * 0.3 * rng.normal())
            .max(15.0) as u64;
        *usd += agent.cost(tok);
        *tokens_total += tok;
        // self-verification is weakly informative (same model judging itself)
        let p_yes = if correct { 0.80 } else { 0.42 };
        if rng.bool(p_yes) {
            yes += 1;
        }
    }
    yes as f64 / k as f64
}

/// AutoMix variants.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AutoMixKind {
    /// Threshold meta-verifier.
    Threshold,
    /// POMDP-lite: belief update with an asymmetric continue/exit value.
    Pomdp,
}

pub fn run_automix(
    task: &LlmTask,
    samples: &[LlmSample],
    agents: &[LlmAgent],
    tiers: &[usize],
    kind: AutoMixKind,
    rng: &mut Rng,
) -> PolicyRun {
    const K_VERIFY: usize = 8; // authors' codebase setting (App. D.2)
    let mut usd = 0.0;
    let mut tokens_total = 0u64;
    let mut hits = 0;
    let mut exits = vec![0usize; tiers.len()];
    for s in samples {
        for (pos, &tier) in tiers.iter().enumerate() {
            let agent = best_of_tier(agents, tier);
            let (ans, tok) = agent.answer(s, 0.0, task, rng);
            usd += agent.cost(tok);
            tokens_total += tok;
            let last = pos + 1 == tiers.len();
            if last {
                if ans == s.truth {
                    hits += 1;
                }
                exits[pos] += 1;
                break;
            }
            let v = automix_verify(
                agent,
                s,
                ans == s.truth,
                task,
                K_VERIFY,
                &mut usd,
                &mut tokens_total,
                rng,
            );
            let accept = match kind {
                AutoMixKind::Threshold => v >= 0.75, // 6/8 verifications
                AutoMixKind::Pomdp => {
                    // belief that the answer is correct, from a Beta-ish
                    // posterior with the verifier's known confusion rates
                    let p_v_given_c = 0.80f64;
                    let p_v_given_w = 0.42f64;
                    let prior = 0.7;
                    let ll_c = p_v_given_c.powf(v * K_VERIFY as f64)
                        * (1.0 - p_v_given_c).powf((1.0 - v) * K_VERIFY as f64);
                    let ll_w = p_v_given_w.powf(v * K_VERIFY as f64)
                        * (1.0 - p_v_given_w).powf((1.0 - v) * K_VERIFY as f64);
                    let belief = prior * ll_c / (prior * ll_c + (1.0 - prior) * ll_w);
                    // exit when expected gain of escalating is negative
                    belief >= 0.85
                }
            };
            if accept {
                if ans == s.truth {
                    hits += 1;
                }
                exits[pos] += 1;
                break;
            }
        }
    }
    let name = match kind {
        AutoMixKind::Threshold => "AutoMix+T",
        AutoMixKind::Pomdp => "AutoMix+P",
    };
    finish(name.to_string(), samples.len(), hits, usd, tokens_total, exits)
}

/// MoT LLM cascade: sample the tier's best model `k_samples` times at
/// temp 1.0; accept the modal answer when consistency is high enough.
pub fn run_mot(
    task: &LlmTask,
    samples: &[LlmSample],
    agents: &[LlmAgent],
    tiers: &[usize],
    k_samples: usize,
    consistency: f64,
    rng: &mut Rng,
) -> PolicyRun {
    let mut usd = 0.0;
    let mut tokens_total = 0u64;
    let mut hits = 0;
    let mut exits = vec![0usize; tiers.len()];
    for s in samples {
        for (pos, &tier) in tiers.iter().enumerate() {
            let agent = best_of_tier(agents, tier);
            let last = pos + 1 == tiers.len();
            if last {
                // final tier answers once at temp 0
                let (ans, tok) = agent.answer(s, 0.0, task, rng);
                usd += agent.cost(tok);
                tokens_total += tok;
                if ans == s.truth {
                    hits += 1;
                }
                exits[pos] += 1;
                break;
            }
            let mut answers = Vec::with_capacity(k_samples);
            for _ in 0..k_samples {
                let (ans, tok) = agent.answer(s, 1.0, task, rng);
                usd += agent.cost(tok);
                tokens_total += tok;
                answers.push(ans);
            }
            let (modal, frac) = agree_votes(&answers);
            if frac as f64 >= consistency {
                if modal == s.truth {
                    hits += 1;
                }
                exits[pos] += 1;
                break;
            }
        }
    }
    finish(
        format!("MoT(k={k_samples},c={consistency:.2})"),
        samples.len(),
        hits,
        usd,
        tokens_total,
        exits,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::api_llm::{build_agents, default_tasks, generate_samples};

    fn setup() -> (LlmTask, Vec<LlmSample>, Vec<LlmAgent>) {
        let task = default_tasks().remove(3); // headlines: small answer space
        let samples = generate_samples(&task);
        let agents = build_agents(&task);
        (task, samples, agents)
    }

    #[test]
    fn abc_beats_single_small_on_accuracy() {
        let (task, samples, agents) = setup();
        let mut rng = Rng::new(1);
        let abc = run_abc_voting(&task, &samples, &agents, &[1, 2, 3], 0.34, &mut rng);
        let small = run_single_model(&task, &samples, best_of_tier(&agents, 1), &mut rng);
        assert!(abc.accuracy > small.accuracy, "{} vs {}", abc.accuracy, small.accuracy);
    }

    #[test]
    fn abc_cheaper_than_single_big() {
        let (task, samples, agents) = setup();
        let mut rng = Rng::new(2);
        let abc = run_abc_voting(&task, &samples, &agents, &[1, 2, 3], 0.34, &mut rng);
        let big = run_single_model(&task, &samples, best_of_tier(&agents, 3), &mut rng);
        assert!(abc.usd_per_query < big.usd_per_query);
        // accuracy competitive: within 2 points (usually above)
        assert!(abc.accuracy >= big.accuracy - 0.02);
    }

    #[test]
    fn abc_exits_mostly_at_tier1_on_easy_task() {
        let (task, samples, agents) = setup();
        let mut rng = Rng::new(3);
        let abc = run_abc_voting(&task, &samples, &agents, &[1, 2, 3], 0.34, &mut rng);
        assert!(abc.exit_fractions[0] > 0.5, "{:?}", abc.exit_fractions);
        let sum: f64 = abc.exit_fractions.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
    }

    #[test]
    fn automix_pays_for_verification() {
        let (task, samples, agents) = setup();
        let mut rng = Rng::new(4);
        let am = run_automix(&task, &samples, &agents, &[1, 2, 3], AutoMixKind::Threshold, &mut rng);
        let frugal = run_frugal_gpt(&task, &samples, &agents, &[1, 2, 3], 0.6, &mut rng);
        // AutoMix's 8 self-verification calls must make it pricier than
        // FrugalGPT at similar routing (paper App. D.2 guarantee).
        assert!(am.usd_per_query > frugal.usd_per_query);
    }

    #[test]
    fn abc_cheaper_than_all_baselines() {
        let (task, samples, agents) = setup();
        let mut rng = Rng::new(5);
        let tiers = [1usize, 2, 3];
        let abc = run_abc_voting(&task, &samples, &agents, &tiers, 0.5, &mut rng);
        let frugal = run_frugal_gpt(&task, &samples, &agents, &tiers, 0.6, &mut rng);
        let am_t = run_automix(&task, &samples, &agents, &tiers, AutoMixKind::Threshold, &mut rng);
        let am_p = run_automix(&task, &samples, &agents, &tiers, AutoMixKind::Pomdp, &mut rng);
        let mot = run_mot(&task, &samples, &agents, &tiers, 5, 0.8, &mut rng);
        for b in [&frugal, &am_t, &am_p, &mot] {
            assert!(
                abc.usd_per_query < b.usd_per_query * 1.05,
                "ABC {} not cheaper than {} ({})",
                abc.usd_per_query,
                b.policy,
                b.usd_per_query
            );
            assert!(
                abc.accuracy >= b.accuracy - 0.03,
                "ABC acc {} too far below {} ({})",
                abc.accuracy,
                b.policy,
                b.accuracy
            );
        }
    }

    #[test]
    fn two_level_cascade_cheaper_than_three() {
        let (task, samples, agents) = setup();
        let mut rng = Rng::new(6);
        let three = run_abc_voting(&task, &samples, &agents, &[1, 2, 3], 0.34, &mut rng);
        let two = run_abc_voting(&task, &samples, &agents, &[1, 2], 0.34, &mut rng);
        assert!(two.usd_per_query <= three.usd_per_query);
    }

    #[test]
    fn mot_deterministic_given_seed() {
        let (task, samples, agents) = setup();
        let a = run_mot(&task, &samples, &agents, &[1, 2], 5, 0.8, &mut Rng::new(7));
        let b = run_mot(&task, &samples, &agents, &[1, 2], 5, 0.8, &mut Rng::new(7));
        assert_eq!(a.accuracy, b.accuracy);
        assert_eq!(a.total_usd, b.total_usd);
    }
}
