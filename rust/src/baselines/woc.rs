//! Wisdom-of-Committees (Wang et al. 2021) confidence-based cascade --
//! the paper's Fig. 2 comparison baseline.
//!
//! WoC cascades SINGLE models with a max-softmax confidence deferral:
//! answer locally when confidence > tau, else pass to the next larger
//! model.  Following the paper's protocol we tune tau over a small grid
//! ("the best four of its confidence thresholds") on validation data and
//! report the most performant configuration.

use std::sync::Arc;

use anyhow::Result;

use crate::data::format::Dataset;
use crate::runtime::executable::TierExecutable;
use crate::types::Label;

/// Per-sample outcome of a WoC run.
#[derive(Debug, Clone)]
pub struct WocResult {
    pub prediction: Label,
    pub exit_level: usize,
}

/// Aggregate outcome + the tau that produced it.
#[derive(Debug, Clone)]
pub struct WocReport {
    pub tau: f32,
    pub accuracy: f64,
    pub exit_fractions: Vec<f64>,
    /// Mean per-sample FLOPs given each level's single-member FLOPs.
    pub mean_flops: f64,
}

/// The tau grid the tuner searches (paper: "best four ... thresholds").
pub const TAU_GRID: [f32; 8] = [0.5, 0.6, 0.7, 0.8, 0.85, 0.9, 0.95, 0.99];

/// Run a WoC cascade at a fixed tau over a dataset.
pub fn run_woc(
    singles: &[Arc<TierExecutable>],
    data: &Dataset,
    tau: f32,
) -> Result<Vec<WocResult>> {
    assert!(!singles.is_empty());
    let dim = data.dim;
    let n = data.n;
    let mut results: Vec<Option<WocResult>> = vec![None; n];
    let mut active: Vec<usize> = (0..n).collect();
    for (level0, single) in singles.iter().enumerate() {
        if active.is_empty() {
            break;
        }
        let mut sub = Vec::with_capacity(active.len() * dim);
        for &i in &active {
            sub.extend_from_slice(data.row(i));
        }
        let outs = single.run_single(&sub, active.len())?;
        let last = level0 + 1 == singles.len();
        let mut still = Vec::new();
        for (j, &i) in active.iter().enumerate() {
            if last || outs[j].confidence > tau {
                results[i] = Some(WocResult {
                    prediction: outs[j].pred,
                    exit_level: level0 + 1,
                });
            } else {
                still.push(i);
            }
        }
        active = still;
    }
    Ok(results.into_iter().map(|r| r.unwrap()).collect())
}

/// Aggregate a WoC run against labels.
pub fn report(
    results: &[WocResult],
    labels: &[Label],
    flops_per_level: &[f64],
    tau: f32,
) -> WocReport {
    let n = results.len();
    let n_levels = flops_per_level.len();
    let mut hits = 0;
    let mut exits = vec![0usize; n_levels];
    for (r, &y) in results.iter().zip(labels) {
        if r.prediction == y {
            hits += 1;
        }
        exits[r.exit_level - 1] += 1;
    }
    // cumulative cost: a sample exiting at level L paid levels 1..=L
    let mut mean_flops = 0.0;
    for (lvl, &cnt) in exits.iter().enumerate() {
        let paid: f64 = flops_per_level[..=lvl].iter().sum();
        mean_flops += cnt as f64 * paid;
    }
    mean_flops /= n.max(1) as f64;
    WocReport {
        tau,
        accuracy: hits as f64 / n.max(1) as f64,
        exit_fractions: exits.iter().map(|&e| e as f64 / n.max(1) as f64).collect(),
        mean_flops,
    }
}

/// Tune tau on `val`, then evaluate on `test`.  The "best" tau maximises
/// val accuracy, breaking ties toward lower cost (the paper evaluates the
/// most performant cascade configuration).
pub fn tune_and_run(
    singles: &[Arc<TierExecutable>],
    val: &Dataset,
    test: &Dataset,
    flops_per_level: &[f64],
) -> Result<WocReport> {
    let mut best: Option<WocReport> = None;
    for &tau in &TAU_GRID {
        let val_res = run_woc(singles, val, tau)?;
        let val_rep = report(&val_res, &val.y, flops_per_level, tau);
        let better = match &best {
            None => true,
            Some(b) => {
                val_rep.accuracy > b.accuracy + 1e-9
                    || ((val_rep.accuracy - b.accuracy).abs() < 1e-9
                        && val_rep.mean_flops < b.mean_flops)
            }
        };
        if better {
            best = Some(val_rep);
        }
    }
    let tau = best.unwrap().tau;
    let test_res = run_woc(singles, test, tau)?;
    Ok(report(&test_res, &test.y, flops_per_level, tau))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_cumulative_flops() {
        let results = vec![
            WocResult { prediction: 0, exit_level: 1 },
            WocResult { prediction: 1, exit_level: 2 },
        ];
        let labels = vec![0, 1];
        let rep = report(&results, &labels, &[10.0, 100.0], 0.5);
        assert_eq!(rep.accuracy, 1.0);
        // sample1 paid 10, sample2 paid 110 -> mean 60
        assert!((rep.mean_flops - 60.0).abs() < 1e-9);
        assert_eq!(rep.exit_fractions, vec![0.5, 0.5]);
    }

    #[test]
    fn tau_grid_is_sane() {
        assert!(TAU_GRID.windows(2).all(|w| w[0] < w[1]));
        assert!(TAU_GRID.iter().all(|&t| (0.0..1.0).contains(&t)));
    }
}
