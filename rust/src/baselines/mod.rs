//! Baseline methods the paper compares against: Wisdom-of-Committees
//! confidence cascades (Fig. 2) and the API-cascade policies
//! FrugalGPT / AutoMix / MoT (Fig. 5).

pub mod api_policies;
pub mod woc;
