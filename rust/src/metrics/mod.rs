//! Serving metrics: named counters, point-in-time gauges, log-bucketed
//! histograms, and the controller event log (`events`).

pub mod events;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

pub use events::{Event, EventKind, EventLog, EventRecord};

/// Shards per [`Counter`]: enough to spread the replica/pipeline
/// threads of one deployment, small enough that the snapshot fold is
/// trivial.
const COUNTER_SHARDS: usize = 8;

/// One cache line per shard, so two threads bumping different shards
/// of the same counter never false-share.
#[repr(align(64))]
#[derive(Debug, Default)]
struct CounterShard(AtomicU64);

/// Each thread sticks to one shard index for its lifetime; indices are
/// dealt round-robin so concurrent hot threads land on distinct shards.
static NEXT_SHARD: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static MY_SHARD: usize =
        NEXT_SHARD.fetch_add(1, Ordering::Relaxed) % COUNTER_SHARDS;
}

/// A monotonically increasing counter, striped across per-thread
/// shards: `inc`/`add` touch only the calling thread's shard (one
/// uncontended atomic), `get` folds all shards for an exact total.
#[derive(Debug, Default)]
pub struct Counter {
    shards: [CounterShard; COUNTER_SHARDS],
}

impl Counter {
    #[inline]
    fn my_shard(&self) -> &AtomicU64 {
        &self.shards[MY_SHARD.with(|s| *s)].0
    }

    pub fn inc(&self) {
        self.my_shard().fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, v: u64) {
        self.my_shard().fetch_add(v, Ordering::Relaxed);
    }

    /// Exact total across shards.  Each shard is monotone, so a
    /// concurrent `get` is a valid point-in-time lower bound.
    pub fn get(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.0.load(Ordering::Relaxed))
            .sum()
    }
}

/// A point-in-time value (current gear, arrival-rate EWMA, queue depth):
/// last write wins, unlike the monotone [`Counter`].  Stored as f64 bits
/// in an `AtomicU64` so set/get are lock-free.
#[derive(Debug)]
pub struct Gauge(AtomicU64);

impl Default for Gauge {
    fn default() -> Self {
        Gauge(AtomicU64::new(0.0f64.to_bits()))
    }
}

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Log-bucketed histogram for positive values (latencies, batch sizes).
///
/// Buckets are `base * growth^i` boundaries covering [1e-7, ~1e4] seconds
/// with ~5% resolution -- good enough for p50/p99 on the serving path
/// without retaining samples.
#[derive(Debug)]
pub struct Histogram {
    counts: Vec<AtomicU64>,
    total: AtomicU64,
    sum_micros: AtomicU64,
}

const HIST_BUCKETS: usize = 512;
const HIST_MIN: f64 = 1e-7;
const HIST_GROWTH: f64 = 1.052;

fn bucket_of(v: f64) -> usize {
    if v <= HIST_MIN {
        return 0;
    }
    let idx = (v / HIST_MIN).ln() / HIST_GROWTH.ln();
    (idx as usize).min(HIST_BUCKETS - 1)
}

fn bucket_value(i: usize) -> f64 {
    HIST_MIN * HIST_GROWTH.powi(i as i32)
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            counts: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            total: AtomicU64::new(0),
            sum_micros: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn record(&self, v: f64) {
        let v = v.max(0.0);
        self.counts[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        self.sum_micros
            .fetch_add((v * 1e6) as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return f64::NAN;
        }
        self.sum() / n as f64
    }

    /// Sum of all recorded values (0 when empty, unlike the NaN mean).
    pub fn sum(&self) -> f64 {
        self.sum_micros.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Approximate quantile (within one bucket's ~5% resolution).
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return f64::NAN;
        }
        let target = (q.clamp(0.0, 1.0) * n as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c.load(Ordering::Relaxed);
            if seen >= target {
                return bucket_value(i);
            }
        }
        bucket_value(HIST_BUCKETS - 1)
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.5)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    pub fn p999(&self) -> f64 {
        self.quantile(0.999)
    }

    /// Cumulative per-bucket counts, for windowed quantiles: take one
    /// snapshot per interval and feed consecutive pairs to
    /// [`Histogram::quantile_between`].  (The histogram itself is
    /// all-time; counts are monotone.)
    pub fn bucket_snapshot(&self) -> Vec<u64> {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    /// Approximate quantile of ONLY the samples recorded between two
    /// [`Histogram::bucket_snapshot`]s (`prev` taken before `cur`).
    /// Returns NaN when the interval holds no samples.  This is what a
    /// feedback controller must use: the all-time quantile latches past
    /// overloads forever, a windowed one recovers with the workload.
    pub fn quantile_between(prev: &[u64], cur: &[u64], q: f64) -> f64 {
        assert_eq!(prev.len(), cur.len(), "snapshot size mismatch");
        let n: u64 = cur
            .iter()
            .zip(prev)
            .map(|(c, p)| c.saturating_sub(*p))
            .sum();
        if n == 0 {
            return f64::NAN;
        }
        let target = (q.clamp(0.0, 1.0) * n as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, (c, p)) in cur.iter().zip(prev).enumerate() {
            seen += c.saturating_sub(*p);
            if seen >= target {
                return bucket_value(i);
            }
        }
        bucket_value(HIST_BUCKETS - 1)
    }
}

/// A registry of named counters, gauges and histograms, plus the
/// shared controller [`EventLog`].
#[derive(Debug, Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
    events: EventLog,
}

impl Metrics {
    pub fn new() -> Arc<Metrics> {
        Arc::new(Metrics::default())
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut g = self.counters.lock().unwrap();
        Arc::clone(g.entry(name.to_string()).or_default())
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut g = self.gauges.lock().unwrap();
        Arc::clone(g.entry(name.to_string()).or_default())
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut g = self.histograms.lock().unwrap();
        Arc::clone(g.entry(name.to_string()).or_default())
    }

    /// Publish an EXISTING histogram under `name` (replacing any prior
    /// binding).  This is how a fleet aliases its tier pools' private
    /// `queue_wait_s`/`service_s` histograms into its own registry as
    /// `tier_{i}_queue_wait_s`/`tier_{i}_service_s`: the pipelines keep
    /// recording through their pre-resolved handles, the fleet registry
    /// snapshots the very same atomics -- zero hot-path cost.
    pub fn register_histogram(&self, name: &str, h: Arc<Histogram>) {
        self.histograms
            .lock()
            .unwrap()
            .insert(name.to_string(), h);
    }

    /// The registry's controller event log (gear shifts + scale
    /// actions).  Writers: the control loop; readers: the wire
    /// `{"cmd":"events"}` command and `repro stats --events`.
    pub fn events(&self) -> &EventLog {
        &self.events
    }

    /// Snapshot all metrics as display lines (name, value description).
    pub fn snapshot(&self) -> Vec<(String, String)> {
        let mut out = Vec::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push((name.clone(), format!("{}", c.get())));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            out.push((name.clone(), format!("{}", g.get())));
        }
        for (name, h) in self.histograms.lock().unwrap().iter() {
            if h.count() > 0 {
                out.push((
                    name.clone(),
                    format!(
                        "n={} mean={:.6} p50={:.6} p99={:.6}",
                        h.count(),
                        h.mean(),
                        h.p50(),
                        h.p99()
                    ),
                ));
            }
        }
        out
    }

    /// Structured snapshot for the wire `stats` command: counters and
    /// gauges as numbers, histograms as `{n, mean, p50, p99, p999}`
    /// objects (machine-readable, unlike the display-string
    /// [`Metrics::snapshot`]).
    pub fn snapshot_json(&self) -> crate::util::json::Json {
        use crate::util::json::{Json, JsonObj};
        let mut counters = JsonObj::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            counters.insert(name.clone(), Json::num(c.get() as f64));
        }
        let mut gauges = JsonObj::new();
        for (name, g) in self.gauges.lock().unwrap().iter() {
            gauges.insert(name.clone(), Json::num(g.get()));
        }
        let mut histograms = JsonObj::new();
        for (name, h) in self.histograms.lock().unwrap().iter() {
            if h.count() == 0 {
                continue;
            }
            let mut o = JsonObj::new();
            o.insert("n", Json::num(h.count() as f64));
            o.insert("mean", Json::num(h.mean()));
            o.insert("p50", Json::num(h.p50()));
            o.insert("p99", Json::num(h.p99()));
            o.insert("p999", Json::num(h.p999()));
            histograms.insert(name.clone(), Json::Obj(o));
        }
        let mut root = JsonObj::new();
        root.insert("counters", Json::Obj(counters));
        root.insert("gauges", Json::Obj(gauges));
        root.insert("histograms", Json::Obj(histograms));
        Json::Obj(root)
    }

    /// Prometheus text exposition (version 0.0.4) of the whole
    /// registry, for the wire `{"cmd":"prom"}` command: counters as
    /// `counter`, gauges as `gauge`, histograms as `summary` with
    /// p50/p99/p999 quantile series plus `_sum`/`_count`.  Registry
    /// names are already `snake_case` identifiers, i.e. valid metric
    /// names; no escaping needed.
    pub fn render_prom(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {}", c.get());
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            let _ = writeln!(out, "# TYPE {name} gauge");
            let _ = writeln!(out, "{name} {}", g.get());
        }
        for (name, h) in self.histograms.lock().unwrap().iter() {
            let n = h.count();
            let _ = writeln!(out, "# TYPE {name} summary");
            if n > 0 {
                for (q, v) in [
                    ("0.5", h.p50()),
                    ("0.99", h.p99()),
                    ("0.999", h.p999()),
                ] {
                    let _ =
                        writeln!(out, "{name}{{quantile=\"{q}\"}} {v}");
                }
            }
            let _ = writeln!(out, "{name}_sum {}", h.sum());
            let _ = writeln!(out, "{name}_count {n}");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_basics() {
        let m = Metrics::new();
        m.counter("a").inc();
        m.counter("a").add(4);
        assert_eq!(m.counter("a").get(), 5);
        assert_eq!(m.counter("b").get(), 0);
    }

    #[test]
    fn gauge_set_get_last_wins() {
        let m = Metrics::new();
        let g = m.gauge("ewma");
        assert_eq!(g.get(), 0.0);
        g.set(123.5);
        assert_eq!(m.gauge("ewma").get(), 123.5);
        g.set(-2.0);
        assert_eq!(g.get(), -2.0);
        // same name resolves to the same gauge
        m.gauge("ewma").set(7.0);
        assert_eq!(g.get(), 7.0);
    }

    #[test]
    fn gauge_appears_in_snapshot() {
        let m = Metrics::new();
        m.gauge("gear_current").set(2.0);
        let snap = m.snapshot();
        let entry = snap.iter().find(|(n, _)| n == "gear_current");
        assert_eq!(entry.map(|(_, v)| v.as_str()), Some("2"));
    }

    #[test]
    fn gauge_concurrent_set_is_one_of_written() {
        let m = Metrics::new();
        let g = m.gauge("x");
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let g = Arc::clone(&g);
                std::thread::spawn(move || {
                    for _ in 0..500 {
                        g.set(t as f64);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let v = g.get();
        assert!((0.0..4.0).contains(&v), "torn gauge read: {v}");
    }

    #[test]
    fn histogram_quantiles_within_resolution() {
        let h = Histogram::default();
        for i in 1..=1000 {
            h.record(i as f64 / 1000.0); // 1ms .. 1s uniform
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.p50();
        assert!((0.45..0.56).contains(&p50), "p50 {p50}");
        let p99 = h.p99();
        assert!((0.93..1.06).contains(&p99), "p99 {p99}");
        let mean = h.mean();
        assert!((0.48..0.53).contains(&mean), "mean {mean}");
    }

    #[test]
    fn histogram_empty_is_nan() {
        let h = Histogram::default();
        assert!(h.p50().is_nan());
        assert!(h.mean().is_nan());
    }

    #[test]
    fn histogram_extremes_clamped() {
        let h = Histogram::default();
        h.record(0.0);
        h.record(1e9);
        assert_eq!(h.count(), 2);
        assert!(h.quantile(0.0) <= 2e-7);
        assert!(h.quantile(1.0) > 1e3);
    }

    #[test]
    fn snapshot_lists_everything() {
        let m = Metrics::new();
        m.counter("reqs").inc();
        m.histogram("lat").record(0.01);
        let snap = m.snapshot();
        let names: Vec<&str> = snap.iter().map(|(n, _)| n.as_str()).collect();
        assert!(names.contains(&"reqs"));
        assert!(names.contains(&"lat"));
    }

    #[test]
    fn windowed_quantile_sees_only_the_interval() {
        let h = Histogram::default();
        // interval 1: slow samples
        for _ in 0..100 {
            h.record(1.0);
        }
        let s1 = h.bucket_snapshot();
        // interval 2: fast samples only
        for _ in 0..100 {
            h.record(0.001);
        }
        let s2 = h.bucket_snapshot();
        // the all-time p99 still reads ~1s, the windowed one ~1ms
        assert!(h.p99() > 0.9, "all-time p99 {}", h.p99());
        let windowed = Histogram::quantile_between(&s1, &s2, 0.99);
        assert!(
            (0.0009..0.0012).contains(&windowed),
            "windowed p99 {windowed}"
        );
        // an empty interval reads NaN, never a stale value
        let s3 = h.bucket_snapshot();
        assert!(Histogram::quantile_between(&s2, &s3, 0.99).is_nan());
    }

    #[test]
    fn windowed_quantile_empty_window_is_nan_at_every_q() {
        let h = Histogram::default();
        // a completely empty histogram: identical empty snapshots
        let s0 = h.bucket_snapshot();
        let s1 = h.bucket_snapshot();
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert!(Histogram::quantile_between(&s0, &s1, q).is_nan(), "q={q}");
        }
        // a busy histogram whose WINDOW is empty must also read NaN --
        // the all-time count must never leak into the interval
        for _ in 0..50 {
            h.record(0.25);
        }
        let s2 = h.bucket_snapshot();
        let s3 = h.bucket_snapshot();
        assert!(Histogram::quantile_between(&s2, &s3, 0.99).is_nan());
        assert!(h.p99() > 0.0, "all-time quantile still works");
    }

    #[test]
    fn windowed_quantile_single_bucket_window() {
        let h = Histogram::default();
        let s0 = h.bucket_snapshot();
        // every interval sample lands in one bucket: every quantile of
        // the window reads that bucket's value, q extremes included
        for _ in 0..7 {
            h.record(0.010);
        }
        let s1 = h.bucket_snapshot();
        let lo = Histogram::quantile_between(&s0, &s1, 0.0);
        let hi = Histogram::quantile_between(&s0, &s1, 1.0);
        let p99 = Histogram::quantile_between(&s0, &s1, 0.99);
        assert_eq!(lo, hi, "one-bucket window has one value");
        assert_eq!(p99, hi);
        assert!((0.009..0.0115).contains(&p99), "p99 {p99} off the bucket");
        // a single sample is the degenerate single-bucket window
        let s2 = h.bucket_snapshot();
        h.record(2.0);
        let s3 = h.bucket_snapshot();
        let one = Histogram::quantile_between(&s2, &s3, 0.99);
        assert!((1.8..2.3).contains(&one), "single-sample window {one}");
    }

    #[test]
    fn windowed_quantile_recovers_after_a_past_overload() {
        // the SLO-latch scenario the controller depends on: a brutal
        // overload, then recovery -- later windows must NOT keep
        // breaching the SLO the way the all-time quantile does
        let h = Histogram::default();
        for _ in 0..10_000 {
            h.record(5.0); // the overload
        }
        let mut prev = h.bucket_snapshot();
        let slo_s = 0.050;
        for _ in 0..3 {
            for _ in 0..100 {
                h.record(0.002); // healthy traffic
            }
            let cur = h.bucket_snapshot();
            let windowed = Histogram::quantile_between(&prev, &cur, 0.99);
            assert!(
                windowed < slo_s,
                "recovered window still breaches the SLO: {windowed}"
            );
            prev = cur;
        }
        // the all-time p99 stays latched at the overload -- which is
        // exactly why the sampler must not use it
        assert!(h.p99() > 1.0, "all-time p99 {}", h.p99());
    }

    #[test]
    fn snapshot_json_is_structured() {
        let m = Metrics::new();
        m.counter("reqs").add(3);
        m.gauge("gear_current").set(1.0);
        m.histogram("lat").record(0.01);
        m.histogram("empty"); // zero-count histograms are elided
        let j = m.snapshot_json();
        assert_eq!(j.get("counters").get("reqs").as_u64(), Some(3));
        assert_eq!(j.get("gauges").get("gear_current").as_f64(), Some(1.0));
        let lat = j.get("histograms").get("lat");
        assert_eq!(lat.get("n").as_u64(), Some(1));
        assert!(lat.get("p50").as_f64().unwrap() > 0.0);
        assert!(j.get("histograms").get("empty").as_obj().is_none());
        // round-trips through text
        let text = j.to_string();
        let back = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(back.get("counters").get("reqs").as_u64(), Some(3));
    }

    #[test]
    fn concurrent_updates() {
        let m = Metrics::new();
        let c = m.counter("x");
        let hs = m.histogram("h");
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let c = Arc::clone(&c);
                let h = Arc::clone(&hs);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                        h.record(0.001);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(c.get(), 8000);
        assert_eq!(hs.count(), 8000);
    }

    #[test]
    fn counter_stripe_fold_is_exact_across_many_threads() {
        // more threads than shards: wrap-around sharing must still fold
        // to the exact total, and mixed inc/add must both stripe
        let c = Arc::new(Counter::default());
        let threads: Vec<_> = (0..3 * COUNTER_SHARDS)
            .map(|t| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    for i in 0..1000u64 {
                        if (t + i as usize) % 2 == 0 {
                            c.inc();
                        } else {
                            c.add(1);
                        }
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(c.get(), 3 * COUNTER_SHARDS as u64 * 1000);
        // and the count actually spread: a single shard can't hold it
        // all when distinct threads were dealt distinct shard indices
        let max_shard = c
            .shards
            .iter()
            .map(|s| s.0.load(Ordering::Relaxed))
            .max()
            .unwrap();
        assert!(max_shard < c.get(), "all increments on one shard");
    }

    #[test]
    fn histogram_snapshot_consistent_under_load() {
        // readers folding quantiles/counts mid-write must only ever see
        // monotone, bounded values -- never a torn or over-total count
        let h = Arc::new(Histogram::default());
        let writers: Vec<_> = (0..4)
            .map(|_| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 1..=2000 {
                        h.record(i as f64 * 1e-5);
                    }
                })
            })
            .collect();
        let reader = {
            let h = Arc::clone(&h);
            std::thread::spawn(move || {
                let mut last_n = 0u64;
                for _ in 0..200 {
                    let n = h.count();
                    assert!(n >= last_n, "count went backwards");
                    assert!(n <= 8000, "count overshot: {n}");
                    last_n = n;
                    if n > 0 {
                        let p99 = h.p99();
                        assert!(
                            p99 > 0.0 && p99 < 0.03,
                            "p99 {p99} outside recorded range"
                        );
                    }
                    std::thread::yield_now();
                }
            })
        };
        for w in writers {
            w.join().unwrap();
        }
        reader.join().unwrap();
        assert_eq!(h.count(), 8000);
    }

    #[test]
    fn registered_histogram_is_an_alias_not_a_copy() {
        let pool = Metrics::new();
        let fleet = Metrics::new();
        let h = pool.histogram("queue_wait_s");
        fleet.register_histogram("tier_1_queue_wait_s", Arc::clone(&h));
        h.record(0.004); // pool-side write ...
        let j = fleet.snapshot_json();
        let seen = j.get("histograms").get("tier_1_queue_wait_s");
        assert_eq!(seen.get("n").as_u64(), Some(1)); // ... fleet-side read
        // re-registering replaces the binding
        fleet.register_histogram("tier_1_queue_wait_s", Arc::new(Histogram::default()));
        let j2 = fleet.snapshot_json();
        assert!(j2.get("histograms").get("tier_1_queue_wait_s").as_obj().is_none());
    }

    #[test]
    fn render_prom_shape() {
        let m = Metrics::new();
        m.counter("requests_total").add(5);
        m.gauge("gear_current").set(2.0);
        m.histogram("request_latency_s").record(0.01);
        m.histogram("request_latency_s").record(0.02);
        m.histogram("empty_hist"); // declared but empty
        let text = m.render_prom();
        assert!(text.contains("# TYPE requests_total counter\nrequests_total 5\n"));
        assert!(text.contains("# TYPE gear_current gauge\ngear_current 2\n"));
        assert!(text.contains("# TYPE request_latency_s summary\n"));
        assert!(text.contains("request_latency_s{quantile=\"0.5\"} "));
        assert!(text.contains("request_latency_s{quantile=\"0.99\"} "));
        assert!(text.contains("request_latency_s{quantile=\"0.999\"} "));
        assert!(text.contains("request_latency_s_count 2\n"));
        // _sum is ~0.03 within micro rounding
        let sum_line = text
            .lines()
            .find(|l| l.starts_with("request_latency_s_sum "))
            .expect("missing _sum");
        let sum: f64 = sum_line.split(' ').nth(1).unwrap().parse().unwrap();
        assert!((0.029..0.031).contains(&sum), "sum {sum}");
        // empty histograms expose zero count and NO quantile series
        assert!(text.contains("empty_hist_count 0\n"));
        assert!(!text.contains("empty_hist{quantile"));
        // every line is a comment or `name[{labels}] value`
        for line in text.lines() {
            assert!(
                line.starts_with("# TYPE ")
                    || line.split(' ').count() == 2,
                "malformed exposition line: {line:?}"
            );
        }
    }
}
